//! The paper's cholesky study (§VI): Fig. 9 resource-distribution sweep
//! (which kernels deserve the fabric), Fig. 8 dependency-graph export, and
//! the day-and-a-half-to-ten-minutes productivity claim.
//!
//! Run: `cargo run --release --example cholesky_codesign [-- --n 512]`

use zynq_estimator::cli::Args;
use zynq_estimator::config::BoardConfig;
use zynq_estimator::experiments;
use zynq_estimator::util::fmt_secs;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let n = args.u64_or("n", 512)?;
    let board = BoardConfig::zynq706();

    // Fig. 9 — FR-* variants vs two-accelerator combinations.
    let table = experiments::fig9(n, &board, experiments::BOARD_REPS)?;
    println!(
        "{}",
        table.render(&format!(
            "Fig. 9: cholesky {n}x{n} (64x64 dp blocks) — estimator vs board emulator"
        ))
    );

    // Fig. 8 — the NB=4 task dependency graph.
    std::fs::create_dir_all("out")?;
    let dot = experiments::fig8(4, &board);
    std::fs::write("out/fig8_cholesky_nb4.dot", &dot)?;
    println!("Fig. 8: wrote out/fig8_cholesky_nb4.dot (render with `dot -Tpng`)\n");

    // §VI productivity: 1.5 days of bitstreams vs minutes of estimation.
    let (meth, trad) = experiments::analysis_time_cholesky(n, &board)?;
    println!("Productivity (§VI):");
    println!("  methodology (measured wall-clock): {}", fmt_secs(meth));
    println!("  traditional hw generation (model): {}", fmt_secs(trad));
    println!("  => {:.0}x", trad / meth);
    Ok(())
}
