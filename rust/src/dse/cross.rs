//! Cross-board design-space exploration — the platform as a swept axis.
//!
//! The paper's cross-board observation (§I outlook; also Nunez-Yanez et
//! al. and Véstias et al. in the related work) is that the best
//! hardware/software split *shifts with the platform*: part selection is a
//! first-class design decision, so the board belongs inside the sweep, not
//! outside it. A [`CrossBoardSweep`] expands a board axis
//! ([`crate::board::BoardSpace`]) times an application list into one
//! per-(board, application) [`SweepContext`] each — its own HLS report
//! cache (the cost model depends on the board's fabric clock), its own
//! resource budget, its own bound frontier — and sweeps them all through
//! **one** shared worker pool, exactly like [`SweepSuite`] does for a
//! multi-application suite on a single board.
//!
//! Three sweep modes:
//! * [`CrossBoardSweep::explore`] — exhaustive, per-entry output
//!   bit-identical to [`SweepContext::explore`] on that entry alone;
//! * [`CrossBoardSweep::explore_pruned`] — bound-guided with **per-board
//!   frontiers only**: every entry keeps the full `dse::prune`
//!   losslessness contract (best point and time-energy Pareto front equal
//!   the exhaustive sweep's, per board);
//! * [`CrossBoardSweep::explore_pruned_global`] — additionally shares a
//!   **cross-board incumbent** between the boards of each application: a
//!   candidate whose bounds are strictly dominated by a point already
//!   evaluated on *any* board of the same application is skipped. The
//!   per-application *global* best and global Pareto front stay exact;
//!   per-board fronts may lose dominated points — use this mode when only
//!   the "which board wins" answer matters.
//!
//! The [`board_winner_table`] digests the result into the decision the
//! programmer actually needs: at every time budget, which board (and
//! which co-design on it) reaches that budget with the least energy.

use super::prune::PruneStats;
use super::sweep::{SweepContext, SweepSuite};
use super::{pareto_front, DsePoint, DseSpace, Objective};
use crate::config::BoardConfig;
use crate::coordinator::task::TaskProgram;
use crate::hls::FpgaPart;

/// Ranked sweep output of one (board, application) entry.
#[derive(Clone, Debug)]
pub struct CrossBoardResult {
    /// Board (platform) name of the entry.
    pub board: String,
    /// Application name of the entry.
    pub app: String,
    /// Evaluated points, ranked by the sweep objective.
    pub points: Vec<DsePoint>,
    /// Cut statistics (counters zero for exhaustive sweeps).
    pub stats: PruneStats,
}

/// A multi-board, multi-application sweep over one shared worker pool.
///
/// Internally a [`SweepSuite`] whose entries are the (board × application)
/// product, plus the bookkeeping that groups entries of the same
/// application for the cross-board incumbent and the winner table.
#[derive(Default)]
pub struct CrossBoardSweep<'p> {
    suite: SweepSuite<'p>,
    /// Parallel to the suite entries: (board name, app name, app group).
    keys: Vec<(String, String, usize)>,
}

impl<'p> CrossBoardSweep<'p> {
    /// An empty sweep; add entries with [`CrossBoardSweep::push`].
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one (board, application) entry. The program must have been
    /// built against `board` (task cycle counts are board-dependent), and
    /// `part` is the board's programmable-logic budget. Entries naming the
    /// same application (on different boards) form one incumbent group for
    /// [`CrossBoardSweep::explore_pruned_global`] and one table in
    /// [`board_winner_table`].
    pub fn push(
        &mut self,
        board_name: &str,
        app_name: &str,
        program: &'p TaskProgram,
        board: &'p BoardConfig,
        part: &FpgaPart,
        space: DseSpace,
    ) {
        let group = match self.keys.iter().find(|(_, a, _)| a == app_name) {
            Some(&(_, _, g)) => g,
            None => self.keys.iter().map(|&(_, _, g)| g + 1).max().unwrap_or(0),
        };
        self.keys
            .push((board_name.to_string(), app_name.to_string(), group));
        self.suite.push(
            &format!("{app_name}@{board_name}"),
            program,
            board,
            part,
            space,
        );
    }

    /// Number of (board, application) entries.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// Whether no entry has been pushed yet.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    fn wrap(&self, results: Vec<super::sweep::SuiteAppResult>) -> Vec<CrossBoardResult> {
        results
            .into_iter()
            .zip(&self.keys)
            .map(|(r, (board, app, _))| CrossBoardResult {
                board: board.clone(),
                app: app.clone(),
                points: r.points,
                stats: r.stats,
            })
            .collect()
    }

    /// Exhaustively sweep every entry through one shared pool. Per-entry
    /// output is bit-identical to [`SweepContext::explore`] on that entry
    /// alone, for any worker count.
    pub fn explore(&self, objective: Objective, workers: usize) -> Vec<CrossBoardResult> {
        self.wrap(self.suite.explore(objective, workers))
    }

    /// Bound-guided pruned sweep with per-board frontiers only: every
    /// entry keeps the full per-board losslessness contract (best point
    /// and time-energy Pareto front equal the exhaustive sweep's).
    pub fn explore_pruned(&self, objective: Objective, workers: usize) -> Vec<CrossBoardResult> {
        self.wrap(self.suite.explore_pruned(objective, workers))
    }

    /// Pruned sweep with the cross-board incumbent: boards of the same
    /// application share a frontier, so a candidate provably dominated by
    /// another board's evaluated point is never simulated
    /// ([`PruneStats::global_cut`] counts them). Exact for each
    /// application's *global* best point and global time-energy Pareto
    /// front; per-board fronts may lose points. Bit-identical for any
    /// worker count.
    pub fn explore_pruned_global(
        &self,
        objective: Objective,
        workers: usize,
    ) -> Vec<CrossBoardResult> {
        let inputs: Vec<(&SweepContext<'p>, &DseSpace)> =
            self.suite.apps().iter().map(|a| (&a.ctx, &a.space)).collect();
        let groups: Vec<Option<usize>> = self.keys.iter().map(|&(_, _, g)| Some(g)).collect();
        let results = super::prune::explore_pruned_grouped(&inputs, &groups, objective, workers);
        self.wrap(
            results
                .into_iter()
                .zip(self.suite.apps())
                .map(|((points, stats), app)| super::sweep::SuiteAppResult {
                    name: app.name.clone(),
                    points,
                    stats,
                })
                .collect(),
        )
    }
}

/// Build one program per (board, app) pair of the axis — board-major, the
/// push order [`sweep_from_programs`] expects. Thin wrapper over
/// [`crate::apps::build_app_program`] so the CLI, the experiment harness
/// and the bench share one expansion instead of three copies.
pub fn build_axis_programs(
    axis: &crate::board::BoardSpace,
    apps: &[&str],
    n: u64,
    bs: u64,
) -> anyhow::Result<Vec<(usize, String, TaskProgram)>> {
    let mut programs = Vec::new();
    for (bi, target) in axis.targets.iter().enumerate() {
        for app in apps {
            let program = crate::apps::build_app_program(app, n, bs, &target.board)?;
            programs.push((bi, app.to_string(), program));
        }
    }
    Ok(programs)
}

/// Assemble a [`CrossBoardSweep`] over the program list of
/// [`build_axis_programs`], using each program's default
/// [`DseSpace::from_program`] space.
pub fn sweep_from_programs<'p>(
    axis: &'p crate::board::BoardSpace,
    programs: &'p [(usize, String, TaskProgram)],
) -> CrossBoardSweep<'p> {
    let mut sweep = CrossBoardSweep::new();
    for (bi, app, program) in programs {
        let target = &axis.targets[*bi];
        sweep.push(
            &target.name,
            app,
            program,
            &target.board,
            &target.part,
            DseSpace::from_program(program),
        );
    }
    sweep
}

/// One row of the cross-board decision table: at `time_budget_ms`, `board`
/// running `codesign` reaches the budget with the least energy any
/// platform of the axis can offer.
#[derive(Clone, Debug)]
pub struct BudgetRow {
    /// The time budget this row unlocks (the point's makespan).
    pub time_budget_ms: f64,
    /// Winning board at this budget.
    pub board: String,
    /// Winning co-design on that board.
    pub codesign: String,
    /// Energy of the winning point (the minimum achievable within budget).
    pub energy_j: f64,
}

/// Digest per-(board, app) sweep results into one decision table per
/// application: the merged cross-board time-energy Pareto front, sorted by
/// ascending time (hence descending energy). Each row is the
/// energy-optimal choice at exactly that row's time budget; for an
/// arbitrary budget, the *last* row that still fits it wins — rows trade
/// time for energy as you read down. Applications appear in first-push
/// order; within a table, exact coordinate ties break by board then
/// co-design name, so the output is deterministic.
pub fn board_winner_table(results: &[CrossBoardResult]) -> Vec<(String, Vec<BudgetRow>)> {
    let mut apps: Vec<&str> = Vec::new();
    for r in results {
        if !apps.contains(&r.app.as_str()) {
            apps.push(&r.app);
        }
    }
    apps.iter()
        .map(|&app| {
            // Merge every board's points for this application.
            let mut merged: Vec<(usize, &DsePoint)> = Vec::new();
            let mut points: Vec<DsePoint> = Vec::new();
            for (ri, r) in results.iter().enumerate() {
                if r.app == app {
                    for p in &r.points {
                        merged.push((ri, p));
                        points.push(p.clone());
                    }
                }
            }
            let mut rows: Vec<BudgetRow> = pareto_front(&points)
                .into_iter()
                .map(|i| {
                    let (ri, p) = merged[i];
                    BudgetRow {
                        time_budget_ms: p.est_ms,
                        board: results[ri].board.clone(),
                        codesign: p.codesign.name.clone(),
                        energy_j: p.energy_j,
                    }
                })
                .collect();
            rows.sort_by(|a, b| {
                a.time_budget_ms
                    .total_cmp(&b.time_budget_ms)
                    .then(a.energy_j.total_cmp(&b.energy_j))
                    .then_with(|| a.board.cmp(&b.board))
                    .then_with(|| a.codesign.cmp(&b.codesign))
            });
            rows.dedup_by(|a, b| {
                a.time_budget_ms.to_bits() == b.time_budget_ms.to_bits()
                    && a.energy_j.to_bits() == b.energy_j.to_bits()
                    && a.board == b.board
                    && a.codesign == b.codesign
            });
            (app.to_string(), rows)
        })
        .collect()
}

/// Render one application's winner table for the CLI.
pub fn render_winner_table(app: &str, rows: &[BudgetRow]) -> String {
    let mut out = format!("== {app}: which board wins at which time budget\n");
    out.push_str(&format!(
        "{:>12} {:>18} {:36} {:>10}\n",
        "budget (ms)", "board", "co-design", "energy (J)"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:>12.2} {:>18} {:36} {:>10.3}\n",
            r.time_budget_ms, r.board, r.codesign, r.energy_j
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::Matmul;
    use crate::board::BoardSpace;
    use crate::dse::pareto_front_coords;

    fn sweep_fixture<'p>(
        programs: &'p [(String, TaskProgram)],
        space: &'p BoardSpace,
    ) -> CrossBoardSweep<'p> {
        let mut sweep = CrossBoardSweep::new();
        for (bi, target) in space.targets.iter().enumerate() {
            let (_, program) = &programs[bi];
            sweep.push(
                &target.name,
                "matmul",
                program,
                &target.board,
                &target.part,
                DseSpace::from_program(program),
            );
        }
        sweep
    }

    fn fixture() -> (BoardSpace, Vec<(String, TaskProgram)>) {
        let space = BoardSpace::resolve(&["zynq702", "zynq706"]).unwrap();
        let programs: Vec<(String, TaskProgram)> = space
            .targets
            .iter()
            .map(|t| (t.name.clone(), Matmul::new(256, 64).build_program(&t.board)))
            .collect();
        (space, programs)
    }

    #[test]
    fn boards_get_distinct_feasible_sets_and_winners() {
        let (space, programs) = fixture();
        let sweep = sweep_fixture(&programs, &space);
        assert_eq!(sweep.len(), 2);
        let results = sweep.explore(Objective::Time, 2);
        let z702 = &results[0];
        let z706 = &results[1];
        assert_eq!(z702.board, "zynq702");
        assert_eq!(z706.board, "zynq706");
        // The smaller part admits strictly fewer co-designs.
        assert!(
            z702.stats.feasible_points < z706.stats.feasible_points,
            "{} vs {}",
            z702.stats.feasible_points,
            z706.stats.feasible_points
        );
        // Both still find a best point, and the bigger/faster fabric wins.
        assert!(!z702.points.is_empty() && !z706.points.is_empty());
        assert!(z706.points[0].est_ms < z702.points[0].est_ms);

        let winners = board_winner_table(&results);
        assert_eq!(winners.len(), 1);
        let (app, rows) = &winners[0];
        assert_eq!(app, "matmul");
        assert!(!rows.is_empty());
        // Sorted by ascending budget, and the tightest budget belongs to
        // the board with the fastest point overall.
        for w in rows.windows(2) {
            assert!(w[0].time_budget_ms <= w[1].time_budget_ms);
        }
        assert_eq!(rows[0].board, "zynq706");
        let s = render_winner_table(app, rows);
        assert!(s.contains("zynq706"));
    }

    #[test]
    fn global_cut_preserves_the_merged_front() {
        let (space, programs) = fixture();
        let sweep = sweep_fixture(&programs, &space);
        let exhaustive = sweep.explore(Objective::Time, 2);
        let global = sweep.explore_pruned_global(Objective::Time, 2);
        // Merged per-app front and best point must match exactly.
        let merge = |rs: &[CrossBoardResult]| {
            let mut all: Vec<DsePoint> = Vec::new();
            for r in rs {
                all.extend(r.points.iter().cloned());
            }
            all.sort_by(|a, b| a.est_ms.total_cmp(&b.est_ms));
            all
        };
        let (e, g) = (merge(&exhaustive), merge(&global));
        assert_eq!(
            e[0].est_ms.to_bits(),
            g[0].est_ms.to_bits(),
            "global best diverged"
        );
        assert_eq!(pareto_front_coords(&e), pareto_front_coords(&g));
        // And the sweep is deterministic across worker counts.
        let serial = sweep.explore_pruned_global(Objective::Time, 1);
        for (a, b) in global.iter().zip(&serial) {
            assert_eq!(a.stats, b.stats);
            assert_eq!(a.points.len(), b.points.len());
            for (x, y) in a.points.iter().zip(&b.points) {
                assert_eq!(x.est_ms.to_bits(), y.est_ms.to_bits());
            }
        }
    }
}
