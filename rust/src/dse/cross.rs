//! Cross-board design-space exploration — the platform as a swept axis.
//!
//! The paper's cross-board observation (§I outlook; also Nunez-Yanez et
//! al. and Véstias et al. in the related work) is that the best
//! hardware/software split *shifts with the platform*: part selection is a
//! first-class design decision, so the board belongs inside the sweep, not
//! outside it. A [`CrossBoardSweep`] expands a board axis
//! ([`crate::board::BoardSpace`]) times an application list into one
//! per-(board, application) [`SweepContext`] each — its own HLS report
//! cache (the cost model depends on the board's fabric clock), its own
//! resource budget, its own bound frontier — and sweeps them all through
//! **one** shared worker pool, exactly like [`SweepSuite`] does for a
//! multi-application suite on a single board.
//!
//! Three sweep modes:
//! * [`CrossBoardSweep::explore`] — exhaustive, per-entry output
//!   bit-identical to [`SweepContext::explore`] on that entry alone;
//! * [`CrossBoardSweep::explore_pruned`] — bound-guided with **per-board
//!   frontiers only**: every entry keeps the full `dse::prune`
//!   losslessness contract (best point and time-energy Pareto front equal
//!   the exhaustive sweep's, per board);
//! * [`CrossBoardSweep::explore_pruned_global`] — additionally shares a
//!   **cross-board incumbent** between the boards of each application: a
//!   candidate whose bounds are strictly dominated by a point already
//!   evaluated on *any* board of the same application is skipped. The
//!   per-application *global* best and global Pareto front stay exact;
//!   per-board fronts may lose dominated points — use this mode when only
//!   the "which board wins" answer matters.
//!
//! The [`board_winner_table`] digests the result into the decision the
//! programmer actually needs: at every time budget, which board (and
//! which co-design on it) reaches that budget with the least energy.

use super::prune::{OrderMode, PruneStats};
use super::sweep::{SweepContext, SweepSuite};
use super::warm::EvalMemo;
use super::{pareto_front, DsePoint, DseSpace, Objective};
use crate::config::BoardConfig;
use crate::coordinator::task::TaskProgram;
use crate::hls::FpgaPart;

/// Ranked sweep output of one (board, application) entry.
#[derive(Clone, Debug)]
pub struct CrossBoardResult {
    /// Board (platform) name of the entry.
    pub board: String,
    /// Application name of the entry.
    pub app: String,
    /// Evaluated points, ranked by the sweep objective.
    pub points: Vec<DsePoint>,
    /// Cut statistics (counters zero for exhaustive sweeps).
    pub stats: PruneStats,
}

/// A multi-board, multi-application sweep over one shared worker pool.
///
/// Internally a [`SweepSuite`] whose entries are the (board × application)
/// product, plus the bookkeeping that groups entries of the same
/// application for the cross-board incumbent and the winner table.
#[derive(Default)]
pub struct CrossBoardSweep<'p> {
    suite: SweepSuite<'p>,
    /// Parallel to the suite entries: (board name, app name, app group).
    keys: Vec<(String, String, usize)>,
}

impl<'p> CrossBoardSweep<'p> {
    /// An empty sweep; add entries with [`CrossBoardSweep::push`].
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one (board, application) entry. The program must have been
    /// built against `board` (task cycle counts are board-dependent), and
    /// `part` is the board's programmable-logic budget. Entries naming the
    /// same application (on different boards) form one incumbent group for
    /// [`CrossBoardSweep::explore_pruned_global`] and one table in
    /// [`board_winner_table`].
    pub fn push(
        &mut self,
        board_name: &str,
        app_name: &str,
        program: &'p TaskProgram,
        board: &'p BoardConfig,
        part: &FpgaPart,
        space: DseSpace,
    ) {
        self.push_key(board_name, app_name);
        self.suite.push(
            &format!("{app_name}@{board_name}"),
            program,
            board,
            part,
            space,
        );
    }

    /// Record an entry's (board, app) key, assigning it to its
    /// application's incumbent group (existing group, or a fresh id) —
    /// shared by [`CrossBoardSweep::push`] and
    /// [`CrossBoardSweep::push_warm`] so the two construction paths can
    /// never diverge on grouping.
    fn push_key(&mut self, board_name: &str, app_name: &str) {
        let group = match self.keys.iter().find(|(_, a, _)| a == app_name) {
            Some(&(_, _, g)) => g,
            None => self.keys.iter().map(|&(_, _, g)| g + 1).max().unwrap_or(0),
        };
        self.keys
            .push((board_name.to_string(), app_name.to_string(), group));
    }

    /// [`CrossBoardSweep::push`] with the entry's HLS cache primed from
    /// the level-1 kernel sub-memo
    /// ([`SweepContext::prime_with_memo`]). Cross-board entries only reuse
    /// reports recorded at the *same* fabric clock and DMA bandwidth —
    /// i.e. across runs over the same board — because the cost model
    /// depends on both; sibling boards still share the occupancy
    /// statistics as ordering priors.
    #[allow(clippy::too_many_arguments)]
    pub fn push_warm(
        &mut self,
        board_name: &str,
        app_name: &str,
        program: &'p TaskProgram,
        board: &'p BoardConfig,
        part: &FpgaPart,
        space: DseSpace,
        memo: &EvalMemo,
    ) {
        self.push_key(board_name, app_name);
        self.suite.push_warm(
            &format!("{app_name}@{board_name}"),
            program,
            board,
            part,
            space,
            memo,
        );
    }

    /// Number of (board, application) entries.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// Whether no entry has been pushed yet.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    fn wrap(&self, results: Vec<super::sweep::SuiteAppResult>) -> Vec<CrossBoardResult> {
        results
            .into_iter()
            .zip(&self.keys)
            .map(|(r, (board, app, _))| CrossBoardResult {
                board: board.clone(),
                app: app.clone(),
                points: r.points,
                stats: r.stats,
            })
            .collect()
    }

    /// Exhaustively sweep every entry through one shared pool. Per-entry
    /// output is bit-identical to [`SweepContext::explore`] on that entry
    /// alone, for any worker count.
    pub fn explore(&self, objective: Objective, workers: usize) -> Vec<CrossBoardResult> {
        self.wrap(self.suite.explore(objective, workers))
    }

    /// Bound-guided pruned sweep with per-board frontiers only: every
    /// entry keeps the full per-board losslessness contract (best point
    /// and time-energy Pareto front equal the exhaustive sweep's).
    pub fn explore_pruned(&self, objective: Objective, workers: usize) -> Vec<CrossBoardResult> {
        self.wrap(self.suite.explore_pruned(objective, workers))
    }

    /// Pruned sweep with the cross-board incumbent: boards of the same
    /// application share a frontier, so a candidate provably dominated by
    /// another board's evaluated point is never simulated
    /// ([`PruneStats::global_cut`] counts them). Exact for each
    /// application's *global* best point and global time-energy Pareto
    /// front; per-board fronts may lose points. Bit-identical for any
    /// worker count.
    pub fn explore_pruned_global(
        &self,
        objective: Objective,
        workers: usize,
    ) -> Vec<CrossBoardResult> {
        let inputs: Vec<(&SweepContext<'p>, &DseSpace)> =
            self.suite.apps().iter().map(|a| (&a.ctx, &a.space)).collect();
        let groups: Vec<Option<usize>> = self.keys.iter().map(|&(_, _, g)| Some(g)).collect();
        let results = super::prune::explore_pruned_grouped(&inputs, &groups, objective, workers);
        self.wrap(
            results
                .into_iter()
                .zip(self.suite.apps())
                .map(|((points, stats), app)| super::sweep::SuiteAppResult {
                    name: app.name.clone(),
                    points,
                    stats,
                })
                .collect(),
        )
    }

    /// Warm-started pruned sweep against a persistent
    /// [`EvalMemo`](super::EvalMemo), with **board-axis warm starts**:
    /// entries run sequentially in push order (each still fanning out over
    /// `workers` threads), and a board's candidate *ordering* is seeded
    /// from the memo's **level-1 kernel sub-memo** — per-kernel occupancy
    /// statistics recorded by sibling entries earlier in the call, or by
    /// earlier runs, scaled by the fabric-clock ratio
    /// ([`EvalMemo::prior_ms_for`]; the entry whose recorded clock is
    /// closest to the current board's wins). This replaces the old
    /// O(contexts) full-memo sibling scan with indexed per-kernel lookups,
    /// and it generalizes it: statistics transfer across *problem sizes*
    /// of an application, not only across boards. Priors never cut: every
    /// candidate is still verified against its own real lower bounds and
    /// really-evaluated (or memo-exact) incumbent points, so each entry
    /// keeps the full per-board losslessness contract of
    /// [`CrossBoardSweep::explore_pruned`] — identical best point and
    /// time-energy Pareto front, per board, for any worker count. Memo
    /// hits skip re-simulation exactly as in
    /// [`SweepContext::explore_warm`]; second warm runs over an unchanged
    /// axis evaluate zero new points.
    pub fn explore_pruned_warm(
        &self,
        memo: &mut EvalMemo,
        objective: Objective,
        workers: usize,
    ) -> Vec<CrossBoardResult> {
        let mut results = Vec::new();
        for (entry, (board_name, app_name, _group)) in self.suite.apps().iter().zip(&self.keys) {
            // Sequential entries: each entry's sweep records its points
            // and kernel statistics before the next entry starts, so
            // earlier in-call siblings and siblings persisted by earlier
            // runs feed the next entry's priors from one place — the
            // kernel sub-memo.
            let (points, stats) = super::prune::explore_pruned_warm(
                &entry.ctx,
                &entry.space,
                Some(&mut *memo),
                OrderMode::Ranked,
                objective,
                workers,
            );
            results.push(CrossBoardResult {
                board: board_name.clone(),
                app: app_name.clone(),
                points,
                stats,
            });
        }
        results
    }

    /// [`CrossBoardSweep::explore_pruned_warm`] with crash recovery:
    /// entries run sequentially through one shared
    /// [`RecoverySession`](super::RecoverySession), each journaling its
    /// rounds to the memo's `.wal` sidecar and checkpointing its candidate
    /// order before its first round. After an interruption, entries that
    /// had completed re-run as pure journal-restored memo hits, the
    /// in-flight entry resumes with its checkpointed order, and untouched
    /// entries run fresh — the per-entry rankings and the subsequently
    /// saved memo are bit-identical to an uninterrupted axis sweep (see
    /// `dse::ckpt`).
    pub fn explore_pruned_warm_recoverable(
        &self,
        memo: &mut EvalMemo,
        objective: Objective,
        workers: usize,
        recovery: &mut super::ckpt::RecoverySession,
    ) -> anyhow::Result<Vec<CrossBoardResult>> {
        let mut results = Vec::new();
        for (entry, (board_name, app_name, _group)) in self.suite.apps().iter().zip(&self.keys) {
            let (points, stats) = super::prune::explore_pruned_warm_recoverable(
                &[(&entry.ctx, &entry.space)],
                Some(&mut *memo),
                OrderMode::Ranked,
                objective,
                workers,
                Some(&mut *recovery),
            )?
            .pop()
            .expect("one input yields one output");
            results.push(CrossBoardResult {
                board: board_name.clone(),
                app: app_name.clone(),
                points,
                stats,
            });
        }
        Ok(results)
    }
}

/// Build one program per (board, app) pair of the axis — board-major, the
/// push order [`sweep_from_programs`] expects. Thin wrapper over
/// [`crate::apps::build_app_program`] so the CLI, the experiment harness
/// and the bench share one expansion instead of three copies.
pub fn build_axis_programs(
    axis: &crate::board::BoardSpace,
    apps: &[&str],
    n: u64,
    bs: u64,
) -> anyhow::Result<Vec<(usize, String, TaskProgram)>> {
    let mut programs = Vec::new();
    for (bi, target) in axis.targets.iter().enumerate() {
        for app in apps {
            let program = crate::apps::build_app_program(app, n, bs, &target.board)?;
            programs.push((bi, app.to_string(), program));
        }
    }
    Ok(programs)
}

/// Assemble a [`CrossBoardSweep`] over the program list of
/// [`build_axis_programs`], using each program's default
/// [`DseSpace::from_program`] space.
pub fn sweep_from_programs<'p>(
    axis: &'p crate::board::BoardSpace,
    programs: &'p [(usize, String, TaskProgram)],
) -> CrossBoardSweep<'p> {
    let mut sweep = CrossBoardSweep::new();
    for (bi, app, program) in programs {
        let target = &axis.targets[*bi];
        sweep.push(
            &target.name,
            app,
            program,
            &target.board,
            &target.part,
            DseSpace::from_program(program),
        );
    }
    sweep
}

/// [`sweep_from_programs`] with every entry's HLS cache primed from the
/// level-1 kernel sub-memo ([`CrossBoardSweep::push_warm`]) — the warm
/// `dse --boards --memo` construction path.
pub fn sweep_from_programs_warm<'p>(
    axis: &'p crate::board::BoardSpace,
    programs: &'p [(usize, String, TaskProgram)],
    memo: &EvalMemo,
) -> CrossBoardSweep<'p> {
    let mut sweep = CrossBoardSweep::new();
    for (bi, app, program) in programs {
        let target = &axis.targets[*bi];
        sweep.push_warm(
            &target.name,
            app,
            program,
            &target.board,
            &target.part,
            DseSpace::from_program(program),
            memo,
        );
    }
    sweep
}

/// One row of a cross-board decision table. The interpretation of "the
/// budget" depends on the [`BudgetAxis`] the table was built for; the row
/// always carries the winning point's full coordinates (time, energy,
/// fabric utilization) so every axis reads off the same struct.
#[derive(Clone, Debug)]
pub struct BudgetRow {
    /// The winning point's makespan. On the [`BudgetAxis::Time`] axis this
    /// *is* the budget the row unlocks.
    pub time_budget_ms: f64,
    /// Winning board at this budget.
    pub board: String,
    /// Winning co-design on that board.
    pub codesign: String,
    /// Energy of the winning point. On the [`BudgetAxis::Energy`] axis
    /// this is the budget the row unlocks.
    pub energy_j: f64,
    /// Fabric utilization of the winning point, in [0, 1]. On the
    /// [`BudgetAxis::Area`] axis this is the budget the row unlocks.
    pub fabric_util: f64,
}

/// The budget axis a winner table answers — "within this budget, which
/// board (and which co-design on it) is best on the other axis?" This is
/// the §I part-selection story at its three decision knobs: a deadline
/// (time), an energy envelope (battery / thermal), and a fabric-area cap
/// (part cost — a point that fits in less fabric fits a cheaper part).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BudgetAxis {
    /// At every time budget: the least-energy (board, co-design).
    Time,
    /// At every energy budget: the fastest (board, co-design).
    Energy,
    /// At every fabric-utilization budget: the fastest (board, co-design).
    Area,
}

impl BudgetAxis {
    /// Parse a CLI axis name (`time` | `energy` | `area`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "time" => Some(BudgetAxis::Time),
            "energy" => Some(BudgetAxis::Energy),
            "area" => Some(BudgetAxis::Area),
            _ => None,
        }
    }

    /// The axis name used in exports and table headers.
    pub fn as_str(&self) -> &'static str {
        match self {
            BudgetAxis::Time => "time",
            BudgetAxis::Energy => "energy",
            BudgetAxis::Area => "area",
        }
    }
}

/// Indices of the (fabric_util, est_ms) Pareto-optimal points — the area
/// axis trades fabric for speed the way the time-energy front trades time
/// for energy.
fn area_time_front(points: &[DsePoint]) -> Vec<usize> {
    let coords: Vec<(f64, f64)> = points.iter().map(|p| (p.fabric_util, p.est_ms)).collect();
    super::front_indices(&coords)
}

/// Digest per-(board, app) sweep results into one decision table per
/// application along a [`BudgetAxis`]:
///
/// * [`BudgetAxis::Time`] — the merged cross-board time-energy Pareto
///   front, sorted by ascending time (hence descending energy). Each row
///   is the energy-optimal choice at exactly that row's time budget;
/// * [`BudgetAxis::Energy`] — the same front read the other way: sorted by
///   ascending energy, each row is the *fastest* choice at exactly that
///   row's energy budget;
/// * [`BudgetAxis::Area`] — the merged (fabric-utilization, time) front,
///   sorted by ascending utilization: each row is the fastest choice that
///   fits in that row's fabric budget (part-cost selection).
///
/// For an arbitrary budget on any axis, the *last* row whose budget
/// coordinate still fits wins — rows trade the budgeted resource for the
/// optimized one as you read down. Applications appear in first-push
/// order; exact coordinate ties break by board then co-design name, so
/// the output is deterministic.
pub fn board_winner_table_for(
    results: &[CrossBoardResult],
    axis: BudgetAxis,
) -> Vec<(String, Vec<BudgetRow>)> {
    let mut apps: Vec<&str> = Vec::new();
    for r in results {
        if !apps.contains(&r.app.as_str()) {
            apps.push(&r.app);
        }
    }
    apps.iter()
        .map(|&app| {
            // Merge every board's points for this application.
            let mut merged: Vec<(usize, &DsePoint)> = Vec::new();
            let mut points: Vec<DsePoint> = Vec::new();
            for (ri, r) in results.iter().enumerate() {
                if r.app == app {
                    for p in &r.points {
                        merged.push((ri, p));
                        points.push(p.clone());
                    }
                }
            }
            let front = match axis {
                BudgetAxis::Time | BudgetAxis::Energy => pareto_front(&points),
                BudgetAxis::Area => area_time_front(&points),
            };
            let mut rows: Vec<BudgetRow> = front
                .into_iter()
                .map(|i| {
                    let (ri, p) = merged[i];
                    BudgetRow {
                        time_budget_ms: p.est_ms,
                        board: results[ri].board.clone(),
                        codesign: p.codesign.name.clone(),
                        energy_j: p.energy_j,
                        fabric_util: p.fabric_util,
                    }
                })
                .collect();
            rows.sort_by(|a, b| {
                let primary = match axis {
                    BudgetAxis::Time => a
                        .time_budget_ms
                        .total_cmp(&b.time_budget_ms)
                        .then(a.energy_j.total_cmp(&b.energy_j)),
                    BudgetAxis::Energy => a
                        .energy_j
                        .total_cmp(&b.energy_j)
                        .then(a.time_budget_ms.total_cmp(&b.time_budget_ms)),
                    BudgetAxis::Area => a
                        .fabric_util
                        .total_cmp(&b.fabric_util)
                        .then(a.time_budget_ms.total_cmp(&b.time_budget_ms)),
                };
                primary
                    .then_with(|| a.board.cmp(&b.board))
                    .then_with(|| a.codesign.cmp(&b.codesign))
            });
            rows.dedup_by(|a, b| {
                a.time_budget_ms.to_bits() == b.time_budget_ms.to_bits()
                    && a.energy_j.to_bits() == b.energy_j.to_bits()
                    && a.board == b.board
                    && a.codesign == b.codesign
            });
            (app.to_string(), rows)
        })
        .collect()
}

/// The time-budget decision table — see
/// [`board_winner_table_for`]`(results, BudgetAxis::Time)`.
pub fn board_winner_table(results: &[CrossBoardResult]) -> Vec<(String, Vec<BudgetRow>)> {
    board_winner_table_for(results, BudgetAxis::Time)
}

/// Render one application's winner table for the CLI (time axis — kept
/// byte-stable for the bench output; other axes use
/// [`render_budget_table`]).
pub fn render_winner_table(app: &str, rows: &[BudgetRow]) -> String {
    let mut out = format!("== {app}: which board wins at which time budget\n");
    out.push_str(&format!(
        "{:>12} {:>18} {:36} {:>10}\n",
        "budget (ms)", "board", "co-design", "energy (J)"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:>12.2} {:>18} {:36} {:>10.3}\n",
            r.time_budget_ms, r.board, r.codesign, r.energy_j
        ));
    }
    out
}

/// Render one application's winner table for any [`BudgetAxis`].
pub fn render_budget_table(app: &str, rows: &[BudgetRow], axis: BudgetAxis) -> String {
    if axis == BudgetAxis::Time {
        return render_winner_table(app, rows);
    }
    let (what, unit) = match axis {
        BudgetAxis::Energy => ("energy", "budget (J)"),
        _ => ("fabric-area", "budget util"),
    };
    let mut out = format!("== {app}: which board wins at which {what} budget\n");
    out.push_str(&format!(
        "{:>12} {:>18} {:36} {:>10} {:>10}\n",
        unit, "board", "co-design", "time (ms)", "energy (J)"
    ));
    for r in rows {
        let budget = match axis {
            BudgetAxis::Energy => format!("{:>12.3}", r.energy_j),
            _ => format!("{:>11.0}%", r.fabric_util * 100.0),
        };
        out.push_str(&format!(
            "{budget} {:>18} {:36} {:>10.2} {:>10.3}\n",
            r.board, r.codesign, r.time_budget_ms, r.energy_j
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::Matmul;
    use crate::board::BoardSpace;
    use crate::dse::pareto_front_coords;

    fn sweep_fixture<'p>(
        programs: &'p [(String, TaskProgram)],
        space: &'p BoardSpace,
    ) -> CrossBoardSweep<'p> {
        let mut sweep = CrossBoardSweep::new();
        for (bi, target) in space.targets.iter().enumerate() {
            let (_, program) = &programs[bi];
            sweep.push(
                &target.name,
                "matmul",
                program,
                &target.board,
                &target.part,
                DseSpace::from_program(program),
            );
        }
        sweep
    }

    fn fixture() -> (BoardSpace, Vec<(String, TaskProgram)>) {
        let space = BoardSpace::resolve(&["zynq702", "zynq706"]).unwrap();
        let programs: Vec<(String, TaskProgram)> = space
            .targets
            .iter()
            .map(|t| (t.name.clone(), Matmul::new(256, 64).build_program(&t.board)))
            .collect();
        (space, programs)
    }

    #[test]
    fn boards_get_distinct_feasible_sets_and_winners() {
        let (space, programs) = fixture();
        let sweep = sweep_fixture(&programs, &space);
        assert_eq!(sweep.len(), 2);
        let results = sweep.explore(Objective::Time, 2);
        let z702 = &results[0];
        let z706 = &results[1];
        assert_eq!(z702.board, "zynq702");
        assert_eq!(z706.board, "zynq706");
        // The smaller part admits strictly fewer co-designs.
        assert!(
            z702.stats.feasible_points < z706.stats.feasible_points,
            "{} vs {}",
            z702.stats.feasible_points,
            z706.stats.feasible_points
        );
        // Both still find a best point, and the bigger/faster fabric wins.
        assert!(!z702.points.is_empty() && !z706.points.is_empty());
        assert!(z706.points[0].est_ms < z702.points[0].est_ms);

        let winners = board_winner_table(&results);
        assert_eq!(winners.len(), 1);
        let (app, rows) = &winners[0];
        assert_eq!(app, "matmul");
        assert!(!rows.is_empty());
        // Sorted by ascending budget, and the tightest budget belongs to
        // the board with the fastest point overall.
        for w in rows.windows(2) {
            assert!(w[0].time_budget_ms <= w[1].time_budget_ms);
        }
        assert_eq!(rows[0].board, "zynq706");
        let s = render_winner_table(app, rows);
        assert!(s.contains("zynq706"));
    }

    #[test]
    fn global_cut_preserves_the_merged_front() {
        let (space, programs) = fixture();
        let sweep = sweep_fixture(&programs, &space);
        let exhaustive = sweep.explore(Objective::Time, 2);
        let global = sweep.explore_pruned_global(Objective::Time, 2);
        // Merged per-app front and best point must match exactly.
        let merge = |rs: &[CrossBoardResult]| {
            let mut all: Vec<DsePoint> = Vec::new();
            for r in rs {
                all.extend(r.points.iter().cloned());
            }
            all.sort_by(|a, b| a.est_ms.total_cmp(&b.est_ms));
            all
        };
        let (e, g) = (merge(&exhaustive), merge(&global));
        assert_eq!(
            e[0].est_ms.to_bits(),
            g[0].est_ms.to_bits(),
            "global best diverged"
        );
        assert_eq!(pareto_front_coords(&e), pareto_front_coords(&g));
        // And the sweep is deterministic across worker counts.
        let serial = sweep.explore_pruned_global(Objective::Time, 1);
        for (a, b) in global.iter().zip(&serial) {
            assert_eq!(a.stats, b.stats);
            assert_eq!(a.points.len(), b.points.len());
            for (x, y) in a.points.iter().zip(&b.points) {
                assert_eq!(x.est_ms.to_bits(), y.est_ms.to_bits());
            }
        }
    }

    #[test]
    fn warm_cross_sweep_is_exact_and_second_run_hits_the_memo() {
        let (space, programs) = fixture();
        let sweep = sweep_fixture(&programs, &space);
        let exhaustive = sweep.explore(Objective::Time, 2);
        let mut memo = super::super::warm::EvalMemo::new();
        let warm = sweep.explore_pruned_warm(&mut memo, Objective::Time, 2);
        // Per-board losslessness: sibling priors only order, never cut.
        for (e, w) in exhaustive.iter().zip(&warm) {
            assert_eq!(e.board, w.board);
            assert_eq!(
                e.points[0].est_ms.to_bits(),
                w.points[0].est_ms.to_bits(),
                "warm best diverged on {}",
                e.board
            );
            assert_eq!(pareto_front_coords(&e.points), pareto_front_coords(&w.points));
        }
        // The later board of the axis got sibling priors (zynq702 swept
        // first); exactness held regardless.
        assert!(warm.iter().map(|r| r.stats.evaluated).sum::<u64>() > 0);
        // Second warm run over the unchanged axis: zero new evaluations,
        // every point a memo hit, bit-identical output.
        let again = sweep.explore_pruned_warm(&mut memo, Objective::Time, 2);
        for (w, a) in warm.iter().zip(&again) {
            assert_eq!(a.stats.evaluated, 0, "{:?}", a.stats);
            assert_eq!(a.stats.memo_hits as usize, w.points.len());
            assert_eq!(a.points.len(), w.points.len());
            for (x, y) in a.points.iter().zip(&w.points) {
                assert_eq!(x.codesign.name, y.codesign.name);
                assert_eq!(x.est_ms.to_bits(), y.est_ms.to_bits());
            }
        }
        // Determinism across worker counts (fresh memo per count so hits
        // match the two-worker run).
        let mut memo1 = super::super::warm::EvalMemo::new();
        let serial = sweep.explore_pruned_warm(&mut memo1, Objective::Time, 1);
        for (a, b) in warm.iter().zip(&serial) {
            assert_eq!(a.stats, b.stats);
            for (x, y) in a.points.iter().zip(&b.points) {
                assert_eq!(x.est_ms.to_bits(), y.est_ms.to_bits());
            }
        }
    }

    #[test]
    fn memo_persisted_siblings_seed_cross_run_sweeps() {
        let (space, programs) = fixture();
        let mut memo = super::super::warm::EvalMemo::new();
        // Run 1: only the first board of the axis.
        let mut sweep_a = CrossBoardSweep::new();
        let ta = &space.targets[0];
        sweep_a.push(
            &ta.name,
            "matmul",
            &programs[0].1,
            &ta.board,
            &ta.part,
            DseSpace::from_program(&programs[0].1),
        );
        sweep_a.explore_pruned_warm(&mut memo, Objective::Time, 2);
        // Run 2 (separate call, separate sweep): the second board alone —
        // its ordering priors can only come from the memo-persisted run-1
        // context. Results must still equal the cold exhaustive sweep.
        let mut sweep_b = CrossBoardSweep::new();
        let tb = &space.targets[1];
        sweep_b.push(
            &tb.name,
            "matmul",
            &programs[1].1,
            &tb.board,
            &tb.part,
            DseSpace::from_program(&programs[1].1),
        );
        let warm = sweep_b.explore_pruned_warm(&mut memo, Objective::Time, 2);
        let exhaustive = sweep_b.explore(Objective::Time, 2);
        assert_eq!(
            exhaustive[0].points[0].est_ms.to_bits(),
            warm[0].points[0].est_ms.to_bits()
        );
        assert_eq!(
            pareto_front_coords(&exhaustive[0].points),
            pareto_front_coords(&warm[0].points)
        );
        // The run-1 context is visible as a memo-persisted sibling of the
        // run-2 board (same app metadata, different fingerprint).
        let fp_b = super::super::warm::context_fingerprint(&sweep_b.suite.apps()[0].ctx);
        let sibs = memo.sibling_points_ms(&programs[1].1.app_name, fp_b);
        assert_eq!(sibs.len(), 1);
        assert!(!sibs[0].1.is_empty());
        assert_eq!(sibs[0].0.to_bits(), ta.board.fabric_freq_mhz.to_bits());
    }

    #[test]
    fn budget_axes_answer_the_three_part_selection_questions() {
        let (space, programs) = fixture();
        let sweep = sweep_fixture(&programs, &space);
        let results = sweep.explore(Objective::Time, 2);

        // Energy axis: same Pareto set as the time axis, read the other
        // way — sorted by ascending energy, hence descending time.
        let time_rows = &board_winner_table_for(&results, BudgetAxis::Time)[0].1;
        let energy_rows = &board_winner_table_for(&results, BudgetAxis::Energy)[0].1;
        assert_eq!(time_rows.len(), energy_rows.len());
        for w in energy_rows.windows(2) {
            assert!(w[0].energy_j <= w[1].energy_j);
            assert!(w[0].time_budget_ms >= w[1].time_budget_ms);
        }
        let mut t: Vec<(u64, u64)> = time_rows
            .iter()
            .map(|r| (r.time_budget_ms.to_bits(), r.energy_j.to_bits()))
            .collect();
        let mut e: Vec<(u64, u64)> = energy_rows
            .iter()
            .map(|r| (r.time_budget_ms.to_bits(), r.energy_j.to_bits()))
            .collect();
        t.sort_unstable();
        e.sort_unstable();
        assert_eq!(t, e);

        // Area axis: ascending fabric budget, nondominated in (util, time),
        // time improving as the budget grows.
        let area_rows = &board_winner_table_for(&results, BudgetAxis::Area)[0].1;
        assert!(!area_rows.is_empty());
        for w in area_rows.windows(2) {
            assert!(w[0].fabric_util <= w[1].fabric_util);
            assert!(w[0].time_budget_ms >= w[1].time_budget_ms);
        }
        // Rendering covers every axis.
        assert!(render_budget_table("matmul", energy_rows, BudgetAxis::Energy)
            .contains("energy budget"));
        assert!(render_budget_table("matmul", area_rows, BudgetAxis::Area).contains('%'));
        assert_eq!(
            render_budget_table("matmul", time_rows, BudgetAxis::Time),
            render_winner_table("matmul", time_rows)
        );
        assert_eq!(BudgetAxis::parse("area"), Some(BudgetAxis::Area));
        assert_eq!(BudgetAxis::parse("bogus"), None);
    }
}
