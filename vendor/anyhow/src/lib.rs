//! Minimal, offline stand-in for the `anyhow` crate.
//!
//! The repository builds without network access, so the subset of `anyhow`
//! it actually uses is vendored here: the type-erased [`Error`], the
//! [`Result`] alias, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Semantics match upstream for that subset:
//!
//! * `Error` wraps any `std::error::Error + Send + Sync + 'static` (so `?`
//!   converts foreign errors) or a plain display message;
//! * `Display` prints the error; the alternate form (`{:#}`) appends the
//!   source chain as `": cause"` segments;
//! * `Debug` prints the error followed by a `Caused by:` list — what
//!   `fn main() -> anyhow::Result<()>` shows on exit.
//!
//! Intentionally not implemented (unused in this repository): `Context`,
//! owning downcasts (`downcast`/`downcast_mut`), and backtrace capture.
//! `downcast_ref` *is* provided — the service daemon classifies sweep
//! cancellation by downcasting to a marker error type.

use std::fmt;

/// A type-erased error, compatible with `?` on any standard error type.
pub struct Error {
    inner: Box<dyn std::error::Error + Send + Sync + 'static>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Wrap a concrete error value.
    pub fn new<E>(error: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { inner: Box::new(error) }
    }

    /// Build an error from a display-able message (what `anyhow!` calls).
    pub fn msg<M>(message: M) -> Self
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error {
            inner: Box::new(MessageError(message)),
        }
    }

    /// Attempt to view the wrapped error as a concrete type. Matches
    /// upstream semantics for errors wrapped via [`Error::new`] / the
    /// blanket `From`; message-only errors (`anyhow!`) never match a
    /// concrete type (their payload is private), exactly as upstream.
    pub fn downcast_ref<E>(&self) -> Option<&E>
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        self.inner.downcast_ref::<E>()
    }

    /// The lowest-level source in the chain (self if there is none).
    pub fn root_cause(&self) -> &(dyn std::error::Error + 'static) {
        let mut cause: &(dyn std::error::Error + 'static) = &*self.inner;
        while let Some(next) = cause.source() {
            cause = next;
        }
        cause
    }
}

/// Message-only payload promoted to a `std::error::Error`.
struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Debug> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl<M: fmt::Display + fmt::Debug> std::error::Error for MessageError<M> {}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)?;
        if f.alternate() {
            let mut source = self.inner.source();
            while let Some(s) = source {
                write!(f, ": {s}")?;
                source = s.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(s) = source {
            write!(f, "\n    {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

// Like upstream anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// display-able expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::format!(
                "condition failed: {}",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug)]
    struct Leaf;
    impl fmt::Display for Leaf {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "leaf cause")
        }
    }
    impl std::error::Error for Leaf {}

    #[derive(Debug)]
    struct Mid(Leaf);
    impl fmt::Display for Mid {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "mid error")
        }
    }
    impl std::error::Error for Mid {
        fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
            Some(&self.0)
        }
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            let _ = "nope".parse::<i32>()?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let name = "x";
        let e = anyhow!("inline {name}");
        assert_eq!(e.to_string(), "inline x");
        let e = anyhow!("positional {}: {}", 1, "two");
        assert_eq!(e.to_string(), "positional 1: two");
        let e = anyhow!(String::from("from expr"));
        assert_eq!(e.to_string(), "from expr");
    }

    #[test]
    fn bail_and_ensure_return_err() {
        fn b() -> Result<()> {
            bail!("boom {}", 7);
        }
        assert_eq!(b().unwrap_err().to_string(), "boom 7");
        fn e(x: u32) -> Result<u32> {
            ensure!(x > 2, "too small: {x}");
            Ok(x)
        }
        assert!(e(1).is_err());
        assert_eq!(e(3).unwrap(), 3);
    }

    #[test]
    fn alternate_display_prints_chain() {
        let e = Error::new(Mid(Leaf));
        assert_eq!(format!("{e}"), "mid error");
        assert_eq!(format!("{e:#}"), "mid error: leaf cause");
        assert!(format!("{e:?}").contains("Caused by:"));
        assert_eq!(e.root_cause().to_string(), "leaf cause");
    }
}
