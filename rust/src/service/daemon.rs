//! The resident estimator daemon behind `zynq-estimator serve`.
//!
//! One [`Service`] owns one shared [`EvalMemo`] and answers NDJSON
//! requests from any number of transports concurrently: the process's
//! stdin/stdout pair and (with `--listen`) a TCP listener where every
//! connection speaks the same one-line-per-message protocol. All
//! transports funnel into [`Service::handle_line`], so the daemon's
//! semantics are transport-independent and the conformance suite can
//! drive the cheap pipe transport and trust the TCP one.
//!
//! **Lane sharding.** With `--lanes N` the single memo lane of the
//! original daemon splits into N lanes routed by *kernel group*: the
//! first context to use a kernel fingerprint claims a lane for it, and
//! every later context locks the union of the lanes owned by its
//! kernels (ascending index order, so lock acquisition is globally
//! deadlock-free). Contexts that share level-1 kernel state therefore
//! always hold intersecting lock sets and see exactly the sequential
//! warmth counters — which is what keeps every response byte-identical
//! to the single-lane daemon for any interleaving — while
//! kernel-disjoint contexts run their program analysis and cold
//! evaluations concurrently under a shared memo *read* lock, taking the
//! write lock only for the brief per-point bookkeeping. Each lane
//! journals to its own WAL shard (`<memo>.wal`, `<memo>.wal.1`, ...),
//! so the crash-safety contract — lose at most the in-flight round —
//! holds independently per lane.
//!
//! **Batch evaluation.** The cold points of a `batch` envelope (and of a
//! `--batch-window-ms` accumulation window) are evaluated together as
//! one chunk-synchronous worker-pool round per context
//! ([`super::query::pre_evaluate`]), then each item's memo bookkeeping
//! and response rendering runs in original arrival order
//! ([`super::query::point_query_prepared`]). Evaluation is a pure
//! function of (context, co-design), so batching changes throughput and
//! never bytes; the conformance suite proves the responses equal the
//! sequential ones.
//!
//! **Coalescing.** Identical in-flight queries (same canonical
//! [`Envelope::coalesce_key`]) share one evaluation: the first arrival
//! becomes the *leader* and computes; later arrivals park on a condvar
//! and receive a clone of the leader's reply, so all N responses are
//! bitwise identical and the memo sees one recording. Coalescing is
//! observable only through the cumulative `coalesced` counter of
//! `{"req":"memo","action":"stats"}` — deliberately not in per-response
//! fields, which would break response bit-identity. Requests carrying a
//! deadline bypass the coalescing table: a follower must never inherit
//! a leader's (possibly longer) deadline.
//!
//! **Overload control.** The daemon bounds every resource a hostile or
//! merely enthusiastic client could exhaust, and sheds load with
//! structured errors instead of stalling or dying:
//!
//! * *Deadlines* — `"deadline_ms"` on any work request (or
//!   `--default-deadline-ms` for all of them) starts a budget at
//!   admission. A point query whose budget expired before its cold
//!   evaluation started answers code 4 (`kind:"TIMEOUT"`); memo hits
//!   are always served. A `dse` sweep polls its deadline at
//!   chunk-synchronous round barriers only — in-flight rounds always
//!   complete, so cancellation never tears a round and the memo stays
//!   byte-identical to never having asked.
//! * *Admission* — per-lane queue depths (`--max-queue`), a global
//!   in-flight cap (`--max-inflight`), a TCP connection cap
//!   (`--max-conns`) and a request-line size limit (`--max-line-bytes`)
//!   refuse excess work with code 5 (`kind:"OVERLOADED"`) and a
//!   `retry_after_ms` backoff hint. Slow readers are bounded by
//!   `--write-timeout-ms`; a disconnected client's queued (never
//!   in-flight) requests are dropped.
//! * *Degradation* — `--breaker-threshold` consecutive memo save
//!   failures open a circuit breaker: the daemon turns read-only,
//!   serving memo hits normally and refusing cold evaluations with
//!   code 6 (`kind:"DEGRADED"`) until a save succeeds again.
//!   `{"req":"health"}` probes readiness (never queued behind work),
//!   and SIGTERM drains: stop admitting, finish in-flight work, save,
//!   exit.
//!
//! **Persistence.** With `--memo <file>` the memo loads with WAL
//! recovery (all shards) at startup, journals every fresh evaluation as
//! a committed WAL round *before* its response is written, and saves
//! atomically every `--save-every` fresh evaluations, at `memo gc`, and
//! at shutdown/EOF. A `kill -9` therefore loses at most the in-flight
//! round per lane — the same contract the recoverable sweeps have. A
//! failed save degrades cleanly: the daemon keeps answering, the shard
//! WALs keep the delta, and the final exit code turns non-zero so
//! supervisors notice.

use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{
    Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};
use std::time::{Duration, Instant};

use crate::config::BoardConfig;
use crate::coordinator::task::TaskProgram;
use crate::dse::warm::{codesign_key, context_fingerprint};
use crate::dse::{EvalMemo, SweepCancelled, SweepContext, SweepJournal};
use crate::hls::{kernel_fingerprint, FpgaPart};
use crate::util::faultpoint;
use crate::util::fnv::Fnv;
use crate::util::json::Value;

use super::proto::{
    err_line, err_obj, ok_line, ok_obj, parse_request, BatchItem, Envelope, PointQuery,
    QueryReply, RequestKind, ServiceError,
};
use super::query::{
    dse_query, point_query_prepared, pre_evaluate, space_for_codesign, PreEvaluated,
};

/// Daemon configuration (the `serve` CLI flags).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Persistent memo file; `None` serves from a process-local memo.
    pub memo_path: Option<PathBuf>,
    /// TCP listen address (e.g. `127.0.0.1:0`); `None` is stdio-only.
    pub listen: Option<String>,
    /// Sweep worker threads (0 → one per core).
    pub workers: usize,
    /// Save the memo after this many fresh evaluations.
    pub save_every: u64,
    /// Byte budget enforced (via `EvalMemo::gc_bytes`) before each save.
    pub max_bytes: Option<usize>,
    /// Per-app most-recent context floor of the byte-budget gc.
    pub app_floor: usize,
    /// Memo lanes (`--lanes`): requests shard by kernel group and
    /// disjoint groups evaluate concurrently. `1` is the original
    /// single-lane daemon, bit for bit.
    pub lanes: usize,
    /// Accumulation window (`--batch-window-ms`) for cross-request batch
    /// evaluation of point queries; `0` disables the window (explicit
    /// `batch` envelopes always batch).
    pub batch_window_ms: u64,
    /// Deadline applied to every work request that does not carry its
    /// own `"deadline_ms"` (`--default-deadline-ms`); `None` means no
    /// implicit deadline. Deadlined requests skip coalescing.
    pub default_deadline_ms: Option<u64>,
    /// Maximum admitted-but-unfinished requests per admission shard
    /// (`--max-queue`); excess answers `OVERLOADED`.
    pub max_queue: usize,
    /// Maximum concurrent TCP connections (`--max-conns`); excess
    /// connections receive one `OVERLOADED` line and are closed.
    pub max_conns: usize,
    /// Maximum requests in flight across all transports
    /// (`--max-inflight`); excess answers `OVERLOADED`.
    pub max_inflight: usize,
    /// Maximum request line length in bytes (`--max-line-bytes`); longer
    /// lines are consumed (the stream stays in sync) and answered with
    /// one `OVERLOADED` line without ever being buffered whole.
    pub max_line_bytes: usize,
    /// TCP write timeout (`--write-timeout-ms`, 0 disables): a client
    /// that stops reading cannot wedge its connection thread forever.
    pub write_timeout_ms: u64,
    /// Consecutive memo-save failures that open the read-only circuit
    /// breaker (`--breaker-threshold`).
    pub breaker_threshold: u32,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            memo_path: None,
            listen: None,
            workers: 0,
            save_every: 8,
            max_bytes: None,
            app_floor: 1,
            lanes: 1,
            batch_window_ms: 0,
            default_deadline_ms: None,
            max_queue: 64,
            max_conns: 64,
            max_inflight: 256,
            max_line_bytes: 1 << 20,
            write_timeout_ms: 10_000,
            breaker_threshold: 3,
        }
    }
}

/// Per-lane mutable state: the lane's shard journal. The lane locks are
/// what serialize requests that share memo state (overlapping kernel
/// groups), so holding them across one request's evaluate-then-record
/// sequence is exactly the sequential semantics the byte-identity
/// contract needs.
struct LaneState {
    journal: Option<SweepJournal>,
}

/// The lock set of one context: every lane owned by one of its kernel
/// fingerprints plus the `primary` lane (which keeps its shard journal).
/// `locks` is ascending and deduplicated — all acquisition happens in
/// ascending lane order, which makes the multi-lock scheme deadlock-free
/// against both other routes and the all-lane quiesce of a save.
#[derive(Clone, Debug, PartialEq, Eq)]
struct Route {
    locks: Vec<usize>,
    primary: usize,
}

/// Kernel-group lane router. The first context to use a kernel
/// fingerprint claims the context's primary lane for it; later contexts
/// that share the kernel must lock that lane too. Routes are computed
/// once per (app, n, bs) context and immutable afterwards — two contexts
/// sharing a kernel always have intersecting lock sets, so their warmth
/// bookkeeping is serialized exactly as in the single-lane daemon.
struct LaneRouter {
    lanes: usize,
    /// kernel fingerprint → lane that owns its level-1 memo state.
    kernel_owner: HashMap<u64, usize>,
    /// (app, n, bs) → computed route (immutable once inserted).
    routes: HashMap<(String, u64, u64), Route>,
}

impl LaneRouter {
    fn new(lanes: usize) -> Self {
        LaneRouter {
            lanes: lanes.max(1),
            kernel_owner: HashMap::new(),
            routes: HashMap::new(),
        }
    }

    fn cached(&self, key: &(String, u64, u64)) -> Option<Route> {
        self.routes.get(key).cloned()
    }

    /// Compute (or fetch) the route of one context given its sorted,
    /// deduplicated kernel fingerprints. A context whose kernels are all
    /// unowned hashes to a fresh primary lane and claims them; a context
    /// overlapping existing groups locks every owner lane and adopts the
    /// lowest as primary, claiming only its still-unowned kernels.
    fn assign(&mut self, key: &(String, u64, u64), fps: &[u64]) -> Route {
        if let Some(r) = self.routes.get(key) {
            return r.clone();
        }
        let mut owners: Vec<usize> = fps
            .iter()
            .filter_map(|fp| self.kernel_owner.get(fp).copied())
            .collect();
        owners.sort_unstable();
        owners.dedup();
        let primary = match owners.first() {
            Some(&o) => o,
            None => {
                let mut h = Fnv::new();
                for &fp in fps {
                    h.u64(fp);
                }
                h.str(&key.0);
                (h.finish() % self.lanes as u64) as usize
            }
        };
        for &fp in fps {
            self.kernel_owner.entry(fp).or_insert(primary);
        }
        let mut locks = owners;
        if !locks.contains(&primary) {
            locks.push(primary);
        }
        locks.sort_unstable();
        let route = Route { locks, primary };
        self.routes.insert(key.clone(), route.clone());
        route
    }
}

/// The accumulation window of one shard: point queries parked here are
/// drained by the window leader into one batch round.
#[derive(Default)]
struct Window {
    pending: Vec<PendingPoint>,
    collecting: bool,
}

/// One window-parked point query and the cell its reply is fanned into.
struct PendingPoint {
    query: PointQuery,
    energy: bool,
    deadline: Option<Instant>,
    cell: Arc<InFlight>,
}

/// One point query flowing through the batch evaluator, with the
/// admission-time deadline it must honor.
#[derive(Clone)]
struct PointItem {
    query: PointQuery,
    energy: bool,
    deadline: Option<Instant>,
}

/// A query in flight: the leader publishes into `slot` and wakes waiters.
struct InFlight {
    slot: Mutex<Option<Result<QueryReply, ServiceError>>>,
    done: Condvar,
}

impl InFlight {
    fn new() -> Self {
        InFlight {
            slot: Mutex::new(None),
            done: Condvar::new(),
        }
    }
}

/// Cumulative service counters (all monotonic, relaxed ordering — they
/// are observability, not synchronization).
#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    coalesced: AtomicU64,
    batched: AtomicU64,
    evaluated: AtomicU64,
    l1_hits: AtomicU64,
    l2_hits: AtomicU64,
    errors: AtomicU64,
    saves: AtomicU64,
    timeouts: AtomicU64,
    overloaded: AtomicU64,
    degraded_rejects: AtomicU64,
}

/// Backoff hint for an `OVERLOADED` response, scaled by how deep the
/// contended resource already is (capped at one second).
fn retry_hint(pressure: u64) -> u64 {
    (25 * (pressure + 1)).min(1000)
}

/// The resident estimator service: shared memo behind a read/write lock,
/// kernel-group lanes with per-shard journals, program and fingerprint
/// caches, in-flight coalescing table, admission accounting and
/// counters. Wrap in an [`Arc`] and call [`Service::handle_line`] from
/// any number of threads.
pub struct Service {
    board: BoardConfig,
    part: FpgaPart,
    cfg: ServeConfig,
    programs: Mutex<BTreeMap<(String, u64, u64), Arc<TaskProgram>>>,
    /// The shared two-level memo. Evaluation and program analysis run
    /// under the *read* lock (so distinct lanes overlap); only the brief
    /// per-point bookkeeping and gc take the write lock.
    memo: RwLock<EvalMemo>,
    /// Cached context fingerprints per (app, n, bs) — the fingerprint
    /// covers program/board/part only, so it is computed once per context
    /// lifetime with a probe analysis and reused ever after.
    fingerprints: Mutex<BTreeMap<(String, u64, u64), u64>>,
    lanes: Vec<Mutex<LaneState>>,
    /// Kernel-group route table. Never held while a lane lock is taken.
    router: Mutex<LaneRouter>,
    windows: Vec<Mutex<Window>>,
    inflight: Mutex<HashMap<String, Arc<InFlight>>>,
    /// Serializes savers; lane locks are only held *inside* a save.
    save_lock: Mutex<()>,
    fresh_since_save: AtomicU64,
    save_failed: AtomicBool,
    /// Consecutive save failures (reset by any success) — the breaker
    /// input.
    save_fail_streak: AtomicU64,
    /// Circuit breaker: open (true) after `breaker_threshold`
    /// consecutive save failures; the daemon serves read-only until a
    /// save succeeds.
    breaker_tripped: AtomicBool,
    /// Admitted-but-unfinished requests per admission shard.
    lane_depth: Vec<AtomicU64>,
    /// Admitted-but-unfinished requests across all shards.
    inflight_total: AtomicU64,
    /// Live TCP connections (stdio is not counted).
    conns: AtomicU64,
    /// Draining (SIGTERM received): admission refuses all new work.
    draining: AtomicBool,
    counters: Counters,
    shutdown: AtomicBool,
    exit_code: Mutex<Option<i32>>,
}

/// Lock that survives a poisoned-by-panic peer: a leader panicking
/// mid-query (fault injection does this on purpose) must not wedge the
/// daemon — worst case the memo lost one partial recording, which the
/// next save rewrites consistently.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// [`lock_unpoisoned`] for the memo read lock.
fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|p| p.into_inner())
}

/// [`lock_unpoisoned`] for the memo write lock.
fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|p| p.into_inner())
}

/// RAII admission token: decrements the shard depth and the global
/// in-flight count however the request ends (answered, panicked, or the
/// connection died while it ran).
struct AdmitGuard<'a> {
    svc: &'a Service,
    shard: Option<usize>,
}

impl Drop for AdmitGuard<'_> {
    fn drop(&mut self) {
        if let Some(s) = self.shard {
            self.svc.lane_depth[s].fetch_sub(1, Ordering::SeqCst);
        }
        self.svc.inflight_total.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Service {
    /// Build the service: load the memo (with WAL recovery across every
    /// shard journal) and open one shard journal per lane. Startup
    /// diagnostics go to stderr — stdout carries only NDJSON responses.
    pub fn new(board: BoardConfig, cfg: ServeConfig) -> anyhow::Result<Self> {
        let n_lanes = cfg.lanes.max(1);
        let mut journals: Vec<Option<SweepJournal>> = (0..n_lanes).map(|_| None).collect();
        let memo = match &cfg.memo_path {
            Some(path) => {
                let (memo, recovered) = EvalMemo::load_with_recovery(path)?;
                if let Some(rec) = &recovered {
                    eprintln!(
                        "serve: recovered {} journaled points across {} contexts \
                         ({} committed rounds) from the journal(s) of {}",
                        rec.n_points(),
                        rec.contexts.len(),
                        rec.rounds,
                        path.display(),
                    );
                }
                eprintln!(
                    "serve: memo {} ({} contexts, {} points, {} kernel entries)",
                    path.display(),
                    memo.n_contexts(),
                    memo.n_points(),
                    memo.n_kernel_entries(),
                );
                for (shard, slot) in journals.iter_mut().enumerate() {
                    *slot = Some(SweepJournal::open_shard(path, shard)?);
                }
                memo
            }
            None => EvalMemo::new(),
        };
        Ok(Service {
            board,
            part: FpgaPart::xc7z045(),
            cfg,
            programs: Mutex::new(BTreeMap::new()),
            memo: RwLock::new(memo),
            fingerprints: Mutex::new(BTreeMap::new()),
            lanes: journals
                .into_iter()
                .map(|journal| Mutex::new(LaneState { journal }))
                .collect(),
            router: Mutex::new(LaneRouter::new(n_lanes)),
            windows: (0..n_lanes).map(|_| Mutex::new(Window::default())).collect(),
            inflight: Mutex::new(HashMap::new()),
            save_lock: Mutex::new(()),
            fresh_since_save: AtomicU64::new(0),
            save_failed: AtomicBool::new(false),
            save_fail_streak: AtomicU64::new(0),
            breaker_tripped: AtomicBool::new(false),
            lane_depth: (0..n_lanes).map(|_| AtomicU64::new(0)).collect(),
            inflight_total: AtomicU64::new(0),
            conns: AtomicU64::new(0),
            draining: AtomicBool::new(false),
            counters: Counters::default(),
            shutdown: AtomicBool::new(false),
            exit_code: Mutex::new(None),
        })
    }

    /// Total requests parsed (well-formed or not).
    pub fn requests(&self) -> u64 {
        self.counters.requests.load(Ordering::Relaxed)
    }

    /// Requests that joined another request's in-flight evaluation.
    pub fn coalesced(&self) -> u64 {
        self.counters.coalesced.load(Ordering::Relaxed)
    }

    /// Point queries answered through a batch round (explicit `batch`
    /// envelopes plus accumulation-window batches).
    pub fn batched(&self) -> u64 {
        self.counters.batched.load(Ordering::Relaxed)
    }

    /// Points freshly simulated across all queries.
    pub fn evaluated(&self) -> u64 {
        self.counters.evaluated.load(Ordering::Relaxed)
    }

    /// Error responses sent (including failed batch items).
    pub fn errors(&self) -> u64 {
        self.counters.errors.load(Ordering::Relaxed)
    }

    /// Requests whose deadline expired before (or during) evaluation.
    pub fn timeouts(&self) -> u64 {
        self.counters.timeouts.load(Ordering::Relaxed)
    }

    /// Requests, lines or connections refused by admission control.
    pub fn overloaded(&self) -> u64 {
        self.counters.overloaded.load(Ordering::Relaxed)
    }

    /// Cold evaluations refused while the save breaker was open.
    pub fn degraded_rejects(&self) -> u64 {
        self.counters.degraded_rejects.load(Ordering::Relaxed)
    }

    /// Whether the save circuit breaker is open (read-only mode).
    pub fn degraded(&self) -> bool {
        self.breaker_tripped.load(Ordering::SeqCst)
    }

    /// Number of memo lanes the service shards across.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    fn workers(&self) -> usize {
        match self.cfg.workers {
            0 => crate::dse::default_workers(),
            w => w,
        }
    }

    /// Admission/window shard of an app (FNV of the name). This is the
    /// cheap hash the queue-depth accounting and the accumulation
    /// windows bucket by; the *evaluation* lock set is the kernel-group
    /// route, which needs the program and is computed after admission.
    fn queue_shard(&self, app: &str) -> usize {
        let mut h = Fnv::new();
        h.str(app);
        (h.finish() % self.lanes.len() as u64) as usize
    }

    /// The kernel-group route of one context (cached after the first
    /// computation). The router mutex is never held while lane locks are
    /// taken, and routes are immutable once assigned.
    fn route_of(&self, program: &TaskProgram, key: &(String, u64, u64)) -> Route {
        if let Some(r) = lock_unpoisoned(&self.router).cached(key) {
            return r;
        }
        let mut fps: Vec<u64> = program
            .kernels
            .iter()
            .map(|k| kernel_fingerprint(&k.name, &k.profile))
            .collect();
        fps.sort_unstable();
        fps.dedup();
        lock_unpoisoned(&self.router).assign(key, &fps)
    }

    /// Acquire a route's lane locks in ascending index order (the global
    /// acquisition order — see [`Route`]).
    fn lock_route(&self, route: &Route) -> Vec<MutexGuard<'_, LaneState>> {
        route
            .locks
            .iter()
            .map(|&l| lock_unpoisoned(&self.lanes[l]))
            .collect()
    }

    /// Admission control for work requests (probes and memo maintenance
    /// bypass it). Returns an RAII token whose drop releases the
    /// capacity. The depth checks are check-then-increment over two
    /// atomics — deliberately approximate under races by at most the
    /// number of racing threads, which is bounded by the connection cap;
    /// the limits are load-shedding thresholds, not exact semaphores.
    fn admit(&self, env: &Envelope) -> Result<AdmitGuard<'_>, ServiceError> {
        if let Err(e) = faultpoint::hit("queue.admit") {
            self.counters.overloaded.fetch_add(1, Ordering::Relaxed);
            return Err(ServiceError::overloaded(format!("{e:#}"), retry_hint(0)));
        }
        if self.draining.load(Ordering::SeqCst) {
            self.counters.overloaded.fetch_add(1, Ordering::Relaxed);
            return Err(ServiceError::overloaded(
                "draining: the daemon is shutting down and admits no new work",
                1000,
            ));
        }
        let inflight = self.inflight_total.load(Ordering::SeqCst);
        if inflight >= self.cfg.max_inflight as u64 {
            self.counters.overloaded.fetch_add(1, Ordering::Relaxed);
            return Err(ServiceError::overloaded(
                format!(
                    "at capacity: {inflight} requests in flight (--max-inflight {})",
                    self.cfg.max_inflight
                ),
                retry_hint(inflight),
            ));
        }
        let shard = match &env.kind {
            RequestKind::Estimate(q) | RequestKind::Energy(q) => Some(self.queue_shard(&q.app)),
            RequestKind::Dse(q) => Some(self.queue_shard(&q.app)),
            _ => None,
        };
        if let Some(s) = shard {
            let depth = self.lane_depth[s].load(Ordering::SeqCst);
            if depth >= self.cfg.max_queue as u64 {
                self.counters.overloaded.fetch_add(1, Ordering::Relaxed);
                return Err(ServiceError::overloaded(
                    format!(
                        "lane queue full: {depth} requests deep on shard {s} (--max-queue {})",
                        self.cfg.max_queue
                    ),
                    retry_hint(depth),
                ));
            }
            self.lane_depth[s].fetch_add(1, Ordering::SeqCst);
        }
        self.inflight_total.fetch_add(1, Ordering::SeqCst);
        Ok(AdmitGuard { svc: self, shard })
    }

    /// Save the memo: serialize savers, quiesce every lane (all lane
    /// locks, ascending index order), close the shard journals (a
    /// successful save deletes the WAL files — keeping the handles would
    /// journal into deleted inodes), enforce the byte budget, save
    /// atomically, reopen the shard journals. On failure the daemon
    /// degrades instead of dying: the shard WALs still carry the delta,
    /// `save_failed` turns the final exit code non-zero, and
    /// `--breaker-threshold` consecutive failures open the read-only
    /// circuit breaker (closed again by the next successful save).
    ///
    /// Callers must not hold any lane lock or memo guard.
    fn save_all(&self) {
        let Some(path) = self.cfg.memo_path.clone() else {
            self.fresh_since_save.store(0, Ordering::Relaxed);
            return;
        };
        let _saver = lock_unpoisoned(&self.save_lock);
        let mut lanes: Vec<_> = self.lanes.iter().map(lock_unpoisoned).collect();
        for lane in &mut lanes {
            lane.journal = None;
        }
        if let Some(max) = self.cfg.max_bytes {
            let gc = write_unpoisoned(&self.memo).gc_bytes(max, self.cfg.app_floor);
            if gc.evicted_contexts > 0 || gc.evicted_kernels > 0 {
                eprintln!(
                    "serve: byte-budget gc evicted {} contexts ({} points), {} kernel entries",
                    gc.evicted_contexts, gc.evicted_points, gc.evicted_kernels
                );
            }
        }
        let saved = faultpoint::hit("save.breaker")
            .and_then(|()| read_unpoisoned(&self.memo).save(&path));
        match saved {
            Ok(()) => {
                self.fresh_since_save.store(0, Ordering::Relaxed);
                self.counters.saves.fetch_add(1, Ordering::Relaxed);
                self.save_fail_streak.store(0, Ordering::SeqCst);
                if self.breaker_tripped.swap(false, Ordering::SeqCst) {
                    eprintln!("serve: memo save recovered — breaker closed, leaving read-only mode");
                }
            }
            Err(e) => {
                self.save_failed.store(true, Ordering::Relaxed);
                let streak = self.save_fail_streak.fetch_add(1, Ordering::SeqCst) + 1;
                eprintln!(
                    "serve: memo save failed ({e:#}) — continuing degraded; \
                     the WAL retains unsaved rounds"
                );
                if streak >= u64::from(self.cfg.breaker_threshold.max(1))
                    && !self.breaker_tripped.swap(true, Ordering::SeqCst)
                {
                    eprintln!(
                        "serve: save breaker OPEN after {streak} consecutive failures — \
                         read-only mode (memo hits served, cold evaluations rejected)"
                    );
                }
            }
        }
        if self.shutdown.load(Ordering::SeqCst) {
            // Final save on shutdown: leave the journals closed so a clean
            // exit leaves no WAL siblings behind (opening a shard journal
            // creates its file eagerly).
            return;
        }
        for (shard, lane) in lanes.iter_mut().enumerate() {
            match SweepJournal::open_shard(&path, shard) {
                Ok(j) => lane.journal = Some(j),
                Err(e) => eprintln!(
                    "serve: journal reopen failed for lane {shard} ({e:#}); \
                     journaling disabled"
                ),
            }
        }
    }

    /// Save when the fresh-evaluation cadence is due. Callers must not
    /// hold any lane lock or memo guard.
    fn maybe_save(&self) {
        if self.cfg.memo_path.is_some()
            && self.fresh_since_save.load(Ordering::Relaxed) >= self.cfg.save_every.max(1)
        {
            self.save_all();
        }
    }

    /// Warmth counters + save cadence for one answered query.
    fn bump_warmth(&self, reply: &QueryReply) {
        self.counters
            .evaluated
            .fetch_add(reply.evaluated, Ordering::Relaxed);
        self.counters
            .l1_hits
            .fetch_add(reply.l1_hits, Ordering::Relaxed);
        self.counters
            .l2_hits
            .fetch_add(reply.l2_hits, Ordering::Relaxed);
        self.fresh_since_save
            .fetch_add(reply.evaluated, Ordering::Relaxed);
    }

    /// Answer one point item against its primary lane: the context
    /// analysis runs under the shared memo read lock (concurrent across
    /// lanes), the bookkeeping under a brief write lock. A panicking
    /// evaluation (fault injection) answers an error instead of tearing
    /// the lane down.
    fn point_item(
        &self,
        program: &TaskProgram,
        q: &PointQuery,
        energy: bool,
        pre: &PreEvaluated,
        lane: &mut LaneState,
    ) -> Result<QueryReply, ServiceError> {
        let cd = q.codesign();
        let space = space_for_codesign(&cd);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let ctx = {
                let memo = read_unpoisoned(&self.memo);
                SweepContext::for_space_warm(program, &self.board, &self.part, &space, &memo)
            };
            let mut memo = write_unpoisoned(&self.memo);
            point_query_prepared(
                &ctx,
                &space,
                &q.app,
                q.n,
                q.bs,
                &cd,
                energy,
                &mut memo,
                lane.journal.as_mut(),
                Some(pre),
            )
        }));
        match outcome {
            Ok(res) => res
                .map(|o| o.reply)
                .map_err(|e| ServiceError::usage(format!("{e:#}"))),
            Err(_) => Err(ServiceError::usage(
                "evaluation panicked (see stderr); request dropped",
            )),
        }
    }

    /// Answer the subset of `items` (by index) that belongs to one
    /// route, with its lane locks held and `lane` its primary lane.
    /// Phase 1 triages each item under the memo read lock — memo hits
    /// always proceed; cold items whose deadline already expired answer
    /// `TIMEOUT`, cold items under an open save breaker answer
    /// `DEGRADED` — then runs one chunk-synchronous worker-pool round
    /// per context over the surviving cold points. Phase 2 performs each
    /// item's bookkeeping and rendering in original arrival order, which
    /// reproduces the sequential responses byte for byte.
    fn run_lane_items(
        &self,
        lane: &mut LaneState,
        items: &[PointItem],
        programs: &[Option<Arc<TaskProgram>>],
        idxs: &[usize],
        out: &mut [Option<Result<QueryReply, ServiceError>>],
    ) {
        let mut groups: Vec<((String, u64, u64), Vec<usize>)> = Vec::new();
        for &i in idxs {
            let q = &items[i].query;
            let key = (q.app.clone(), q.n, q.bs);
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, members)) => members.push(i),
                None => groups.push((key, vec![i])),
            }
        }
        let workers = self.workers();
        let degraded = self.degraded();
        let mut pres: Vec<PreEvaluated> = Vec::with_capacity(groups.len());
        for (key, members) in &mut groups {
            let program = programs[members[0]]
                .as_ref()
                .expect("grouped items resolved their program");
            let fp = self.fingerprint(program, key);
            let mut live: Vec<usize> = Vec::with_capacity(members.len());
            let mut cds = Vec::with_capacity(members.len());
            {
                let memo = read_unpoisoned(&self.memo);
                for &i in members.iter() {
                    let it = &items[i];
                    let cd = it.query.codesign();
                    let cold = memo.lookup(fp, &codesign_key(&cd)).is_none();
                    if cold && it.deadline.is_some_and(|d| Instant::now() >= d) {
                        self.counters.timeouts.fetch_add(1, Ordering::Relaxed);
                        out[i] = Some(Err(ServiceError::timeout(
                            "deadline exceeded before evaluation (memo miss left cold)",
                        )));
                        continue;
                    }
                    if cold && degraded {
                        self.counters.degraded_rejects.fetch_add(1, Ordering::Relaxed);
                        out[i] = Some(Err(ServiceError::degraded(
                            "read-only degraded mode (save breaker open): cold \
                             evaluation rejected, memo hits still served",
                        )));
                        continue;
                    }
                    live.push(i);
                    cds.push(cd);
                }
                pres.push(pre_evaluate(
                    program,
                    &self.board,
                    &self.part,
                    fp,
                    &cds,
                    &memo,
                    workers,
                ));
            }
            *members = live;
        }
        let mut group_of: BTreeMap<usize, usize> = BTreeMap::new();
        for (g, (_, members)) in groups.iter().enumerate() {
            for &i in members {
                group_of.insert(i, g);
            }
        }
        for &i in idxs {
            if out[i].is_some() {
                // Triaged in phase 1 (timeout or degraded rejection).
                continue;
            }
            let it = &items[i];
            let program = programs[i].as_ref().expect("lane items have programs");
            let res = self.point_item(program, &it.query, it.energy, &pres[group_of[&i]], lane);
            if let Ok(reply) = &res {
                self.bump_warmth(reply);
            }
            out[i] = Some(res);
        }
    }

    /// Answer a slice of point queries with cross-request batch
    /// evaluation. Items group per kernel-group route; routes are
    /// processed in ascending lock-set order (cosmetic — routes either
    /// share all their serialization or none of it) with their lane
    /// locks held, and within a route each context's cold points run as
    /// one worker-pool round. Every response is byte-identical to
    /// handling the items one request at a time in the same order.
    fn run_point_items(&self, items: &[PointItem]) -> Vec<Result<QueryReply, ServiceError>> {
        let mut out: Vec<Option<Result<QueryReply, ServiceError>>> =
            items.iter().map(|_| None).collect();
        let mut programs: Vec<Option<Arc<TaskProgram>>> = Vec::with_capacity(items.len());
        for (i, it) in items.iter().enumerate() {
            match self.program(&it.query.app, it.query.n, it.query.bs) {
                Ok(p) => programs.push(Some(p)),
                Err(e) => {
                    out[i] = Some(Err(e));
                    programs.push(None);
                }
            }
        }
        let mut by_route: Vec<(Route, Vec<usize>)> = Vec::new();
        for (i, it) in items.iter().enumerate() {
            let Some(program) = &programs[i] else { continue };
            let key = (it.query.app.clone(), it.query.n, it.query.bs);
            let route = self.route_of(program, &key);
            match by_route.iter_mut().find(|(r, _)| *r == route) {
                Some((_, members)) => members.push(i),
                None => by_route.push((route, vec![i])),
            }
        }
        by_route.sort_by(|a, b| (&a.0.locks, a.0.primary).cmp(&(&b.0.locks, b.0.primary)));
        for (route, idxs) in &by_route {
            let mut guards = self.lock_route(route);
            let p = route
                .locks
                .iter()
                .position(|&l| l == route.primary)
                .expect("primary lane is always in the lock set");
            self.run_lane_items(&mut guards[p], items, &programs, idxs, &mut out);
        }
        self.maybe_save();
        out.into_iter()
            .map(|r| r.expect("every item answered"))
            .collect()
    }

    /// Answer a `batch` envelope: parse-failed items answer their error
    /// in place, valid items run through the batch evaluator (inheriting
    /// the envelope's deadline), and every item's response object is
    /// exactly what the standalone request line would have produced
    /// (same [`ok_obj`]/[`err_obj`] builders, same replies).
    fn run_batch(&self, batch: &[BatchItem], deadline: Option<Instant>) -> QueryReply {
        let mut queries: Vec<PointItem> = Vec::new();
        let mut slots: Vec<Result<usize, ServiceError>> = Vec::with_capacity(batch.len());
        for item in batch {
            match &item.query {
                Ok(q) => {
                    slots.push(Ok(queries.len()));
                    queries.push(PointItem {
                        query: q.clone(),
                        energy: item.energy,
                        deadline,
                    });
                }
                Err(e) => slots.push(Err(e.clone())),
            }
        }
        self.counters
            .batched
            .fetch_add(queries.len() as u64, Ordering::Relaxed);
        let replies = self.run_point_items(&queries);
        let mut objs: Vec<Value> = Vec::with_capacity(batch.len());
        let (mut l1, mut l2, mut evaluated, mut failed) = (0u64, 0u64, 0u64, 0u64);
        for (item, slot) in batch.iter().zip(&slots) {
            let req = if item.energy { "energy" } else { "estimate" };
            let obj = match slot {
                Ok(j) => match &replies[*j] {
                    Ok(reply) => {
                        l1 += reply.l1_hits;
                        l2 += reply.l2_hits;
                        evaluated += reply.evaluated;
                        ok_obj(&item.id, req, reply)
                    }
                    Err(e) => {
                        failed += 1;
                        err_obj(&item.id, e)
                    }
                },
                Err(e) => {
                    failed += 1;
                    err_obj(&item.id, e)
                }
            };
            objs.push(obj);
        }
        self.counters.errors.fetch_add(failed, Ordering::Relaxed);
        QueryReply {
            text: format!(
                "batch: {} items ({} evaluated, {} l2 hits, {} failed)\n",
                batch.len(),
                evaluated,
                l2,
                failed
            ),
            l1_hits: l1,
            l2_hits: l2,
            evaluated,
            extra: vec![
                ("items".into(), Value::Arr(objs)),
                ("items_total".into(), (batch.len() as u64).into()),
                ("items_failed".into(), failed.into()),
            ],
        }
    }

    fn program(&self, app: &str, n: u64, bs: u64) -> Result<Arc<TaskProgram>, ServiceError> {
        let key = (app.to_string(), n, bs);
        if let Some(p) = lock_unpoisoned(&self.programs).get(&key) {
            return Ok(Arc::clone(p));
        }
        // Built outside the cache lock: program construction is pure.
        let program = crate::apps::build_app_program(app, n, bs, &self.board)
            .map_err(|e| ServiceError::usage(format!("{e:#}")))?;
        let program = Arc::new(program);
        lock_unpoisoned(&self.programs)
            .entry(key)
            .or_insert_with(|| Arc::clone(&program));
        Ok(program)
    }

    /// Context fingerprint of one (app, n, bs) context, cached. The
    /// fingerprint covers program/board/part only — never the swept
    /// space — so one probe analysis computes it and every later request
    /// (the hot path) reuses it without touching the program again.
    fn fingerprint(&self, program: &TaskProgram, key: &(String, u64, u64)) -> u64 {
        if let Some(fp) = lock_unpoisoned(&self.fingerprints).get(key) {
            return *fp;
        }
        let ctx = SweepContext::new(program, &self.board, self.part.clone());
        let fp = context_fingerprint(&ctx);
        lock_unpoisoned(&self.fingerprints).insert(key.clone(), fp);
        fp
    }

    fn run_query(
        &self,
        env: &Envelope,
        deadline: Option<Instant>,
    ) -> Result<QueryReply, ServiceError> {
        match &env.kind {
            RequestKind::Estimate(q) | RequestKind::Energy(q) => {
                let energy = matches!(env.kind, RequestKind::Energy(_));
                let mut replies = self.run_point_items(&[PointItem {
                    query: q.clone(),
                    energy,
                    deadline,
                }]);
                replies.pop().expect("one item, one reply")
            }
            RequestKind::Batch(items) => Ok(self.run_batch(items, deadline)),
            RequestKind::Dse(q) => {
                if self.degraded() {
                    self.counters.degraded_rejects.fetch_add(1, Ordering::Relaxed);
                    return Err(ServiceError::degraded(
                        "read-only degraded mode (save breaker open): dse sweeps \
                         evaluate cold points and are rejected",
                    ));
                }
                let program = self.program(&q.app, q.n, q.bs)?;
                let workers = self.workers();
                let key = (q.app.clone(), q.n, q.bs);
                let route = self.route_of(&program, &key);
                let reply = {
                    let mut guards = self.lock_route(&route);
                    let p = route
                        .locks
                        .iter()
                        .position(|&l| l == route.primary)
                        .expect("primary lane is always in the lock set");
                    // Sweeps mutate the memo throughout (bound seeding +
                    // recording), so they run under the write lock; lanes
                    // still overlap on their point-query evaluations.
                    let mut memo = write_unpoisoned(&self.memo);
                    let res = match deadline {
                        Some(d) => {
                            let cancel = move || Instant::now() >= d;
                            dse_query(
                                &program,
                                &self.board,
                                &self.part,
                                q,
                                workers,
                                &mut memo,
                                guards[p].journal.as_mut(),
                                Some(&cancel),
                            )
                        }
                        None => dse_query(
                            &program,
                            &self.board,
                            &self.part,
                            q,
                            workers,
                            &mut memo,
                            guards[p].journal.as_mut(),
                            None,
                        ),
                    };
                    res.map_err(|e| {
                        if e.downcast_ref::<SweepCancelled>().is_some() {
                            self.counters.timeouts.fetch_add(1, Ordering::Relaxed);
                            ServiceError::timeout(
                                "deadline exceeded: sweep cancelled at a round \
                                 barrier (memo untouched)",
                            )
                        } else {
                            ServiceError::usage(format!("{e:#}"))
                        }
                    })?
                };
                self.bump_warmth(&reply);
                self.maybe_save();
                Ok(reply)
            }
            RequestKind::MemoStats => {
                let stats = read_unpoisoned(&self.memo).stats();
                let degraded = self.save_failed.load(Ordering::Relaxed);
                let saves = self.counters.saves.load(Ordering::Relaxed);
                let mut text = stats.render();
                text.push_str(&format!(
                    "service: {} requests, {} coalesced, {} batched, {} evaluated, \
                     {} errors, {} saves, {} lanes{}\n",
                    self.requests(),
                    self.coalesced(),
                    self.batched(),
                    self.evaluated(),
                    self.errors(),
                    saves,
                    self.lanes.len(),
                    if degraded { ", DEGRADED" } else { "" },
                ));
                let mut extra = crate::metrics::export::service_stats_fields(
                    &stats,
                    self.requests(),
                    self.coalesced(),
                    self.batched(),
                    self.evaluated(),
                    self.errors(),
                    saves,
                    self.lanes.len() as u64,
                    degraded,
                );
                extra.push(("timeouts".into(), self.timeouts().into()));
                extra.push(("overloaded".into(), self.overloaded().into()));
                extra.push(("degraded_rejects".into(), self.degraded_rejects().into()));
                Ok(QueryReply {
                    text,
                    l1_hits: self.counters.l1_hits.load(Ordering::Relaxed),
                    l2_hits: self.counters.l2_hits.load(Ordering::Relaxed),
                    evaluated: 0,
                    extra,
                })
            }
            RequestKind::MemoGc(spec) => {
                if self.degraded() {
                    self.counters.degraded_rejects.fetch_add(1, Ordering::Relaxed);
                    return Err(ServiceError::degraded(
                        "read-only degraded mode (save breaker open): gc rewrites \
                         the memo file and is rejected",
                    ));
                }
                let (report, n_contexts, n_points, n_kernels) = {
                    let mut memo = write_unpoisoned(&self.memo);
                    let report = match spec.max_bytes {
                        Some(max) => memo.gc_bytes(max, spec.app_floor),
                        None => memo.gc(spec.keep_contexts, spec.keep_points, spec.keep_kernels),
                    };
                    (
                        report,
                        memo.n_contexts(),
                        memo.n_points(),
                        memo.n_kernel_entries(),
                    )
                };
                // Persist immediately: the WALs may reference evicted
                // contexts, so the post-gc truth must reach disk before
                // any replay could resurrect them.
                self.save_all();
                let text = format!(
                    "gc: evicted {} contexts ({} points) and {} kernel entries \
                     ({} contexts, {} points, {} kernel entries retained, all bit-exact)\n",
                    report.evicted_contexts,
                    report.evicted_points,
                    report.evicted_kernels,
                    n_contexts,
                    n_points,
                    n_kernels,
                );
                Ok(QueryReply {
                    text,
                    extra: vec![
                        (
                            "evicted_contexts".into(),
                            (report.evicted_contexts as u64).into(),
                        ),
                        (
                            "evicted_points".into(),
                            (report.evicted_points as u64).into(),
                        ),
                        (
                            "evicted_kernels".into(),
                            (report.evicted_kernels as u64).into(),
                        ),
                    ],
                    ..QueryReply::default()
                })
            }
            RequestKind::Health => {
                let degraded = self.degraded();
                let draining = self.draining.load(Ordering::SeqCst);
                let ready = !degraded && !draining;
                let state = if draining {
                    "draining"
                } else if degraded {
                    "degraded"
                } else {
                    "ready"
                };
                let inflight = self.inflight_total.load(Ordering::SeqCst);
                let conns = self.conns.load(Ordering::SeqCst);
                let memo_bytes = read_unpoisoned(&self.memo).stats().bytes as u64;
                let depths: Vec<Value> = self
                    .lane_depth
                    .iter()
                    .map(|d| Value::Int(d.load(Ordering::SeqCst) as i64))
                    .collect();
                let text = format!(
                    "health: {state} ({} lanes, {inflight} in flight, {conns} conns, \
                     memo {memo_bytes} bytes)\n",
                    self.lanes.len(),
                );
                Ok(QueryReply {
                    text,
                    extra: vec![
                        ("ready".into(), Value::Bool(ready)),
                        ("degraded".into(), Value::Bool(degraded)),
                        ("draining".into(), Value::Bool(draining)),
                        ("lanes".into(), (self.lanes.len() as u64).into()),
                        ("lane_depths".into(), Value::Arr(depths)),
                        ("inflight".into(), inflight.into()),
                        ("conns".into(), conns.into()),
                        ("memo_bytes".into(), memo_bytes.into()),
                        ("timeouts".into(), self.timeouts().into()),
                        ("overloaded".into(), self.overloaded().into()),
                        ("degraded_rejects".into(), self.degraded_rejects().into()),
                        ("max_queue".into(), (self.cfg.max_queue as u64).into()),
                        ("max_inflight".into(), (self.cfg.max_inflight as u64).into()),
                        ("max_conns".into(), (self.cfg.max_conns as u64).into()),
                        (
                            "max_line_bytes".into(),
                            (self.cfg.max_line_bytes as u64).into(),
                        ),
                        (
                            "default_deadline_ms".into(),
                            match self.cfg.default_deadline_ms {
                                Some(ms) => ms.into(),
                                None => Value::Null,
                            },
                        ),
                        (
                            "breaker_threshold".into(),
                            u64::from(self.cfg.breaker_threshold).into(),
                        ),
                    ],
                    ..QueryReply::default()
                })
            }
            RequestKind::Ping => Ok(QueryReply {
                text: "pong\n".into(),
                ..QueryReply::default()
            }),
            RequestKind::Shutdown => unreachable!("shutdown handled in handle_line"),
        }
    }

    /// Run one coalescable query. The leader (first arrival for the key)
    /// evaluates under panic isolation and fans the result out; followers
    /// wait and clone it, so all coalesced responses are bitwise
    /// identical and exactly one evaluation happened. Only deadline-free
    /// requests enter (see [`Service::handle_line`]), so a leader's
    /// reply is always valid for its followers.
    fn coalesced_query(&self, key: String, env: &Envelope) -> Result<QueryReply, ServiceError> {
        let cell = {
            let mut inflight = lock_unpoisoned(&self.inflight);
            match inflight.get(&key) {
                Some(cell) => {
                    self.counters.coalesced.fetch_add(1, Ordering::Relaxed);
                    let cell = Arc::clone(cell);
                    drop(inflight);
                    let mut slot = lock_unpoisoned(&cell.slot);
                    while slot.is_none() {
                        slot = cell
                            .done
                            .wait(slot)
                            .unwrap_or_else(|p| p.into_inner());
                    }
                    return slot.clone().expect("slot published before notify");
                }
                None => {
                    let cell = Arc::new(InFlight::new());
                    inflight.insert(key.clone(), Arc::clone(&cell));
                    cell
                }
            }
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.run_query(env, None)
        }))
        .unwrap_or_else(|_| {
            Err(ServiceError::usage(
                "evaluation panicked (see stderr); request dropped",
            ))
        });
        lock_unpoisoned(&self.inflight).remove(&key);
        *lock_unpoisoned(&cell.slot) = Some(result.clone());
        cell.done.notify_all();
        result
    }

    /// The window-batched point path (`--batch-window-ms > 0`): the first
    /// arrival of a shard becomes the window leader, sleeps out the
    /// accumulation window while later arrivals enqueue, then runs the
    /// whole window as one batch round and fans the per-request replies
    /// back out — each byte-identical to handling the same arrivals
    /// sequentially (including per-item deadline triage). Windowed
    /// queries skip the coalescing table: within a batch, a duplicate
    /// item is a level-2 hit of its predecessor, which is the sequential
    /// answer.
    fn windowed_point(
        &self,
        q: &PointQuery,
        energy: bool,
        deadline: Option<Instant>,
    ) -> Result<QueryReply, ServiceError> {
        let shard = self.queue_shard(&q.app);
        let cell = Arc::new(InFlight::new());
        let leader = {
            let mut w = lock_unpoisoned(&self.windows[shard]);
            w.pending.push(PendingPoint {
                query: q.clone(),
                energy,
                deadline,
                cell: Arc::clone(&cell),
            });
            !std::mem::replace(&mut w.collecting, true)
        };
        if leader {
            std::thread::sleep(Duration::from_millis(self.cfg.batch_window_ms));
            let pending = {
                let mut w = lock_unpoisoned(&self.windows[shard]);
                w.collecting = false;
                std::mem::take(&mut w.pending)
            };
            let items: Vec<PointItem> = pending
                .iter()
                .map(|p| PointItem {
                    query: p.query.clone(),
                    energy: p.energy,
                    deadline: p.deadline,
                })
                .collect();
            self.counters
                .batched
                .fetch_add(items.len() as u64, Ordering::Relaxed);
            let replies =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    self.run_point_items(&items)
                }))
                .unwrap_or_else(|_| {
                    items
                        .iter()
                        .map(|_| {
                            Err(ServiceError::usage(
                                "evaluation panicked (see stderr); request dropped",
                            ))
                        })
                        .collect()
                });
            for (p, reply) in pending.iter().zip(replies) {
                *lock_unpoisoned(&p.cell.slot) = Some(reply);
                p.cell.done.notify_all();
            }
        }
        let mut slot = lock_unpoisoned(&cell.slot);
        loop {
            match slot.take() {
                Some(res) => return res,
                None => slot = cell.done.wait(slot).unwrap_or_else(|p| p.into_inner()),
            }
        }
    }

    /// One `OVERLOADED` response line for a request line that exceeded
    /// `--max-line-bytes` (the reader consumed it without buffering it,
    /// so the stream stays in sync and the next line parses normally).
    fn oversized_line(&self, total: usize) -> String {
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        self.counters.errors.fetch_add(1, Ordering::Relaxed);
        self.counters.overloaded.fetch_add(1, Ordering::Relaxed);
        err_line(
            &Value::Null,
            &ServiceError::overloaded(
                format!(
                    "request line of {total} bytes exceeds --max-line-bytes {}",
                    self.cfg.max_line_bytes
                ),
                100,
            ),
        )
    }

    /// Process one NDJSON line. Returns the response line (None for
    /// blank input) and whether the daemon should shut down. Work
    /// requests (`estimate`/`energy`/`batch`/`dse`) pass admission
    /// control first and hold their admission token until answered;
    /// probes (`ping`/`health`) and memo maintenance always bypass it so
    /// an overloaded daemon stays observable.
    pub fn handle_line(&self, line: &str) -> (Option<String>, bool) {
        let line = line.trim();
        if line.is_empty() {
            return (None, false);
        }
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        let env = match parse_request(line) {
            Ok(env) => env,
            Err((id, err)) => {
                self.counters.errors.fetch_add(1, Ordering::Relaxed);
                return (Some(err_line(&id, &err)), false);
            }
        };
        if matches!(env.kind, RequestKind::Shutdown) {
            let code = self.finalize();
            let reply = QueryReply {
                text: if code == 0 {
                    "shutdown: memo saved\n".into()
                } else {
                    "shutdown: DEGRADED (memo save failed; WAL retained)\n".into()
                },
                extra: vec![("exit_code".into(), Value::Int(code as i64))],
                ..QueryReply::default()
            };
            return (Some(ok_line(&env.id, env.req_name(), &reply)), true);
        }
        let _admit = match &env.kind {
            RequestKind::Estimate(_)
            | RequestKind::Energy(_)
            | RequestKind::Batch(_)
            | RequestKind::Dse(_) => match self.admit(&env) {
                Ok(guard) => Some(guard),
                Err(err) => {
                    self.counters.errors.fetch_add(1, Ordering::Relaxed);
                    return (Some(err_line(&env.id, &err)), false);
                }
            },
            _ => None,
        };
        let deadline = env
            .deadline_ms
            .or(self.cfg.default_deadline_ms)
            .map(|ms| Instant::now() + Duration::from_millis(ms));
        let result = match &env.kind {
            RequestKind::Estimate(q) | RequestKind::Energy(q)
                if self.cfg.batch_window_ms > 0 =>
            {
                self.windowed_point(q, matches!(env.kind, RequestKind::Energy(_)), deadline)
            }
            _ => match env.coalesce_key() {
                // A deadlined request must not join (or lead) a shared
                // evaluation — followers would inherit the wrong budget.
                Some(key) if deadline.is_none() => self.coalesced_query(key, &env),
                _ => self.run_query(&env, deadline),
            },
        };
        match result {
            Ok(reply) => (Some(ok_line(&env.id, env.req_name(), &reply)), false),
            Err(err) => {
                self.counters.errors.fetch_add(1, Ordering::Relaxed);
                (Some(err_line(&env.id, &err)), false)
            }
        }
    }

    /// Final save + exit code; idempotent (a TCP shutdown racing stdin
    /// EOF performs one save). `0` clean, `1` when any save failed.
    pub fn finalize(&self) -> i32 {
        let mut code_slot = lock_unpoisoned(&self.exit_code);
        if let Some(code) = *code_slot {
            return code;
        }
        self.shutdown.store(true, Ordering::SeqCst);
        self.save_all();
        let code = i32::from(self.save_failed.load(Ordering::Relaxed));
        *code_slot = Some(code);
        code
    }

    /// Whether a shutdown request has been processed.
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

/// Outcome of one bounded line read.
enum LineRead {
    /// Clean end of stream (no bytes pending).
    Eof,
    /// A complete line (or an unterminated final line) is in the buffer.
    Line,
    /// The line exceeded the byte limit; it was consumed but never
    /// buffered whole. Carries the total line length seen.
    Oversized(usize),
}

/// Read one `\n`-terminated line of at most `max` bytes into `buf`.
/// Longer lines are drained from the stream (so the connection stays in
/// sync for the next request) while the buffer stays bounded at `max` —
/// a client cannot make the daemon allocate an unbounded line.
fn read_bounded_line<R: BufRead>(
    reader: &mut R,
    max: usize,
    buf: &mut Vec<u8>,
) -> std::io::Result<LineRead> {
    buf.clear();
    let mut total = 0usize;
    let mut over = false;
    loop {
        let (consumed, found_nl) = {
            let chunk = reader.fill_buf()?;
            if chunk.is_empty() {
                return Ok(if over {
                    LineRead::Oversized(total)
                } else if total == 0 {
                    LineRead::Eof
                } else {
                    LineRead::Line
                });
            }
            let (part, found_nl) = match chunk.iter().position(|&b| b == b'\n') {
                Some(p) => (&chunk[..p], true),
                None => (chunk, false),
            };
            total += part.len();
            if !over && total > max {
                over = true;
                buf.clear();
            }
            if !over {
                buf.extend_from_slice(part);
            }
            (part.len() + usize::from(found_nl), found_nl)
        };
        reader.consume(consumed);
        if found_nl {
            return Ok(if over {
                LineRead::Oversized(total)
            } else {
                LineRead::Line
            });
        }
    }
}

/// One NDJSON connection loop over any buffered reader/writer pair.
/// Returns `true` when the peer asked for shutdown. A read error, write
/// error or injected `conn.read`/`conn.write` fault ends the connection
/// exactly like a client disconnect: requests not yet admitted die
/// unanswered, the request in flight (if any) completed before its
/// response write failed, and the shared service state stays consistent.
fn serve_connection<R: BufRead, W: Write>(svc: &Service, mut reader: R, mut writer: W) -> bool {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if faultpoint::hit("conn.read").is_err() {
            return false;
        }
        let read = match read_bounded_line(&mut reader, svc.cfg.max_line_bytes, &mut buf) {
            Ok(r) => r,
            Err(_) => return false,
        };
        let (response, quit) = match read {
            LineRead::Eof => return false,
            LineRead::Oversized(total) => (Some(svc.oversized_line(total)), false),
            LineRead::Line => svc.handle_line(&String::from_utf8_lossy(&buf)),
        };
        if let Some(r) = response {
            let wrote = faultpoint::hit("conn.write")
                .map_err(|e| std::io::Error::other(format!("{e:#}")))
                .and_then(|()| writeln!(writer, "{r}"))
                .and_then(|()| writer.flush());
            if wrote.is_err() {
                return false;
            }
        }
        if quit {
            return true;
        }
        if svc.is_shutdown() {
            return false;
        }
    }
}

/// Decrements the live-connection count when a TCP connection thread
/// ends, however it ends.
struct ConnGuard(Arc<Service>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.conns.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Accept loop of the TCP transport: non-blocking accept polled against
/// the shutdown flag, one thread per connection, `--max-conns` enforced
/// at accept (excess connections get one `OVERLOADED` line and are
/// closed without a thread). A `shutdown` request on a TCP connection
/// finalizes and exits the whole process (stdin cannot be unblocked
/// portably).
fn serve_tcp(svc: Arc<Service>, listener: std::net::TcpListener) {
    listener
        .set_nonblocking(true)
        .expect("set_nonblocking on listener");
    loop {
        if svc.is_shutdown() {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                if svc.conns.load(Ordering::SeqCst) >= svc.cfg.max_conns as u64 {
                    svc.counters.overloaded.fetch_add(1, Ordering::Relaxed);
                    let err = ServiceError::overloaded(
                        format!("connection limit reached (--max-conns {})", svc.cfg.max_conns),
                        1000,
                    );
                    let _ = writeln!(&mut &stream, "{}", err_line(&Value::Null, &err));
                    continue;
                }
                svc.conns.fetch_add(1, Ordering::SeqCst);
                if svc.cfg.write_timeout_ms > 0 {
                    // A peer that stops reading blocks our writes; the
                    // timeout turns that into a write error, which ends
                    // the connection like a disconnect.
                    let _ = stream.set_write_timeout(Some(Duration::from_millis(
                        svc.cfg.write_timeout_ms,
                    )));
                }
                let svc = Arc::clone(&svc);
                std::thread::spawn(move || {
                    let _guard = ConnGuard(Arc::clone(&svc));
                    let reader = std::io::BufReader::new(match stream.try_clone() {
                        Ok(s) => s,
                        Err(_) => return,
                    });
                    if serve_connection(&svc, reader, &stream) {
                        let code = svc.finalize();
                        std::process::exit(code);
                    }
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(_) => return,
        }
    }
}

/// SIGTERM latch. The handler is a single atomic store (async-signal-
/// safe); the drain monitor thread polls [`term::pending`] and performs
/// the actual drain outside signal context.
#[cfg(unix)]
mod term {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);
    const SIGTERM: i32 = 15;

    extern "C" fn on_term(_sig: i32) {
        TERM.store(true, Ordering::SeqCst);
    }

    /// Install the SIGTERM handler (libc `signal`, declared here to keep
    /// the build dependency-free).
    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        unsafe {
            signal(SIGTERM, on_term as extern "C" fn(i32) as usize);
        }
    }

    pub fn pending() -> bool {
        TERM.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod term {
    pub fn install() {}

    pub fn pending() -> bool {
        false
    }
}

/// Run the daemon to completion on the current thread: bind the optional
/// TCP listener, then serve stdin/stdout until a `shutdown` request or
/// EOF. Returns the process exit code.
pub fn serve(board: BoardConfig, cfg: ServeConfig) -> anyhow::Result<i32> {
    run(Service::new(board, cfg)?)
}

/// [`serve`] with a prebuilt service — lets callers distinguish
/// construction failures (memo load) from runtime ones (bind). Installs
/// the SIGTERM drain: on the first SIGTERM the daemon stops admitting
/// work, waits for the in-flight requests to finish, saves the memo and
/// exits with the usual clean/degraded code.
pub fn run(svc: Service) -> anyhow::Result<i32> {
    let listen = svc.cfg.listen.clone();
    if svc.lanes() > 1 || svc.cfg.batch_window_ms > 0 {
        eprintln!(
            "serve: {} lanes, batch window {} ms",
            svc.lanes(),
            svc.cfg.batch_window_ms
        );
    }
    let svc = Arc::new(svc);
    term::install();
    {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || loop {
            if svc.is_shutdown() {
                return;
            }
            if term::pending() {
                svc.draining.store(true, Ordering::SeqCst);
                eprintln!(
                    "serve: SIGTERM — draining ({} in flight)",
                    svc.inflight_total.load(Ordering::SeqCst)
                );
                while svc.inflight_total.load(Ordering::SeqCst) > 0 {
                    std::thread::sleep(Duration::from_millis(10));
                }
                let code = svc.finalize();
                if code == 0 {
                    eprintln!("serve: drained and saved (SIGTERM)");
                } else {
                    eprintln!("serve: drained, DEGRADED (SIGTERM; memo save failed, WAL retained)");
                }
                std::process::exit(code);
            }
            std::thread::sleep(Duration::from_millis(25));
        });
    }
    if let Some(addr) = listen {
        let listener = std::net::TcpListener::bind(&addr)
            .map_err(|e| anyhow::anyhow!("serve: cannot listen on {addr}: {e}"))?;
        // Tests and CI parse this line to discover an OS-assigned port
        // (always bind port 0 in scripts — fixed ports collide).
        eprintln!("serve: listening on {}", listener.local_addr()?);
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || serve_tcp(svc, listener));
    }
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    if serve_connection(&svc, stdin.lock(), stdout.lock()) {
        return Ok(svc.finalize());
    }
    // stdin closed without a shutdown request: if a TCP shutdown already
    // ran, report its code; otherwise treat EOF as a graceful shutdown.
    Ok(svc.finalize())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn service() -> Service {
        Service::new(BoardConfig::zynq706(), ServeConfig::default()).unwrap()
    }

    fn service_with(lanes: usize, batch_window_ms: u64) -> Service {
        Service::new(
            BoardConfig::zynq706(),
            ServeConfig {
                lanes,
                batch_window_ms,
                ..ServeConfig::default()
            },
        )
        .unwrap()
    }

    fn get_u64(v: &crate::util::json::Value, key: &str) -> u64 {
        v.get(key).and_then(|x| x.as_u64()).unwrap()
    }

    #[test]
    fn estimate_then_repeat_hits_the_memo_with_identical_response() {
        let svc = service();
        let req = r#"{"id":1,"req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]}"#;
        let (first, quit) = svc.handle_line(req);
        assert!(!quit);
        let first = first.unwrap();
        let (second, _) = svc.handle_line(req);
        let second = second.unwrap();
        assert_eq!(first, second, "hit must be bitwise identical to the evaluation");
        let v = parse(&second).unwrap();
        assert_eq!(get_u64(&v, "evaluated"), 0);
        assert_eq!(get_u64(&v, "l2_hits"), 1);
        assert_eq!(svc.evaluated(), 1, "one evaluation total across both");
    }

    #[test]
    fn malformed_lines_answer_with_the_cli_error_taxonomy_and_keep_serving() {
        let svc = service();
        let (bad, quit) = svc.handle_line("this is not json");
        assert!(!quit);
        let bad = parse(&bad.unwrap()).unwrap();
        assert_eq!(bad.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(get_u64(&bad, "code"), 1);
        let (unknown, _) = svc.handle_line(r#"{"id":7,"req":"frobnicate"}"#);
        let unknown = parse(&unknown.unwrap()).unwrap();
        assert_eq!(get_u64(&unknown, "code"), 2);
        assert_eq!(
            unknown.get("id").and_then(|v| v.as_i64()),
            Some(7),
            "errors still correlate by id"
        );
        let (ping, _) = svc.handle_line(r#"{"req":"ping"}"#);
        let ping = parse(&ping.unwrap()).unwrap();
        assert_eq!(ping.get("ok").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(svc.errors(), 2);
    }

    #[test]
    fn stats_reports_cumulative_counters_and_gc_runs_in_place() {
        let svc = service();
        svc.handle_line(r#"{"req":"estimate","app":"matmul","n":128,"accel":["mxm64:U8"]}"#);
        svc.handle_line(r#"{"req":"estimate","app":"matmul","n":128,"accel":["mxm64:U8"]}"#);
        let (stats, _) = svc.handle_line(r#"{"req":"memo","action":"stats"}"#);
        let stats = parse(&stats.unwrap()).unwrap();
        assert_eq!(get_u64(&stats, "contexts"), 1);
        assert_eq!(get_u64(&stats, "total_evaluated"), 1);
        assert_eq!(get_u64(&stats, "requests"), 3);
        assert_eq!(get_u64(&stats, "lanes"), 1);
        assert_eq!(get_u64(&stats, "timeouts"), 0);
        assert_eq!(get_u64(&stats, "overloaded"), 0);
        assert_eq!(get_u64(&stats, "degraded_rejects"), 0);
        let (gc, _) = svc.handle_line(r#"{"req":"memo","action":"gc","max_bytes":0,"app_floor":1}"#);
        let gc = parse(&gc.unwrap()).unwrap();
        assert_eq!(
            get_u64(&gc, "evicted_contexts"),
            0,
            "the per-app floor protects the only context even under a zero budget"
        );
    }

    #[test]
    fn shutdown_line_finalizes_and_requests_exit() {
        let svc = service();
        let (resp, quit) = svc.handle_line(r#"{"id":9,"req":"shutdown"}"#);
        assert!(quit);
        let v = parse(&resp.unwrap()).unwrap();
        assert_eq!(v.get("ok").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(v.get("exit_code").and_then(|x| x.as_i64()), Some(0));
        assert!(svc.is_shutdown());
        assert_eq!(svc.finalize(), 0, "finalize is idempotent");
    }

    #[test]
    fn batch_envelope_items_equal_the_standalone_response_lines() {
        // Reference: two standalone requests on a fresh service.
        let seq = service();
        let est = r#"{"id":"a","req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]}"#;
        let en = r#"{"id":"b","req":"energy","app":"matmul","n":256,"accel":["mxm64:U32"]}"#;
        let (est_line, _) = seq.handle_line(est);
        let (en_line, _) = seq.handle_line(en);
        // Batch: the same two queries in one envelope on a fresh service.
        let svc = service_with(4, 0);
        let (resp, _) = svc.handle_line(
            r#"{"id":8,"req":"batch","items":[
                {"id":"a","req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]},
                {"id":"b","req":"energy","app":"matmul","n":256,"accel":["mxm64:U32"]},
                {"id":"c","req":"estimate"}]}"#,
        );
        let v = parse(&resp.unwrap()).unwrap();
        assert_eq!(v.get("ok").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(get_u64(&v, "evaluated"), 1, "energy reuses the estimate's point");
        assert_eq!(get_u64(&v, "items_failed"), 1);
        let Some(Value::Arr(items)) = v.get("items") else {
            panic!("batch response carries items");
        };
        assert_eq!(items.len(), 3);
        assert_eq!(items[0].to_json(), parse(&est_line.unwrap()).unwrap().to_json());
        assert_eq!(items[1].to_json(), parse(&en_line.unwrap()).unwrap().to_json());
        assert_eq!(items[2].get("ok").and_then(|x| x.as_bool()), Some(false));
        assert_eq!(svc.batched(), 2, "only valid items enter the batch round");
        assert_eq!(svc.errors(), 1, "the failed item counts as an error");
    }

    #[test]
    fn multi_lane_service_shards_apps_and_answers_like_single_lane() {
        let multi = service_with(4, 0);
        let single = service();
        assert_eq!(multi.lanes(), 4);
        let reqs = [
            r#"{"req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]}"#,
            r#"{"req":"estimate","app":"lu","n":256,"accel":["trsm_row:U16"]}"#,
            r#"{"req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]}"#,
        ];
        for req in reqs {
            let (a, _) = multi.handle_line(req);
            let (b, _) = single.handle_line(req);
            assert_eq!(a, b, "lane count must never change a response byte");
        }
        assert_eq!(multi.evaluated(), single.evaluated());
    }

    #[test]
    fn windowed_point_queries_batch_and_answer_identically() {
        let windowed = service_with(2, 5);
        let plain = service();
        let req = r#"{"id":1,"req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]}"#;
        let (a, _) = windowed.handle_line(req);
        let (b, _) = plain.handle_line(req);
        assert_eq!(a, b, "the window changes latency, never bytes");
        assert_eq!(windowed.batched(), 1);
    }

    #[test]
    fn deadline_zero_times_out_cold_points_but_serves_memo_hits() {
        let svc = service();
        let warm = r#"{"id":1,"req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]}"#;
        svc.handle_line(warm).0.unwrap();
        let (plain, _) = svc.handle_line(warm);
        let with_deadline =
            r#"{"id":1,"req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"],"deadline_ms":0}"#;
        let (hit, _) = svc.handle_line(with_deadline);
        assert_eq!(
            plain.unwrap(),
            hit.unwrap(),
            "an expired deadline never blocks a memo hit, and bytes match the plain hit"
        );
        let cold =
            r#"{"id":2,"req":"estimate","app":"matmul","n":256,"accel":["mxm64:U8"],"deadline_ms":0}"#;
        let (t, _) = svc.handle_line(cold);
        let t = parse(&t.unwrap()).unwrap();
        assert_eq!(get_u64(&t, "code"), 4);
        assert_eq!(t.get("kind").and_then(|x| x.as_str()), Some("TIMEOUT"));
        assert_eq!(svc.timeouts(), 1);
        assert_eq!(svc.evaluated(), 1, "the timed-out point never evaluated");
    }

    #[test]
    fn dse_deadline_cancels_at_the_barrier_and_leaves_the_memo_cold() {
        let svc = service();
        let (resp, _) =
            svc.handle_line(r#"{"id":3,"req":"dse","app":"matmul","n":128,"top":3,"deadline_ms":0}"#);
        let v = parse(&resp.unwrap()).unwrap();
        assert_eq!(get_u64(&v, "code"), 4);
        assert_eq!(v.get("kind").and_then(|x| x.as_str()), Some("TIMEOUT"));
        assert_eq!(svc.timeouts(), 1);
        assert_eq!(svc.evaluated(), 0, "a cancelled sweep records nothing");
        let (ok, _) = svc.handle_line(r#"{"id":4,"req":"dse","app":"matmul","n":128,"top":3}"#);
        let ok = parse(&ok.unwrap()).unwrap();
        assert_eq!(
            ok.get("ok").and_then(|x| x.as_bool()),
            Some(true),
            "the same sweep without a deadline still runs"
        );
    }

    #[test]
    fn admission_rejects_work_over_capacity_but_serves_probes() {
        let svc = Service::new(
            BoardConfig::zynq706(),
            ServeConfig {
                max_inflight: 0,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let (resp, _) =
            svc.handle_line(r#"{"id":5,"req":"estimate","app":"matmul","n":128,"accel":["mxm64:U8"]}"#);
        let v = parse(&resp.unwrap()).unwrap();
        assert_eq!(get_u64(&v, "code"), 5);
        assert_eq!(v.get("kind").and_then(|x| x.as_str()), Some("OVERLOADED"));
        assert!(get_u64(&v, "retry_after_ms") >= 1, "backoff hint present");
        let (ping, _) = svc.handle_line(r#"{"req":"ping"}"#);
        let ping = parse(&ping.unwrap()).unwrap();
        assert_eq!(ping.get("ok").and_then(|x| x.as_bool()), Some(true));
        let (health, _) = svc.handle_line(r#"{"req":"health"}"#);
        let health = parse(&health.unwrap()).unwrap();
        assert_eq!(health.get("ok").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(svc.overloaded(), 1);
        assert_eq!(svc.evaluated(), 0);
    }

    #[test]
    fn health_probe_reports_readiness_and_limits() {
        let svc = service();
        let (resp, quit) = svc.handle_line(r#"{"id":6,"req":"health"}"#);
        assert!(!quit);
        let v = parse(&resp.unwrap()).unwrap();
        assert_eq!(v.get("ok").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(v.get("ready").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(v.get("degraded").and_then(|x| x.as_bool()), Some(false));
        assert_eq!(v.get("draining").and_then(|x| x.as_bool()), Some(false));
        assert_eq!(get_u64(&v, "lanes"), 1);
        assert_eq!(get_u64(&v, "inflight"), 0);
        assert_eq!(get_u64(&v, "max_queue"), 64);
        let Some(Value::Arr(depths)) = v.get("lane_depths") else {
            panic!("health carries per-lane queue depths");
        };
        assert_eq!(depths.len(), 1);
    }

    #[test]
    fn draining_service_rejects_new_work_but_probes_still_answer() {
        let svc = service();
        svc.draining.store(true, Ordering::SeqCst);
        let (resp, _) =
            svc.handle_line(r#"{"id":7,"req":"estimate","app":"matmul","n":128,"accel":["mxm64:U8"]}"#);
        let v = parse(&resp.unwrap()).unwrap();
        assert_eq!(get_u64(&v, "code"), 5);
        assert_eq!(v.get("kind").and_then(|x| x.as_str()), Some("OVERLOADED"));
        let (health, _) = svc.handle_line(r#"{"req":"health"}"#);
        let health = parse(&health.unwrap()).unwrap();
        assert_eq!(health.get("ready").and_then(|x| x.as_bool()), Some(false));
        assert_eq!(health.get("draining").and_then(|x| x.as_bool()), Some(true));
    }

    #[test]
    fn kernel_group_router_keeps_overlapping_contexts_on_intersecting_lanes() {
        let mut r = LaneRouter::new(4);
        let ka = ("a".to_string(), 128u64, 32u64);
        let kb = ("b".to_string(), 128, 32);
        let kc = ("c".to_string(), 128, 32);
        let ra = r.assign(&ka, &[1, 2]);
        let rb = r.assign(&kb, &[3]);
        let rc = r.assign(&kc, &[2, 3]);
        assert_eq!(ra.locks, vec![ra.primary], "fresh kernels take one lane");
        assert_eq!(rb.locks, vec![rb.primary]);
        assert!(
            rc.locks.contains(&ra.primary),
            "sharing kernel 2 pulls in a's lane"
        );
        assert!(
            rc.locks.contains(&rb.primary),
            "sharing kernel 3 pulls in b's lane"
        );
        let mut sorted = rc.locks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(rc.locks, sorted, "lock sets are ascending and deduplicated");
        assert!(rc.locks.contains(&rc.primary));
        assert_eq!(r.cached(&kc), Some(rc), "routes are immutable once assigned");
        assert_eq!(
            r.assign(&ka, &[1, 2]),
            ra,
            "re-assigning an existing context returns its cached route"
        );
        let mut single = LaneRouter::new(1);
        assert_eq!(single.assign(&ka, &[1, 2]).locks, vec![0]);
    }

    #[test]
    fn bounded_reader_rejects_oversized_lines_and_keeps_the_stream_in_sync() {
        let svc = Service::new(
            BoardConfig::zynq706(),
            ServeConfig {
                max_line_bytes: 64,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let input = format!("{}\n{}\n", "x".repeat(200), r#"{"req":"ping"}"#);
        let mut out: Vec<u8> = Vec::new();
        let quit = serve_connection(&svc, std::io::Cursor::new(input.into_bytes()), &mut out);
        assert!(!quit);
        let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().lines().collect();
        assert_eq!(lines.len(), 2, "one response per line, oversized included");
        let first = parse(lines[0]).unwrap();
        assert_eq!(get_u64(&first, "code"), 5);
        assert_eq!(first.get("kind").and_then(|x| x.as_str()), Some("OVERLOADED"));
        let second = parse(lines[1]).unwrap();
        assert_eq!(second.get("ok").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(svc.overloaded(), 1);
    }

    #[cfg(unix)]
    #[test]
    fn repeated_save_failures_trip_the_breaker_into_read_only_mode() {
        // Deleting the memo's directory makes every subsequent save fail
        // for real (no faultpoints here — arming a real site would leak
        // into unrelated lib tests; see util::faultpoint's test notes).
        // The open WAL handles survive the unlink on unix.
        let dir = std::env::temp_dir().join(format!("zynq-breaker-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let svc = Service::new(
            BoardConfig::zynq706(),
            ServeConfig {
                memo_path: Some(dir.join("m.memo")),
                breaker_threshold: 2,
                save_every: 1_000_000,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        svc.handle_line(r#"{"req":"estimate","app":"matmul","n":128,"accel":["mxm64:U8"]}"#);
        std::fs::remove_dir_all(&dir).unwrap();
        svc.handle_line(r#"{"req":"memo","action":"gc","max_bytes":1000000,"app_floor":1}"#);
        assert!(!svc.degraded(), "one failure stays under the threshold");
        svc.handle_line(r#"{"req":"memo","action":"gc","max_bytes":1000000,"app_floor":1}"#);
        assert!(svc.degraded(), "two consecutive failures trip the breaker");
        let (hit, _) =
            svc.handle_line(r#"{"req":"estimate","app":"matmul","n":128,"accel":["mxm64:U8"]}"#);
        let hit = parse(&hit.unwrap()).unwrap();
        assert_eq!(
            hit.get("ok").and_then(|x| x.as_bool()),
            Some(true),
            "memo hits still serve read-only"
        );
        let (cold, _) =
            svc.handle_line(r#"{"req":"estimate","app":"matmul","n":128,"accel":["mxm64:U16"]}"#);
        let cold = parse(&cold.unwrap()).unwrap();
        assert_eq!(get_u64(&cold, "code"), 6);
        assert_eq!(cold.get("kind").and_then(|x| x.as_str()), Some("DEGRADED"));
        let (dse, _) = svc.handle_line(r#"{"req":"dse","app":"matmul","n":128,"top":2}"#);
        assert_eq!(get_u64(&parse(&dse.unwrap()).unwrap(), "code"), 6);
        assert!(svc.degraded_rejects() >= 2);
        assert_eq!(svc.finalize(), 1, "a degraded daemon exits non-zero");
    }
}
