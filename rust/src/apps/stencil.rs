//! Blocked Jacobi stencil — an extra application beyond the paper's two,
//! exercising the halo-exchange dependence pattern common in the
//! cyber-physical workloads the paper's introduction motivates (AXIOM).
//!
//! One sweep updates every BS×BS tile from its 4 neighbours (5-point
//! stencil), double-buffered A → B, then the roles swap. Each tile update
//! is a task:
//!
//! ```c
//! #pragma omp target device(fpga,smp)
//! #pragma omp task in([BS*BS]C,[BS*BS]N,[BS*BS]S,[BS*BS]W,[BS*BS]E) \
//!                  out([BS*BS]O)
//! void jacobiBlock(REAL *C, REAL *N, REAL *S, REAL *W, REAL *E, REAL *O);
//! ```
//!
//! Unlike matmul's accumulation chains or cholesky's panel graph, the
//! inter-sweep dependences form a diamond wavefront — a third distinct
//! graph shape for the estimator test suite.

use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::task::{Dep, KernelDecl, KernelProfile, TaskProgram, Targets};

const A_BASE: u64 = 0x6000_0000;
const B_BASE: u64 = 0x7000_0000;

#[derive(Clone, Copy, Debug)]
/// Blocked Jacobi stencil (extra halo-exchange domain app).
pub struct Stencil {
    /// Grid dimension (elements per side).
    pub n: u64,
    /// Tile dimension.
    pub bs: u64,
    /// Number of Jacobi sweeps.
    pub sweeps: u32,
}

impl Stencil {
    /// An `n`×`n` grid with `bs`×`bs` tiles and `sweeps` Jacobi sweeps.
    pub fn new(n: u64, bs: u64, sweeps: u32) -> Self {
        assert!(n % bs == 0);
        assert!(sweeps >= 1);
        Self { n, bs, sweeps }
    }

    /// Number of tile blocks per side.
    pub fn nb(&self) -> u64 {
        self.n / self.bs
    }

    /// The kernel name for this tile size (e.g. `jacobi64`).
    pub fn kernel_name(&self) -> String {
        format!("jacobi{}", self.bs)
    }

    /// Workload profile of one 5-point tile update.
    pub fn profile(&self) -> KernelProfile {
        let bs = self.bs;
        KernelProfile {
            // 5 reads, 4 adds + 1 mul per point.
            flops: 5 * bs * bs,
            inner_trip: bs * bs,
            in_bytes: 5 * bs * bs * 4, // centre + 4 halo tiles
            out_bytes: bs * bs * 4,
            dtype_bytes: 4,
            divsqrt: false,
        }
    }

    fn tile_bytes(&self) -> u64 {
        self.bs * self.bs * 4
    }

    fn addr(&self, base: u64, row: i64, col: i64) -> u64 {
        let nb = self.nb() as i64;
        // Clamp halo reads at the boundary (Neumann-ish): boundary tiles
        // read themselves, which keeps the dependence structure regular.
        let r = row.clamp(0, nb - 1) as u64;
        let c = col.clamp(0, nb - 1) as u64;
        base + (r * self.nb() + c) * self.tile_bytes()
    }

    /// Build the task program (double-buffered sweep trace).
    pub fn build_program(&self, board: &BoardConfig) -> TaskProgram {
        let mut p = TaskProgram::new(&format!(
            "stencil{}-bs{}-s{}",
            self.n, self.bs, self.sweeps
        ));
        let profile = self.profile();
        let smp_cycles = super::smp_cycles_model(&profile, board);
        let k = p.add_kernel(KernelDecl {
            name: self.kernel_name(),
            targets: Targets::BOTH,
            profile,
        });
        let nb = self.nb() as i64;
        let tb = self.tile_bytes();
        for s in 0..self.sweeps {
            let (src, dst) = if s % 2 == 0 {
                (A_BASE, B_BASE)
            } else {
                (B_BASE, A_BASE)
            };
            for i in 0..nb {
                for j in 0..nb {
                    let mut deps = vec![
                        Dep::input(self.addr(src, i, j), tb),
                        Dep::input(self.addr(src, i - 1, j), tb),
                        Dep::input(self.addr(src, i + 1, j), tb),
                        Dep::input(self.addr(src, i, j - 1), tb),
                        Dep::input(self.addr(src, i, j + 1), tb),
                    ];
                    // Clamping can duplicate addresses at corners; dedup so
                    // transfer accounting stays honest.
                    deps.sort_by_key(|d| d.addr);
                    deps.dedup_by_key(|d| d.addr);
                    deps.push(Dep::output(self.addr(dst, i, j), tb));
                    p.add_task(k, smp_cycles, deps);
                }
            }
        }
        p
    }
}

/// A small co-design set for the stencil example/bench: granularity and
/// accelerator-count exploration like the paper's matmul study.
pub fn example_codesigns() -> Vec<CoDesign> {
    vec![
        CoDesign::new("1acc").with_accel("jacobi64", 16),
        CoDesign::new("2acc")
            .with_accel("jacobi64", 16)
            .with_accel("jacobi64", 16),
        CoDesign::new("2acc + smp")
            .with_accel("jacobi64", 16)
            .with_accel("jacobi64", 16)
            .with_smp("jacobi64"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::deps::DepGraph;

    #[test]
    fn task_count() {
        let b = BoardConfig::zynq706();
        let p = Stencil::new(256, 64, 3).build_program(&b); // 4x4 tiles
        assert_eq!(p.tasks.len(), 3 * 16);
        assert!(p.validate().is_empty());
    }

    #[test]
    fn sweeps_serialize_through_buffers() {
        let b = BoardConfig::zynq706();
        let p = Stencil::new(256, 64, 2).build_program(&b);
        let g = DepGraph::build(&p);
        // Sweep 2's tile (i,j) depends on sweep 1's neighbourhood.
        assert!(g.depth() >= 2);
        // Within one sweep everything is parallel.
        let p1 = Stencil::new(256, 64, 1).build_program(&b);
        let g1 = DepGraph::build(&p1);
        assert_eq!(g1.depth(), 1);
        assert_eq!(g1.max_level_width(), 16);
    }

    #[test]
    fn corner_tiles_dedup_halo() {
        let b = BoardConfig::zynq706();
        let p = Stencil::new(128, 64, 1).build_program(&b); // 2x2 tiles
        // Corner tile reads: centre + 2 distinct neighbours (clamped) = 3.
        let t = &p.tasks[0];
        let reads = t.deps.iter().filter(|d| d.dir.reads()).count();
        assert_eq!(reads, 3);
    }

    #[test]
    fn second_sweep_flips_buffers() {
        let b = BoardConfig::zynq706();
        let p = Stencil::new(128, 64, 2).build_program(&b);
        let first_out = p.tasks[0].deps.iter().find(|d| d.dir.writes()).unwrap();
        let second_out = p.tasks[4].deps.iter().find(|d| d.dir.writes()).unwrap();
        assert!(first_out.addr >= B_BASE);
        assert!(second_out.addr < B_BASE);
    }
}
