//! Wire protocol of the estimator service: newline-delimited JSON
//! requests and responses (NDJSON), one object per line, over stdin/stdout
//! or a TCP connection.
//!
//! Every request is an object with a `"req"` discriminator and an
//! optional `"id"` (any JSON value, echoed verbatim in the response so
//! clients can correlate out-of-order traffic):
//!
//! ```text
//! {"id": 1, "req": "estimate", "app": "matmul", "n": 256, "bs": 64,
//!  "accel": ["mxm64:U32"], "smp": []}
//! {"id": 2, "req": "energy",   "app": "matmul", "accel": ["mxm64:U32"]}
//! {"id": 3, "req": "dse",      "app": "matmul", "n": 256,
//!  "objective": "time", "top": 5, "mixed": false, "order": "ranked"}
//! {"id": 4, "req": "memo", "action": "stats"}
//! {"id": 5, "req": "memo", "action": "gc", "max_bytes": 65536, "app_floor": 1}
//! {"id": 6, "req": "ping"}
//! {"id": 7, "req": "shutdown"}
//! {"id": 9, "req": "health"}
//! {"id": 10, "req": "estimate", "app": "matmul", "accel": ["mxm64:U32"],
//!  "deadline_ms": 250}
//! {"id": 8, "req": "batch", "items": [
//!    {"id": "a", "req": "estimate", "app": "matmul", "accel": ["mxm64:U32"]},
//!    {"id": "b", "req": "energy",   "app": "lu",     "accel": ["trsm_row:U16"]}]}
//! ```
//!
//! A `batch` envelope carries any number of `estimate`/`energy` items
//! (up to [`MAX_BATCH_ITEMS`]); its response embeds one object per item
//! under `"items"`, each byte-identical to the response line the same
//! request would have received standalone — cold items are evaluated
//! together in one worker-pool round, which changes throughput, never
//! bytes. Item parse failures are isolated: one malformed item yields
//! one error object in place, the rest of the batch still runs.
//!
//! Successful responses carry `"ok": true`, the echoed `"id"`/`"req"`, a
//! `"text"` field whose bytes equal the one-shot CLI stdout for the same
//! query, the memo warmth counters (`"l1_hits"`, `"l2_hits"`,
//! `"evaluated"`), and query-specific numeric fields encoded as exact
//! `f64` bit patterns (the memo convention — lossless round-trips).
//! Failures carry `"ok": false` plus a `"code"` that mirrors the CLI exit
//! code taxonomy: `1` for malformed/unsatisfiable requests, `2` for an
//! unknown `"req"`, `3` for corrupt input files. Overload-control
//! failures extend the taxonomy with `4` (`"kind":"TIMEOUT"` — the
//! request's `deadline_ms` expired before evaluation could start or
//! between sweep rounds), `5` (`"kind":"OVERLOADED"` — admission was
//! refused, with a `"retry_after_ms"` backoff hint) and `6`
//! (`"kind":"DEGRADED"` — persistence is broken and the daemon answers
//! memo hits only). Any query request accepts an optional
//! `"deadline_ms"` budget; `{"req":"health"}` probes readiness without
//! consuming admission capacity.

use crate::config::{AccelSpec, CoDesign};
use crate::dse::{Objective, OrderMode};
use crate::util::json::{obj, parse, Value};

/// A structured service failure: the `code` mirrors the CLI exit-code
/// taxonomy (1 usage/runtime, 2 unknown request, 3 corrupt input), so a
/// client scripting against the daemon sees the same classification a
/// shell script sees from the one-shot CLI. Overload-control failures
/// (codes 4–6) additionally carry a machine-readable `kind` tag and, for
/// `OVERLOADED`, a `retry_after_ms` backoff hint.
#[derive(Clone, Debug)]
pub struct ServiceError {
    /// CLI-taxonomy error class.
    pub code: i64,
    /// Human-readable message.
    pub message: String,
    /// Machine-readable class tag for overload-control errors
    /// (`TIMEOUT` / `OVERLOADED` / `DEGRADED`); absent on the classic
    /// codes 1–3.
    pub kind: Option<&'static str>,
    /// Suggested client backoff before retrying (OVERLOADED only).
    pub retry_after_ms: Option<u64>,
}

impl ServiceError {
    fn new(code: i64, msg: impl Into<String>) -> Self {
        Self {
            code,
            message: msg.into(),
            kind: None,
            retry_after_ms: None,
        }
    }

    /// A usage/runtime error (CLI exit code 1).
    pub fn usage(msg: impl Into<String>) -> Self {
        Self::new(1, msg)
    }

    /// An unknown-request error (CLI exit code 2).
    pub fn unknown(msg: impl Into<String>) -> Self {
        Self::new(2, msg)
    }

    /// A deadline-exceeded error (code 4, `kind:"TIMEOUT"`): the
    /// request's budget expired before evaluation could start or at a
    /// sweep round boundary.
    pub fn timeout(msg: impl Into<String>) -> Self {
        Self {
            kind: Some("TIMEOUT"),
            ..Self::new(4, msg)
        }
    }

    /// An admission-refused error (code 5, `kind:"OVERLOADED"`) with a
    /// client backoff hint in milliseconds.
    pub fn overloaded(msg: impl Into<String>, retry_after_ms: u64) -> Self {
        Self {
            kind: Some("OVERLOADED"),
            retry_after_ms: Some(retry_after_ms),
            ..Self::new(5, msg)
        }
    }

    /// A read-only-mode error (code 6, `kind:"DEGRADED"`): persistence is
    /// broken, the daemon answers memo hits but refuses cold evaluations.
    pub fn degraded(msg: impl Into<String>) -> Self {
        Self {
            kind: Some("DEGRADED"),
            ..Self::new(6, msg)
        }
    }
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} (code {})", self.message, self.code)
    }
}

/// A point query (`estimate` / `energy`): one application configuration
/// and one co-design.
#[derive(Clone, Debug)]
pub struct PointQuery {
    /// Application name (`matmul`, `cholesky`, `lu`, `stencil`).
    pub app: String,
    /// Problem size.
    pub n: u64,
    /// Block size.
    pub bs: u64,
    /// Accelerator instances.
    pub accels: Vec<AccelSpec>,
    /// Kernels additionally allowed on the SMP cores.
    pub smp: Vec<String>,
}

impl PointQuery {
    /// The co-design this query describes.
    pub fn codesign(&self) -> CoDesign {
        let mut cd = CoDesign::new("service");
        cd.accels = self.accels.clone();
        cd.smp_kernels = self.smp.clone();
        cd
    }
}

/// A `dse` sweep query over one application's co-design space.
#[derive(Clone, Debug)]
pub struct DseQuery {
    /// Application name.
    pub app: String,
    /// Problem size.
    pub n: u64,
    /// Block size.
    pub bs: u64,
    /// Ranking objective.
    pub objective: Objective,
    /// Rows of the ranking table to render.
    pub top: usize,
    /// Allow heterogeneous unroll variants per kernel.
    pub mixed: bool,
    /// Bound-round candidate order.
    pub order: OrderMode,
}

/// Knobs of a `memo gc` request — mirrors `dse memo gc` on the CLI.
#[derive(Clone, Debug)]
pub struct GcSpec {
    /// Serialized-size budget; `Some` selects the byte-budget policy.
    pub max_bytes: Option<usize>,
    /// Most-recent contexts per app that are never evicted.
    pub app_floor: usize,
    /// LRU context cap of the legacy count-based policy.
    pub keep_contexts: usize,
    /// Cumulative point budget of the count-based policy.
    pub keep_points: usize,
    /// Level-1 kernel entry cap.
    pub keep_kernels: usize,
}

/// Largest accepted `batch` envelope — one NDJSON request line must stay
/// bounded, and a single worker-pool round has no use for more points.
pub const MAX_BATCH_ITEMS: usize = 1024;

/// One item of a `batch` envelope: an `estimate`/`energy` point query
/// with its own correlation id. Parsing is per-item lenient — a
/// malformed item carries its error here and answers with one error
/// object in the batch response instead of failing the whole envelope.
#[derive(Clone, Debug)]
pub struct BatchItem {
    /// Item correlation id (echoed in the item's response object).
    pub id: Value,
    /// `true` renders the energy view (item `"req":"energy"`).
    pub energy: bool,
    /// The parsed point query, or the item's own parse error.
    pub query: Result<PointQuery, ServiceError>,
}

/// One parsed request.
#[derive(Clone, Debug)]
pub enum RequestKind {
    /// Memo-backed single-point estimate.
    Estimate(PointQuery),
    /// Memo-backed single-point energy report.
    Energy(PointQuery),
    /// Several point queries answered as one batch-evaluated response.
    Batch(Vec<BatchItem>),
    /// Warm design-space exploration.
    Dse(DseQuery),
    /// Memo layout + service counters.
    MemoStats,
    /// Memo garbage collection.
    MemoGc(GcSpec),
    /// Liveness probe.
    Ping,
    /// Readiness/overload probe: lane depths, memo bytes, limit and
    /// degraded/draining flags. Never consumes admission capacity.
    Health,
    /// Save the memo and stop the daemon.
    Shutdown,
}

/// A request envelope: the echoed correlation id plus the parsed kind.
#[derive(Clone, Debug)]
pub struct Envelope {
    /// Client correlation id (echoed verbatim; `null` when absent).
    pub id: Value,
    /// The parsed request.
    pub kind: RequestKind,
    /// Per-request deadline budget in milliseconds (`"deadline_ms"`);
    /// `None` falls back to the daemon's `--default-deadline-ms`.
    pub deadline_ms: Option<u64>,
}

impl Envelope {
    /// Canonical coalescing key of the request, excluding the id: two
    /// requests with equal keys are the same query and may share one
    /// evaluation. Uses [`crate::dse::warm::codesign_key`] for point
    /// queries so instance order cannot split a key.
    pub fn coalesce_key(&self) -> Option<String> {
        match &self.kind {
            RequestKind::Estimate(q) => Some(format!(
                "estimate|{}|{}|{}|{}",
                q.app,
                q.n,
                q.bs,
                crate::dse::warm::codesign_key(&q.codesign())
            )),
            RequestKind::Energy(q) => Some(format!(
                "energy|{}|{}|{}|{}",
                q.app,
                q.n,
                q.bs,
                crate::dse::warm::codesign_key(&q.codesign())
            )),
            RequestKind::Dse(q) => Some(format!(
                "dse|{}|{}|{}|{}|{}|{}|{}",
                q.app,
                q.n,
                q.bs,
                q.objective.as_str(),
                q.top,
                q.mixed,
                q.order.as_str()
            )),
            _ => None,
        }
    }

    /// The request name echoed in responses.
    pub fn req_name(&self) -> &'static str {
        match &self.kind {
            RequestKind::Estimate(_) => "estimate",
            RequestKind::Energy(_) => "energy",
            RequestKind::Batch(_) => "batch",
            RequestKind::Dse(_) => "dse",
            RequestKind::MemoStats | RequestKind::MemoGc(_) => "memo",
            RequestKind::Ping => "ping",
            RequestKind::Health => "health",
            RequestKind::Shutdown => "shutdown",
        }
    }
}

fn u64_field(v: &Value, key: &str, default: u64) -> Result<u64, ServiceError> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(default),
        Some(x) => x
            .as_u64()
            .ok_or_else(|| ServiceError::usage(format!("'{key}' expects a non-negative integer"))),
    }
}

fn str_field<'v>(v: &'v Value, key: &str) -> Result<Option<&'v str>, ServiceError> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(x) => x
            .as_str()
            .map(Some)
            .ok_or_else(|| ServiceError::usage(format!("'{key}' expects a string"))),
    }
}

fn bool_field(v: &Value, key: &str) -> Result<bool, ServiceError> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(false),
        Some(x) => x
            .as_bool()
            .ok_or_else(|| ServiceError::usage(format!("'{key}' expects a boolean"))),
    }
}

fn str_list(v: &Value, key: &str) -> Result<Vec<String>, ServiceError> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(Vec::new()),
        Some(Value::Str(s)) => Ok(vec![s.clone()]),
        Some(Value::Arr(items)) => items
            .iter()
            .map(|x| {
                x.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| ServiceError::usage(format!("'{key}' expects strings")))
            })
            .collect(),
        Some(_) => Err(ServiceError::usage(format!(
            "'{key}' expects a string or an array of strings"
        ))),
    }
}

fn point_query(v: &Value) -> Result<PointQuery, ServiceError> {
    let app = str_field(v, "app")?
        .ok_or_else(|| ServiceError::usage("request requires 'app'"))?
        .to_string();
    let accels = str_list(v, "accel")?
        .iter()
        .map(|s| AccelSpec::parse(s).map_err(|e| ServiceError::usage(format!("{e:#}"))))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(PointQuery {
        app,
        n: u64_field(v, "n", 512)?,
        bs: u64_field(v, "bs", 64)?,
        accels,
        smp: str_list(v, "smp")?,
    })
}

fn parse_batch_item(item: &Value) -> BatchItem {
    let id = item.get("id").cloned().unwrap_or(Value::Null);
    let err = |id: Value, e: ServiceError| BatchItem {
        id,
        energy: false,
        query: Err(e),
    };
    if item.as_obj().is_none() {
        return err(id, ServiceError::usage("batch items must be JSON objects"));
    }
    let energy = match str_field(item, "req") {
        Ok(None) | Ok(Some("estimate")) => false,
        Ok(Some("energy")) => true,
        Ok(Some(other)) => {
            return err(
                id,
                ServiceError::usage(format!(
                    "batch items accept req estimate|energy, got '{other}'"
                )),
            )
        }
        Err(e) => return err(id, e),
    };
    BatchItem {
        id,
        energy,
        query: point_query(item),
    }
}

/// Parse one NDJSON request line. On failure, returns the best-effort
/// correlation id alongside the error so the caller can still address its
/// error response.
pub fn parse_request(line: &str) -> Result<Envelope, (Value, ServiceError)> {
    let v = parse(line)
        .map_err(|e| (Value::Null, ServiceError::usage(format!("malformed request line: {e}"))))?;
    if v.as_obj().is_none() {
        return Err((
            Value::Null,
            ServiceError::usage("request must be a JSON object"),
        ));
    }
    let id = v.get("id").cloned().unwrap_or(Value::Null);
    let fail = |e: ServiceError| (id.clone(), e);
    let req = match str_field(&v, "req").map_err(fail)? {
        Some(r) => r.to_string(),
        None => return Err(fail(ServiceError::usage("request requires 'req'"))),
    };
    let kind = match req.as_str() {
        "estimate" => RequestKind::Estimate(point_query(&v).map_err(fail)?),
        "energy" => RequestKind::Energy(point_query(&v).map_err(fail)?),
        "batch" => {
            let items = match v.get("items") {
                Some(Value::Arr(items)) => items,
                Some(_) => return Err(fail(ServiceError::usage("'items' expects an array"))),
                None => return Err(fail(ServiceError::usage("'batch' requires 'items'"))),
            };
            if items.len() > MAX_BATCH_ITEMS {
                return Err(fail(ServiceError::usage(format!(
                    "batch exceeds {MAX_BATCH_ITEMS} items"
                ))));
            }
            RequestKind::Batch(items.iter().map(parse_batch_item).collect())
        }
        "dse" => {
            let objective = match str_field(&v, "objective").map_err(fail)? {
                None => Objective::Time,
                Some(o) => Objective::parse(o).ok_or_else(|| {
                    fail(ServiceError::usage(format!(
                        "unknown objective '{o}' (time|energy|edp)"
                    )))
                })?,
            };
            let order = match str_field(&v, "order").map_err(fail)? {
                None => OrderMode::Ranked,
                Some(o) => OrderMode::parse(o).ok_or_else(|| {
                    fail(ServiceError::usage(format!(
                        "unknown order '{o}' (fifo|bound|ranked)"
                    )))
                })?,
            };
            RequestKind::Dse(DseQuery {
                app: str_field(&v, "app")
                    .map_err(fail)?
                    .unwrap_or("matmul")
                    .to_string(),
                n: u64_field(&v, "n", 512).map_err(fail)?,
                bs: u64_field(&v, "bs", 64).map_err(fail)?,
                objective,
                top: u64_field(&v, "top", 15).map_err(fail)? as usize,
                mixed: bool_field(&v, "mixed").map_err(fail)?,
                order,
            })
        }
        "memo" => match str_field(&v, "action").map_err(fail)?.unwrap_or("stats") {
            "stats" => RequestKind::MemoStats,
            "gc" => {
                let max_bytes = match v.get("max_bytes") {
                    None | Some(Value::Null) => None,
                    Some(x) => Some(x.as_u64().ok_or_else(|| {
                        fail(ServiceError::usage(
                            "'max_bytes' expects a non-negative integer",
                        ))
                    })? as usize),
                };
                RequestKind::MemoGc(GcSpec {
                    max_bytes,
                    app_floor: u64_field(&v, "app_floor", 1).map_err(fail)? as usize,
                    keep_contexts: u64_field(&v, "keep_contexts", 16).map_err(fail)? as usize,
                    keep_points: u64_field(&v, "keep_points", u64::MAX)
                        .map_err(fail)?
                        .min(usize::MAX as u64) as usize,
                    keep_kernels: u64_field(&v, "keep_kernels", 256).map_err(fail)? as usize,
                })
            }
            other => {
                return Err(fail(ServiceError::usage(format!(
                    "unknown memo action '{other}' (stats|gc)"
                ))))
            }
        },
        "ping" => RequestKind::Ping,
        "health" => RequestKind::Health,
        "shutdown" => RequestKind::Shutdown,
        other => {
            return Err(fail(ServiceError::unknown(format!(
                "unknown request '{other}' (estimate|energy|batch|dse|memo|ping|health|shutdown)"
            ))))
        }
    };
    let deadline_ms = match v.get("deadline_ms") {
        None | Some(Value::Null) => None,
        Some(x) => Some(x.as_u64().ok_or_else(|| {
            fail(ServiceError::usage(
                "'deadline_ms' expects a non-negative integer",
            ))
        })?),
    };
    Ok(Envelope {
        id,
        kind,
        deadline_ms,
    })
}

/// What a successful query produced: the CLI-identical text plus the
/// warmth counters and query-specific exact-bits fields.
#[derive(Clone, Debug, Default)]
pub struct QueryReply {
    /// Byte-identical to the one-shot CLI stdout for the same query.
    pub text: String,
    /// Level-1 kernel sub-memo hits while priming the HLS cache.
    pub l1_hits: u64,
    /// Level-2 exact point hits.
    pub l2_hits: u64,
    /// Points freshly simulated to answer this query.
    pub evaluated: u64,
    /// Query-specific extra fields (numbers as exact `f64` bit patterns).
    pub extra: Vec<(String, Value)>,
}

/// Build a success response object. Shared by top-level response lines
/// and the per-item objects of a `batch` response — one builder is what
/// makes a batch item byte-identical to the standalone response line for
/// the same query.
pub fn ok_obj(id: &Value, req: &str, reply: &QueryReply) -> Value {
    let mut fields: Vec<(&str, Value)> = vec![
        ("id", id.clone()),
        ("ok", true.into()),
        ("req", req.into()),
        ("text", reply.text.as_str().into()),
        ("l1_hits", reply.l1_hits.into()),
        ("l2_hits", reply.l2_hits.into()),
        ("evaluated", reply.evaluated.into()),
    ];
    for (k, v) in &reply.extra {
        fields.push((k.as_str(), v.clone()));
    }
    obj(fields)
}

/// Serialize a success response line (no trailing newline).
pub fn ok_line(id: &Value, req: &str, reply: &QueryReply) -> String {
    ok_obj(id, req, reply).to_json()
}

/// Build an error response object (top-level lines and batch items alike).
/// Overload-control errors additionally carry their `kind` tag and, when
/// present, the `retry_after_ms` backoff hint.
pub fn err_obj(id: &Value, err: &ServiceError) -> Value {
    let mut fields: Vec<(&str, Value)> = vec![
        ("id", id.clone()),
        ("ok", false.into()),
        ("code", err.code.into()),
        ("error", err.message.as_str().into()),
    ];
    if let Some(kind) = err.kind {
        fields.push(("kind", kind.into()));
    }
    if let Some(ms) = err.retry_after_ms {
        fields.push(("retry_after_ms", ms.into()));
    }
    obj(fields)
}

/// Serialize an error response line (no trailing newline).
pub fn err_line(id: &Value, err: &ServiceError) -> String {
    err_obj(id, err).to_json()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_documented_request_shapes() {
        let e = parse_request(
            r#"{"id":1,"req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]}"#,
        )
        .unwrap();
        assert_eq!(e.id.as_i64(), Some(1));
        match &e.kind {
            RequestKind::Estimate(q) => {
                assert_eq!(q.app, "matmul");
                assert_eq!(q.n, 256);
                assert_eq!(q.bs, 64);
                assert_eq!(q.accels.len(), 1);
            }
            other => panic!("{other:?}"),
        }
        let d = parse_request(r#"{"req":"dse","app":"matmul","top":3,"mixed":true}"#).unwrap();
        match &d.kind {
            RequestKind::Dse(q) => {
                assert_eq!(q.top, 3);
                assert!(q.mixed);
                assert_eq!(q.order, OrderMode::Ranked);
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            parse_request(r#"{"req":"memo","action":"gc","max_bytes":4096}"#)
                .unwrap()
                .kind,
            RequestKind::MemoGc(GcSpec {
                max_bytes: Some(4096),
                app_floor: 1,
                ..
            })
        ));
        assert!(matches!(
            parse_request(r#"{"req":"shutdown"}"#).unwrap().kind,
            RequestKind::Shutdown
        ));
    }

    #[test]
    fn error_codes_mirror_the_cli_taxonomy() {
        // Malformed line and bad fields: usage class (1).
        assert_eq!(parse_request("not json").unwrap_err().1.code, 1);
        assert_eq!(parse_request("[1,2]").unwrap_err().1.code, 1);
        assert_eq!(
            parse_request(r#"{"req":"estimate"}"#).unwrap_err().1.code,
            1,
            "estimate requires app"
        );
        assert_eq!(
            parse_request(r#"{"req":"dse","n":"many"}"#).unwrap_err().1.code,
            1
        );
        // Unknown request: 2, like an unknown CLI command.
        let (id, err) = parse_request(r#"{"id":9,"req":"frobnicate"}"#).unwrap_err();
        assert_eq!(err.code, 2);
        assert_eq!(id.as_i64(), Some(9), "id still echoed on errors");
    }

    #[test]
    fn batch_envelopes_parse_per_item_and_isolate_item_failures() {
        let e = parse_request(
            r#"{"id":8,"req":"batch","items":[
                {"id":"a","req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]},
                {"id":"b","req":"energy","app":"lu","accel":["trsm_row:U16"]},
                {"id":"c","req":"dse","app":"matmul"},
                {"id":"d"}]}"#,
        )
        .unwrap();
        let RequestKind::Batch(items) = &e.kind else {
            panic!("{:?}", e.kind);
        };
        assert_eq!(items.len(), 4);
        assert!(!items[0].energy);
        assert!(items[0].query.is_ok());
        assert!(items[1].energy);
        assert!(items[1].query.is_ok());
        assert!(
            items[2].query.is_err(),
            "dse is not batchable; the item fails alone"
        );
        assert_eq!(items[2].id.as_str(), Some("c"), "failed items keep their id");
        assert!(
            items[3].query.is_err(),
            "item without 'app' fails alone (req defaults to estimate)"
        );
        assert!(e.coalesce_key().is_none(), "batches never coalesce");
        assert_eq!(e.req_name(), "batch");
        // Envelope-level failures: missing/NaN items, oversized batches.
        assert_eq!(
            parse_request(r#"{"req":"batch"}"#).unwrap_err().1.code,
            1,
            "batch requires items"
        );
        assert_eq!(
            parse_request(r#"{"req":"batch","items":7}"#)
                .unwrap_err()
                .1
                .code,
            1
        );
        let oversized = format!(
            r#"{{"req":"batch","items":[{}]}}"#,
            vec!["{}"; MAX_BATCH_ITEMS + 1].join(",")
        );
        assert_eq!(parse_request(&oversized).unwrap_err().1.code, 1);
    }

    #[test]
    fn deadline_health_and_overload_errors_round_trip() {
        // deadline_ms is optional on every request and must be an integer.
        let e = parse_request(
            r#"{"id":1,"req":"estimate","app":"matmul","accel":[],"deadline_ms":250}"#,
        )
        .unwrap();
        assert_eq!(e.deadline_ms, Some(250));
        assert_eq!(
            parse_request(r#"{"req":"ping"}"#).unwrap().deadline_ms,
            None
        );
        assert_eq!(
            parse_request(r#"{"req":"ping","deadline_ms":"soon"}"#)
                .unwrap_err()
                .1
                .code,
            1
        );
        // health parses and never coalesces.
        let h = parse_request(r#"{"id":2,"req":"health"}"#).unwrap();
        assert!(matches!(h.kind, RequestKind::Health));
        assert_eq!(h.req_name(), "health");
        assert!(h.coalesce_key().is_none());
        // Overload-control errors serialize their kind (and backoff hint).
        let t = err_obj(&Value::Null, &ServiceError::timeout("deadline exceeded"));
        assert_eq!(t.get("code").and_then(Value::as_i64), Some(4));
        assert_eq!(t.get("kind").and_then(Value::as_str), Some("TIMEOUT"));
        assert!(t.get("retry_after_ms").is_none());
        let o = err_obj(&Value::Null, &ServiceError::overloaded("lane queue full", 40));
        assert_eq!(o.get("code").and_then(Value::as_i64), Some(5));
        assert_eq!(o.get("kind").and_then(Value::as_str), Some("OVERLOADED"));
        assert_eq!(o.get("retry_after_ms").and_then(Value::as_u64), Some(40));
        let d = err_obj(&Value::Null, &ServiceError::degraded("memo save failing"));
        assert_eq!(d.get("code").and_then(Value::as_i64), Some(6));
        assert_eq!(d.get("kind").and_then(Value::as_str), Some("DEGRADED"));
        // Classic codes stay untagged — batch-item bytes are unchanged.
        let u = err_obj(&Value::Null, &ServiceError::usage("nope"));
        assert!(u.get("kind").is_none());
        assert!(u.get("retry_after_ms").is_none());
    }

    #[test]
    fn coalesce_keys_ignore_instance_order_and_id() {
        let a = parse_request(
            r#"{"id":1,"req":"estimate","app":"matmul","accel":["mxm64:U32","mxm64:U16"]}"#,
        )
        .unwrap();
        let b = parse_request(
            r#"{"id":2,"req":"estimate","app":"matmul","accel":["mxm64:U16","mxm64:U32"]}"#,
        )
        .unwrap();
        assert_eq!(a.coalesce_key(), b.coalesce_key());
        let c = parse_request(r#"{"req":"ping"}"#).unwrap();
        assert!(c.coalesce_key().is_none());
    }
}
