//! L3 hot-path benchmark — the §Perf target: the estimator must simulate
//! millions of tasks per second so that whole co-design sweeps stay in the
//! "coffee break" regime the paper promises even for much larger apps.
//!
//! Measures: event-engine throughput (tasks/s) for large synthetic
//! programs, dependence-tracker build rate, and end-to-end sweep latency.

use zynq_estimator::apps::{cholesky::Cholesky, matmul::Matmul};
use zynq_estimator::config::{BoardConfig, CoDesign};
use zynq_estimator::coordinator::deps::DepGraph;
use zynq_estimator::coordinator::elaborate::ElabProgram;
use zynq_estimator::coordinator::sched::Policy;
use zynq_estimator::hls::FpgaPart;
use zynq_estimator::sim::engine::{resolve_codesign, Simulator};
use zynq_estimator::sim::EstimatorModel;
use zynq_estimator::util::bench::{bench, black_box};

fn main() {
    let board = BoardConfig::zynq706();

    // Large workloads: matmul NB=16 (4096 tasks) and NB=24 (13824 tasks),
    // cholesky NB=40 (12340 tasks).
    for (name, program, cd) in [
        (
            "matmul NB=16 (4096 tasks, 2acc+smp)",
            Matmul::new(1024, 64).build_program(&board),
            CoDesign::new("2acc+smp")
                .with_accel("mxm64", 32)
                .with_accel("mxm64", 32)
                .with_smp("mxm64"),
        ),
        (
            "matmul NB=24 (13824 tasks, 2acc)",
            Matmul::new(1536, 64).build_program(&board),
            CoDesign::new("2acc")
                .with_accel("mxm64", 32)
                .with_accel("mxm64", 32),
        ),
        (
            "cholesky NB=40 (12341 tasks, dgemm+dtrsm)",
            Cholesky::new(2560, 64).build_program(&board),
            CoDesign::new("pair")
                .with_accel("dgemm", 16)
                .with_accel("dtrsm", 16),
        ),
    ] {
        let n_tasks = program.tasks.len();
        let graph = DepGraph::build(&program);
        let elab = ElabProgram::build(&program, &graph);
        let (accels, smp) =
            resolve_codesign(&program, &cd, &board, &FpgaPart::xc7z045()).unwrap();
        let stats = bench(&format!("simulate {name}"), 2, 20, || {
            let sim = Simulator::new(&program, &elab, &board, &accels, &smp, Policy::Greedy);
            let mut model = EstimatorModel::new(&board);
            black_box(sim.run(&mut model));
        });
        println!(
            "    -> {:.2} M simulated tasks/s\n",
            n_tasks as f64 / (stats.min_ms / 1e3) / 1e6
        );
    }

    // Dependence tracking and program generation rates.
    let big = Matmul::new(1536, 64).build_program(&board);
    bench("DepGraph::build (13824 tasks)", 2, 20, || {
        black_box(DepGraph::build(&big));
    });
    bench("Matmul::build_program (13824 tasks)", 2, 20, || {
        black_box(Matmul::new(1536, 64).build_program(&board));
    });
}
