"""Layer-1 Pallas kernels for the paper's mxmBlock (Fig. 1).

HARDWARE ADAPTATION (DESIGN.md section 4). The paper's kernel is HLS C for
the Zynq fabric: BRAM-resident A/B/C tiles fed by AXI DMA, a pipelined MAC
loop over DSP48 slices. The TPU restatement of the same insight:

  * the BRAM tile becomes a **VMEM block** (`BlockSpec` keeps the operand
    tiles resident next to the compute unit);
  * the DSP MAC cascade becomes the **MXU** — one `jnp.dot` per tile pair
    drives the 128x128 systolic array, so BS=128 maps 1:1 onto an MXU pass
    while BS=64 under-fills it (the same granularity trade-off the paper
    sweeps on the FPGA);
  * the per-accelerator input DMA becomes the **HBM->VMEM BlockSpec
    schedule**: in `matmul_tiled` the grid walks K and Pallas
    double-buffers the next tile while the MXU consumes the current one —
    the overlap the paper models as scaling input DMA channels.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the correctness path and the
real-TPU numbers are estimated analytically (DESIGN.md section 5).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INTERPRET = True  # CPU PJRT cannot execute Mosaic lowerings.


def _mxm_kernel(a_ref, b_ref, c_ref, o_ref):
    """Single-tile body: O = A @ B + C, fully VMEM-resident."""
    o_ref[...] = (
        jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
        + c_ref[...]
    )


def mxm_block(a, b, c):
    """The paper's mxmBlock as a Pallas call: ``C' = A @ B + C``.

    One grid step, whole-tile BlockSpecs: for BS<=128 the full A/B/C tile
    set fits VMEM with double-buffering headroom (3 x 64 KiB at BS=128).
    """
    bs = a.shape[0]
    assert a.shape == b.shape == c.shape == (bs, bs)
    return pl.pallas_call(
        _mxm_kernel,
        out_shape=jax.ShapeDtypeStruct((bs, bs), jnp.float32),
        interpret=INTERPRET,
    )(a, b, c)


def _tiled_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps):
    """Grid body for the full-matrix kernel: accumulate over the K walk.

    The grid is (M/bm, N/bn, K/bk) with K innermost; `acc_ref` is VMEM
    scratch that lives across the K steps of one (i, j) tile — the same
    role as the HLS kernel's BRAM C tile.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def matmul_tiled(a, b, bm=128, bn=128, bk=128):
    """Layer-2-facing full matmul: C = A @ B with an HBM->VMEM schedule.

    BlockSpecs express exactly what the paper expressed with per-accelerator
    DMA: which HBM tile streams into local memory at each grid step.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_tiled_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        # VMEM accumulator tile (f32), persistent across the K walk — the
        # role the HLS kernel's BRAM C buffer plays on the fabric.
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=INTERPRET,
    )(a, b)


def mxm_block_bf16(a, b, c):
    """MXU-native variant: bf16 operands, f32 accumulate.

    On a real TPU this is the preferred numerics for the MXU (the systolic
    array multiplies bf16 natively and accumulates in f32); the Zynq paper
    has no analogue because DSP48 slices are fixed-point/float32. Exposed
    as a separate artifact so the Rust side can A/B the dtypes.
    """
    bs = a.shape[0]

    def kernel(a_ref, b_ref, c_ref, o_ref):
        o_ref[...] = (
            jnp.dot(
                a_ref[...].astype(jnp.bfloat16),
                b_ref[...].astype(jnp.bfloat16),
                preferred_element_type=jnp.float32,
            )
            + c_ref[...]
        )

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((bs, bs), jnp.float32),
        interpret=INTERPRET,
    )(a, b, c)
