//! Property-based tests over randomly generated programs and co-designs.
//!
//! The external `proptest` crate is not in the vendored dependency set, so
//! this uses the repository's seeded PRNG with a small forall harness —
//! same idea: hundreds of random cases per invariant, fully reproducible
//! (failures print the case seed).

use std::collections::HashMap;

use zynq_estimator::config::{BoardConfig, CoDesign};
use zynq_estimator::coordinator::deps::DepGraph;
use zynq_estimator::coordinator::elaborate::ElabProgram;
use zynq_estimator::coordinator::sched::Policy;
use zynq_estimator::coordinator::task::{
    Dep, Dir, KernelDecl, KernelProfile, TaskProgram, Targets,
};
use zynq_estimator::hls::{CostModel, FpgaPart};
use zynq_estimator::sim::engine::{resolve_codesign, SegKind, Simulator};
use zynq_estimator::sim::time::transfer_ps;
use zynq_estimator::sim::EstimatorModel;
use zynq_estimator::util::{json, Rng};

fn forall(iters: u64, base_seed: u64, f: impl Fn(u64, &mut Rng)) {
    for i in 0..iters {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        f(seed, &mut rng);
    }
}

/// Random task program: 1-4 kernels (always SMP-capable, sometimes FPGA),
/// up to 80 tasks over a small shared address pool so dependences collide.
fn random_program(rng: &mut Rng) -> TaskProgram {
    let mut p = TaskProgram::new("prop");
    let n_kernels = rng.gen_range(1, 5);
    for k in 0..n_kernels {
        let fpga = rng.next_f64() < 0.7;
        p.add_kernel(KernelDecl {
            name: format!("k{k}"),
            targets: Targets { smp: true, fpga },
            profile: KernelProfile {
                flops: rng.gen_range(1_000, 1_000_000),
                inner_trip: rng.gen_range(1_000, 500_000),
                in_bytes: rng.gen_range(256, 65_536),
                out_bytes: rng.gen_range(256, 32_768),
                dtype_bytes: if rng.next_f64() < 0.5 { 4 } else { 8 },
                divsqrt: rng.next_f64() < 0.3,
            },
        });
    }
    let n_tasks = rng.gen_range(1, 81);
    let pool: Vec<u64> = (0..12).map(|i| 0x1000 + i * 0x1000).collect();
    for _ in 0..n_tasks {
        let kernel = rng.gen_range(0, n_kernels) as u16;
        let n_deps = rng.gen_range(1, 4);
        let mut deps = Vec::new();
        let mut used = std::collections::HashSet::new();
        for _ in 0..n_deps {
            let addr = pool[rng.gen_range(0, pool.len() as u64) as usize];
            if !used.insert(addr) {
                continue;
            }
            let dir = match rng.gen_range(0, 3) {
                0 => Dir::In,
                1 => Dir::Out,
                _ => Dir::InOut,
            };
            deps.push(Dep {
                addr,
                len: rng.gen_range(64, 16_384),
                dir,
            });
        }
        if deps.is_empty() {
            deps.push(Dep::inout(pool[0], 64));
        }
        p.add_task(kernel, rng.gen_range(1_000, 2_000_000), deps);
    }
    p
}

fn random_codesign(rng: &mut Rng, p: &TaskProgram) -> CoDesign {
    let mut cd = CoDesign::new("prop");
    for k in &p.kernels {
        if k.targets.fpga {
            let n_acc = rng.gen_range(0, 3);
            for _ in 0..n_acc {
                let unroll = 1 << rng.gen_range(1, 5); // 2..16
                cd = cd.with_accel(&k.name, unroll);
            }
            if n_acc > 0 && rng.next_f64() < 0.5 {
                cd = cd.with_smp(&k.name);
            }
        }
    }
    cd
}

#[test]
fn prop_depgraph_respects_program_order_and_bounds() {
    forall(300, 0xDEAD, |seed, rng| {
        let p = random_program(rng);
        let g = DepGraph::build(&p);
        assert!(g.respects_program_order(), "seed {seed}");
        // Critical path with unit weights is between 1 and n.
        let d = g.depth();
        assert!(d >= 1 && d <= p.tasks.len() as u64, "seed {seed}");
        // Weighted critical path <= serial sum.
        let w: Vec<u64> = p.tasks.iter().map(|t| t.smp_cycles).collect();
        let cp = g.critical_path(&|t| w[t as usize]);
        let serial: u64 = w.iter().sum();
        assert!(cp <= serial, "seed {seed}");
    });
}

#[test]
fn prop_simulation_is_valid_schedule() {
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    forall(150, 0xBEEF, |seed, rng| {
        let p = random_program(rng);
        let cd = random_codesign(rng, &p);
        let Ok((accels, smp)) = resolve_codesign(&p, &cd, &board, &part) else {
            return; // infeasible co-design: rejection is a valid outcome
        };
        let g = DepGraph::build(&p);
        let e = ElabProgram::build(&p, &g);
        let policy = if rng.next_f64() < 0.5 {
            Policy::Greedy
        } else {
            Policy::Lookahead
        };
        let sim = Simulator::new(&p, &e, &board, &accels, &smp, policy);
        let mut model = EstimatorModel::new(&board);
        let res = sim.run(&mut model);

        // 1. Schedule validity: no device overlap, segments in range.
        let errs = res.validate();
        assert!(errs.is_empty(), "seed {seed}: {errs:?}");

        // 2. Every task executed exactly once on exactly one device class.
        assert_eq!(
            res.tasks_on_smp + res.tasks_on_accel,
            p.tasks.len(),
            "seed {seed}"
        );

        // 3. Dependence correctness: every successor's non-creation work
        //    starts at/after its predecessor's completion.
        let mut task_end: HashMap<u32, u64> = HashMap::new();
        let mut task_start: HashMap<u32, u64> = HashMap::new();
        for s in &res.segments {
            if s.kind == SegKind::Creation {
                continue;
            }
            let e = task_end.entry(s.task).or_insert(0);
            *e = (*e).max(s.end);
            let st = task_start.entry(s.task).or_insert(u64::MAX);
            *st = (*st).min(s.start);
        }
        for (t, preds) in g.preds.iter().enumerate() {
            for &pr in preds {
                let pred_end = task_end[&pr];
                let succ_start = task_start[&(t as u32)];
                assert!(
                    succ_start >= pred_end,
                    "seed {seed}: task {t} starts {succ_start} before pred {pr} ends {pred_end}"
                );
            }
        }

        // 4. Makespan bounded below by the critical path of pure compute
        //    (any device's best case can't beat the dependency chain).
        let smp_clock = board.smp_clock();
        let best_case = |t: u32| {
            let task = &p.tasks[t as usize];
            let smp_ps = smp_clock.cycles_to_ps(task.smp_cycles);
            accels
                .iter()
                .filter(|a| a.kernel == task.kernel)
                .map(|a| a.report.compute_ps())
                .min()
                .map(|acc| acc.min(smp_ps))
                .unwrap_or(smp_ps)
        };
        let cp = g.critical_path(&best_case);
        assert!(
            res.makespan >= cp,
            "seed {seed}: makespan {} < critical path {cp}",
            res.makespan
        );
    });
}

#[test]
fn prop_inout_chains_serialize_in_time() {
    // Directed check of the §IV semantics: tasks inout-chained on one
    // address never overlap, under any co-design.
    let board = BoardConfig::zynq706();
    forall(100, 0xC0FFEE, |seed, rng| {
        let mut p = TaskProgram::new("chain");
        p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::BOTH,
            profile: KernelProfile {
                flops: 10_000,
                inner_trip: 10_000,
                in_bytes: 4_096,
                out_bytes: 4_096,
                dtype_bytes: 4,
                divsqrt: false,
            },
        });
        let n = rng.gen_range(2, 30);
        for _ in 0..n {
            p.add_task(0, rng.gen_range(10_000, 100_000), vec![Dep::inout(0x42, 4_096)]);
        }
        let cd = random_codesign(rng, &p);
        let res = zynq_estimator::sim::estimate(&p, &cd, &board).unwrap();
        let mut intervals: Vec<(u64, u64)> = Vec::new();
        for s in &res.segments {
            if matches!(s.kind, SegKind::SmpCompute | SegKind::AccelTask) {
                intervals.push((s.start, s.end));
            }
        }
        intervals.sort_unstable();
        for w in intervals.windows(2) {
            assert!(w[1].0 >= w[0].1, "seed {seed}: chain tasks overlap");
        }
    });
}

#[test]
fn prop_dma_model_monotone() {
    let board = BoardConfig::zynq706();
    forall(500, 0xD1A, |seed, rng| {
        let b1 = rng.gen_range(1, 1 << 22);
        let b2 = b1 + rng.gen_range(1, 1 << 20);
        // Monotone in bytes.
        assert!(
            transfer_ps(b2, board.dma_bw_mbps) >= transfer_ps(b1, board.dma_bw_mbps),
            "seed {seed}"
        );
        // Input transfer non-increasing in accelerator count.
        let k1 = rng.gen_range(1, 8) as u32;
        let k2 = k1 + 1;
        let t1 = zynq_estimator::sim::dma::input_transfer_ps(&board, b1, k1);
        let t2 = zynq_estimator::sim::dma::input_transfer_ps(&board, b1, k2);
        assert!(t2 <= t1, "seed {seed}");
        // Output transfer invariant in accelerator count (shared channel).
        let o1 = zynq_estimator::sim::dma::output_transfer_ps(&board, b1, k1);
        let o2 = zynq_estimator::sim::dma::output_transfer_ps(&board, b1, k2);
        assert_eq!(o1, o2, "seed {seed}");
    });
}

#[test]
fn prop_hls_model_monotone_and_feasibility_antitone() {
    let board = BoardConfig::zynq706();
    let cm = CostModel::from_board(&board);
    let part = FpgaPart::xc7z045();
    forall(300, 0x8175, |seed, rng| {
        let profile = KernelProfile {
            flops: rng.gen_range(1_000, 10_000_000),
            inner_trip: rng.gen_range(1_000, 5_000_000),
            in_bytes: rng.gen_range(1_024, 1 << 20),
            out_bytes: rng.gen_range(1_024, 1 << 19),
            dtype_bytes: if rng.next_f64() < 0.5 { 4 } else { 8 },
            divsqrt: rng.next_f64() < 0.5,
        };
        let u1 = 1 << rng.gen_range(0, 6); // 1..32
        let u2 = u1 * 2;
        let r1 = cm.estimate("k", &profile, u1);
        let r2 = cm.estimate("k", &profile, u2);
        assert!(r2.compute_cycles <= r1.compute_cycles, "seed {seed}");
        assert!(r2.resources.dsps >= r1.resources.dsps, "seed {seed}");
        assert!(r2.resources.luts >= r1.resources.luts, "seed {seed}");
        assert!(r2.resources.bram18 >= r1.resources.bram18, "seed {seed}");
        // If the bigger variant fits n times, the smaller fits n times.
        let fits2 = part.fits(&[r2.resources, r2.resources]);
        let fits1 = part.fits(&[r1.resources, r1.resources]);
        if fits2 {
            assert!(fits1, "seed {seed}: feasibility must be antitone in unroll");
        }
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_value(rng: &mut Rng, depth: u32) -> json::Value {
        match rng.gen_range(0, if depth == 0 { 5 } else { 7 }) {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.next_f64() < 0.5),
            2 => json::Value::Int(rng.next_u64() as i64 / 2),
            3 => json::Value::Num((rng.next_f64() - 0.5) * 1e6),
            4 => {
                let n = rng.gen_range(0, 12);
                json::Value::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.gen_range(32, 127) as u8 as char;
                            if c == '\\' { 'x' } else { c }
                        })
                        .collect(),
                )
            }
            5 => {
                let n = rng.gen_range(0, 5);
                json::Value::Arr((0..n).map(|_| random_value(rng, depth - 1)).collect())
            }
            _ => {
                let n = rng.gen_range(0, 5);
                json::Value::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                        .collect(),
                )
            }
        }
    }
    forall(500, 0x15A4, |seed, rng| {
        let v = random_value(rng, 3);
        let text = v.to_json();
        let back = json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e} in {text}"));
        match (&v, &back) {
            (json::Value::Num(a), json::Value::Num(b)) => {
                assert!((a - b).abs() <= a.abs() * 1e-12, "seed {seed}")
            }
            _ => assert_eq!(v, back, "seed {seed}"),
        }
    });
}

#[test]
fn prop_trace_roundtrip_random_programs() {
    forall(100, 0x7ACE, |seed, rng| {
        let p = random_program(rng);
        let text = zynq_estimator::trace::write_trace(&p);
        let p2 = zynq_estimator::trace::read_trace(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(p.tasks.len(), p2.tasks.len(), "seed {seed}");
        for (a, b) in p.tasks.iter().zip(&p2.tasks) {
            assert_eq!(a.deps, b.deps, "seed {seed}");
            assert_eq!(a.smp_cycles, b.smp_cycles, "seed {seed}");
        }
    });
}

#[test]
fn prop_estimator_deterministic_board_seeded() {
    let board = BoardConfig::zynq706();
    forall(50, 0x5EED, |seed, rng| {
        let p = random_program(rng);
        let cd = random_codesign(rng, &p);
        let Ok(r1) = zynq_estimator::sim::estimate(&p, &cd, &board) else {
            return;
        };
        let r2 = zynq_estimator::sim::estimate(&p, &cd, &board).unwrap();
        assert_eq!(r1.makespan, r2.makespan, "seed {seed}");
        let b1 = zynq_estimator::sim::emulate(&p, &cd, &board).unwrap();
        let b2 = zynq_estimator::sim::emulate(&p, &cd, &board).unwrap();
        assert_eq!(b1.makespan, b2.makespan, "seed {seed}");
    });
}
