//! Paraver trace writer — the Extrae-equivalent output path (Fig. 7).
//!
//! The paper integrates its simulator with a modified Extrae so that the
//! estimated execution can be inspected in Paraver ("an approximate
//! visualization of what one would expect in a real task execution"). This
//! module writes the three-file Paraver bundle directly from a [`SimResult`]:
//!
//! * `.prv` — the trace: one thread row per device (SMP cores, FPGA
//!   accelerators, DMA submit, DMA output channels), state records for
//!   busy/idle intervals and event records carrying kernel / task-id /
//!   segment-kind, matching Fig. 7's row layout;
//! * `.pcf` — the config: state names, event types, kernel value tables
//!   and a colour palette;
//! * `.row` — the row labels.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use crate::config::BoardConfig;
use crate::coordinator::task::TaskProgram;
use crate::sim::engine::{DeviceLabel, SegKind, SimResult};

/// Event type ids (Extrae convention: user events in the 4xxxxxxx range).
pub const EV_KERNEL: u64 = 40_000_001;
/// Event type: segment kind (creation/compute/submit/DMA).
pub const EV_SEGKIND: u64 = 40_000_002;
/// Event type: task instance id.
pub const EV_TASKID: u64 = 40_000_003;

fn seg_state(kind: SegKind) -> u32 {
    match kind {
        SegKind::Creation => 2,
        SegKind::SmpCompute => 1,
        SegKind::AccelTask => 1,
        SegKind::SubmitIn | SegKind::SubmitOut => 3,
        SegKind::DmaIn | SegKind::DmaOut => 4,
    }
}

fn seg_kind_value(kind: SegKind) -> u64 {
    match kind {
        SegKind::Creation => 1,
        SegKind::SmpCompute => 2,
        SegKind::AccelTask => 3,
        SegKind::SubmitIn => 4,
        SegKind::SubmitOut => 5,
        SegKind::DmaIn => 6,
        SegKind::DmaOut => 7,
    }
}

/// The device → row mapping. Row order mirrors the paper's Fig. 7: SMP
/// first, accelerators in the middle, shared locked resources (output DMA,
/// submit) last.
pub fn device_rows(board: &BoardConfig, result: &SimResult) -> Vec<(DeviceLabel, String)> {
    let mut rows = Vec::new();
    for c in 0..board.smp_cores {
        rows.push((
            DeviceLabel::Smp(c),
            format!("SMP core {c}"),
        ));
    }
    for (i, k) in result.accel_kernels.iter().enumerate() {
        rows.push((
            DeviceLabel::Accel(i as u32),
            format!("FPGA acc {i} ({k})"),
        ));
    }
    let max_chan = result
        .segments
        .iter()
        .filter_map(|s| match s.device {
            DeviceLabel::DmaChan(n) => Some(n),
            _ => None,
        })
        .max()
        .unwrap_or(0);
    for n in 0..=max_chan {
        rows.push((DeviceLabel::DmaChan(n), format!("DMA out {n}")));
    }
    rows.push((DeviceLabel::DmaSubmit, "DMA submit".to_string()));
    rows
}

/// Render the `.prv` trace body. Times are nanoseconds (Paraver's usual
/// unit for Extrae traces).
pub fn to_prv(program: &TaskProgram, board: &BoardConfig, result: &SimResult) -> String {
    let rows = device_rows(board, result);
    let row_of: BTreeMap<DeviceLabel, usize> = rows
        .iter()
        .enumerate()
        .map(|(i, (d, _))| (*d, i + 1)) // Paraver ids are 1-based
        .collect();
    let dur_ns = result.makespan / 1000;
    let nthreads = rows.len();
    let mut out = String::new();
    // Header: #Paraver (dd/mm/yy at hh:mm):ftime:nNodes(nCpus):nAppl:applList
    out.push_str(&format!(
        "#Paraver (01/01/15 at 00:00):{dur_ns}:1({nthreads}):1:1({nthreads}:1)\n"
    ));

    // Sort segments per row by start for contiguous idle/busy states.
    let mut per_row: BTreeMap<usize, Vec<&crate::sim::engine::Segment>> = BTreeMap::new();
    for s in &result.segments {
        per_row
            .entry(row_of[&s.device])
            .or_default()
            .push(s);
    }
    for (row, segs) in &mut per_row {
        segs.sort_by_key(|s| s.start);
        let mut cursor = 0u64;
        for s in segs.iter() {
            let (b, e) = (s.start / 1000, s.end / 1000);
            if b > cursor {
                // Idle gap.
                let _ = writeln!(out, "1:{row}:1:1:{row}:{cursor}:{b}:0");
            }
            let _ = writeln!(out, "1:{row}:1:1:{row}:{b}:{e}:{}", seg_state(s.kind));
            // Events at segment start (kernel, kind, task id) and end
            // (value 0 = end marker), Extrae style.
            let _ = writeln!(
                out,
                "2:{row}:1:1:{row}:{b}:{EV_KERNEL}:{}:{EV_SEGKIND}:{}:{EV_TASKID}:{}",
                s.kernel as u64 + 1,
                seg_kind_value(s.kind),
                s.task as u64 + 1
            );
            let _ = writeln!(
                out,
                "2:{row}:1:1:{row}:{e}:{EV_KERNEL}:0:{EV_SEGKIND}:0:{EV_TASKID}:0"
            );
            cursor = e.max(cursor);
        }
        if cursor < dur_ns {
            let _ = writeln!(out, "1:{row}:1:1:{row}:{cursor}:{dur_ns}:0");
        }
    }
    let _ = program;
    out
}

/// Render the `.pcf` config.
pub fn to_pcf(program: &TaskProgram) -> String {
    let mut out = String::new();
    out.push_str("DEFAULT_OPTIONS\n\nLEVEL               THREAD\nUNITS               NANOSEC\n\n");
    out.push_str("STATES\n0    Idle\n1    Running\n2    Task creation\n3    DMA submit\n4    DMA transfer\n\n");
    out.push_str("STATES_COLOR\n0    {117,195,255}\n1    {0,0,255}\n2    {255,255,170}\n3    {174,129,255}\n4    {255,140,0}\n\n");
    out.push_str(&format!("EVENT_TYPE\n0    {EV_KERNEL}    Kernel name\nVALUES\n0      End\n"));
    for (i, k) in program.kernels.iter().enumerate() {
        out.push_str(&format!("{}      {}\n", i + 1, k.name));
    }
    out.push('\n');
    out.push_str(&format!(
        "EVENT_TYPE\n0    {EV_SEGKIND}    Segment kind\nVALUES\n0      End\n1      Creation\n2      SMP compute\n3      Accelerator task\n4      Submit in\n5      Submit out\n6      DMA in\n7      DMA out\n\n"
    ));
    out.push_str(&format!(
        "EVENT_TYPE\n0    {EV_TASKID}    Task instance\n\n"
    ));
    out
}

/// Render the `.row` labels.
pub fn to_row(board: &BoardConfig, result: &SimResult) -> String {
    let rows = device_rows(board, result);
    let mut out = format!("LEVEL THREAD SIZE {}\n", rows.len());
    for (_, name) in &rows {
        out.push_str(name);
        out.push('\n');
    }
    out
}

/// Write the three-file bundle `<stem>.prv/.pcf/.row`.
pub fn save_bundle(
    program: &TaskProgram,
    board: &BoardConfig,
    result: &SimResult,
    stem: &Path,
) -> anyhow::Result<()> {
    std::fs::write(stem.with_extension("prv"), to_prv(program, board, result))?;
    std::fs::write(stem.with_extension("pcf"), to_pcf(program))?;
    std::fs::write(stem.with_extension("row"), to_row(board, result))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::Matmul;
    use crate::config::CoDesign;
    use crate::sim::estimate;

    fn fixture() -> (TaskProgram, BoardConfig, SimResult) {
        let b = BoardConfig::zynq706();
        let app = Matmul::new(256, 64);
        let p = app.build_program(&b);
        let cd = CoDesign::new("1acc").with_accel("mxm64", 32);
        let r = estimate(&p, &cd, &b).unwrap();
        (p, b, r)
    }

    #[test]
    fn prv_header_and_records_well_formed() {
        let (p, b, r) = fixture();
        let prv = to_prv(&p, &b, &r);
        let mut lines = prv.lines();
        let header = lines.next().unwrap();
        assert!(header.starts_with("#Paraver "));
        // The date field contains ':'; the duration follows the first "):".
        let dur: u64 = header
            .split_once("):")
            .unwrap()
            .1
            .split(':')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(dur, r.makespan / 1000);
        for line in lines {
            let kind = line.split(':').next().unwrap();
            assert!(kind == "1" || kind == "2", "bad record: {line}");
            if kind == "1" {
                let f: Vec<u64> = line.split(':').skip(1).map(|x| x.parse().unwrap()).collect();
                assert!(f[4] <= f[5], "state begin after end: {line}");
                assert!(f[5] <= dur);
            }
        }
    }

    #[test]
    fn states_partition_each_row() {
        let (p, b, r) = fixture();
        let prv = to_prv(&p, &b, &r);
        let dur: u64 = prv
            .lines()
            .next()
            .unwrap()
            .split_once("):")
            .unwrap()
            .1
            .split(':')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        let mut per_row: BTreeMap<u64, Vec<(u64, u64)>> = BTreeMap::new();
        for line in prv.lines().skip(1).filter(|l| l.starts_with("1:")) {
            let f: Vec<u64> = line.split(':').skip(1).map(|x| x.parse().unwrap()).collect();
            per_row.entry(f[0]).or_default().push((f[4], f[5]));
        }
        for (_row, mut iv) in per_row {
            iv.sort_unstable();
            // Contiguous cover from 0 to dur (non-empty rows).
            assert_eq!(iv.first().unwrap().0, 0);
            assert_eq!(iv.last().unwrap().1, dur);
            for w in iv.windows(2) {
                assert_eq!(w[0].1, w[1].0, "states must tile the row");
            }
        }
    }

    #[test]
    fn pcf_lists_kernels_and_states() {
        let (p, _, _) = fixture();
        let pcf = to_pcf(&p);
        assert!(pcf.contains("mxm64"));
        assert!(pcf.contains("STATES"));
        assert!(pcf.contains("Segment kind"));
    }

    #[test]
    fn row_labels_match_fig7_layout() {
        let (_, b, r) = fixture();
        let row = to_row(&b, &r);
        let lines: Vec<&str> = row.lines().collect();
        assert!(lines[0].starts_with("LEVEL THREAD SIZE"));
        assert!(lines[1].starts_with("SMP core 0"));
        assert!(lines.iter().any(|l| l.starts_with("FPGA acc 0")));
        // Shared locked resources last (paper: "last two bars").
        assert!(lines.last().unwrap().starts_with("DMA submit"));
        assert!(lines[lines.len() - 2].starts_with("DMA out"));
    }

    #[test]
    fn bundle_written_to_disk() {
        let (p, b, r) = fixture();
        let dir = std::env::temp_dir().join("zynq_est_prv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let stem = dir.join("trace");
        save_bundle(&p, &b, &r, &stem).unwrap();
        for ext in ["prv", "pcf", "row"] {
            assert!(stem.with_extension(ext).exists());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
