//! Fig. 5 regeneration: tiled matmul, estimator vs board emulator over the
//! six co-designs, normalized to the slowest ("1acc 128 + smp" in the
//! paper). Shape to hold: best = 1acc 128 (FPGA only), "+smp" variants
//! collapse under the greedy policy, estimator optimistic but same trend.

use zynq_estimator::config::BoardConfig;
use zynq_estimator::experiments;
use zynq_estimator::util::bench::bench;

fn main() {
    let board = BoardConfig::zynq706();
    let table = experiments::fig5(512, &board, experiments::BOARD_REPS).unwrap();
    println!(
        "{}",
        table.render("Fig. 5: matmul 512x512 — estimator vs board emulator (normalized to slowest)")
    );

    // Harness timing: the cost of one full co-design analysis — the number
    // behind the paper's "less than 5 minutes of work (coffee break)".
    bench("fig5 full sweep (6 configs, est+10x board)", 1, 5, || {
        experiments::fig5(512, &board, experiments::BOARD_REPS).unwrap();
    });
    bench("fig5 estimator only (6 configs)", 1, 10, || {
        for (cd, app) in zynq_estimator::apps::matmul::fig5_cases(512) {
            let p = app.build_program(&board);
            zynq_estimator::sim::estimate(&p, &cd, &board).unwrap();
        }
    });
}
