//! Sweep-engine guarantees: parallel `explore()` is bit-identical to the
//! serial path (any worker count, any objective), and `SweepContext`
//! cached estimation equals a fresh `sim::estimate` for random co-designs
//! (seeded forall harness, same style as `proptests.rs`).

use zynq_estimator::apps::{cholesky::Cholesky, matmul::Matmul};
use zynq_estimator::config::{BoardConfig, CoDesign};
use zynq_estimator::coordinator::task::{
    Dep, Dir, KernelDecl, KernelProfile, TaskProgram, Targets,
};
use zynq_estimator::dse::{sweep, DsePoint, DseSpace, Objective, SweepContext};
use zynq_estimator::hls::FpgaPart;
use zynq_estimator::util::Rng;

fn forall(iters: u64, base_seed: u64, f: impl Fn(u64, &mut Rng)) {
    for i in 0..iters {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        f(seed, &mut rng);
    }
}

/// Random task program: 1-4 kernels (always SMP-capable, sometimes FPGA),
/// up to 60 tasks over a small shared address pool so dependences collide.
fn random_program(rng: &mut Rng) -> TaskProgram {
    let mut p = TaskProgram::new("prop");
    let n_kernels = rng.gen_range(1, 5);
    for k in 0..n_kernels {
        let fpga = rng.next_f64() < 0.7;
        p.add_kernel(KernelDecl {
            name: format!("k{k}"),
            targets: Targets { smp: true, fpga },
            profile: KernelProfile {
                flops: rng.gen_range(1_000, 1_000_000),
                inner_trip: rng.gen_range(1_000, 500_000),
                in_bytes: rng.gen_range(256, 65_536),
                out_bytes: rng.gen_range(256, 32_768),
                dtype_bytes: if rng.next_f64() < 0.5 { 4 } else { 8 },
                divsqrt: rng.next_f64() < 0.3,
            },
        });
    }
    let n_tasks = rng.gen_range(1, 61);
    let pool: Vec<u64> = (0..12).map(|i| 0x1000 + i * 0x1000).collect();
    for _ in 0..n_tasks {
        let kernel = rng.gen_range(0, n_kernels) as u16;
        let n_deps = rng.gen_range(1, 4);
        let mut deps = Vec::new();
        let mut used = std::collections::HashSet::new();
        for _ in 0..n_deps {
            let addr = pool[rng.gen_range(0, pool.len() as u64) as usize];
            if !used.insert(addr) {
                continue;
            }
            let dir = match rng.gen_range(0, 3) {
                0 => Dir::In,
                1 => Dir::Out,
                _ => Dir::InOut,
            };
            deps.push(Dep {
                addr,
                len: rng.gen_range(64, 16_384),
                dir,
            });
        }
        if deps.is_empty() {
            deps.push(Dep::inout(pool[0], 64));
        }
        p.add_task(kernel, rng.gen_range(1_000, 2_000_000), deps);
    }
    p
}

fn random_codesign(rng: &mut Rng, p: &TaskProgram) -> CoDesign {
    let mut cd = CoDesign::new("prop");
    for k in &p.kernels {
        if k.targets.fpga {
            let n_acc = rng.gen_range(0, 3);
            for _ in 0..n_acc {
                let unroll = 1 << rng.gen_range(1, 5); // 2..16
                cd = cd.with_accel(&k.name, unroll);
            }
            if n_acc > 0 && rng.next_f64() < 0.5 {
                cd = cd.with_smp(&k.name);
            }
        }
    }
    cd
}

fn assert_points_bit_identical(a: &[DsePoint], b: &[DsePoint], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: point count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.codesign.name, y.codesign.name, "{what}: name at rank {i}");
        assert_eq!(
            x.codesign.accels, y.codesign.accels,
            "{what}: accels at rank {i}"
        );
        assert_eq!(
            x.est_ms.to_bits(),
            y.est_ms.to_bits(),
            "{what}: est_ms at rank {i}"
        );
        assert_eq!(
            x.energy_j.to_bits(),
            y.energy_j.to_bits(),
            "{what}: energy_j at rank {i}"
        );
        assert_eq!(x.edp.to_bits(), y.edp.to_bits(), "{what}: edp at rank {i}");
        assert_eq!(
            x.fabric_util.to_bits(),
            y.fabric_util.to_bits(),
            "{what}: fabric_util at rank {i}"
        );
    }
}

#[test]
fn parallel_explore_is_bit_identical_to_serial() {
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    for (name, program) in [
        ("matmul", Matmul::new(512, 64).build_program(&board)),
        ("cholesky", Cholesky::new(256, 64).build_program(&board)),
    ] {
        let space = DseSpace::from_program(&program);
        let ctx = SweepContext::for_space(&program, &board, &part, &space);
        for objective in [Objective::Time, Objective::Energy, Objective::Edp] {
            let serial = ctx.explore(&space, objective, 1);
            for workers in [2, 3, 4, 8] {
                let parallel = ctx.explore(&space, objective, workers);
                assert_points_bit_identical(
                    &serial,
                    &parallel,
                    &format!("{name}/{objective:?}/workers={workers}"),
                );
            }
        }
    }
}

#[test]
fn parallel_explore_matches_seed_rebuild_baseline() {
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(512, 64).build_program(&board);
    let space = DseSpace::from_program(&program);
    let baseline =
        sweep::explore_rebuild_baseline(&program, &board, &part, &space, Objective::Time)
            .unwrap();
    let ctx = SweepContext::for_space(&program, &board, &part, &space);
    let parallel = ctx.explore(&space, Objective::Time, 4);
    assert_points_bit_identical(&baseline, &parallel, "matmul vs seed baseline");
}

#[test]
fn free_explore_wrapper_still_ranks_like_the_seed() {
    // The public entry point (parallel by default) must keep the seed's
    // headline result: the 2x half-unroll matmul discovery.
    let board = BoardConfig::zynq706();
    let program = Matmul::new(512, 128).build_program(&board);
    let space = DseSpace::from_program(&program);
    let pts = zynq_estimator::dse::explore(
        &program,
        &board,
        &FpgaPart::xc7z045(),
        &space,
        Objective::Time,
    )
    .unwrap();
    assert!(!pts.is_empty());
    for w in pts.windows(2) {
        assert!(w[0].est_ms <= w[1].est_ms, "ranking must be sorted");
    }
}

#[test]
fn prop_cached_estimation_equals_fresh_estimate() {
    let board = BoardConfig::zynq706();
    forall(60, 0x5EEB, |seed, rng| {
        let p = random_program(rng);
        let ctx = SweepContext::new(&p, &board, FpgaPart::xc7z045());
        for _ in 0..4 {
            let cd = random_codesign(rng, &p);
            let fresh = zynq_estimator::sim::estimate(&p, &cd, &board);
            let cached = ctx.estimate(&cd);
            match (fresh, cached) {
                (Ok(f), Ok(c)) => {
                    assert_eq!(f.makespan, c.makespan, "seed {seed}");
                    assert_eq!(f.tasks_on_smp, c.tasks_on_smp, "seed {seed}");
                    assert_eq!(f.tasks_on_accel, c.tasks_on_accel, "seed {seed}");
                    assert_eq!(f.device_busy, c.device_busy, "seed {seed}");
                    assert_eq!(f.segments.len(), c.segments.len(), "seed {seed}");
                }
                (Err(_), Err(_)) => {} // both reject: fine
                (f, c) => panic!(
                    "seed {seed}: paths disagree on feasibility (fresh ok={}, cached ok={})",
                    f.is_ok(),
                    c.is_ok()
                ),
            }
        }
    });
}

#[test]
fn prop_concurrent_identical_dse_requests_coalesce_to_one_evaluation() {
    // Service-layer determinism: N clients firing the same `dse` request
    // at one daemon must cost exactly one evaluation pass in total, for
    // any worker count. Clients that arrive while the leader is in
    // flight park and receive a clone of its reply (bitwise identical);
    // a client that arrives after completion re-runs warm and evaluates
    // nothing — either way the memo sees one evaluation.
    use std::sync::{Arc, Barrier};
    use zynq_estimator::service::{ServeConfig, Service};
    forall(6, 0xC0A1E5CE, |seed, rng| {
        let workers = 1 + rng.gen_range(0, 4) as usize;
        let n_clients = 2 + rng.gen_range(0, 6) as usize;
        let n = if rng.next_f64() < 0.5 { 128 } else { 256 };
        let cfg = ServeConfig {
            workers,
            ..ServeConfig::default()
        };
        let svc = Arc::new(Service::new(BoardConfig::zynq706(), cfg).unwrap());
        let req = format!(r#"{{"req":"dse","app":"matmul","n":{n},"top":5}}"#);
        let barrier = Arc::new(Barrier::new(n_clients));
        let handles: Vec<_> = (0..n_clients)
            .map(|_| {
                let svc = Arc::clone(&svc);
                let req = req.clone();
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    barrier.wait();
                    svc.handle_line(&req).0.expect("dse must answer")
                })
            })
            .collect();
        let responses: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let evaluated = |r: &str| {
            zynq_estimator::util::json::parse(r)
                .unwrap()
                .get("evaluated")
                .and_then(|v| v.as_u64())
                .unwrap()
        };
        let cold: Vec<&String> = responses.iter().filter(|r| evaluated(r) > 0).collect();
        assert!(!cold.is_empty(), "seed {seed}: someone must have evaluated");
        for r in &cold[1..] {
            assert_eq!(
                **r, *cold[0],
                "seed {seed} workers={workers}: coalesced responses diverged"
            );
        }
        assert_eq!(
            svc.evaluated(),
            evaluated(cold[0]),
            "seed {seed} workers={workers}: more than one evaluation pass for {n_clients} clients"
        );
        assert_eq!(svc.requests(), n_clients as u64, "seed {seed}");
        assert_eq!(svc.errors(), 0, "seed {seed}");
    });
}

#[test]
fn prop_worker_reuse_is_stateless_across_points() {
    // Evaluating A, then B, then A again through one reused worker must
    // reproduce A exactly — i.e. `Simulator::reset` leaks nothing.
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    forall(40, 0xA11C, |seed, rng| {
        let p = random_program(rng);
        let ctx = SweepContext::new(&p, &board, part.clone());
        let mut w = ctx.worker();
        let a = random_codesign(rng, &p);
        let b = random_codesign(rng, &p);
        let r1 = w.evaluate(&a);
        let _ = w.evaluate(&b);
        let r2 = w.evaluate(&a);
        match (r1, r2) {
            (Some(x), Some(y)) => {
                assert_eq!(x.est_ms.to_bits(), y.est_ms.to_bits(), "seed {seed}");
                assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "seed {seed}");
            }
            (None, None) => {}
            _ => panic!("seed {seed}: reused worker changed feasibility"),
        }
    });
}
