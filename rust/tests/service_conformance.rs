//! Black-box conformance suite for `zynq-estimator serve`: spawn the
//! real binary, drive NDJSON over its stdin/stdout (and a TCP
//! connection), and pin the protocol contracts down from the outside —
//! responses byte-identical to the one-shot CLI for the same queries,
//! structured errors mirroring the CLI exit-code taxonomy, round two of
//! a persisted session answered entirely from the memo, and a process
//! killed mid-query (injected `eval.point!abort`) losing at most the
//! in-flight round.
//!
//! Everything here goes through child processes, so the suite exercises
//! the same faultpoint env plumbing (`ZYNQ_FAULTS`) real deployments
//! use; no in-process faultpoint arming.

use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};

use zynq_estimator::dse::SweepJournal;
use zynq_estimator::util::json::{parse, Value};

const EXE: &str = env!("CARGO_BIN_EXE_zynq-estimator");

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("zynq_serve_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// One daemon child with its NDJSON pipe pair.
struct Daemon {
    child: Child,
    stdin: Option<ChildStdin>,
    stdout: BufReader<ChildStdout>,
}

impl Daemon {
    fn spawn(args: &[&str], faults: Option<&str>) -> Daemon {
        let mut cmd = Command::new(EXE);
        cmd.arg("serve").args(args);
        cmd.stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::null());
        match faults {
            Some(f) => cmd.env("ZYNQ_FAULTS", f),
            None => cmd.env_remove("ZYNQ_FAULTS"),
        };
        let mut child = cmd.spawn().expect("spawn serve daemon");
        let stdin = child.stdin.take().unwrap();
        let stdout = BufReader::new(child.stdout.take().unwrap());
        Daemon {
            child,
            stdin: Some(stdin),
            stdout,
        }
    }

    /// Send one request line, read one response line. `None` when the
    /// daemon died instead of answering (the injected-abort leg).
    fn request(&mut self, line: &str) -> Option<Value> {
        let stdin = self.stdin.as_mut().expect("stdin already closed");
        if writeln!(stdin, "{line}").and_then(|_| stdin.flush()).is_err() {
            return None;
        }
        let mut buf = String::new();
        match self.stdout.read_line(&mut buf) {
            Ok(0) | Err(_) => None,
            Ok(_) => Some(parse(buf.trim_end()).expect("response must be one JSON object")),
        }
    }

    /// Close stdin and reap the child.
    fn wait(mut self) -> std::process::ExitStatus {
        drop(self.stdin.take());
        self.child.wait().expect("wait on daemon")
    }
}

/// Send `shutdown`, assert the acknowledged exit code, reap the child.
fn shutdown_clean(mut daemon: Daemon) {
    let resp = daemon.request(r#"{"req":"shutdown"}"#).expect("shutdown ack");
    assert!(is_ok(&resp), "{resp:?}");
    assert_eq!(resp.get("exit_code").and_then(|v| v.as_i64()), Some(0));
    let status = daemon.wait();
    assert!(status.success(), "clean shutdown must exit 0: {status:?}");
}

fn one_shot(args: &[&str]) -> std::process::Output {
    let mut cmd = Command::new(EXE);
    cmd.args(args);
    cmd.env_remove("ZYNQ_FAULTS");
    cmd.output().expect("run one-shot CLI")
}

fn is_ok(v: &Value) -> bool {
    v.get("ok").and_then(|x| x.as_bool()) == Some(true)
}

fn text(v: &Value) -> &str {
    v.get("text").and_then(|x| x.as_str()).expect("text field")
}

fn u(v: &Value, key: &str) -> u64 {
    v.get(key)
        .and_then(|x| x.as_u64())
        .unwrap_or_else(|| panic!("missing u64 field '{key}' in {v:?}"))
}

const EST_A: &str = r#"{"id":1,"req":"estimate","app":"matmul","n":256,"bs":64,"accel":["mxm64:U32"]}"#;
const EST_B: &str = r#"{"id":2,"req":"estimate","app":"matmul","n":256,"bs":64,"accel":["mxm64:U16"]}"#;
const ENERGY_A: &str = r#"{"id":3,"req":"energy","app":"matmul","n":256,"bs":64,"accel":["mxm64:U32"]}"#;
const LU_A: &str = r#"{"id":21,"req":"estimate","app":"lu","n":256,"bs":64,"accel":["trsm_row:U16"]}"#;
const LU_B: &str = r#"{"id":22,"req":"estimate","app":"lu","n":256,"bs":64,"accel":["lugemm:U8"]}"#;
const CH_A: &str = r#"{"id":31,"req":"estimate","app":"cholesky","n":128,"bs":64,"accel":["dgemm:U16"]}"#;
const CH_B: &str = r#"{"id":32,"req":"estimate","app":"cholesky","n":128,"bs":64,"accel":["dsyrk:U8"]}"#;

/// Spawn `serve --listen 127.0.0.1:0 <args>` and parse the bound address
/// off stderr. Always port 0: a fixed port collides the moment two CI
/// jobs (or two test binaries) run in parallel. The stderr reader is
/// returned alive so the child never sees a closed pipe.
fn spawn_tcp(
    args: &[&str],
) -> (
    Child,
    ChildStdin,
    String,
    BufReader<std::process::ChildStderr>,
) {
    let mut cmd = Command::new(EXE);
    cmd.arg("serve").args(args).args(["--listen", "127.0.0.1:0"]);
    cmd.stdin(Stdio::piped())
        .stdout(Stdio::null())
        .stderr(Stdio::piped());
    cmd.env_remove("ZYNQ_FAULTS");
    let mut child = cmd.spawn().expect("spawn TCP daemon");
    let stdin = child.stdin.take().unwrap();
    let mut stderr = BufReader::new(child.stderr.take().unwrap());
    let addr = loop {
        let mut line = String::new();
        assert!(
            stderr.read_line(&mut line).unwrap() > 0,
            "daemon exited before announcing its listener"
        );
        if let Some(rest) = line.trim().strip_prefix("serve: listening on ") {
            break rest.to_string();
        }
    };
    (child, stdin, addr, stderr)
}

#[test]
fn daemon_point_responses_are_byte_identical_to_the_one_shot_cli() {
    let mut daemon = Daemon::spawn(&[], None);
    let est = daemon.request(EST_A).unwrap();
    assert!(is_ok(&est), "{est:?}");
    assert_eq!(u(&est, "evaluated"), 1, "cold daemon must evaluate");
    let energy = daemon.request(ENERGY_A).unwrap();
    assert!(is_ok(&energy), "{energy:?}");
    assert_eq!(u(&energy, "evaluated"), 0, "energy view reuses the estimate's entry");
    shutdown_clean(daemon);

    // The same queries through the one-shot CLI: stdout must equal the
    // daemon's `text` field byte for byte (shared query core).
    let cli_est = one_shot(&[
        "estimate", "--app", "matmul", "--n", "256", "--bs", "64", "--accel", "mxm64:U32",
    ]);
    assert!(cli_est.status.success(), "{}", String::from_utf8_lossy(&cli_est.stderr));
    assert_eq!(
        String::from_utf8(cli_est.stdout).unwrap(),
        text(&est),
        "daemon estimate text diverged from the one-shot CLI"
    );
    let cli_energy = one_shot(&[
        "energy", "--app", "matmul", "--n", "256", "--bs", "64", "--accel", "mxm64:U32",
    ]);
    assert!(cli_energy.status.success());
    assert_eq!(
        String::from_utf8(cli_energy.stdout).unwrap(),
        text(&energy),
        "daemon energy text diverged from the one-shot CLI"
    );
    assert!(text(&est).starts_with("== estimate: matmul n=256 bs=64"));
    assert!(text(&energy).contains("total energy:"));
}

#[test]
fn one_shot_estimate_and_energy_share_one_memo_entry_across_invocations() {
    // The regression the service work fixed: a second identical one-shot
    // invocation must be answered from the persistent memo, with
    // bit-identical stdout.
    let d = tmpdir("oneshot_memo");
    let memo = d.join("memo.json").display().to_string();
    let args = [
        "estimate", "--app", "matmul", "--n", "192", "--bs", "64", "--accel", "mxm64:U16",
        "--memo", &memo,
    ];
    let first = one_shot(&args);
    assert!(first.status.success(), "{}", String::from_utf8_lossy(&first.stderr));
    assert!(
        String::from_utf8_lossy(&first.stderr).contains("miss, 1 point evaluated and recorded"),
        "first run must record"
    );
    let second = one_shot(&args);
    assert!(second.status.success());
    assert_eq!(first.stdout, second.stdout, "memo hit changed the reported numbers");
    assert!(
        String::from_utf8_lossy(&second.stderr).contains("L2 hit, 0 points evaluated"),
        "second run must be a pure memo hit: {}",
        String::from_utf8_lossy(&second.stderr)
    );
    // `energy` on the same co-design reads the same entry (one cache).
    let energy = one_shot(&[
        "energy", "--app", "matmul", "--n", "192", "--bs", "64", "--accel", "mxm64:U16",
        "--memo", &memo,
    ]);
    assert!(energy.status.success());
    assert!(
        String::from_utf8_lossy(&energy.stderr).contains("L2 hit, 0 points evaluated"),
        "energy must hit the entry estimate recorded"
    );
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn malformed_requests_answer_structured_errors_and_the_daemon_survives() {
    let mut daemon = Daemon::spawn(&[], None);
    let bad = daemon.request("this is not json").unwrap();
    assert!(!is_ok(&bad));
    assert_eq!(u(&bad, "code"), 1, "malformed line is the usage class");
    let unknown = daemon.request(r#"{"id":5,"req":"frobnicate"}"#).unwrap();
    assert_eq!(u(&unknown, "code"), 2, "unknown request mirrors CLI exit 2");
    assert_eq!(unknown.get("id").and_then(|v| v.as_i64()), Some(5));
    let missing = daemon.request(r#"{"req":"estimate"}"#).unwrap();
    assert_eq!(u(&missing, "code"), 1, "missing 'app' is a usage error");
    let unsat = daemon
        .request(r#"{"req":"estimate","app":"nosuchapp"}"#)
        .unwrap();
    assert_eq!(u(&unsat, "code"), 1);
    // The daemon still serves after every error class.
    let ping = daemon.request(r#"{"req":"ping"}"#).unwrap();
    assert!(is_ok(&ping), "{ping:?}");
    assert_eq!(text(&ping), "pong\n");
    shutdown_clean(daemon);
}

#[test]
fn health_probe_reports_readiness_without_consuming_admission_capacity() {
    // `health` must answer on a daemon whose admission would reject all
    // work (--max-inflight floor of 1 still admits; use the probe both
    // before and after real traffic to pin its shape).
    let mut daemon = Daemon::spawn(&["--lanes", "2"], None);
    let before = daemon.request(r#"{"id":1,"req":"health"}"#).unwrap();
    assert!(is_ok(&before), "{before:?}");
    assert_eq!(before.get("ready").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(before.get("degraded").and_then(|v| v.as_bool()), Some(false));
    assert_eq!(before.get("draining").and_then(|v| v.as_bool()), Some(false));
    assert_eq!(u(&before, "lanes"), 2);
    assert_eq!(u(&before, "inflight"), 0);
    assert!(text(&before).starts_with("health: ready"), "{}", text(&before));
    let est = daemon.request(EST_A).unwrap();
    assert!(is_ok(&est), "{est:?}");
    let after = daemon.request(r#"{"id":2,"req":"health"}"#).unwrap();
    assert_eq!(u(&after, "inflight"), 0, "finished work must release its token");
    assert_eq!(u(&after, "timeouts"), 0);
    assert_eq!(u(&after, "overloaded"), 0);
    assert_eq!(u(&after, "degraded_rejects"), 0);
    shutdown_clean(daemon);
}

#[test]
fn round_two_is_answered_entirely_from_the_persistent_memo() {
    let d = tmpdir("two_rounds");
    let memo = d.join("serve-memo.json").display().to_string();
    let dse = r#"{"id":4,"req":"dse","app":"matmul","n":128,"top":5}"#;
    let batch = [EST_A, EST_B, ENERGY_A, dse];

    let mut round1 = Vec::new();
    let mut daemon = Daemon::spawn(&["--memo", &memo, "--workers", "2"], None);
    for req in batch {
        let resp = daemon.request(req).unwrap();
        assert!(is_ok(&resp), "{resp:?}");
        round1.push(resp);
    }
    shutdown_clean(daemon);
    assert!(
        round1.iter().map(|r| u(r, "evaluated")).sum::<u64>() > 0,
        "round 1 must evaluate something"
    );
    assert!(
        !SweepJournal::wal_path(d.join("serve-memo.json").as_path()).exists(),
        "a clean shutdown save must delete the WAL"
    );

    let mut daemon = Daemon::spawn(&["--memo", &memo, "--workers", "2"], None);
    for (req, first) in batch.iter().zip(&round1) {
        let resp = daemon.request(req).unwrap();
        assert!(is_ok(&resp), "{resp:?}");
        assert_eq!(
            u(&resp, "evaluated"),
            0,
            "round 2 must be answered entirely from the memo: {req}"
        );
        if *req != dse {
            assert_eq!(u(&resp, "l2_hits"), 1, "{req}");
            assert_eq!(
                text(&resp),
                text(first),
                "round 2 text diverged from round 1: {req}"
            );
        }
    }
    shutdown_clean(daemon);
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn daemon_dse_text_is_a_prefix_of_the_one_shot_cli_output() {
    // Same query, same worker count, both starting cold: the daemon's
    // `text` (ranking table + pruning line) must be a byte-exact prefix
    // of `dse --memo` stdout, which only appends memo/timing lines.
    let mut daemon = Daemon::spawn(&["--workers", "2"], None);
    let resp = daemon
        .request(r#"{"id":1,"req":"dse","app":"matmul","n":128}"#)
        .unwrap();
    assert!(is_ok(&resp), "{resp:?}");
    shutdown_clean(daemon);
    let dse_text = text(&resp);
    assert!(dse_text.contains("pruning: "), "{dse_text}");

    let d = tmpdir("dse_prefix");
    let memo = d.join("fresh.json").display().to_string();
    let cli = one_shot(&[
        "dse", "--app", "matmul", "--n", "128", "--memo", &memo, "--workers", "2",
    ]);
    assert!(cli.status.success(), "{}", String::from_utf8_lossy(&cli.stderr));
    let stdout = String::from_utf8(cli.stdout).unwrap();
    assert!(
        stdout.starts_with(dse_text),
        "daemon dse text is not a prefix of the CLI output:\n--- daemon\n{dse_text}\n--- cli\n{stdout}"
    );
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn kill_mid_query_loses_at_most_the_in_flight_round() {
    let d = tmpdir("abort");
    let memo_path = d.join("serve-memo.json");
    let memo = memo_path.display().to_string();

    // Session 1: evaluate A, shut down cleanly (memo saved).
    let mut daemon = Daemon::spawn(&["--memo", &memo], None);
    let first = daemon.request(EST_A).unwrap();
    assert_eq!(u(&first, "evaluated"), 1);
    shutdown_clean(daemon);
    let snapshot = std::fs::read(&memo_path).unwrap();

    // Session 2, with `eval.point!abort` armed through the environment:
    // the memo hit for A needs no evaluation (the fault stays cold), the
    // fresh point B aborts the process mid-evaluation — the stand-in for
    // kill -9 while a query is in flight.
    let mut daemon = Daemon::spawn(&["--memo", &memo], Some("eval.point!abort"));
    let hit = daemon.request(EST_A).expect("memo hit must not evaluate");
    assert_eq!(u(&hit, "evaluated"), 0);
    assert_eq!(text(&hit), text(&first), "hit text diverged after restart");
    let dead = daemon.request(EST_B);
    assert!(dead.is_none(), "the armed abort must kill the daemon mid-query");
    let status = daemon.wait();
    assert!(!status.success(), "aborted daemon must not exit cleanly");
    assert_eq!(
        std::fs::read(&memo_path).unwrap(),
        snapshot,
        "the crash must not touch the saved memo"
    );
    assert!(
        !SweepJournal::wal_path(&memo_path).exists(),
        "the aborted evaluation never committed a WAL round"
    );

    // Session 3: only the in-flight query was lost — A still answers
    // bit-identically from the memo, B evaluates fresh.
    let mut daemon = Daemon::spawn(&["--memo", &memo], None);
    let again = daemon.request(EST_A).unwrap();
    assert_eq!(u(&again, "evaluated"), 0);
    assert_eq!(text(&again), text(&first));
    let fresh = daemon.request(EST_B).unwrap();
    assert!(is_ok(&fresh), "{fresh:?}");
    assert_eq!(u(&fresh, "evaluated"), 1, "the lost point re-evaluates");
    let stats = daemon.request(r#"{"req":"memo","action":"stats"}"#).unwrap();
    assert_eq!(u(&stats, "points"), 2, "both points recorded after recovery");
    shutdown_clean(daemon);
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn sharded_daemon_answers_concurrent_tcp_clients_with_sequential_bytes() {
    // Three clients, each owning one app (apps are kernel-disjoint, so
    // each client's context state lives in one lane): any interleaving
    // against `--lanes 4` must reproduce, byte for byte, the responses a
    // single-lane daemon gives the same per-client sequences run one
    // after another — and cost exactly the distinct cold points.
    let sequences: [&[&str]; 3] = [
        &[EST_A, EST_B, EST_A],
        &[LU_A, LU_B, LU_A],
        &[CH_A, CH_B, CH_A],
    ];
    let mut reference = Daemon::spawn(&["--workers", "2"], None);
    let mut expect: Vec<Vec<String>> = Vec::new();
    for seq in sequences {
        expect.push(
            seq.iter()
                .map(|r| reference.request(r).unwrap().to_json())
                .collect(),
        );
    }
    shutdown_clean(reference);

    let (mut child, stdin, addr, _stderr) = spawn_tcp(&["--lanes", "4", "--workers", "2"]);
    let handles: Vec<_> = sequences
        .iter()
        .map(|seq| {
            let addr = addr.clone();
            let seq: Vec<String> = seq.iter().map(|s| s.to_string()).collect();
            std::thread::spawn(move || {
                let stream = std::net::TcpStream::connect(&addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut out = Vec::new();
                for req in &seq {
                    writeln!(&stream, "{req}").unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    out.push(parse(line.trim()).unwrap().to_json());
                }
                out
            })
        })
        .collect();
    let got: Vec<Vec<String>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(
        got, expect,
        "sharded concurrent responses diverged from the single-lane sequential run"
    );

    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(&stream, "{}", r#"{"req":"memo","action":"stats"}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let stats = parse(line.trim()).unwrap();
    assert_eq!(
        u(&stats, "total_evaluated"),
        6,
        "aggregate evaluations must equal the distinct cold points"
    );
    assert_eq!(u(&stats, "lanes"), 4);
    writeln!(&stream, "{}", r#"{"req":"shutdown"}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let ack = parse(line.trim()).unwrap();
    assert!(is_ok(&ack), "{ack:?}");
    let status = child.wait().unwrap();
    assert!(status.success(), "clean TCP shutdown must exit 0: {status:?}");
    drop(stdin);
}

#[test]
fn batch_envelope_answers_equal_the_standalone_lines_black_box() {
    // The same three queries as standalone lines on one fresh daemon and
    // as one `batch` envelope on another: each item object must equal
    // the standalone response line, and the envelope's aggregate must
    // count one evaluation (estimate A) + one (estimate B) with the
    // energy view riding A's entry.
    let mut seq = Daemon::spawn(&[], None);
    let expect: Vec<String> = [EST_A, EST_B, ENERGY_A]
        .iter()
        .map(|r| seq.request(r).unwrap().to_json())
        .collect();
    shutdown_clean(seq);

    let mut daemon = Daemon::spawn(&["--lanes", "2"], None);
    let envelope = format!(r#"{{"id":10,"req":"batch","items":[{EST_A},{EST_B},{ENERGY_A}]}}"#);
    let resp = daemon.request(&envelope).unwrap();
    assert!(is_ok(&resp), "{resp:?}");
    assert_eq!(u(&resp, "evaluated"), 2, "two cold points, one energy hit");
    assert_eq!(u(&resp, "items_failed"), 0);
    let Some(Value::Arr(items)) = resp.get("items") else {
        panic!("batch response must carry items: {resp:?}");
    };
    assert_eq!(items.len(), 3);
    for (i, (item, exp)) in items.iter().zip(&expect).enumerate() {
        assert_eq!(
            item.to_json(),
            *exp,
            "batch item {i} diverged from its standalone response line"
        );
    }
    shutdown_clean(daemon);
}

#[test]
fn kill_mid_batch_loses_at_most_the_in_flight_round_per_shard() {
    let d = tmpdir("abort_batch");
    let memo_path = d.join("serve-memo.json");
    let memo = memo_path.display().to_string();
    let args = ["--lanes", "4", "--memo", memo.as_str()];
    let warm_batch = format!(r#"{{"id":1,"req":"batch","items":[{EST_A},{LU_A}]}}"#);
    let cold_batch = format!(r#"{{"id":2,"req":"batch","items":[{EST_B},{LU_B}]}}"#);

    // Session 1: two cold points (two lanes) in one batch, clean shutdown.
    let mut daemon = Daemon::spawn(&args, None);
    let warm = daemon.request(&warm_batch).unwrap();
    assert!(is_ok(&warm), "{warm:?}");
    assert_eq!(u(&warm, "evaluated"), 2);
    shutdown_clean(daemon);
    let snapshot = std::fs::read(&memo_path).unwrap();

    // Session 2, `eval.point!abort` armed: the all-hit batch answers
    // without evaluating (the fault stays cold), the cold batch aborts
    // the process mid-round — kill -9 while a batch round is in flight.
    let mut daemon = Daemon::spawn(&args, Some("eval.point!abort"));
    let hits = daemon.request(&warm_batch).expect("all-hit batch must answer");
    assert_eq!(u(&hits, "evaluated"), 0);
    let dead = daemon.request(&cold_batch);
    assert!(dead.is_none(), "the armed abort must kill the daemon mid-batch");
    let status = daemon.wait();
    assert!(!status.success());
    assert_eq!(
        std::fs::read(&memo_path).unwrap(),
        snapshot,
        "the crash must not touch the saved memo"
    );
    for wal in SweepJournal::shard_wal_paths(&memo_path) {
        let wal_text = std::fs::read_to_string(&wal).unwrap();
        assert!(
            !wal_text.contains(r#""t":"commit""#),
            "{}: the aborted round must not have committed to any shard WAL",
            wal.display()
        );
    }

    // Session 3: only the in-flight round was lost, on every shard.
    let mut daemon = Daemon::spawn(&args, None);
    let again = daemon.request(&warm_batch).unwrap();
    assert_eq!(u(&again, "evaluated"), 0, "saved points answer from the memo");
    let fresh = daemon.request(&cold_batch).unwrap();
    assert_eq!(u(&fresh, "evaluated"), 2, "the lost points re-evaluate");
    let stats = daemon.request(r#"{"req":"memo","action":"stats"}"#).unwrap();
    assert_eq!(u(&stats, "points"), 4, "all four points recorded after recovery");
    shutdown_clean(daemon);
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn degraded_multi_lane_daemon_recovers_from_shard_wals() {
    let d = tmpdir("degraded_lanes");
    let memo_path = d.join("m.json");
    let memo = memo_path.display().to_string();

    // The only save attempt (at shutdown — the default cadence never
    // fires for two evaluations) fails: the shard WALs are the only
    // persistence. The daemon acknowledges the degraded shutdown, exits
    // 1, and a faultless restart replays every shard's committed rounds.
    let mut daemon = Daemon::spawn(&["--lanes", "4", "--memo", &memo], Some("memo.save!error"));
    assert_eq!(u(&daemon.request(EST_A).unwrap(), "evaluated"), 1);
    assert_eq!(u(&daemon.request(LU_A).unwrap(), "evaluated"), 1);
    let ack = daemon.request(r#"{"req":"shutdown"}"#).unwrap();
    assert_eq!(ack.get("exit_code").and_then(|v| v.as_i64()), Some(1));
    assert!(text(&ack).contains("DEGRADED"), "{}", text(&ack));
    let status = daemon.wait();
    assert!(!status.success(), "degraded daemon must exit non-zero");
    assert!(!memo_path.exists(), "no save ever succeeded");
    assert!(
        !SweepJournal::shard_wal_paths(&memo_path).is_empty(),
        "the shard WALs must retain the unsaved rounds"
    );

    let mut daemon = Daemon::spawn(&["--lanes", "4", "--memo", &memo], None);
    assert_eq!(
        u(&daemon.request(EST_A).unwrap(), "evaluated"),
        0,
        "point A must recover from its shard WAL"
    );
    assert_eq!(
        u(&daemon.request(LU_A).unwrap(), "evaluated"),
        0,
        "point B must recover from its shard WAL"
    );
    shutdown_clean(daemon);
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn tcp_transport_speaks_the_same_protocol() {
    let mut cmd = Command::new(EXE);
    cmd.args(["serve", "--listen", "127.0.0.1:0"]);
    cmd.stdin(Stdio::piped())
        .stdout(Stdio::null())
        .stderr(Stdio::piped());
    cmd.env_remove("ZYNQ_FAULTS");
    let mut child = cmd.spawn().unwrap();
    // Keep stdin open: EOF on stdin is a graceful shutdown.
    let stdin = child.stdin.take().unwrap();
    let mut stderr = BufReader::new(child.stderr.take().unwrap());
    let addr = loop {
        let mut line = String::new();
        assert!(
            stderr.read_line(&mut line).unwrap() > 0,
            "daemon exited before announcing its listener"
        );
        if let Some(rest) = line.trim().strip_prefix("serve: listening on ") {
            break rest.to_string();
        }
    };
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = &stream;
    writeln!(writer, "{}", r#"{"id":1,"req":"ping"}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let pong = parse(line.trim()).unwrap();
    assert!(is_ok(&pong), "{pong:?}");
    assert_eq!(text(&pong), "pong\n");
    // A TCP shutdown acknowledges, then exits the whole process.
    writeln!(writer, "{}", r#"{"req":"shutdown"}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let ack = parse(line.trim()).unwrap();
    assert!(is_ok(&ack), "{ack:?}");
    assert_eq!(ack.get("exit_code").and_then(|v| v.as_i64()), Some(0));
    let status = child.wait().unwrap();
    assert!(status.success(), "TCP shutdown must exit 0: {status:?}");
    drop(stdin);
}
