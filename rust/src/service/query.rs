//! Memo-backed query core shared by the one-shot CLI and the daemon.
//!
//! Byte-identity between `zynq-estimator estimate ...` and the daemon's
//! `{"req":"estimate",...}` response is not asserted after the fact — it
//! is guaranteed by construction: both entry points call the same
//! functions here, and the rendered report is derived **only** from the
//! [`MemoValues`] bit patterns, never from transient simulation state. A
//! level-2 memo hit therefore prints the exact bytes the original
//! evaluation printed, whether it happened in this process, a previous
//! CLI invocation, or a daemon three restarts ago.

use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::task::TaskProgram;
use crate::dse::warm::{codesign_key, context_fingerprint, MemoValues};
use crate::dse::{DsePoint, DseSpace, EvalMemo, KernelSpace, SweepContext, SweepJournal};
use crate::hls::FpgaPart;
use crate::util::json::Value;

use super::proto::{DseQuery, QueryReply};

/// The minimal [`DseSpace`] covering exactly one co-design: per distinct
/// kernel, the sorted deduplicated unroll set, an instance cap equal to
/// the requested instance count, and SMP enablement from the `+ smp`
/// list. Priming a [`SweepContext`] for this space runs the cost model
/// (or level-1 sub-memo) for precisely the reports the point needs.
pub fn space_for_codesign(cd: &CoDesign) -> DseSpace {
    let mut kernels: Vec<KernelSpace> = Vec::new();
    for a in &cd.accels {
        match kernels.iter_mut().find(|k| k.kernel == a.kernel) {
            Some(k) => {
                k.unrolls.push(a.unroll);
                k.max_instances += 1;
            }
            None => kernels.push(KernelSpace {
                kernel: a.kernel.clone(),
                unrolls: vec![a.unroll],
                max_instances: 1,
                try_smp: cd.smp_kernels.contains(&a.kernel),
            }),
        }
    }
    for k in &mut kernels {
        k.unrolls.sort_unstable();
        k.unrolls.dedup();
    }
    // SMP-only kernels (no accelerator instance) still matter to the key
    // space, but they need no HLS report; `resolve` handles them.
    DseSpace {
        kernels,
        mixed: false,
    }
}

/// The union [`DseSpace`] covering several co-designs at once: per
/// distinct kernel across all of them, the merged sorted unroll set, the
/// largest instance count any one co-design requests, and SMP enablement
/// when any co-design asks for it. The daemon's batch path primes one
/// evaluation context for a whole group of cold points from this space —
/// the space only governs which HLS reports get primed, so a superset
/// space cannot change any individual evaluation.
pub fn space_for_codesigns(cds: &[CoDesign]) -> DseSpace {
    let mut kernels: Vec<KernelSpace> = Vec::new();
    for cd in cds {
        for ks in space_for_codesign(cd).kernels {
            match kernels.iter_mut().find(|k| k.kernel == ks.kernel) {
                Some(k) => {
                    k.unrolls.extend(ks.unrolls);
                    k.max_instances = k.max_instances.max(ks.max_instances);
                    k.try_smp = k.try_smp || ks.try_smp;
                }
                None => kernels.push(ks),
            }
        }
    }
    for k in &mut kernels {
        k.unrolls.sort_unstable();
        k.unrolls.dedup();
    }
    DseSpace {
        kernels,
        mixed: false,
    }
}

/// Points evaluated ahead of the memo bookkeeping. The daemon's batch
/// path runs one chunk-synchronous worker-pool round over every cold
/// point of a batch (under a shared memo read lock, so distinct lanes
/// evaluate concurrently), then feeds each result to
/// [`point_query_prepared`] in request order. An evaluation is a pure
/// function of (context, co-design) — bit-identical whether it runs here
/// or inline — so consuming a pre-evaluated point cannot change a single
/// response byte; it only changes where and when the simulation ran.
#[derive(Default)]
pub struct PreEvaluated {
    /// Evaluated points keyed by canonical co-design key.
    pub points: std::collections::BTreeMap<String, DsePoint>,
}

/// Evaluate every *cold* co-design of `cds` — deduplicated by canonical
/// key, first arrival wins — in one chunk-synchronous worker-pool round.
/// `fingerprint` must be the context fingerprint of `(program, board,
/// part)` (the daemon caches it per context). Co-designs that do not
/// resolve (unknown kernel, kernel with no device) are skipped here; the
/// inline path of [`point_query_prepared`] reports their error.
pub fn pre_evaluate(
    program: &TaskProgram,
    board: &BoardConfig,
    part: &FpgaPart,
    fingerprint: u64,
    cds: &[CoDesign],
    memo: &EvalMemo,
    workers: usize,
) -> PreEvaluated {
    let mut cold: Vec<CoDesign> = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for cd in cds {
        let key = codesign_key(cd);
        if memo.lookup(fingerprint, &key).is_none() && seen.insert(key) {
            cold.push(cd.clone());
        }
    }
    if cold.is_empty() {
        return PreEvaluated::default();
    }
    let space = space_for_codesigns(&cold);
    let ctx = SweepContext::for_space_warm(program, board, part, &space, memo);
    let evaluable: Vec<CoDesign> = cold
        .into_iter()
        .filter(|cd| ctx.resolve(cd).is_ok())
        .collect();
    let points = ctx.evaluate_all(&evaluable, workers);
    PreEvaluated {
        points: points
            .into_iter()
            .map(|p| (codesign_key(&p.codesign), p))
            .collect(),
    }
}

/// Outcome of a point query: the reply plus whether it was a level-2 hit.
pub struct PointOutcome {
    /// The rendered reply (CLI stdout bytes + counters + exact bits).
    pub reply: QueryReply,
    /// Exact recorded numbers the reply was rendered from.
    pub values: MemoValues,
    /// `true` when the memo answered without re-simulation.
    pub hit: bool,
}

fn bits_extra(values: &MemoValues) -> Vec<(String, Value)> {
    vec![
        ("est_ms_bits".into(), values.est_ms.to_bits().into()),
        ("energy_j_bits".into(), values.energy_j.to_bits().into()),
        ("edp_bits".into(), values.edp.to_bits().into()),
        (
            "fabric_util_bits".into(),
            values.fabric_util.to_bits().into(),
        ),
    ]
}

/// Render the `estimate` report from exact memo values. The header names
/// the canonical co-design key, so the report itself documents which memo
/// entry served it.
fn render_estimate(app: &str, n: u64, bs: u64, key: &str, v: &MemoValues) -> String {
    format!(
        "== estimate: {app} n={n} bs={bs} [{key}]\n  \
         est makespan:  {:.3} ms\n  \
         energy:        {:.3} J\n  \
         EDP:           {:.4} mJ*s\n  \
         fabric util:   {:.1}%\n",
        v.est_ms,
        v.energy_j,
        v.edp * 1e3,
        v.fabric_util * 100.0,
    )
}

/// Render the `energy` report from exact memo values (totals view — the
/// memo records the evaluation's energy total, not the per-rail split; the
/// split is derivable by re-running `estimate --policy` paths but is not
/// part of the cached contract).
fn render_energy(app: &str, n: u64, bs: u64, key: &str, v: &MemoValues) -> String {
    let mean_w = v.energy_j / (v.est_ms / 1e3).max(1e-12);
    format!(
        "== energy: {app} n={n} bs={bs} [{key}]\n  \
         est makespan:  {:.3} ms\n  \
         total energy:  {:.3} J  (mean {:.2} W)\n  \
         EDP:           {:.4} mJ*s\n  \
         fabric util:   {:.1}%\n",
        v.est_ms,
        v.energy_j,
        mean_w,
        v.edp * 1e3,
        v.fabric_util * 100.0,
    )
}

/// Answer one `estimate`/`energy` query through the memo: level-2 hit →
/// exact recorded numbers, miss → one evaluation recorded back at both
/// memo levels (and journaled as one committed WAL round when a journal
/// is given, so a crash after the response cannot lose the evaluation).
#[allow(clippy::too_many_arguments)]
pub fn point_query(
    program: &TaskProgram,
    board: &BoardConfig,
    part: &FpgaPart,
    app: &str,
    n: u64,
    bs: u64,
    cd: &CoDesign,
    energy_view: bool,
    memo: &mut EvalMemo,
    journal: Option<&mut SweepJournal>,
) -> anyhow::Result<PointOutcome> {
    let space = space_for_codesign(cd);
    let ctx = SweepContext::for_space_warm(program, board, part, &space, memo);
    point_query_prepared(&ctx, &space, app, n, bs, cd, energy_view, memo, journal, None)
}

/// [`point_query`] against a caller-built context. The daemon builds the
/// context under a shared memo *read* lock (so per-request program
/// analysis does not serialize across lanes) and performs the memo
/// bookkeeping here under a brief write lock. `ctx` must be primed for
/// `space ==` [`space_for_codesign`]`(cd)` against the memo state after
/// any earlier request of the same lane — exactly what the sequential
/// path sees. When `pre` carries the point's key, the recorded point is
/// taken from the batch's worker-pool round instead of simulating
/// inline — bit-identical by construction, see [`PreEvaluated`].
#[allow(clippy::too_many_arguments)]
pub fn point_query_prepared(
    ctx: &SweepContext<'_>,
    space: &DseSpace,
    app: &str,
    n: u64,
    bs: u64,
    cd: &CoDesign,
    energy_view: bool,
    memo: &mut EvalMemo,
    journal: Option<&mut SweepJournal>,
    pre: Option<&PreEvaluated>,
) -> anyhow::Result<PointOutcome> {
    let fingerprint = context_fingerprint(ctx);
    let key = codesign_key(cd);
    let clock = memo.touch(fingerprint);
    let (values, hit) = match memo.lookup(fingerprint, &key) {
        Some(v) => (v, true),
        None => {
            // Surface unsatisfiable co-designs (unknown kernel, kernel
            // with no device) as errors before paying for a worker.
            ctx.resolve(cd)?;
            let point = match pre.and_then(|pe| pe.points.get(&key)) {
                Some(p) => p.clone(),
                None => ctx
                    .worker()
                    .evaluate(cd)
                    .ok_or_else(|| anyhow::anyhow!("co-design '{key}' cannot be evaluated"))?,
            };
            memo.record(ctx, fingerprint, &key, &point);
            memo.record_kernels(ctx, space);
            memo.record_occupancy(ctx, std::slice::from_ref(&point));
            if let Some(j) = journal {
                j.log_context(fingerprint, ctx, clock);
                j.log_point(fingerprint, &key, &point);
                j.commit_round()?;
            }
            (
                MemoValues {
                    est_ms: point.est_ms,
                    energy_j: point.energy_j,
                    edp: point.edp,
                    fabric_util: point.fabric_util,
                },
                false,
            )
        }
    };
    let text = if energy_view {
        render_energy(app, n, bs, &key, &values)
    } else {
        render_estimate(app, n, bs, &key, &values)
    };
    Ok(PointOutcome {
        reply: QueryReply {
            text,
            l1_hits: ctx.kernel_memo_hits() as u64,
            l2_hits: hit as u64,
            evaluated: (!hit) as u64,
            extra: bits_extra(&values),
        },
        values,
        hit,
    })
}

/// Answer one `dse` query as a warm sweep over the shared memo. The reply
/// text is the ranked table plus the pruning line — the deterministic
/// prefix of the one-shot `dse --memo` stdout (the CLI follows it with
/// wall-clock timing lines, which are inherently not part of the
/// byte-identity contract). Freshly evaluated points are journaled as one
/// committed WAL round.
///
/// `cancel`, when present, is the daemon's per-request deadline hook,
/// polled at chunk-synchronous round barriers only (see
/// [`SweepContext::explore_warm_cancellable`]): a cancelled sweep
/// surfaces [`crate::dse::SweepCancelled`] (downcastable from the
/// returned error) and leaves the memo **byte-identical** — nothing is
/// recorded or journaled.
#[allow(clippy::too_many_arguments)]
pub fn dse_query(
    program: &TaskProgram,
    board: &BoardConfig,
    part: &FpgaPart,
    q: &DseQuery,
    workers: usize,
    memo: &mut EvalMemo,
    journal: Option<&mut SweepJournal>,
    cancel: Option<&(dyn Fn() -> bool + Sync)>,
) -> anyhow::Result<QueryReply> {
    let mut space = DseSpace::from_program(program);
    space.mixed = q.mixed;
    let ctx = SweepContext::for_space_warm(program, board, part, &space, memo);
    let fingerprint = context_fingerprint(&ctx);
    let before: std::collections::BTreeSet<String> = memo
        .points_ms(fingerprint)
        .into_iter()
        .map(|(k, _)| k)
        .collect();
    let (points, stats) = match cancel {
        Some(c) => ctx.explore_warm_cancellable(&space, memo, q.objective, workers, q.order, c)?,
        None => ctx.explore_warm(&space, memo, q.objective, workers, q.order),
    };
    if let Some(j) = journal {
        // Journal exactly the delta this sweep added, as one round.
        let mut fresh = 0usize;
        for p in &points {
            let key = codesign_key(&p.codesign);
            if !before.contains(&key) && memo.lookup(fingerprint, &key).is_some() {
                if fresh == 0 {
                    j.log_context(fingerprint, &ctx, memo.last_used(fingerprint).unwrap_or(0));
                }
                j.log_point(fingerprint, &key, p);
                fresh += 1;
            }
        }
        if fresh > 0 {
            j.commit_round()?;
        }
    }
    let mut text = crate::dse::render(&points, q.top, q.objective);
    text.push_str(&format!("pruning: {}\n", stats.render()));
    let best = points.first();
    let mut extra: Vec<(String, Value)> = vec![
        ("feasible".into(), stats.feasible_points.into()),
        ("points".into(), (points.len() as u64).into()),
    ];
    if let Some(b) = best {
        extra.push(("best".into(), codesign_key(&b.codesign).into()));
        extra.push(("best_est_ms_bits".into(), b.est_ms.to_bits().into()));
        extra.push(("best_energy_j_bits".into(), b.energy_j.to_bits().into()));
    }
    Ok(QueryReply {
        text,
        l1_hits: stats.kernel_hits,
        l2_hits: stats.memo_hits,
        evaluated: stats.evaluated,
        extra,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AccelSpec;
    use crate::dse::Objective;
    use crate::dse::OrderMode;

    fn fixture() -> (TaskProgram, BoardConfig, FpgaPart) {
        let board = BoardConfig::zynq706();
        let program = crate::apps::build_app_program("matmul", 256, 64, &board).unwrap();
        (program, board, FpgaPart::xc7z045())
    }

    fn codesign() -> CoDesign {
        let mut cd = CoDesign::new("cli");
        cd.accels.push(AccelSpec::parse("mxm64:U32").unwrap());
        cd
    }

    #[test]
    fn second_identical_point_query_is_a_hit_with_identical_bytes() {
        let (program, board, part) = fixture();
        let cd = codesign();
        let mut memo = EvalMemo::new();
        let first = point_query(
            &program, &board, &part, "matmul", 256, 64, &cd, false, &mut memo, None,
        )
        .unwrap();
        assert!(!first.hit);
        assert_eq!(first.reply.evaluated, 1);
        let second = point_query(
            &program, &board, &part, "matmul", 256, 64, &cd, false, &mut memo, None,
        )
        .unwrap();
        assert!(second.hit, "second identical query must be a level-2 hit");
        assert_eq!(second.reply.evaluated, 0);
        assert_eq!(second.reply.l2_hits, 1);
        assert_eq!(
            first.reply.text, second.reply.text,
            "hit must render the exact bytes of the original evaluation"
        );
        assert_eq!(first.values.est_ms.to_bits(), second.values.est_ms.to_bits());
    }

    #[test]
    fn point_query_matches_the_full_sweep_memo_entry() {
        // A point recorded by `dse` must serve `estimate` for the same
        // co-design bit-identically: the two paths share one key space.
        let (program, board, part) = fixture();
        let mut memo = EvalMemo::new();
        let q = DseQuery {
            app: "matmul".into(),
            n: 256,
            bs: 64,
            objective: Objective::Time,
            top: 5,
            mixed: false,
            order: OrderMode::Ranked,
        };
        let reply = dse_query(&program, &board, &part, &q, 2, &mut memo, None, None).unwrap();
        assert!(reply.evaluated > 0);
        let cd = codesign();
        let out = point_query(
            &program, &board, &part, "matmul", 256, 64, &cd, false, &mut memo, None,
        )
        .unwrap();
        assert!(
            out.hit,
            "estimate of a swept co-design must hit the dse-recorded entry"
        );
    }

    #[test]
    fn cancelled_dse_query_surfaces_sweep_cancelled_and_spares_the_memo() {
        let (program, board, part) = fixture();
        let mut memo = EvalMemo::new();
        let before = memo.to_json();
        let q = DseQuery {
            app: "matmul".into(),
            n: 256,
            bs: 64,
            objective: Objective::Time,
            top: 5,
            mixed: false,
            order: OrderMode::Ranked,
        };
        let err = dse_query(
            &program,
            &board,
            &part,
            &q,
            2,
            &mut memo,
            None,
            Some(&(|| true)),
        )
        .unwrap_err();
        assert!(
            err.downcast_ref::<crate::dse::SweepCancelled>().is_some(),
            "{err:#}"
        );
        assert_eq!(memo.to_json(), before, "cancelled dse touched the memo");
        // A hook that never fires answers byte-identically to the plain path.
        let cancellable = dse_query(
            &program,
            &board,
            &part,
            &q,
            2,
            &mut memo,
            None,
            Some(&(|| false)),
        )
        .unwrap();
        let mut memo2 = EvalMemo::new();
        let plain = dse_query(&program, &board, &part, &q, 2, &mut memo2, None, None).unwrap();
        assert_eq!(cancellable.text, plain.text);
        assert_eq!(memo.to_json(), memo2.to_json());
    }

    #[test]
    fn energy_view_renders_from_the_same_entry() {
        let (program, board, part) = fixture();
        let cd = codesign();
        let mut memo = EvalMemo::new();
        let est = point_query(
            &program, &board, &part, "matmul", 256, 64, &cd, false, &mut memo, None,
        )
        .unwrap();
        let en = point_query(
            &program, &board, &part, "matmul", 256, 64, &cd, true, &mut memo, None,
        )
        .unwrap();
        assert!(en.hit, "energy shares the estimate's memo entry");
        assert_eq!(est.values.energy_j.to_bits(), en.values.energy_j.to_bits());
        assert!(en.reply.text.starts_with("== energy: matmul n=256 bs=64"));
    }

    #[test]
    fn pre_evaluated_points_answer_bit_identically_to_inline_evaluation() {
        let (program, board, part) = fixture();
        let cd = codesign();
        // Inline reference path.
        let mut memo_a = EvalMemo::new();
        let inline = point_query(
            &program, &board, &part, "matmul", 256, 64, &cd, false, &mut memo_a, None,
        )
        .unwrap();
        // Batch path: one pool round up front, then the same bookkeeping.
        let mut memo_b = EvalMemo::new();
        let space = space_for_codesign(&cd);
        let ctx = SweepContext::for_space_warm(&program, &board, &part, &space, &memo_b);
        let fingerprint = context_fingerprint(&ctx);
        let pre = pre_evaluate(
            &program,
            &board,
            &part,
            fingerprint,
            std::slice::from_ref(&cd),
            &memo_b,
            2,
        );
        assert_eq!(pre.points.len(), 1, "one cold point, one pre-evaluation");
        let batched = point_query_prepared(
            &ctx,
            &space,
            "matmul",
            256,
            64,
            &cd,
            false,
            &mut memo_b,
            None,
            Some(&pre),
        )
        .unwrap();
        assert_eq!(inline.reply.text, batched.reply.text);
        assert_eq!(
            inline.values.est_ms.to_bits(),
            batched.values.est_ms.to_bits()
        );
        assert_eq!(
            batched.reply.evaluated, 1,
            "a consumed pre-evaluation still counts as freshly evaluated"
        );
        // The memo is equally warm afterwards: a repeat is a pure hit.
        let again = point_query(
            &program, &board, &part, "matmul", 256, 64, &cd, false, &mut memo_b, None,
        )
        .unwrap();
        assert!(again.hit);
        assert_eq!(again.reply.text, inline.reply.text);
    }

    #[test]
    fn union_space_merges_kernels_without_changing_per_codesign_coverage() {
        let a = codesign();
        let mut b = CoDesign::new("cli");
        b.accels.push(AccelSpec::parse("mxm64:U16").unwrap());
        b.accels.push(AccelSpec::parse("mxm64:U16").unwrap());
        let union = space_for_codesigns(&[a.clone(), b]);
        assert_eq!(union.kernels.len(), 1);
        let k = &union.kernels[0];
        assert_eq!(k.kernel, "mxm64");
        assert_eq!(k.unrolls, vec![16, 32], "merged, sorted, deduplicated");
        assert_eq!(k.max_instances, 2, "largest single-co-design demand");
        // The union primes a superset of what each single space primes.
        let single = space_for_codesign(&a);
        assert!(single.kernels[0]
            .unrolls
            .iter()
            .all(|u| k.unrolls.contains(u)));
    }

    #[test]
    fn unsatisfiable_codesigns_error_instead_of_recording() {
        let (program, board, part) = fixture();
        let mut cd = CoDesign::new("cli");
        cd.accels.push(AccelSpec::parse("nosuch:U8").unwrap());
        let mut memo = EvalMemo::new();
        let err = point_query(
            &program, &board, &part, "matmul", 256, 64, &cd, false, &mut memo, None,
        );
        assert!(err.is_err());
        assert_eq!(memo.n_points(), 0, "failed queries must not pollute the memo");
    }
}
