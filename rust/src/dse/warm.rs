//! Warm-start layer for the DSE engine — a persistent **two-level**
//! evaluation memo.
//!
//! The paper's promise is turning the co-design decision "from hours to
//! minutes"; after the sweep/prune/cross layers, the remaining redundancy
//! is *between* sweeps: a robustness study re-sweeps near-identical
//! spaces, a cross-board study sweeps sibling platforms, an analyst
//! iterating on a space re-simulates points an earlier run already
//! evaluated — and a size study re-runs the HLS cost model on the exact
//! same kernels. CEDR (Mack et al., 2022) observes that *kernel-level*
//! characterization — not whole-application traces — is the reusable unit
//! across workloads; the [`EvalMemo`] applies both granularities to the
//! estimator:
//!
//! * **Level 2 — exact per-context points.** Every evaluated point is
//!   recorded under a key that fingerprints **everything the evaluation
//!   depends on** — the task program (kernel declarations, profiles, every
//!   task's cycles and dependences), the board description, the FPGA part,
//!   and the estimator version — plus a canonical form of the co-design. A
//!   memo hit is therefore *bit-identical* to re-simulating by
//!   construction: two sweeps that share a key evaluated the exact same
//!   deterministic function. Any change to the program, board, part or
//!   estimator changes the fingerprint and misses cleanly (asserted by the
//!   warm-start property tests, which perturb each ingredient and check
//!   the memo refuses the hit).
//! * **Level 1 — per-kernel sub-memo.** Keyed on
//!   [`hls::kernel_fingerprint`](crate::hls::kernel_fingerprint) (kernel
//!   name + workload profile + estimator version) × unroll × the two
//!   board-derived cost-model constants, each entry stores the exact
//!   [`HlsReport`] plus per-task occupancy statistics aggregated from
//!   recorded points. Because a blocked application's kernel profile
//!   depends on the *block* size, not the problem size, two problem sizes
//!   of one app share level-1 entries even though their level-2 contexts
//!   differ: a sweep of matmul-2048 warm-starts from matmul-1024 by
//!   pre-filling the [`SweepContext`] HLS cache
//!   ([`SweepContext::prime_with_memo`] — reports reused only on an exact
//!   constants match, hence bit-identical) and by seeding *ordering
//!   priors* from the occupancy statistics (priors only — candidates are
//!   still cut exclusively by their own real bounds, so per-context
//!   results stay exact). The same statistics serve sibling boards on the
//!   cross-board axis, scaled by the fabric-clock ratio, replacing the old
//!   full-memo sibling scan.
//!
//! A warm sweep ([`SweepContext::explore_warm`]) returns level-2 hits
//! without re-simulation and seeds its bound frontier with them, so
//! bound-guided pruning starts from a warm incumbent. Seeded points are
//! always members of the current sweep's own candidate set, which is what
//! keeps the cut lossless — a frontier point that cuts a candidate is
//! itself part of the returned ranking.
//!
//! The memo serializes through the repository's own JSON substrate
//! ([`crate::util::json`]), with every `f64` stored as its exact bit
//! pattern so a save/load round-trip cannot perturb a single ULP. Each
//! context also carries its time-energy **frontier** (the Pareto set of
//! its recorded points) as a compact, report-friendly summary.
//!
//! **Hygiene.** Long-lived memo files are bounded rather than monotonic:
//! [`EvalMemo::stats`] reports the layout, [`EvalMemo::gc`] evicts whole
//! contexts least-recently-used first (recency is a persisted *logical*
//! clock bumped per warm sweep — deterministic, no wall time), and
//! [`EvalMemo::compact`] rewrites the file in the current schema with
//! empty contexts dropped. Eviction never edits a surviving context, so
//! every retained entry stays bit-exact. The `dse memo stats|gc|compact`
//! CLI subcommands expose the three operations.
//!
//! Lifecycle: `load_or_new` → any number of warm sweeps (each records its
//! new evaluations at both levels) → `save`. Memo files are versioned; a
//! file written by a different estimator version or schema — or a
//! truncated/corrupt one — is quarantined to a numbered `<path>.bak.N`
//! sibling on load ([`crate::util::persist::quarantine`]) and the sweep
//! starts fresh with a warning, instead of erroring the whole run or
//! silently serving stale numbers.
//!
//! **Crash safety.** [`EvalMemo::save`] is atomic (write-to-temp → fsync →
//! rename, via [`crate::util::persist::write_atomic`]): a crash mid-save
//! leaves the previous good file on disk, never a torn one. During a
//! recoverable sweep a [`SweepJournal`] additionally appends every freshly
//! evaluated point to an append-only side journal (`<path>.wal`) in
//! deterministic chunk-round order — each round is flushed as a single
//! fsynced write ending in a `commit` marker, so the on-disk journal is
//! always a whole number of committed rounds plus at most one torn tail
//! line (which replay drops). On load, [`EvalMemo::load_with_recovery`]
//! replays the committed rounds over the base file, so a kill -9 mid-sweep
//! loses at most the in-flight round. A successful save deletes the
//! journal (and any sweep checkpoint): the sidecars only ever carry the
//! delta since the last good save.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use crate::config::CoDesign;
use crate::hls::{kernel_fingerprint, HlsReport};
use crate::util::fnv::Fnv;
use crate::util::json::{arr, obj, parse, Value};

use super::sweep::SweepContext;
use super::{DsePoint, DseSpace};

/// Memo file schema version (bumped on layout changes; also folded into
/// the context fingerprint so schema bumps invalidate old entries).
/// v2 added the level-1 kernel sub-memo, per-context recency/task-count
/// metadata and the persisted logical clock.
pub const MEMO_SCHEMA_VERSION: i64 = 2;

/// Fingerprint of everything a point evaluation depends on: the estimator
/// version, the task program (kernels, profiles, tasks, dependences), the
/// board description and the FPGA part. The swept [`DseSpace`] is
/// deliberately **not** part of the key — the memo exists to be shared
/// across spaces over the same (program, board, part) triple. The
/// board-emulator-only `emu` block is excluded too: estimator results do
/// not depend on it.
pub fn context_fingerprint(ctx: &SweepContext<'_>) -> u64 {
    let mut h = Fnv::new();
    h.str(env!("CARGO_PKG_VERSION"));
    h.u64(MEMO_SCHEMA_VERSION as u64);
    let p = ctx.program;
    h.str(&p.app_name);
    h.u64(p.kernels.len() as u64);
    for k in &p.kernels {
        h.str(&k.name);
        h.bool(k.targets.smp);
        h.bool(k.targets.fpga);
        h.u64(k.profile.flops);
        h.u64(k.profile.inner_trip);
        h.u64(k.profile.in_bytes);
        h.u64(k.profile.out_bytes);
        h.u64(k.profile.dtype_bytes as u64);
        h.bool(k.profile.divsqrt);
    }
    h.u64(p.tasks.len() as u64);
    for t in &p.tasks {
        h.u64(t.kernel as u64);
        h.u64(t.smp_cycles);
        h.u64(t.creation_ns);
        h.u64(t.deps.len() as u64);
        for d in &t.deps {
            h.u64(d.addr);
            h.u64(d.len);
            h.str(d.dir.as_str());
        }
    }
    let b = ctx.board;
    h.str(&b.name);
    h.u64(b.smp_cores as u64);
    h.f64(b.smp_freq_mhz);
    h.f64(b.fabric_freq_mhz);
    h.bool(b.dma_in_scales);
    h.bool(b.dma_out_scales);
    h.f64(b.dma_bw_mbps);
    h.f64(b.dma_submit_us);
    h.f64(b.task_creation_us);
    h.f64(b.smp_flops_per_cycle);
    h.f64(b.smp_divsqrt_penalty);
    h.f64(b.smp_dp_penalty);
    h.f64(b.smp_l1_kb);
    h.f64(b.smp_cache_alpha);
    let part = &ctx.part;
    h.str(&part.name);
    h.u64(part.budget.luts);
    h.u64(part.budget.ffs);
    h.u64(part.budget.dsps);
    h.u64(part.budget.bram18);
    h.f64(part.routable_fraction);
    // Model constants that are code rather than config: the power model's
    // watts feed every energy/EDP figure, so a same-version tweak to
    // `PowerModel::default()` must miss instead of serving stale numbers.
    // (Structural changes to the cost model or scheduler still require a
    // MEMO_SCHEMA_VERSION bump — that is what the constant is for.)
    let pm = ctx.power_model();
    h.f64(pm.ps_static_w);
    h.f64(pm.smp_dynamic_w);
    h.f64(pm.pl_static_w);
    h.f64(pm.pl_static_per_util_w);
    h.f64(pm.w_per_dsp_100mhz);
    h.f64(pm.w_per_bram_100mhz);
    h.f64(pm.w_per_10kluts_100mhz);
    h.f64(pm.dma_dynamic_w);
    h.finish()
}

/// Canonical memo key of a co-design: sorted accelerator specs plus the
/// sorted, deduplicated "+ smp" kernel list. Two co-designs that simulate
/// identically (instance order is irrelevant to the engine) share one key.
pub fn codesign_key(cd: &CoDesign) -> String {
    let mut accels: Vec<String> = cd
        .accels
        .iter()
        .map(|a| format!("{}:U{}", a.kernel, a.unroll))
        .collect();
    accels.sort();
    let mut smp: Vec<&str> = cd.smp_kernels.iter().map(String::as_str).collect();
    smp.sort_unstable();
    smp.dedup();
    format!("{}|smp:{}", accels.join("+"), smp.join(","))
}

/// Per-kernel task counts of a program, indexed by `KernelId` — the
/// denominator of the level-1 per-task occupancy statistics.
pub(crate) fn kernel_task_counts(program: &crate::coordinator::task::TaskProgram) -> Vec<u64> {
    let mut counts = vec![0u64; program.kernels.len()];
    for t in &program.tasks {
        counts[t.kernel as usize] += 1;
    }
    counts
}

/// Stored evaluation result — `f64`s as exact bit patterns so JSON
/// round-trips are lossless.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct MemoPoint {
    est_ms: u64,
    energy_j: u64,
    edp: u64,
    fabric_util: u64,
}

/// A memo hit, decoded back to the evaluation's exact numbers.
#[derive(Clone, Copy, Debug)]
pub struct MemoValues {
    /// Estimated makespan (ms) — bit-identical to the recorded evaluation.
    pub est_ms: f64,
    /// Total platform energy (J).
    pub energy_j: f64,
    /// Energy-delay product (J·s).
    pub edp: f64,
    /// Fabric utilization in [0, 1].
    pub fabric_util: f64,
}

/// One (program, board, part) context of the memo: its recorded points
/// plus human-readable metadata for reports and the recency/size metadata
/// the hygiene layer needs.
#[derive(Clone, Debug, Default)]
struct MemoContext {
    app: String,
    board: String,
    part: String,
    fabric_mhz: f64,
    n_tasks: u64,
    last_used: u64,
    points: BTreeMap<String, MemoPoint>,
}

impl MemoContext {
    /// Time-energy Pareto frontier of the recorded points (exact bits),
    /// sorted — the compact summary serialized next to the points.
    fn frontier(&self) -> Vec<(u64, u64)> {
        let pts: Vec<(f64, f64)> = self
            .points
            .values()
            .map(|p| (f64::from_bits(p.est_ms), f64::from_bits(p.energy_j)))
            .collect();
        let mut front: Vec<(u64, u64)> = super::front_indices(&pts)
            .into_iter()
            .map(|i| (pts[i].0.to_bits(), pts[i].1.to_bits()))
            .collect();
        front.sort_unstable();
        front.dedup();
        front
    }
}

/// Level-1 key: kernel fingerprint, unroll factor and the exact bit
/// patterns of the two board-derived cost-model constants (fabric clock,
/// DMA bandwidth). Report reuse requires the full key to match; prior
/// lookups range over the `(fingerprint, unroll)` prefix and scale by the
/// clock ratio.
type KernelKey = (u64, u32, u64, u64);

/// One level-1 entry: the exact HLS report of a kernel variant plus the
/// per-task occupancy statistics aggregated from recorded points.
#[derive(Clone, Debug)]
struct KernelEntry {
    report: HlsReport,
    /// Recorded points whose co-design used this variant.
    samples: u64,
    /// Bit pattern of the minimum observed `est_ms × instances / tasks`
    /// over those points — "per-task, per-instance occupancy". `min` (not
    /// a mean) keeps the statistic independent of recording order, hence
    /// of the worker count. `f64::INFINITY` until the first sample.
    min_task_ms: u64,
    last_used: u64,
}

/// Memo layout summary — see [`EvalMemo::stats`].
#[derive(Clone, Debug)]
pub struct MemoStats {
    /// Level-2 contexts recorded.
    pub contexts: usize,
    /// Total level-2 points across every context.
    pub points: usize,
    /// Level-1 kernel sub-memo entries.
    pub kernel_entries: usize,
    /// Serialized size of the memo document, in bytes.
    pub bytes: usize,
    /// Per-context rows, in fingerprint order.
    pub rows: Vec<MemoContextStat>,
}

/// One context row of [`MemoStats`].
#[derive(Clone, Debug)]
pub struct MemoContextStat {
    /// Context fingerprint.
    pub fingerprint: u64,
    /// Application name recorded with the context.
    pub app: String,
    /// Board name recorded with the context.
    pub board: String,
    /// FPGA part name recorded with the context.
    pub part: String,
    /// Points recorded under the context.
    pub points: usize,
    /// Task count of the recorded program — what distinguishes two
    /// problem sizes of one app at a glance (their level-2 contexts never
    /// share entries; only the kernel sub-memo transfers).
    pub tasks: u64,
    /// Logical-clock value of the context's last warm sweep (higher =
    /// more recent; the LRU order [`EvalMemo::gc`] evicts by).
    pub last_used: u64,
}

impl MemoStats {
    /// Render the stats as the `dse memo stats` CLI table.
    pub fn render(&self) -> String {
        let mut out = format!(
            "== memo: {} contexts, {} points, {} kernel entries, {} bytes (schema v{})\n",
            self.contexts, self.points, self.kernel_entries, self.bytes, MEMO_SCHEMA_VERSION
        );
        out.push_str(&format!(
            "{:>16} {:24} {:>16} {:>12} {:>8} {:>8} {:>10}\n",
            "fingerprint", "app", "board", "part", "tasks", "points", "last-used"
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "{:016x} {:24} {:>16} {:>12} {:>8} {:>8} {:>10}\n",
                r.fingerprint, r.app, r.board, r.part, r.tasks, r.points, r.last_used
            ));
        }
        out
    }
}

/// What [`EvalMemo::gc`] removed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcReport {
    /// Whole contexts evicted (least recently used first).
    pub evicted_contexts: usize,
    /// Points that went with the evicted contexts.
    pub evicted_points: usize,
    /// Level-1 kernel entries evicted.
    pub evicted_kernels: usize,
}

/// Persistent two-level evaluation memo — see the module docs for the
/// exactness contract and lifecycle.
#[derive(Clone, Debug, Default)]
pub struct EvalMemo {
    contexts: BTreeMap<u64, MemoContext>,
    kernels: BTreeMap<KernelKey, KernelEntry>,
    /// `app name → context fingerprints` (sorted), maintained on insert —
    /// the index behind [`EvalMemo::sibling_points_ms`], replacing the
    /// old O(contexts) full scan.
    app_index: BTreeMap<String, Vec<u64>>,
    /// Logical recency clock: bumped once per warm sweep per context
    /// (never wall time, so files are deterministic).
    clock: u64,
}

impl EvalMemo {
    /// An empty memo.
    pub fn new() -> Self {
        Self::default()
    }

    /// Load a memo file, or start empty when the file does not exist yet.
    /// A malformed file — truncated, corrupt, or written by a different
    /// estimator version/schema — is quarantined to the next numbered
    /// `<path>.bak.N` sibling and the memo starts fresh with a warning: a
    /// stale side file must never error an entire sweep (and must never be
    /// silently served either). Any committed journal rounds next to the
    /// file are replayed; use [`EvalMemo::load_with_recovery`] to learn
    /// *what* was replayed.
    pub fn load_or_new(path: &Path) -> anyhow::Result<Self> {
        Ok(Self::load_with_recovery(path)?.0)
    }

    /// [`EvalMemo::load_or_new`] plus the journal-recovery report: when a
    /// `<path>.wal` sibling (or any numbered `<path>.wal.<k>` shard
    /// journal of a multi-lane daemon) with committed rounds exists, the
    /// committed points and context-recency snapshots of every journal
    /// are replayed into the returned memo and described by one merged
    /// [`WalRecovery`]. A corrupt journal is quarantined like a corrupt
    /// memo and ignored — recovery is best-effort, never a new failure
    /// mode — and corruption in one shard never blocks replay of the
    /// others.
    pub fn load_with_recovery(path: &Path) -> anyhow::Result<(Self, Option<WalRecovery>)> {
        crate::util::faultpoint::hit("memo.load")?;
        let mut memo = if !path.exists() {
            Self::new()
        } else {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
            match Self::from_json(&text) {
                Ok(memo) => memo,
                Err(e) => {
                    let bak = crate::util::persist::quarantine(path)
                        .map_err(|re| anyhow::anyhow!("{re} (while quarantining: {e})"))?;
                    eprintln!(
                        "warning: {}: {e}; moved to {} and starting a fresh memo",
                        path.display(),
                        bak.display()
                    );
                    Self::new()
                }
            }
        };
        let mut combined = WalRecovery::default();
        for wal in SweepJournal::shard_wal_paths(path) {
            let text = std::fs::read_to_string(&wal)
                .map_err(|e| anyhow::anyhow!("{}: {e}", wal.display()))?;
            match memo.replay_wal_text(&text) {
                Ok(rec) if rec.is_empty() => {}
                Ok(rec) => {
                    eprintln!(
                        "note: {}: replayed {} points over {} committed rounds from the journal",
                        wal.display(),
                        rec.n_points(),
                        rec.rounds
                    );
                    combined.merge(rec);
                }
                Err(e) => match crate::util::persist::quarantine(&wal) {
                    Ok(bak) => eprintln!(
                        "warning: {}: {e}; journal moved to {} and ignored",
                        wal.display(),
                        bak.display()
                    ),
                    Err(re) => eprintln!(
                        "warning: {}: {e}; journal could not be quarantined ({re}), ignored",
                        wal.display()
                    ),
                },
            }
        }
        if combined.is_empty() {
            Ok((memo, None))
        } else {
            Ok((memo, Some(combined)))
        }
    }

    /// Save the memo atomically (write-to-temp → fsync → rename): a crash
    /// mid-save leaves the previous good file, never a torn one. A
    /// successful save supersedes the side journal and any sweep
    /// checkpoint, so both sidecars are deleted.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        crate::util::faultpoint::hit("memo.save")?;
        crate::util::persist::write_atomic(path, self.to_json().as_bytes())?;
        for wal in SweepJournal::shard_wal_paths(path) {
            let _ = std::fs::remove_file(wal);
        }
        let _ = std::fs::remove_file(PathBuf::from(format!("{}.ckpt", path.display())));
        Ok(())
    }

    /// Replay a journal document (the text of a `<memo>.wal` sibling) over
    /// this memo: apply every context-recency snapshot and every point of
    /// every *committed* round, and report what was restored. Points after
    /// the last `commit` marker — the in-flight round of a crash — are
    /// dropped, as is at most one torn tail line. All-or-nothing: a
    /// structurally corrupt journal returns `Err` without mutating the
    /// memo (the caller quarantines it). Public so the fuzz harness can
    /// drive it with arbitrary bytes.
    pub fn replay_wal_text(&mut self, text: &str) -> anyhow::Result<WalRecovery> {
        crate::util::faultpoint::hit("wal.replay")?;
        let mut ctxs: BTreeMap<u64, StagedWalCtx> = BTreeMap::new();
        let mut committed: Vec<(u64, String, MemoPoint)> = Vec::new();
        let mut pending: Vec<(u64, String, MemoPoint)> = Vec::new();
        let mut rounds = 0u64;
        let lines: Vec<&str> = text.lines().collect();
        for (i, raw) in lines.iter().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            let v = match parse(line) {
                Ok(v) => v,
                Err(e) => {
                    // An unparseable *final* line is the expected torn-tail
                    // signature of the crash itself; anything earlier is
                    // corruption. Lines that parse but fail validation are
                    // always corruption — a torn write cannot produce
                    // valid JSON with bad semantics.
                    let is_tail = lines[i + 1..].iter().all(|l| l.trim().is_empty());
                    if is_tail {
                        break;
                    }
                    anyhow::bail!("journal line {}: parse: {e}", i + 1);
                }
            };
            let kind = stage_wal_line(&v, &mut ctxs, &mut pending)
                .map_err(|e| anyhow::anyhow!("journal line {}: {e}", i + 1))?;
            if let WalLine::Commit = kind {
                committed.append(&mut pending);
                rounds += 1;
            }
        }
        // Every committed point must belong to a journaled or already
        // known context (the writer always journals a context before any
        // of its points).
        for (fp, key, _) in &committed {
            anyhow::ensure!(
                ctxs.contains_key(fp) || self.contexts.contains_key(fp),
                "journal point '{key}' references unknown context {fp:016x}"
            );
        }
        // Stage accepted: apply.
        let mut rec = WalRecovery {
            rounds,
            ..WalRecovery::default()
        };
        for (fp, sc) in &ctxs {
            let entry = self.contexts.entry(*fp).or_insert_with(|| MemoContext {
                app: sc.app.clone(),
                board: sc.board.clone(),
                part: sc.part.clone(),
                fabric_mhz: sc.fabric_mhz,
                n_tasks: sc.n_tasks,
                last_used: 0,
                points: BTreeMap::new(),
            });
            entry.last_used = entry.last_used.max(sc.last_used);
            self.clock = self.clock.max(sc.last_used);
            rec.contexts.insert(*fp);
        }
        for (fp, key, pt) in committed {
            let entry = self.contexts.get_mut(&fp).expect("context staged above");
            entry.points.insert(key.clone(), pt);
            rec.points.entry(fp).or_default().insert(key);
        }
        self.rebuild_index();
        Ok(rec)
    }

    /// Number of contexts recorded.
    pub fn n_contexts(&self) -> usize {
        self.contexts.len()
    }

    /// Total recorded points across every context.
    pub fn n_points(&self) -> usize {
        self.contexts.values().map(|c| c.points.len()).sum()
    }

    /// Number of level-1 kernel sub-memo entries.
    pub fn n_kernel_entries(&self) -> usize {
        self.kernels.len()
    }

    /// Mark a context as used by the current warm sweep: bumps the logical
    /// clock and refreshes the context's recency (a context not recorded
    /// yet is refreshed when [`EvalMemo::record`] creates it). The warm
    /// engine calls this once per `(sweep, context)`, so LRU order tracks
    /// sweeps, not lookups. Returns the new clock value — the recency the
    /// context carries for this sweep, which the recoverable sweep
    /// snapshots into the journal.
    pub fn touch(&mut self, fingerprint: u64) -> u64 {
        self.clock += 1;
        if let Some(c) = self.contexts.get_mut(&fingerprint) {
            c.last_used = self.clock;
        }
        self.clock
    }

    /// Exact-hit lookup.
    pub fn lookup(&self, fingerprint: u64, key: &str) -> Option<MemoValues> {
        let p = self.contexts.get(&fingerprint)?.points.get(key)?;
        Some(MemoValues {
            est_ms: f64::from_bits(p.est_ms),
            energy_j: f64::from_bits(p.energy_j),
            edp: f64::from_bits(p.edp),
            fabric_util: f64::from_bits(p.fabric_util),
        })
    }

    /// Record one evaluated point under its context. Idempotent: a key can
    /// only ever map to one value (the evaluation is deterministic), so
    /// re-recording overwrites with identical bits.
    pub fn record(&mut self, ctx: &SweepContext<'_>, fingerprint: u64, key: &str, p: &DsePoint) {
        let clock = self.clock;
        let entry = match self.contexts.entry(fingerprint) {
            std::collections::btree_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::btree_map::Entry::Vacant(e) => {
                let fps = self.app_index.entry(ctx.program.app_name.clone()).or_default();
                if let Err(i) = fps.binary_search(&fingerprint) {
                    fps.insert(i, fingerprint);
                }
                e.insert(MemoContext {
                    app: ctx.program.app_name.clone(),
                    board: ctx.board.name.clone(),
                    part: ctx.part.name.clone(),
                    fabric_mhz: ctx.board.fabric_freq_mhz,
                    n_tasks: ctx.program.tasks.len() as u64,
                    last_used: clock,
                    points: BTreeMap::new(),
                })
            }
        };
        debug_assert_eq!(entry.fabric_mhz.to_bits(), ctx.board.fabric_freq_mhz.to_bits());
        entry.last_used = entry.last_used.max(clock);
        entry.points.insert(
            key.to_string(),
            MemoPoint {
                est_ms: p.est_ms.to_bits(),
                energy_j: p.energy_j.to_bits(),
                edp: p.edp.to_bits(),
                fabric_util: p.fabric_util.to_bits(),
            },
        );
    }

    /// Level-1 lookup: the exact HLS report of a kernel variant, served
    /// only when *both* cost-model constants match bit for bit — the
    /// report is then bit-identical to a fresh cost-model call by
    /// construction (the model is a pure function of the fingerprinted
    /// profile, the unroll and these two constants).
    pub fn lookup_report(
        &self,
        kfp: u64,
        unroll: u32,
        fabric_mhz: f64,
        dma_bw_mbps: f64,
    ) -> Option<&HlsReport> {
        self.kernels
            .get(&(kfp, unroll, fabric_mhz.to_bits(), dma_bw_mbps.to_bits()))
            .map(|e| &e.report)
    }

    /// Record the level-1 entry of every `(kernel, unroll)` variant a
    /// space can touch, serving the reports from the context's memoized
    /// cache. Idempotent (a key maps to one deterministic report);
    /// refreshes the entries' recency.
    pub fn record_kernels(&mut self, ctx: &SweepContext<'_>, space: &DseSpace) {
        let fabric = ctx.board.fabric_freq_mhz.to_bits();
        let dma = ctx.board.dma_bw_mbps.to_bits();
        let clock = self.clock;
        for ks in &space.kernels {
            let Some(kid) = ctx.program.kernel_id(&ks.kernel) else {
                continue;
            };
            let kfp = kernel_fingerprint(&ks.kernel, &ctx.program.kernel(kid).profile);
            for &u in &ks.unrolls {
                let entry = self
                    .kernels
                    .entry((kfp, u, fabric, dma))
                    .or_insert_with(|| KernelEntry {
                        report: ctx.report_for(kid, &ks.kernel, u),
                        samples: 0,
                        min_task_ms: f64::INFINITY.to_bits(),
                        last_used: clock,
                    });
                entry.last_used = entry.last_used.max(clock);
            }
        }
    }

    /// Fold freshly evaluated points into the level-1 occupancy
    /// statistics: for every accelerator variant a point uses, the
    /// variant's `min_task_ms` absorbs `est_ms × instances / tasks`. The
    /// `min` makes the statistic independent of the recording order, so
    /// warm sweeps stay bit-deterministic for any worker count.
    pub fn record_occupancy(&mut self, ctx: &SweepContext<'_>, points: &[DsePoint]) {
        let fabric = ctx.board.fabric_freq_mhz.to_bits();
        let dma = ctx.board.dma_bw_mbps.to_bits();
        let counts = kernel_task_counts(ctx.program);
        for p in points {
            // Instances per kernel (a mixed co-design can split one
            // kernel's tasks across variants; the kernel's instance count
            // is the occupancy denominator either way).
            let mut per_kernel: BTreeMap<&str, u64> = BTreeMap::new();
            for a in &p.codesign.accels {
                *per_kernel.entry(a.kernel.as_str()).or_insert(0) += 1;
            }
            for a in &p.codesign.accels {
                let Some(kid) = ctx.program.kernel_id(&a.kernel) else {
                    continue;
                };
                let tasks = counts[kid as usize];
                if tasks == 0 {
                    continue;
                }
                let instances = per_kernel[a.kernel.as_str()];
                let kfp = kernel_fingerprint(&a.kernel, &ctx.program.kernel(kid).profile);
                let Some(e) = self.kernels.get_mut(&(kfp, a.unroll, fabric, dma)) else {
                    continue;
                };
                let task_ms = p.est_ms * instances as f64 / tasks as f64;
                let cur = f64::from_bits(e.min_task_ms);
                if task_ms < cur {
                    e.min_task_ms = task_ms.to_bits();
                }
                e.samples += 1;
            }
        }
    }

    /// The level-1 entry of `(kfp, unroll)` whose recorded fabric clock is
    /// closest (log-ratio) to `my_mhz`, skipping entries with no occupancy
    /// samples yet. Ties break on the BTreeMap key order — deterministic.
    fn best_kernel_entry(&self, kfp: u64, unroll: u32, my_mhz: f64) -> Option<(&KernelEntry, f64)> {
        let lo = (kfp, unroll, u64::MIN, u64::MIN);
        let hi = (kfp, unroll, u64::MAX, u64::MAX);
        let mut best: Option<(&KernelEntry, f64, f64)> = None;
        for (&(_, _, fab_bits, _), e) in self.kernels.range(lo..=hi) {
            if e.samples == 0 {
                continue;
            }
            let fab = f64::from_bits(fab_bits);
            if fab <= 0.0 || !fab.is_finite() || my_mhz <= 0.0 {
                continue;
            }
            let dist = (fab / my_mhz).ln().abs();
            let better = match best {
                Some((_, _, d)) => dist < d,
                None => true,
            };
            if better {
                best = Some((e, fab, dist));
            }
        }
        best.map(|(e, fab, _)| (e, fab))
    }

    /// Predicted makespan of a candidate from the level-1 occupancy
    /// statistics: per kernel, the mean scaled per-task occupancy of its
    /// variants × the context's task count / the instance count, summed.
    /// Sibling entries recorded at a different fabric clock scale by the
    /// clock ratio. `None` when the candidate has no accelerators or some
    /// variant has no statistics yet. **Ordering prior only** — never a
    /// cut source, so a bad prediction costs evaluations, never
    /// correctness.
    pub fn prior_ms_for(
        &self,
        ctx: &SweepContext<'_>,
        task_counts: &[u64],
        cd: &CoDesign,
    ) -> Option<f64> {
        if cd.accels.is_empty() {
            return None;
        }
        let my_mhz = ctx.board.fabric_freq_mhz;
        // kernel name → (Σ scaled per-task ms over instances, instances).
        let mut groups: BTreeMap<&str, (f64, u64)> = BTreeMap::new();
        for a in &cd.accels {
            let kid = ctx.program.kernel_id(&a.kernel)?;
            if task_counts[kid as usize] == 0 {
                continue;
            }
            let kfp = kernel_fingerprint(&a.kernel, &ctx.program.kernel(kid).profile);
            let (e, fab) = self.best_kernel_entry(kfp, a.unroll, my_mhz)?;
            let scaled = f64::from_bits(e.min_task_ms) * (fab / my_mhz);
            let g = groups.entry(a.kernel.as_str()).or_insert((0.0, 0));
            g.0 += scaled;
            g.1 += 1;
        }
        if groups.is_empty() {
            return None;
        }
        let mut pred = 0.0;
        for (name, (sum, n)) in groups {
            let kid = ctx.program.kernel_id(name)?;
            let tasks = task_counts[kid as usize] as f64;
            pred += (sum / n as f64) * tasks / n as f64;
        }
        Some(pred)
    }

    /// The `(est_ms, energy_j)` frontier of one context (exact values),
    /// sorted by ascending time — empty when the context is unknown.
    pub fn frontier(&self, fingerprint: u64) -> Vec<(f64, f64)> {
        self.contexts
            .get(&fingerprint)
            .map(|c| {
                c.frontier()
                    .into_iter()
                    .map(|(m, e)| (f64::from_bits(m), f64::from_bits(e)))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Per-context `(key → est_ms)` map (diagnostics / tests). Empty when
    /// the context is unknown.
    pub fn points_ms(&self, fingerprint: u64) -> Vec<(String, f64)> {
        self.contexts
            .get(&fingerprint)
            .map(|c| {
                c.points
                    .iter()
                    .map(|(k, p)| (k.clone(), f64::from_bits(p.est_ms)))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Sibling contexts of an application persisted in the memo: every
    /// context whose recorded `app` metadata matches `app`, except the
    /// `exclude` fingerprint (the caller's own context), as
    /// `(fabric_mhz, key → est_ms)` pairs in deterministic (fingerprint)
    /// order. Served from the maintained app index — O(siblings), not
    /// O(contexts).
    pub fn sibling_points_ms(&self, app: &str, exclude: u64) -> Vec<(f64, Vec<(String, f64)>)> {
        let Some(fps) = self.app_index.get(app) else {
            return Vec::new();
        };
        fps.iter()
            .filter(|&&fp| fp != exclude)
            .filter_map(|fp| self.contexts.get(fp))
            .map(|c| {
                let pts: Vec<(String, f64)> = c
                    .points
                    .iter()
                    .map(|(k, p)| (k.clone(), f64::from_bits(p.est_ms)))
                    .collect();
                (c.fabric_mhz, pts)
            })
            .collect()
    }

    /// Layout summary: context/point/kernel-entry counts, the serialized
    /// size, and one row per context in fingerprint order.
    pub fn stats(&self) -> MemoStats {
        MemoStats {
            contexts: self.contexts.len(),
            points: self.n_points(),
            kernel_entries: self.kernels.len(),
            bytes: self.to_json().len(),
            rows: self
                .contexts
                .iter()
                .map(|(&fp, c)| MemoContextStat {
                    fingerprint: fp,
                    app: c.app.clone(),
                    board: c.board.clone(),
                    part: c.part.clone(),
                    points: c.points.len(),
                    tasks: c.n_tasks,
                    last_used: c.last_used,
                })
                .collect(),
        }
    }

    /// Bound the memo: contexts are kept in strict most-recently-used
    /// order (by the persisted logical clock) until either cap trips —
    /// more than `keep_contexts` keepers, or a cumulative `keep_points`
    /// budget exceeded — and everything less recent is evicted, so a
    /// retained context is never older than an evicted one. Level-1
    /// entries are capped at `keep_kernels` the same way. Eviction removes
    /// whole contexts/entries and never edits a survivor, so every
    /// retained lookup stays bit-exact. Deterministic: recency ties break
    /// on the fingerprint order.
    pub fn gc(
        &mut self,
        keep_contexts: usize,
        keep_points: usize,
        keep_kernels: usize,
    ) -> GcReport {
        let mut report = GcReport::default();
        // Contexts, most recent first.
        let mut order: Vec<(u64, u64)> = self
            .contexts
            .iter()
            .map(|(&fp, c)| (c.last_used, fp))
            .collect();
        order.sort_by_key(|&(lu, fp)| (std::cmp::Reverse(lu), fp));
        let mut keep: Vec<u64> = Vec::new();
        let mut points = 0usize;
        for &(_, fp) in &order {
            let n = self.contexts[&fp].points.len();
            if keep.len() >= keep_contexts || points + n > keep_points {
                // LRU prefix only: once a cap trips, every less-recent
                // context goes too (keeping an older context while a
                // newer one is evicted would invert the LRU contract).
                break;
            }
            points += n;
            keep.push(fp);
        }
        keep.sort_unstable();
        let before = self.contexts.len();
        let evicted: Vec<u64> = self
            .contexts
            .keys()
            .copied()
            .filter(|fp| keep.binary_search(fp).is_err())
            .collect();
        for fp in &evicted {
            if let Some(c) = self.contexts.remove(fp) {
                report.evicted_points += c.points.len();
            }
        }
        report.evicted_contexts = before - self.contexts.len();
        // Kernel entries, most recent first.
        if self.kernels.len() > keep_kernels {
            let mut korder: Vec<(u64, KernelKey)> = self
                .kernels
                .iter()
                .map(|(&k, e)| (e.last_used, k))
                .collect();
            korder.sort_by_key(|&(lu, k)| (std::cmp::Reverse(lu), k));
            let drop: Vec<KernelKey> = korder
                .into_iter()
                .skip(keep_kernels)
                .map(|(_, k)| k)
                .collect();
            for k in drop {
                self.kernels.remove(&k);
                report.evicted_kernels += 1;
            }
        }
        self.rebuild_index();
        report
    }

    /// Bound the **serialized size** of the memo to a byte budget —
    /// the gc policy of a resident memo (the `serve` daemon and
    /// `dse memo gc --max-bytes`). Whole contexts are evicted least
    /// recently used first (ties on fingerprint — deterministic) until
    /// the serialized document fits `max_bytes`, with one guarantee the
    /// plain LRU [`EvalMemo::gc`] does not give: the `per_app_floor`
    /// most-recent contexts of **every** application are never evicted,
    /// even when the floors alone exceed the budget (floors win over the
    /// budget — a service must not forget the context a client is
    /// actively querying just because another app flooded the memo).
    /// If evicting every unprotected context still leaves the document
    /// over budget, level-1 kernel entries are trimmed LRU-first too.
    /// Like every hygiene operation, eviction removes whole
    /// contexts/entries and never edits a survivor, so retained lookups
    /// stay bit-exact.
    pub fn gc_bytes(&mut self, max_bytes: usize, per_app_floor: usize) -> GcReport {
        let mut report = GcReport::default();
        if self.to_json().len() <= max_bytes {
            return report;
        }
        // Per-app floors: the most recent `per_app_floor` contexts of each
        // app (recency ties break on fingerprint, like `gc`).
        let mut protected: BTreeSet<u64> = BTreeSet::new();
        for fps in self.app_index.values() {
            let mut by_recency: Vec<(std::cmp::Reverse<u64>, u64)> = fps
                .iter()
                .filter_map(|&fp| {
                    self.contexts
                        .get(&fp)
                        .map(|c| (std::cmp::Reverse(c.last_used), fp))
                })
                .collect();
            by_recency.sort_unstable();
            protected.extend(by_recency.iter().take(per_app_floor).map(|&(_, fp)| fp));
        }
        // Evict unprotected contexts, least recent first.
        let mut order: Vec<(u64, u64)> = self
            .contexts
            .iter()
            .filter(|(fp, _)| !protected.contains(fp))
            .map(|(&fp, c)| (c.last_used, fp))
            .collect();
        order.sort_unstable();
        for (_, fp) in order {
            if self.to_json().len() <= max_bytes {
                break;
            }
            if let Some(c) = self.contexts.remove(&fp) {
                report.evicted_contexts += 1;
                report.evicted_points += c.points.len();
            }
        }
        // Still over budget (only floors remain): trim kernel entries.
        if self.to_json().len() > max_bytes {
            let mut korder: Vec<(u64, KernelKey)> = self
                .kernels
                .iter()
                .map(|(&k, e)| (e.last_used, k))
                .collect();
            korder.sort_unstable();
            for (_, k) in korder {
                if self.to_json().len() <= max_bytes {
                    break;
                }
                self.kernels.remove(&k);
                report.evicted_kernels += 1;
            }
        }
        self.rebuild_index();
        report
    }

    /// Recency (logical-clock value) of one context, `None` when unknown —
    /// what the service journal snapshots after a warm query.
    pub fn last_used(&self, fingerprint: u64) -> Option<u64> {
        self.contexts.get(&fingerprint).map(|c| c.last_used)
    }

    /// Compact the memo in place: drop contexts with no points (gc'd or
    /// never-recorded shells) and rebuild the app index. Saving afterwards
    /// rewrites the file in the current schema version with normalized
    /// encoding — the "versioned compaction" of long-lived memo files.
    /// Returns the number of contexts dropped.
    pub fn compact(&mut self) -> usize {
        let before = self.contexts.len();
        self.contexts.retain(|_, c| !c.points.is_empty());
        self.rebuild_index();
        before - self.contexts.len()
    }

    fn rebuild_index(&mut self) {
        self.app_index.clear();
        for (&fp, c) in &self.contexts {
            self.app_index.entry(c.app.clone()).or_default().push(fp);
        }
        // BTreeMap iteration is fingerprint-ordered, so the per-app lists
        // come out sorted.
    }

    /// Serialize to the memo JSON document.
    pub fn to_json(&self) -> String {
        let contexts: Vec<Value> = self
            .contexts
            .iter()
            .map(|(fp, c)| {
                let points: Vec<Value> = c
                    .points
                    .iter()
                    .map(|(k, p)| {
                        obj(vec![
                            ("key", k.as_str().into()),
                            ("est_ms", p.est_ms.into()),
                            ("energy_j", p.energy_j.into()),
                            ("edp", p.edp.into()),
                            ("fabric_util", p.fabric_util.into()),
                        ])
                    })
                    .collect();
                let frontier: Vec<Value> = c
                    .frontier()
                    .into_iter()
                    .map(|(m, e)| obj(vec![("est_ms", m.into()), ("energy_j", e.into())]))
                    .collect();
                obj(vec![
                    ("fp", format!("{fp:016x}").into()),
                    ("app", c.app.as_str().into()),
                    ("board", c.board.as_str().into()),
                    ("part", c.part.as_str().into()),
                    ("fabric_mhz", c.fabric_mhz.into()),
                    ("n_tasks", c.n_tasks.into()),
                    ("last_used", c.last_used.into()),
                    ("points", arr(points)),
                    ("frontier", arr(frontier)),
                ])
            })
            .collect();
        let kernels: Vec<Value> = self
            .kernels
            .iter()
            .map(|(&(kfp, unroll, fabric, dma), e)| {
                obj(vec![
                    ("kfp", format!("{kfp:016x}").into()),
                    ("unroll", unroll.into()),
                    ("fabric_mhz", fabric.into()),
                    ("dma_bw_mbps", dma.into()),
                    ("samples", e.samples.into()),
                    ("min_task_ms", e.min_task_ms.into()),
                    ("last_used", e.last_used.into()),
                    ("report", e.report.to_json_value()),
                ])
            })
            .collect();
        obj(vec![
            ("version", MEMO_SCHEMA_VERSION.into()),
            ("estimator", env!("CARGO_PKG_VERSION").into()),
            ("clock", self.clock.into()),
            ("contexts", arr(contexts)),
            ("kernels", arr(kernels)),
        ])
        .to_json()
    }

    /// Parse a memo JSON document (version- and estimator-checked; any
    /// structural defect is an error — [`EvalMemo::load_or_new`] turns
    /// errors into a `.bak` quarantine instead of failing the sweep).
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let v = parse(text).map_err(|e| anyhow::anyhow!("memo parse: {e}"))?;
        let version = v
            .get("version")
            .and_then(Value::as_i64)
            .ok_or_else(|| anyhow::anyhow!("memo file has no version"))?;
        anyhow::ensure!(
            version == MEMO_SCHEMA_VERSION,
            "memo schema v{version} != v{MEMO_SCHEMA_VERSION}"
        );
        let estimator = v.get("estimator").and_then(Value::as_str).unwrap_or("");
        anyhow::ensure!(
            estimator == env!("CARGO_PKG_VERSION"),
            "memo written by estimator v{estimator}, this is v{} (results would not be comparable)",
            env!("CARGO_PKG_VERSION")
        );
        let mut memo = EvalMemo::new();
        memo.clock = v.get("clock").and_then(Value::as_u64).unwrap_or(0);
        let contexts = v
            .get("contexts")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow::anyhow!("memo file has no contexts array"))?;
        for c in contexts {
            let fp_str = c
                .get("fp")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow::anyhow!("memo context has no fp"))?;
            let fp = u64::from_str_radix(fp_str, 16)
                .map_err(|_| anyhow::anyhow!("bad memo fingerprint '{fp_str}'"))?;
            let fabric_mhz = c.get("fabric_mhz").and_then(Value::as_f64).unwrap_or(0.0);
            anyhow::ensure!(
                fabric_mhz.is_finite() && fabric_mhz >= 0.0,
                "memo context {fp_str} field 'fabric_mhz': {fabric_mhz} is not a finite \
                 non-negative number"
            );
            let mut mc = MemoContext {
                app: c.get("app").and_then(Value::as_str).unwrap_or("").to_string(),
                board: c.get("board").and_then(Value::as_str).unwrap_or("").to_string(),
                part: c.get("part").and_then(Value::as_str).unwrap_or("").to_string(),
                fabric_mhz,
                n_tasks: c.get("n_tasks").and_then(Value::as_u64).unwrap_or(0),
                last_used: c.get("last_used").and_then(Value::as_u64).unwrap_or(0),
                points: BTreeMap::new(),
            };
            for p in c.get("points").and_then(Value::as_arr).unwrap_or(&[]) {
                let key = p
                    .get("key")
                    .and_then(Value::as_str)
                    .ok_or_else(|| anyhow::anyhow!("memo point has no key"))?;
                // Named-field validation: every point metric must decode
                // to a finite, non-negative number — a NaN in the memo
                // would poison every comparison it touches downstream.
                let bits = |field: &str| -> anyhow::Result<u64> {
                    let b = p
                        .get(field)
                        .and_then(Value::as_i64)
                        .map(|i| i as u64)
                        .ok_or_else(|| anyhow::anyhow!("memo point '{key}' misses {field}"))?;
                    let x = f64::from_bits(b);
                    anyhow::ensure!(
                        x.is_finite() && x >= 0.0,
                        "memo point '{key}' field '{field}': not a finite non-negative number"
                    );
                    Ok(b)
                };
                mc.points.insert(
                    key.to_string(),
                    MemoPoint {
                        est_ms: bits("est_ms")?,
                        energy_j: bits("energy_j")?,
                        edp: bits("edp")?,
                        fabric_util: bits("fabric_util")?,
                    },
                );
            }
            memo.contexts.insert(fp, mc);
        }
        for k in v.get("kernels").and_then(Value::as_arr).unwrap_or(&[]) {
            let kfp_str = k
                .get("kfp")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow::anyhow!("memo kernel entry has no kfp"))?;
            let kfp = u64::from_str_radix(kfp_str, 16)
                .map_err(|_| anyhow::anyhow!("bad kernel fingerprint '{kfp_str}'"))?;
            let u = |field: &str| -> anyhow::Result<u64> {
                k.get(field)
                    .and_then(Value::as_i64)
                    .map(|i| i as u64)
                    .ok_or_else(|| anyhow::anyhow!("memo kernel '{kfp_str}' misses {field}"))
            };
            let report = HlsReport::from_json_value(
                k.get("report")
                    .ok_or_else(|| anyhow::anyhow!("memo kernel '{kfp_str}' misses report"))?,
            )?;
            memo.kernels.insert(
                (kfp, u("unroll")? as u32, u("fabric_mhz")?, u("dma_bw_mbps")?),
                KernelEntry {
                    report,
                    samples: u("samples")?,
                    min_task_ms: u("min_task_ms")?,
                    last_used: u("last_used")?,
                },
            );
        }
        memo.rebuild_index();
        Ok(memo)
    }
}

/// What a journal replay restored — the recoverable sweep uses it to
/// treat restored points exactly like the fresh evaluations they were
/// (occupancy recording) and to skip re-touching contexts whose recency
/// the journal already restored, so a resumed sweep reproduces the
/// uninterrupted run bit for bit.
#[derive(Clone, Debug, Default)]
pub struct WalRecovery {
    /// Contexts whose recency snapshot was restored (their `touch` already
    /// happened in the interrupted sweep and is part of the restored
    /// clock).
    pub contexts: BTreeSet<u64>,
    /// Restored point keys, per context fingerprint.
    pub points: BTreeMap<u64, BTreeSet<String>>,
    /// Committed rounds replayed.
    pub rounds: u64,
}

impl WalRecovery {
    /// True when the journal restored nothing.
    pub fn is_empty(&self) -> bool {
        self.contexts.is_empty() && self.points.is_empty()
    }

    /// Total restored points across every context.
    pub fn n_points(&self) -> usize {
        self.points.values().map(BTreeSet::len).sum()
    }

    /// Whether `(fingerprint, key)` was restored from the journal.
    pub fn contains(&self, fingerprint: u64, key: &str) -> bool {
        self.points.get(&fingerprint).is_some_and(|s| s.contains(key))
    }

    /// Fold another journal's recovery report into this one — multi-shard
    /// service journals (`<memo>.wal`, `<memo>.wal.1`, ...) replay as one
    /// combined report.
    pub fn merge(&mut self, other: WalRecovery) {
        self.contexts.extend(other.contexts);
        for (fp, keys) in other.points {
            self.points.entry(fp).or_default().extend(keys);
        }
        self.rounds += other.rounds;
    }
}

/// Staged `ctx` journal record (not yet applied to the memo).
struct StagedWalCtx {
    app: String,
    board: String,
    part: String,
    fabric_mhz: f64,
    n_tasks: u64,
    last_used: u64,
}

/// Kind of one parsed journal line.
enum WalLine {
    Hdr,
    Ctx,
    Pt,
    Commit,
}

/// Stage one parsed journal line (see [`SweepJournal`] for the format).
fn stage_wal_line(
    v: &Value,
    ctxs: &mut BTreeMap<u64, StagedWalCtx>,
    pending: &mut Vec<(u64, String, MemoPoint)>,
) -> anyhow::Result<WalLine> {
    let t = v
        .get("t")
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow::anyhow!("record has no 't'"))?;
    let fp_of = |v: &Value| -> anyhow::Result<u64> {
        let s = v
            .get("fp")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("record has no fp"))?;
        u64::from_str_radix(s, 16).map_err(|_| anyhow::anyhow!("bad fingerprint '{s}'"))
    };
    match t {
        "hdr" => {
            let ver = v.get("version").and_then(Value::as_i64).unwrap_or(-1);
            anyhow::ensure!(
                ver == MEMO_SCHEMA_VERSION,
                "journal schema v{ver} != v{MEMO_SCHEMA_VERSION}"
            );
            let est = v.get("estimator").and_then(Value::as_str).unwrap_or("");
            anyhow::ensure!(
                est == env!("CARGO_PKG_VERSION"),
                "journal written by estimator v{est}, this is v{}",
                env!("CARGO_PKG_VERSION")
            );
            Ok(WalLine::Hdr)
        }
        "ctx" => {
            let fp = fp_of(v)?;
            let fabric_bits = v
                .get("fabric_mhz")
                .and_then(Value::as_i64)
                .ok_or_else(|| anyhow::anyhow!("ctx record misses fabric_mhz"))?
                as u64;
            let sc = StagedWalCtx {
                app: v.get("app").and_then(Value::as_str).unwrap_or("").to_string(),
                board: v.get("board").and_then(Value::as_str).unwrap_or("").to_string(),
                part: v.get("part").and_then(Value::as_str).unwrap_or("").to_string(),
                fabric_mhz: f64::from_bits(fabric_bits),
                n_tasks: v.get("n_tasks").and_then(Value::as_u64).unwrap_or(0),
                last_used: v.get("last_used").and_then(Value::as_u64).unwrap_or(0),
            };
            match ctxs.entry(fp) {
                std::collections::btree_map::Entry::Vacant(e) => {
                    e.insert(sc);
                }
                std::collections::btree_map::Entry::Occupied(mut e) => {
                    // Later snapshots carry newer metadata; recency is
                    // the max over all snapshots.
                    let lu = e.get().last_used.max(sc.last_used);
                    let slot = e.get_mut();
                    *slot = sc;
                    slot.last_used = lu;
                }
            }
            Ok(WalLine::Ctx)
        }
        "pt" => {
            let fp = fp_of(v)?;
            let key = v
                .get("key")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow::anyhow!("pt record has no key"))?;
            let bits = |field: &str| -> anyhow::Result<u64> {
                let b = v
                    .get(field)
                    .and_then(Value::as_i64)
                    .ok_or_else(|| anyhow::anyhow!("pt record '{key}' misses {field}"))?
                    as u64;
                let x = f64::from_bits(b);
                anyhow::ensure!(
                    x.is_finite() && x >= 0.0,
                    "pt record '{key}' field '{field}': not a finite non-negative number"
                );
                Ok(b)
            };
            pending.push((
                fp,
                key.to_string(),
                MemoPoint {
                    est_ms: bits("est_ms")?,
                    energy_j: bits("energy_j")?,
                    edp: bits("edp")?,
                    fabric_util: bits("fabric_util")?,
                },
            ));
            Ok(WalLine::Pt)
        }
        "commit" => Ok(WalLine::Commit),
        other => anyhow::bail!("unknown journal record '{other}'"),
    }
}

/// Append-only side journal of a recoverable sweep, written next to the
/// memo file as `<memo>.wal`.
///
/// Records are JSON lines: one `hdr` line per journal session (schema +
/// estimator version, checked on replay), `ctx` lines snapshotting the
/// recency metadata of every context the sweep touched, `pt` lines for
/// every freshly evaluated point, and a `commit` marker closing each
/// round. All lines of a round are buffered in memory and appended with a
/// **single** write + fsync in [`SweepJournal::commit_round`], so the
/// on-disk journal always holds a whole number of committed rounds plus at
/// most one torn tail line — replay applies committed rounds only and
/// drops the rest, which is exactly the "lose at most the in-flight
/// chunk" contract.
pub struct SweepJournal {
    file: std::fs::File,
    path: PathBuf,
    buf: String,
    rounds: u64,
}

impl SweepJournal {
    /// Path of the journal sibling of a memo file.
    pub fn wal_path(memo_path: &Path) -> PathBuf {
        PathBuf::from(format!("{}.wal", memo_path.display()))
    }

    /// Journal path of one service lane: shard 0 keeps the plain
    /// `<memo>.wal` name (single-lane daemons and recoverable sweeps are
    /// byte-for-byte unchanged), shard `k > 0` journals to
    /// `<memo>.wal.<k>`.
    pub fn shard_wal_path(memo_path: &Path, shard: usize) -> PathBuf {
        if shard == 0 {
            Self::wal_path(memo_path)
        } else {
            PathBuf::from(format!("{}.wal.{shard}", memo_path.display()))
        }
    }

    /// Every journal sibling of `memo_path` that exists on disk: the base
    /// `<memo>.wal` first, then numbered `<memo>.wal.<k>` shard journals
    /// in ascending shard order. Replay and post-save cleanup both walk
    /// this list, so the "lose at most the in-flight round" contract
    /// holds independently per shard.
    pub fn shard_wal_paths(memo_path: &Path) -> Vec<PathBuf> {
        let mut out = Vec::new();
        let base = Self::wal_path(memo_path);
        if base.exists() {
            out.push(base);
        }
        let Some(name) = memo_path.file_name() else {
            return out;
        };
        let prefix = format!("{}.wal.", name.to_string_lossy());
        let dir = match memo_path.parent() {
            Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
            _ => PathBuf::from("."),
        };
        let mut numbered: Vec<(u64, PathBuf)> = Vec::new();
        if let Ok(entries) = std::fs::read_dir(&dir) {
            for entry in entries.flatten() {
                let fname = entry.file_name();
                let fname = fname.to_string_lossy();
                let Some(rest) = fname.strip_prefix(&prefix) else {
                    continue;
                };
                if !rest.is_empty() && rest.bytes().all(|b| b.is_ascii_digit()) {
                    if let Ok(shard) = rest.parse::<u64>() {
                        numbered.push((shard, entry.path()));
                    }
                }
            }
        }
        numbered.sort_unstable_by_key(|(shard, _)| *shard);
        out.extend(numbered.into_iter().map(|(_, p)| p));
        out
    }

    /// Open the journal next to `memo_path` in append mode (a journal left
    /// by an interrupted sweep is extended, never truncated past its last
    /// complete line — its committed rounds were already replayed into the
    /// memo the caller loaded) and buffer the session header.
    ///
    /// If the existing journal ends in a torn line (a crash mid-append:
    /// records never contain literal newlines, so "complete" is exactly
    /// "newline-terminated"), that tail is cut off first — appending after
    /// it would glue the new session's first record onto the garbage and
    /// corrupt the whole journal on the next replay.
    pub fn open(memo_path: &Path) -> anyhow::Result<Self> {
        Self::open_at(Self::wal_path(memo_path))
    }

    /// Open the shard-`k` journal of `memo_path` (see
    /// [`SweepJournal::shard_wal_path`]) — one per service lane, so
    /// concurrent lanes never interleave records inside one file.
    pub fn open_shard(memo_path: &Path, shard: usize) -> anyhow::Result<Self> {
        Self::open_at(Self::shard_wal_path(memo_path, shard))
    }

    fn open_at(path: PathBuf) -> anyhow::Result<Self> {
        if let Ok(bytes) = std::fs::read(&path) {
            if !bytes.is_empty() && bytes.last() != Some(&b'\n') {
                let keep = bytes.iter().rposition(|&b| b == b'\n').map_or(0, |i| i + 1);
                let f = std::fs::OpenOptions::new()
                    .write(true)
                    .open(&path)
                    .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
                f.set_len(keep as u64)
                    .map_err(|e| anyhow::anyhow!("{}: truncating torn tail: {e}", path.display()))?;
            }
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let mut j = Self {
            file,
            path,
            buf: String::new(),
            rounds: 0,
        };
        j.push_line(obj(vec![
            ("t", "hdr".into()),
            ("version", MEMO_SCHEMA_VERSION.into()),
            ("estimator", env!("CARGO_PKG_VERSION").into()),
        ]));
        Ok(j)
    }

    fn push_line(&mut self, v: Value) {
        self.buf.push_str(&v.to_json());
        self.buf.push('\n');
    }

    /// Buffer a context-recency snapshot (flushed with the next commit).
    pub fn log_context(&mut self, fp: u64, ctx: &SweepContext<'_>, last_used: u64) {
        self.push_line(obj(vec![
            ("t", "ctx".into()),
            ("fp", format!("{fp:016x}").into()),
            ("app", ctx.program.app_name.as_str().into()),
            ("board", ctx.board.name.as_str().into()),
            ("part", ctx.part.name.as_str().into()),
            ("fabric_mhz", ctx.board.fabric_freq_mhz.to_bits().into()),
            ("n_tasks", (ctx.program.tasks.len() as u64).into()),
            ("last_used", last_used.into()),
        ]));
    }

    /// Buffer one freshly evaluated point (flushed with the next commit).
    pub fn log_point(&mut self, fp: u64, key: &str, p: &DsePoint) {
        self.push_line(obj(vec![
            ("t", "pt".into()),
            ("fp", format!("{fp:016x}").into()),
            ("key", key.into()),
            ("est_ms", p.est_ms.to_bits().into()),
            ("energy_j", p.energy_j.to_bits().into()),
            ("edp", p.edp.to_bits().into()),
            ("fabric_util", p.fabric_util.to_bits().into()),
        ]));
    }

    /// Rounds committed through this journal instance.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// Append every buffered record plus a round-commit marker in one
    /// write, then fsync: the round reaches disk entirely or — modulo a
    /// torn tail the replay drops — not at all.
    pub fn commit_round(&mut self) -> anyhow::Result<()> {
        use std::io::Write;
        crate::util::faultpoint::hit("wal.append")?;
        self.rounds += 1;
        self.push_line(obj(vec![
            ("t", "commit".into()),
            ("round", self.rounds.into()),
        ]));
        let res = self
            .file
            .write_all(self.buf.as_bytes())
            .and_then(|()| self.file.sync_all());
        self.buf.clear();
        res.map_err(|e| anyhow::anyhow!("{}: {e}", self.path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::Matmul;
    use crate::config::BoardConfig;
    use crate::dse::{DseSpace, Objective, OrderMode, SweepContext};
    use crate::hls::FpgaPart;

    fn fixture<'p>(
        program: &'p crate::coordinator::task::TaskProgram,
        board: &'p BoardConfig,
        space: &DseSpace,
    ) -> SweepContext<'p> {
        SweepContext::for_space(program, board, &FpgaPart::xc7z045(), space)
    }

    #[test]
    fn codesign_key_is_order_invariant() {
        let a = CoDesign::new("a")
            .with_accel("mxm64", 32)
            .with_accel("mxm64", 64)
            .with_smp("mxm64");
        let b = CoDesign::new("b")
            .with_accel("mxm64", 64)
            .with_accel("mxm64", 32)
            .with_smp("mxm64");
        assert_eq!(codesign_key(&a), codesign_key(&b));
        let c = CoDesign::new("c").with_accel("mxm64", 32).with_accel("mxm64", 32);
        assert_ne!(codesign_key(&a), codesign_key(&c));
    }

    #[test]
    fn fingerprint_separates_mismatchable_keys() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(256, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let base = context_fingerprint(&fixture(&p, &board, &space));
        // Same inputs -> same fingerprint.
        assert_eq!(base, context_fingerprint(&fixture(&p, &board, &space)));
        // A different program (task cycle counts differ) must miss.
        let p2 = Matmul::new(512, 64).build_program(&board);
        assert_ne!(base, context_fingerprint(&fixture(&p2, &board, &space)));
        // A perturbed board must miss.
        let mut b2 = board.clone();
        b2.fabric_freq_mhz += 1.0;
        let p3 = Matmul::new(256, 64).build_program(&b2);
        assert_ne!(base, context_fingerprint(&fixture(&p3, &b2, &space)));
        // A different part must miss.
        let ctx_small = SweepContext::for_space(&p, &board, &FpgaPart::xc7z020(), &space);
        assert_ne!(base, context_fingerprint(&ctx_small));
        // The emulator block is explicitly NOT part of the key.
        let mut b3 = board.clone();
        b3.emu.seed ^= 1;
        let p4 = Matmul::new(256, 64).build_program(&b3);
        assert_eq!(base, context_fingerprint(&fixture(&p4, &b3, &space)));
    }

    #[test]
    fn memo_json_roundtrip_is_bit_exact() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(256, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let ctx = fixture(&p, &board, &space);
        let fp = context_fingerprint(&ctx);
        let mut memo = EvalMemo::new();
        let (points, _) = ctx.explore_pruned(&space, Objective::Time, 2);
        memo.touch(fp);
        for pt in &points {
            memo.record(&ctx, fp, &codesign_key(&pt.codesign), pt);
        }
        memo.record_kernels(&ctx, &space);
        memo.record_occupancy(&ctx, &points);
        assert_eq!(memo.n_contexts(), 1);
        assert_eq!(memo.n_points(), points.len());
        assert_eq!(memo.n_kernel_entries(), 4); // unrolls {8, 16, 32, 64}
        let back = EvalMemo::from_json(&memo.to_json()).unwrap();
        for pt in &points {
            let hit = back.lookup(fp, &codesign_key(&pt.codesign)).unwrap();
            assert_eq!(hit.est_ms.to_bits(), pt.est_ms.to_bits());
            assert_eq!(hit.energy_j.to_bits(), pt.energy_j.to_bits());
            assert_eq!(hit.edp.to_bits(), pt.edp.to_bits());
            assert_eq!(hit.fabric_util.to_bits(), pt.fabric_util.to_bits());
        }
        assert!(back.lookup(fp ^ 1, "anything").is_none());
        assert!(!back.frontier(fp).is_empty());
        assert_eq!(back.points_ms(fp).len(), points.len());
        // Level-1 entries round-trip bit for bit too, including stats.
        assert_eq!(back.n_kernel_entries(), memo.n_kernel_entries());
        let kid = p.kernel_id("mxm64").unwrap();
        let kfp = crate::hls::kernel_fingerprint("mxm64", &p.kernel(kid).profile);
        let served = back
            .lookup_report(kfp, 32, board.fabric_freq_mhz, board.dma_bw_mbps)
            .expect("primed variant must be served");
        assert_eq!(*served, ctx.report_for(kid, "mxm64", 32));
        // A perturbed constant must miss (report validity domain).
        assert!(back
            .lookup_report(kfp, 32, board.fabric_freq_mhz + 1.0, board.dma_bw_mbps)
            .is_none());
    }

    #[test]
    fn memo_rejects_foreign_versions() {
        assert!(EvalMemo::from_json("{\"version\": 999, \"contexts\": []}").is_err());
        assert!(EvalMemo::from_json("{\"version\": 1, \"contexts\": []}").is_err());
        assert!(EvalMemo::from_json("{\"contexts\": []}").is_err());
        let wrong_estimator = format!(
            "{{\"version\": {MEMO_SCHEMA_VERSION}, \"estimator\": \"0.0.0\", \"contexts\": []}}"
        );
        assert!(EvalMemo::from_json(&wrong_estimator).is_err());
        assert!(EvalMemo::from_json("not json").is_err());
    }

    #[test]
    fn load_or_new_handles_missing_files() {
        let dir = std::env::temp_dir().join("zynq_warm_memo_t");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("memo.json");
        std::fs::remove_file(&path).ok();
        let memo = EvalMemo::load_or_new(&path).unwrap();
        assert_eq!(memo.n_points(), 0);
        memo.save(&path).unwrap();
        assert!(EvalMemo::load_or_new(&path).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_or_new_quarantines_corrupt_files() {
        let dir = std::env::temp_dir().join("zynq_warm_memo_bak");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("memo.json");
        // Truncated/corrupt file: the sweep must start fresh, and the bad
        // file must be preserved as a numbered .bak sibling instead of
        // erroring the run.
        std::fs::write(&path, "{\"version\": 2, \"estim").unwrap();
        let memo = EvalMemo::load_or_new(&path).unwrap();
        assert_eq!(memo.n_points(), 0);
        assert!(!path.exists(), "corrupt file must be moved aside");
        assert!(dir.join("memo.json.bak.1").exists(), "first quarantine is .bak.1");
        // A second corrupt load must not clobber the first quarantine.
        std::fs::write(&path, "{\"version\": 1, \"contexts\": []}").unwrap();
        assert!(EvalMemo::load_or_new(&path).unwrap().n_points() == 0);
        assert!(dir.join("memo.json.bak.1").exists(), "first generation retained");
        assert!(dir.join("memo.json.bak.2").exists(), "second generation is .bak.2");
        assert_eq!(
            std::fs::read_to_string(dir.join("memo.json.bak.1")).unwrap(),
            "{\"version\": 2, \"estim"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A populated memo for the journal tests, together with its context
    /// fingerprint and the sweep context/space that produced it.
    fn journal_fixture() -> (
        crate::coordinator::task::TaskProgram,
        BoardConfig,
        DseSpace,
    ) {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(256, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        (p, board, space)
    }

    #[test]
    fn journal_roundtrip_restores_committed_rounds_only() {
        let dir = std::env::temp_dir().join("zynq_warm_wal_rt");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("memo.json");
        let (p, board, space) = journal_fixture();
        let ctx = fixture(&p, &board, &space);
        let fp = context_fingerprint(&ctx);
        let (points, _) = ctx.explore_pruned(&space, Objective::Time, 2);
        assert!(points.len() >= 2, "fixture needs at least two points");

        // Journal one committed round plus one uncommitted point.
        let mut j = SweepJournal::open(&path).unwrap();
        j.log_context(fp, &ctx, 7);
        j.log_point(fp, &codesign_key(&points[0].codesign), &points[0]);
        j.commit_round().unwrap();
        j.log_point(fp, &codesign_key(&points[1].codesign), &points[1]);
        drop(j); // crash before the second commit
        let (memo, rec) = EvalMemo::load_with_recovery(&path).unwrap();
        let rec = rec.expect("journal must be reported");
        assert_eq!(rec.rounds, 1);
        assert_eq!(rec.n_points(), 1);
        assert!(rec.contexts.contains(&fp));
        assert!(rec.contains(fp, &codesign_key(&points[0].codesign)));
        assert!(
            !rec.contains(fp, &codesign_key(&points[1].codesign)),
            "uncommitted in-flight point must be dropped"
        );
        // The restored point is bit-identical, the recency snapshot and
        // clock were applied, and the uncommitted point is absent.
        let hit = memo.lookup(fp, &codesign_key(&points[0].codesign)).unwrap();
        assert_eq!(hit.est_ms.to_bits(), points[0].est_ms.to_bits());
        assert!(memo.lookup(fp, &codesign_key(&points[1].codesign)).is_none());
        assert_eq!(memo.stats().rows[0].last_used, 7);
        // Saving deletes the journal: the sidecar only carries the delta
        // since the last good save.
        memo.save(&path).unwrap();
        assert!(!SweepJournal::wal_path(&path).exists());
        let (_, rec2) = EvalMemo::load_with_recovery(&path).unwrap();
        assert!(rec2.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn journal_replay_drops_torn_tail_and_quarantines_corruption() {
        let dir = std::env::temp_dir().join("zynq_warm_wal_torn");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("memo.json");
        let (p, board, space) = journal_fixture();
        let ctx = fixture(&p, &board, &space);
        let fp = context_fingerprint(&ctx);
        let (points, _) = ctx.explore_pruned(&space, Objective::Time, 2);
        let mut j = SweepJournal::open(&path).unwrap();
        j.log_context(fp, &ctx, 3);
        j.log_point(fp, &codesign_key(&points[0].codesign), &points[0]);
        j.commit_round().unwrap();
        drop(j);
        let wal = SweepJournal::wal_path(&path);
        let good = std::fs::read_to_string(&wal).unwrap();

        // A torn tail (half a line, as a kill mid-write leaves) is
        // dropped; the committed round still replays.
        std::fs::write(&wal, format!("{good}{{\"t\":\"pt\",\"fp\"")).unwrap();
        let (memo, rec) = EvalMemo::load_with_recovery(&path).unwrap();
        assert_eq!(rec.expect("committed round survives").n_points(), 1);
        assert!(memo.lookup(fp, &codesign_key(&points[0].codesign)).is_some());
        assert!(wal.exists(), "a merely-torn journal is not quarantined");

        // Mid-file corruption is all-or-nothing: nothing replays and the
        // journal is quarantined as evidence.
        std::fs::write(&wal, format!("not json\n{good}")).unwrap();
        let (memo, rec) = EvalMemo::load_with_recovery(&path).unwrap();
        assert!(rec.is_none());
        assert_eq!(memo.n_points(), 0);
        assert!(!wal.exists(), "corrupt journal must be moved aside");
        assert!(
            PathBuf::from(format!("{}.bak.1", wal.display())).exists(),
            "corrupt journal must be preserved"
        );

        // A journal from a different schema/estimator is refused too.
        std::fs::write(&wal, "{\"t\":\"hdr\",\"version\":1,\"estimator\":\"0.0.0\"}\n").unwrap();
        let (_, rec) = EvalMemo::load_with_recovery(&path).unwrap();
        assert!(rec.is_none());
        assert!(!wal.exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn journal_points_reject_non_finite_fields() {
        let mut memo = EvalMemo::new();
        let hdr = format!(
            "{{\"t\":\"hdr\",\"version\":{MEMO_SCHEMA_VERSION},\"estimator\":\"{}\"}}",
            env!("CARGO_PKG_VERSION")
        );
        let ctx = "{\"t\":\"ctx\",\"fp\":\"00000000000000aa\",\"app\":\"a\",\"board\":\"b\",\
                   \"part\":\"p\",\"fabric_mhz\":0,\"n_tasks\":1,\"last_used\":1}";
        let nan = f64::NAN.to_bits() as i64;
        let pt = format!(
            "{{\"t\":\"pt\",\"fp\":\"00000000000000aa\",\"key\":\"k\",\"est_ms\":{nan},\
             \"energy_j\":0,\"edp\":0,\"fabric_util\":0}}"
        );
        let text = format!("{hdr}\n{ctx}\n{pt}\n{{\"t\":\"commit\",\"round\":1}}\nx");
        let err = memo.replay_wal_text(&text).unwrap_err().to_string();
        assert!(err.contains("est_ms"), "{err}");
        // And the same validation guards the memo document itself.
        let doc = format!(
            "{{\"version\":{MEMO_SCHEMA_VERSION},\"estimator\":\"{}\",\"clock\":0,\
             \"contexts\":[{{\"fp\":\"00000000000000aa\",\"app\":\"a\",\"board\":\"b\",\
             \"part\":\"p\",\"fabric_mhz\":0,\"n_tasks\":1,\"last_used\":1,\"points\":\
             [{{\"key\":\"k\",\"est_ms\":{nan},\"energy_j\":0,\"edp\":0,\"fabric_util\":0}}],\
             \"frontier\":[]}}],\"kernels\":[]}}",
            env!("CARGO_PKG_VERSION")
        );
        let err = EvalMemo::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("est_ms"), "{err}");
    }

    #[test]
    fn warm_sweep_skips_memo_hits_and_stays_exact() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(256, 64).build_program(&board);
        let space = DseSpace::from_program(&p).with_mixed();
        let ctx = fixture(&p, &board, &space);
        let mut memo = EvalMemo::new();
        let (cold, cold_stats) = ctx.explore_pruned(&space, Objective::Time, 2);
        let (first, first_stats) =
            ctx.explore_warm(&space, &mut memo, Objective::Time, 2, OrderMode::Ranked);
        assert_eq!(first_stats.memo_hits, 0);
        assert!(first_stats.evaluated > 0);
        // Exactness vs the cold pruned sweep: best + Pareto front.
        assert_eq!(
            cold[0].est_ms.to_bits(),
            first[0].est_ms.to_bits(),
            "warm best diverged ({} vs {})",
            cold[0].codesign.name,
            first[0].codesign.name
        );
        assert_eq!(
            super::super::pareto_front_coords(&cold),
            super::super::pareto_front_coords(&first)
        );
        assert!(cold_stats.evaluated > 0);
        // Second sweep over the identical space: zero evaluations, every
        // point served from the memo, ranking bit-identical.
        let (second, second_stats) =
            ctx.explore_warm(&space, &mut memo, Objective::Time, 2, OrderMode::Ranked);
        assert_eq!(second_stats.evaluated, 0, "{second_stats:?}");
        assert_eq!(second_stats.memo_hits as usize, first.len());
        assert_eq!(second.len(), first.len());
        for (a, b) in second.iter().zip(&first) {
            assert_eq!(a.codesign.name, b.codesign.name);
            assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits());
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        }
    }

    #[test]
    fn gc_is_lru_by_context_and_survivors_stay_exact() {
        let board = BoardConfig::zynq706();
        let old_p = Matmul::new(128, 64).build_program(&board);
        let new_p = Matmul::new(256, 64).build_program(&board);
        let old_space = DseSpace::from_program(&old_p);
        let new_space = DseSpace::from_program(&new_p);
        let old_ctx = fixture(&old_p, &board, &old_space);
        let new_ctx = fixture(&new_p, &board, &new_space);
        let mut memo = EvalMemo::new();
        let (old_pts, _) =
            old_ctx.explore_warm(&old_space, &mut memo, Objective::Time, 2, OrderMode::Ranked);
        let (new_pts, _) =
            new_ctx.explore_warm(&new_space, &mut memo, Objective::Time, 2, OrderMode::Ranked);
        assert_eq!(memo.n_contexts(), 2);
        let bytes_before = memo.to_json().len();
        let old_fp = context_fingerprint(&old_ctx);
        let new_fp = context_fingerprint(&new_ctx);

        let report = memo.gc(1, usize::MAX, usize::MAX);
        assert_eq!(report.evicted_contexts, 1);
        assert_eq!(report.evicted_points, old_pts.len());
        // LRU: the earlier-swept context goes, the recent one survives
        // with every point bit-exact.
        assert!(memo.lookup(old_fp, &codesign_key(&old_pts[0].codesign)).is_none());
        for pt in &new_pts {
            let hit = memo.lookup(new_fp, &codesign_key(&pt.codesign)).unwrap();
            assert_eq!(hit.est_ms.to_bits(), pt.est_ms.to_bits());
            assert_eq!(hit.energy_j.to_bits(), pt.energy_j.to_bits());
        }
        // The file is strictly smaller, and the stats/compact paths agree.
        assert!(memo.to_json().len() < bytes_before);
        let stats = memo.stats();
        assert_eq!(stats.contexts, 1);
        assert_eq!(stats.points, new_pts.len());
        assert_eq!(memo.compact(), 0);
        // The evicted context is gone from the sibling index too.
        assert!(memo.sibling_points_ms(&old_p.app_name, 0).is_empty());
        // Kernel-entry cap: both programs share one kernel profile, so the
        // sub-memo has 4 entries; cap to 2 and the survivors still serve.
        assert_eq!(memo.n_kernel_entries(), 4);
        let r2 = memo.gc(usize::MAX, usize::MAX, 2);
        assert_eq!(r2.evicted_kernels, 2);
        assert_eq!(memo.n_kernel_entries(), 2);
    }

    /// A synthetic point for the gc-policy tests — recording does not
    /// care where the numbers came from, only that they round-trip.
    fn synthetic_point(ms: f64) -> DsePoint {
        DsePoint {
            codesign: CoDesign::new("synthetic"),
            est_ms: ms,
            energy_j: ms * 2.0,
            edp: ms * ms * 1e-3,
            fabric_util: 0.25,
        }
    }

    /// Record one synthetic point into `memo` under a fresh context built
    /// from `program`, returning its fingerprint.
    fn record_context(
        memo: &mut EvalMemo,
        program: &crate::coordinator::task::TaskProgram,
        board: &BoardConfig,
        ms: f64,
    ) -> u64 {
        let space = DseSpace::from_program(program);
        let ctx = fixture(program, board, &space);
        let fp = context_fingerprint(&ctx);
        memo.touch(fp);
        memo.record(&ctx, fp, "synthetic", &synthetic_point(ms));
        fp
    }

    #[test]
    fn gc_bytes_respects_per_app_floors_even_under_zero_budget() {
        let board = BoardConfig::zynq706();
        let m128 = Matmul::new(128, 64).build_program(&board);
        let m256 = Matmul::new(256, 64).build_program(&board);
        let c128 = crate::apps::cholesky::Cholesky::new(128, 64).build_program(&board);
        let c256 = crate::apps::cholesky::Cholesky::new(256, 64).build_program(&board);
        let mut memo = EvalMemo::new();
        // Recency order: matmul-128, matmul-256, cholesky-128, cholesky-256.
        let fp_m128 = record_context(&mut memo, &m128, &board, 1.0);
        let fp_m256 = record_context(&mut memo, &m256, &board, 2.0);
        let fp_c128 = record_context(&mut memo, &c128, &board, 3.0);
        let fp_c256 = record_context(&mut memo, &c256, &board, 4.0);
        assert_eq!(memo.n_contexts(), 4);
        // Impossible budget: everything evictable goes — but the floor
        // keeps the most-recent context of *every* app, so a full gc under
        // the byte budget can never forget matmul-256 or cholesky-256.
        let report = memo.gc_bytes(0, 1);
        assert_eq!(report.evicted_contexts, 2);
        assert_eq!(report.evicted_points, 2);
        assert!(memo.lookup(fp_m128, "synthetic").is_none());
        assert!(memo.lookup(fp_c128, "synthetic").is_none());
        // Survivors stay bit-exact.
        let m = memo.lookup(fp_m256, "synthetic").expect("matmul floor survives");
        assert_eq!(m.est_ms.to_bits(), 2.0f64.to_bits());
        assert_eq!(m.energy_j.to_bits(), 4.0f64.to_bits());
        let c = memo.lookup(fp_c256, "synthetic").expect("cholesky floor survives");
        assert_eq!(c.est_ms.to_bits(), 4.0f64.to_bits());
        // Idempotent once only floors remain.
        assert_eq!(memo.gc_bytes(0, 1), GcReport::default());
        assert_eq!(memo.n_contexts(), 2);
    }

    #[test]
    fn gc_bytes_evicts_lru_until_the_budget_is_met() {
        let board = BoardConfig::zynq706();
        let a = Matmul::new(128, 64).build_program(&board);
        let b = Matmul::new(256, 64).build_program(&board);
        let c = Matmul::new(512, 64).build_program(&board);
        let mut memo = EvalMemo::new();
        let fp_a = record_context(&mut memo, &a, &board, 1.0);
        let fp_b = record_context(&mut memo, &b, &board, 2.0);
        let fp_c = record_context(&mut memo, &c, &board, 3.0);
        let full = memo.to_json().len();
        // A budget one byte short of the full document: evicting the
        // single least-recent unprotected context must suffice.
        let report = memo.gc_bytes(full - 1, 1);
        assert_eq!(report.evicted_contexts, 1);
        assert!(memo.to_json().len() <= full - 1);
        assert!(memo.lookup(fp_a, "synthetic").is_none(), "LRU context evicted");
        assert!(memo.lookup(fp_b, "synthetic").is_some());
        assert!(memo.lookup(fp_c, "synthetic").is_some());
        // A generous budget is a no-op.
        assert_eq!(memo.gc_bytes(usize::MAX, 1), GcReport::default());
        // The sibling index follows the eviction.
        assert_eq!(memo.sibling_points_ms(&a.app_name, fp_c).len(), 1);
    }

    #[test]
    fn gc_bytes_trims_kernel_entries_when_floors_exceed_the_budget() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(256, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let ctx = fixture(&p, &board, &space);
        let fp = context_fingerprint(&ctx);
        let mut memo = EvalMemo::new();
        memo.touch(fp);
        memo.record(&ctx, fp, "synthetic", &synthetic_point(1.0));
        memo.record_kernels(&ctx, &space);
        assert_eq!(memo.n_kernel_entries(), 4);
        // The only context is floored; the budget is impossible, so the
        // level-1 entries are trimmed instead — and the floored context's
        // points still serve bit-exactly.
        let report = memo.gc_bytes(1, 1);
        assert_eq!(report.evicted_contexts, 0);
        assert_eq!(report.evicted_kernels, 4);
        assert_eq!(memo.n_kernel_entries(), 0);
        let hit = memo.lookup(fp, "synthetic").expect("floored context survives");
        assert_eq!(hit.est_ms.to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn kernel_priors_need_samples_and_scale_with_tasks() {
        let board = BoardConfig::zynq706();
        let small = Matmul::new(128, 64).build_program(&board);
        let large = Matmul::new(256, 64).build_program(&board);
        let space = DseSpace::from_program(&small);
        let small_ctx = fixture(&small, &board, &space);
        let large_space = DseSpace::from_program(&large);
        let large_ctx = fixture(&large, &board, &large_space);
        let mut memo = EvalMemo::new();
        let counts = kernel_task_counts(&large);
        // No statistics yet: no prior.
        let probe = CoDesign::new("x").with_accel("mxm64", 32);
        assert!(memo.prior_ms_for(&large_ctx, &counts, &probe).is_none());
        let (pts, _) =
            small_ctx.explore_warm(&space, &mut memo, Objective::Time, 2, OrderMode::Ranked);
        // Any evaluated accelerated point has occupancy samples for every
        // variant it used, so its co-design gets a prior at both sizes.
        let cd = &pts
            .iter()
            .find(|p| !p.codesign.accels.is_empty())
            .expect("space has accelerated points")
            .codesign;
        // Statistics from the small size predict the large size, scaled by
        // the task-count ratio (8x the tasks here).
        let small_counts = kernel_task_counts(&small);
        let p_small = memo.prior_ms_for(&small_ctx, &small_counts, cd).unwrap();
        let p_large = memo.prior_ms_for(&large_ctx, &counts, cd).unwrap();
        assert!(p_small > 0.0);
        assert!((p_large / p_small - 8.0).abs() < 1e-9, "{p_large} vs {p_small}");
        // smp-only candidates have no kernel prior.
        assert!(memo
            .prior_ms_for(&large_ctx, &counts, &CoDesign::new("smp").with_smp("mxm64"))
            .is_none());
    }
}
