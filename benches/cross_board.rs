//! Cross-board DSE benchmark — the platform as a swept axis.
//!
//! Sweeps matmul + cholesky over the zynq702/zynq706 board axis through
//! one shared pool, exhaustively and with both pruned modes (per-board
//! lossless, and with the cross-board incumbent), asserting the
//! losslessness contracts via `experiments::cross_board_dse`. Emits
//! `BENCH_cross_board.json` — per-(board, app) point accounting plus the
//! "which board wins at which budget" tables — which CI uploads in the
//! `bench-results` artifact and gates with `bench-check`.

use zynq_estimator::board::BoardSpace;
use zynq_estimator::dse::{default_workers, BudgetAxis};
use zynq_estimator::experiments;
use zynq_estimator::metrics::export::{budget_tables_json, cross_board_json};
use zynq_estimator::util::json::{obj, parse, Value};

fn main() {
    let boards = BoardSpace::resolve(&["zynq702", "zynq706"]).expect("built-in boards");
    let workers = default_workers();
    let n = 512;
    let apps = ["matmul", "cholesky"];
    let r = experiments::cross_board_dse(n, &boards, &apps, workers)
        .expect("cross-board sweep must be lossless");

    println!(
        "== Cross-board DSE (n = {n}, {} boards x {} apps, {workers} workers, one shared pool)",
        boards.targets.len(),
        apps.len()
    );
    println!(
        "{:>10} {:>16} {:>9} {:>9} {:>10} {:>10}  {}",
        "app", "board", "feasible", "pruned", "bound cut", "global cut", "best co-design"
    );
    for (p, g) in r.results.iter().zip(&r.global_results) {
        println!(
            "{:>10} {:>16} {:>9} {:>9} {:>10} {:>10}  {}",
            p.app,
            p.board,
            p.stats.feasible_points,
            p.stats.evaluated,
            p.stats.bound_cut,
            g.stats.global_cut,
            p.points
                .first()
                .map(|pt| pt.codesign.name.as_str())
                .unwrap_or("-"),
        );
    }
    for (app, rows) in &r.winners {
        print!("{}", zynq_estimator::dse::cross::render_winner_table(app, rows));
    }
    println!(
        "exhaustive {:.3} s, pruned {:.3} s ({:.2}x), global-cut {:.3} s ({:.2}x)",
        r.exhaustive_s,
        r.pruned_s,
        r.exhaustive_s / r.pruned_s.max(1e-12),
        r.global_s,
        r.exhaustive_s / r.global_s.max(1e-12),
    );

    let detail = parse(&cross_board_json(&r.results, &r.winners))
        .expect("own export must be valid JSON");
    // The other two §I budget axes, embedded machine-readably next to the
    // time-budget winner tables.
    let budget_tables = obj(vec![
        (
            "energy",
            parse(&budget_tables_json(BudgetAxis::Energy, &r.energy_winners))
                .expect("energy budget export must be valid JSON"),
        ),
        (
            "area",
            parse(&budget_tables_json(BudgetAxis::Area, &r.area_winners))
                .expect("area budget export must be valid JSON"),
        ),
    ]);
    let global_cut: u64 = r.global_results.iter().map(|x| x.stats.global_cut).sum();
    let out = obj(vec![
        ("n", n.into()),
        ("workers", r.workers.into()),
        (
            "boards",
            Value::Arr(
                boards
                    .targets
                    .iter()
                    .map(|t| t.name.as_str().into())
                    .collect(),
            ),
        ),
        ("exhaustive_s", r.exhaustive_s.into()),
        ("pruned_s", r.pruned_s.into()),
        ("global_s", r.global_s.into()),
        ("speedup", (r.exhaustive_s / r.pruned_s.max(1e-12)).into()),
        ("global_cut_total", global_cut.into()),
        ("cross_board", detail),
        ("budget_tables", budget_tables),
    ])
    .to_json();
    match std::fs::write("BENCH_cross_board.json", &out) {
        Ok(()) => println!("wrote BENCH_cross_board.json ({} bytes)", out.len()),
        Err(e) => eprintln!("could not write BENCH_cross_board.json: {e}"),
    }
}
