//! Crash-safe file persistence: atomic replace-on-save and numbered
//! quarantine of corrupt files.
//!
//! The persistent artifacts of the DSE layer (the evaluation memo, sweep
//! checkpoints) are the accumulated value of hours of estimation, so a
//! save must never be able to destroy the previous good copy: a torn
//! write during `std::fs::write` leaves a half-file that fails to parse
//! and costs the whole cache. [`write_atomic`] closes that hole with the
//! classic write-to-temp → fsync → rename sequence (rename is atomic on
//! POSIX filesystems), and [`quarantine`] preserves *every* corrupt file
//! under numbered `.bak.N` suffixes — a second corrupt load must not
//! clobber the evidence of the first — with a retention cap so repeated
//! corruption cannot grow the directory without bound.

use std::path::{Path, PathBuf};

/// How many quarantined `.bak.N` siblings [`quarantine`] retains per file
/// before evicting the oldest.
pub const QUARANTINE_CAP: usize = 8;

/// Atomically replace `path` with `bytes`: write a `<path>.tmp` sibling,
/// fsync it, then rename over the destination (and best-effort fsync the
/// directory so the rename itself is durable). A crash at any step leaves
/// either the old file or the new file, never a torn mix.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    use std::io::Write;
    let tmp = PathBuf::from(format!("{}.tmp", path.display()));
    let write_temp = || -> std::io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()
    };
    if let Err(e) = write_temp() {
        let _ = std::fs::remove_file(&tmp);
        anyhow::bail!("{}: {e}", tmp.display());
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        anyhow::bail!("{}: rename to {}: {e}", tmp.display(), path.display());
    }
    // Durability of the rename needs the directory entry flushed too;
    // best-effort (not all platforms allow fsync on a directory handle).
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Numbered `.bak.N` siblings of `path` that already exist, as
/// `(N, full path)` pairs sorted ascending by `N`. Found by scanning the
/// directory (suffix numbers grow without bound across evictions, so a
/// fixed probe range would eventually miss — and then clobber — the
/// newest generations).
fn existing_quarantines(path: &Path) -> Vec<(u64, PathBuf)> {
    let Some(file_name) = path.file_name().and_then(|n| n.to_str()) else {
        return Vec::new();
    };
    let prefix = format!("{file_name}.bak.");
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let mut found = Vec::new();
    if let Ok(entries) = std::fs::read_dir(&dir) {
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(suffix) = name.strip_prefix(&prefix) {
                if let Ok(n) = suffix.parse::<u64>() {
                    found.push((n, dir.join(name)));
                }
            }
        }
    }
    found.sort_unstable_by_key(|(n, _)| *n);
    found
}

/// Move a corrupt `path` aside to the next free `<path>.bak.N` (N starts
/// at 1 and always increases past the highest retained suffix, so a second
/// quarantine never clobbers the first), evicting the lowest-numbered
/// quarantine when more than [`QUARANTINE_CAP`] would be retained.
/// Returns the quarantine path.
pub fn quarantine(path: &Path) -> anyhow::Result<PathBuf> {
    let existing = existing_quarantines(path);
    let next = existing.iter().map(|(n, _)| *n).max().unwrap_or(0) + 1;
    let bak = PathBuf::from(format!("{}.bak.{next}", path.display()));
    std::fs::rename(path, &bak)
        .map_err(|e| anyhow::anyhow!("{}: rename to {}: {e}", path.display(), bak.display()))?;
    if existing.len() + 1 > QUARANTINE_CAP {
        for (_, old) in existing
            .iter()
            .take(existing.len() + 1 - QUARANTINE_CAP)
        {
            let _ = std::fs::remove_file(old);
        }
    }
    Ok(bak)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("zynq_persist_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn write_atomic_replaces_and_leaves_no_temp() {
        let d = tmpdir("atomic");
        let p = d.join("memo.json");
        write_atomic(&p, b"v1").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"v1");
        write_atomic(&p, b"v2-longer-content").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"v2-longer-content");
        assert!(!PathBuf::from(format!("{}.tmp", p.display())).exists());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn quarantine_numbers_do_not_clobber() {
        let d = tmpdir("numbered");
        let p = d.join("memo.json");
        std::fs::write(&p, b"corrupt-1").unwrap();
        let b1 = quarantine(&p).unwrap();
        assert!(b1.display().to_string().ends_with(".bak.1"));
        std::fs::write(&p, b"corrupt-2").unwrap();
        let b2 = quarantine(&p).unwrap();
        assert!(b2.display().to_string().ends_with(".bak.2"));
        // Both generations retained, original gone.
        assert_eq!(std::fs::read(&b1).unwrap(), b"corrupt-1");
        assert_eq!(std::fs::read(&b2).unwrap(), b"corrupt-2");
        assert!(!p.exists());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn quarantine_caps_retained_generations() {
        let d = tmpdir("capped");
        let p = d.join("memo.json");
        for i in 0..(QUARANTINE_CAP + 3) {
            std::fs::write(&p, format!("corrupt-{i}")).unwrap();
            quarantine(&p).unwrap();
        }
        let retained = existing_quarantines(&p);
        assert!(retained.len() <= QUARANTINE_CAP, "{} retained", retained.len());
        // The newest generation is always among the survivors.
        assert!(retained
            .iter()
            .any(|(n, _)| *n == (QUARANTINE_CAP + 3) as u64));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn quarantine_of_missing_file_errors() {
        let d = tmpdir("missing");
        assert!(quarantine(&d.join("nope.json")).is_err());
        std::fs::remove_dir_all(&d).ok();
    }
}
