//! The resident estimator daemon behind `zynq-estimator serve`.
//!
//! One [`Service`] owns one shared [`EvalMemo`] and answers NDJSON
//! requests from any number of transports concurrently: the process's
//! stdin/stdout pair and (with `--listen`) a TCP listener where every
//! connection speaks the same one-line-per-message protocol. All
//! transports funnel into [`Service::handle_line`], so the daemon's
//! semantics are transport-independent and the conformance suite can
//! drive the cheap pipe transport and trust the TCP one.
//!
//! **Coalescing.** Identical in-flight queries (same canonical
//! [`Envelope::coalesce_key`]) share one evaluation: the first arrival
//! becomes the *leader* and computes; later arrivals park on a condvar
//! and receive a clone of the leader's reply, so all N responses are
//! bitwise identical and the memo sees one recording. Coalescing is
//! observable only through the cumulative `coalesced` counter of
//! `{"req":"memo","action":"stats"}` — deliberately not in per-response
//! fields, which would break response bit-identity.
//!
//! **Persistence.** With `--memo <file>` the memo loads with WAL
//! recovery at startup, journals every fresh evaluation as a committed
//! WAL round *before* its response is written, and saves atomically
//! every `--save-every` fresh evaluations, at `memo gc`, and at
//! shutdown/EOF. A `kill -9` therefore loses at most the in-flight
//! round — the same contract the recoverable sweeps have. A failed save
//! degrades cleanly: the daemon keeps answering, the WAL keeps the
//! delta, and the final exit code turns non-zero so supervisors notice.

use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::config::BoardConfig;
use crate::coordinator::task::TaskProgram;
use crate::dse::{EvalMemo, SweepJournal};
use crate::hls::FpgaPart;
use crate::util::json::Value;

use super::proto::{
    err_line, ok_line, parse_request, Envelope, QueryReply, RequestKind, ServiceError,
};
use super::query::{dse_query, point_query};

/// Daemon configuration (the `serve` CLI flags).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Persistent memo file; `None` serves from a process-local memo.
    pub memo_path: Option<PathBuf>,
    /// TCP listen address (e.g. `127.0.0.1:7070`); `None` is stdio-only.
    pub listen: Option<String>,
    /// Sweep worker threads (0 → one per core).
    pub workers: usize,
    /// Save the memo after this many fresh evaluations.
    pub save_every: u64,
    /// Byte budget enforced (via `EvalMemo::gc_bytes`) before each save.
    pub max_bytes: Option<usize>,
    /// Per-app most-recent context floor of the byte-budget gc.
    pub app_floor: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            memo_path: None,
            listen: None,
            workers: 0,
            save_every: 8,
            max_bytes: None,
            app_floor: 1,
        }
    }
}

/// The memo plus everything that must stay mutually consistent with it
/// (journal handle, save bookkeeping) — one lock, one owner at a time.
struct MemoLane {
    memo: EvalMemo,
    journal: Option<SweepJournal>,
    fresh_since_save: u64,
    save_failed: bool,
}

/// A query in flight: the leader publishes into `slot` and wakes waiters.
struct InFlight {
    slot: Mutex<Option<Result<QueryReply, ServiceError>>>,
    done: Condvar,
}

/// Cumulative service counters (all monotonic, relaxed ordering — they
/// are observability, not synchronization).
#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    coalesced: AtomicU64,
    evaluated: AtomicU64,
    l1_hits: AtomicU64,
    l2_hits: AtomicU64,
    errors: AtomicU64,
    saves: AtomicU64,
}

/// The resident estimator service: shared memo, program cache, in-flight
/// coalescing table and counters. Wrap in an [`Arc`] and call
/// [`Service::handle_line`] from any number of threads.
pub struct Service {
    board: BoardConfig,
    part: FpgaPart,
    cfg: ServeConfig,
    programs: Mutex<BTreeMap<(String, u64, u64), Arc<TaskProgram>>>,
    lane: Mutex<MemoLane>,
    inflight: Mutex<HashMap<String, Arc<InFlight>>>,
    counters: Counters,
    shutdown: AtomicBool,
    exit_code: Mutex<Option<i32>>,
}

/// Lock that survives a poisoned-by-panic peer: a leader panicking
/// mid-query (fault injection does this on purpose) must not wedge the
/// daemon — worst case the memo lane lost one partial recording, which
/// the next save rewrites consistently.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl Service {
    /// Build the service: load the memo (with WAL recovery) and open its
    /// journal. Startup diagnostics go to stderr — stdout carries only
    /// NDJSON responses.
    pub fn new(board: BoardConfig, cfg: ServeConfig) -> anyhow::Result<Self> {
        let (memo, journal) = match &cfg.memo_path {
            Some(path) => {
                let (memo, recovered) = EvalMemo::load_with_recovery(path)?;
                if let Some(rec) = &recovered {
                    eprintln!(
                        "serve: recovered {} journaled points across {} contexts \
                         ({} committed rounds) from {}",
                        rec.n_points(),
                        rec.contexts.len(),
                        rec.rounds,
                        SweepJournal::wal_path(path).display(),
                    );
                }
                eprintln!(
                    "serve: memo {} ({} contexts, {} points, {} kernel entries)",
                    path.display(),
                    memo.n_contexts(),
                    memo.n_points(),
                    memo.n_kernel_entries(),
                );
                let journal = SweepJournal::open(path)?;
                (memo, Some(journal))
            }
            None => (EvalMemo::new(), None),
        };
        Ok(Service {
            board,
            part: FpgaPart::xc7z045(),
            cfg,
            programs: Mutex::new(BTreeMap::new()),
            lane: Mutex::new(MemoLane {
                memo,
                journal,
                fresh_since_save: 0,
                save_failed: false,
            }),
            inflight: Mutex::new(HashMap::new()),
            counters: Counters::default(),
            shutdown: AtomicBool::new(false),
            exit_code: Mutex::new(None),
        })
    }

    /// Total requests parsed (well-formed or not).
    pub fn requests(&self) -> u64 {
        self.counters.requests.load(Ordering::Relaxed)
    }

    /// Requests that joined another request's in-flight evaluation.
    pub fn coalesced(&self) -> u64 {
        self.counters.coalesced.load(Ordering::Relaxed)
    }

    /// Points freshly simulated across all queries.
    pub fn evaluated(&self) -> u64 {
        self.counters.evaluated.load(Ordering::Relaxed)
    }

    /// Error responses sent.
    pub fn errors(&self) -> u64 {
        self.counters.errors.load(Ordering::Relaxed)
    }

    fn workers(&self) -> usize {
        match self.cfg.workers {
            0 => crate::dse::default_workers(),
            w => w,
        }
    }

    fn program(&self, app: &str, n: u64, bs: u64) -> Result<Arc<TaskProgram>, ServiceError> {
        let key = (app.to_string(), n, bs);
        if let Some(p) = lock_unpoisoned(&self.programs).get(&key) {
            return Ok(Arc::clone(p));
        }
        // Built outside the cache lock: program construction is pure.
        let program = crate::apps::build_app_program(app, n, bs, &self.board)
            .map_err(|e| ServiceError::usage(format!("{e:#}")))?;
        let program = Arc::new(program);
        lock_unpoisoned(&self.programs)
            .entry(key)
            .or_insert_with(|| Arc::clone(&program));
        Ok(program)
    }

    /// Save the memo under the lane lock: enforce the byte budget, close
    /// the journal (a successful save deletes the `.wal` file — keeping
    /// the handle would journal into a deleted inode), save atomically,
    /// reopen the journal. On failure the daemon degrades instead of
    /// dying: the WAL still carries the delta, `save_failed` turns the
    /// final exit code non-zero.
    fn save_lane(&self, lane: &mut MemoLane) {
        let Some(path) = &self.cfg.memo_path else {
            lane.fresh_since_save = 0;
            return;
        };
        if let Some(max) = self.cfg.max_bytes {
            let gc = lane.memo.gc_bytes(max, self.cfg.app_floor);
            if gc.evicted_contexts > 0 || gc.evicted_kernels > 0 {
                eprintln!(
                    "serve: byte-budget gc evicted {} contexts ({} points), {} kernel entries",
                    gc.evicted_contexts, gc.evicted_points, gc.evicted_kernels
                );
            }
        }
        lane.journal = None;
        match lane.memo.save(path) {
            Ok(()) => {
                lane.fresh_since_save = 0;
                self.counters.saves.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                lane.save_failed = true;
                eprintln!(
                    "serve: memo save failed ({e:#}) — continuing degraded; \
                     the WAL retains unsaved rounds"
                );
            }
        }
        match SweepJournal::open(path) {
            Ok(j) => lane.journal = Some(j),
            Err(e) => eprintln!("serve: journal reopen failed ({e:#}); journaling disabled"),
        }
    }

    fn run_query(&self, env: &Envelope) -> Result<QueryReply, ServiceError> {
        let map_err = |e: anyhow::Error| ServiceError::usage(format!("{e:#}"));
        match &env.kind {
            RequestKind::Estimate(q) | RequestKind::Energy(q) => {
                let energy_view = matches!(env.kind, RequestKind::Energy(_));
                let program = self.program(&q.app, q.n, q.bs)?;
                let cd = q.codesign();
                let mut lane = lock_unpoisoned(&self.lane);
                let MemoLane { memo, journal, .. } = &mut *lane;
                let out = point_query(
                    &program,
                    &self.board,
                    &self.part,
                    &q.app,
                    q.n,
                    q.bs,
                    &cd,
                    energy_view,
                    memo,
                    journal.as_mut(),
                )
                .map_err(map_err)?;
                self.after_query(&mut lane, &out.reply);
                Ok(out.reply)
            }
            RequestKind::Dse(q) => {
                let program = self.program(&q.app, q.n, q.bs)?;
                let workers = self.workers();
                let mut lane = lock_unpoisoned(&self.lane);
                let MemoLane { memo, journal, .. } = &mut *lane;
                let reply = dse_query(
                    &program,
                    &self.board,
                    &self.part,
                    q,
                    workers,
                    memo,
                    journal.as_mut(),
                )
                .map_err(map_err)?;
                self.after_query(&mut lane, &reply);
                Ok(reply)
            }
            RequestKind::MemoStats => {
                let lane = lock_unpoisoned(&self.lane);
                let stats = lane.memo.stats();
                let mut text = stats.render();
                text.push_str(&format!(
                    "service: {} requests, {} coalesced, {} evaluated, {} errors, {} saves{}\n",
                    self.requests(),
                    self.coalesced(),
                    self.evaluated(),
                    self.errors(),
                    self.counters.saves.load(Ordering::Relaxed),
                    if lane.save_failed { ", DEGRADED" } else { "" },
                ));
                let extra = crate::metrics::export::service_stats_fields(
                    &stats,
                    self.requests(),
                    self.coalesced(),
                    self.evaluated(),
                    self.errors(),
                    self.counters.saves.load(Ordering::Relaxed),
                    lane.save_failed,
                );
                Ok(QueryReply {
                    text,
                    l1_hits: self.counters.l1_hits.load(Ordering::Relaxed),
                    l2_hits: self.counters.l2_hits.load(Ordering::Relaxed),
                    evaluated: 0,
                    extra,
                })
            }
            RequestKind::MemoGc(spec) => {
                let mut lane = lock_unpoisoned(&self.lane);
                let report = match spec.max_bytes {
                    Some(max) => lane.memo.gc_bytes(max, spec.app_floor),
                    None => lane
                        .memo
                        .gc(spec.keep_contexts, spec.keep_points, spec.keep_kernels),
                };
                // Persist immediately: the WAL may reference evicted
                // contexts, so the post-gc truth must reach disk before
                // any replay could resurrect them.
                self.save_lane(&mut lane);
                let text = format!(
                    "gc: evicted {} contexts ({} points) and {} kernel entries \
                     ({} contexts, {} points, {} kernel entries retained, all bit-exact)\n",
                    report.evicted_contexts,
                    report.evicted_points,
                    report.evicted_kernels,
                    lane.memo.n_contexts(),
                    lane.memo.n_points(),
                    lane.memo.n_kernel_entries(),
                );
                Ok(QueryReply {
                    text,
                    extra: vec![
                        (
                            "evicted_contexts".into(),
                            (report.evicted_contexts as u64).into(),
                        ),
                        (
                            "evicted_points".into(),
                            (report.evicted_points as u64).into(),
                        ),
                        (
                            "evicted_kernels".into(),
                            (report.evicted_kernels as u64).into(),
                        ),
                    ],
                    ..QueryReply::default()
                })
            }
            RequestKind::Ping => Ok(QueryReply {
                text: "pong\n".into(),
                ..QueryReply::default()
            }),
            RequestKind::Shutdown => unreachable!("shutdown handled in handle_line"),
        }
    }

    /// Post-query bookkeeping under the lane lock: counters and the
    /// periodic save cadence.
    fn after_query(&self, lane: &mut MemoLane, reply: &QueryReply) {
        self.counters
            .evaluated
            .fetch_add(reply.evaluated, Ordering::Relaxed);
        self.counters
            .l1_hits
            .fetch_add(reply.l1_hits, Ordering::Relaxed);
        self.counters
            .l2_hits
            .fetch_add(reply.l2_hits, Ordering::Relaxed);
        lane.fresh_since_save += reply.evaluated;
        if self.cfg.memo_path.is_some() && lane.fresh_since_save >= self.cfg.save_every.max(1) {
            self.save_lane(lane);
        }
    }

    /// Run one coalescable query. The leader (first arrival for the key)
    /// evaluates under panic isolation and fans the result out; followers
    /// wait and clone it, so all coalesced responses are bitwise
    /// identical and exactly one evaluation happened.
    fn coalesced_query(&self, key: String, env: &Envelope) -> Result<QueryReply, ServiceError> {
        let cell = {
            let mut inflight = lock_unpoisoned(&self.inflight);
            match inflight.get(&key) {
                Some(cell) => {
                    self.counters.coalesced.fetch_add(1, Ordering::Relaxed);
                    let cell = Arc::clone(cell);
                    drop(inflight);
                    let mut slot = lock_unpoisoned(&cell.slot);
                    while slot.is_none() {
                        slot = cell
                            .done
                            .wait(slot)
                            .unwrap_or_else(|p| p.into_inner());
                    }
                    return slot.clone().expect("slot published before notify");
                }
                None => {
                    let cell = Arc::new(InFlight {
                        slot: Mutex::new(None),
                        done: Condvar::new(),
                    });
                    inflight.insert(key.clone(), Arc::clone(&cell));
                    cell
                }
            }
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.run_query(env)))
            .unwrap_or_else(|_| {
                Err(ServiceError::usage(
                    "evaluation panicked (see stderr); request dropped",
                ))
            });
        lock_unpoisoned(&self.inflight).remove(&key);
        *lock_unpoisoned(&cell.slot) = Some(result.clone());
        cell.done.notify_all();
        result
    }

    /// Process one NDJSON line. Returns the response line (None for
    /// blank input) and whether the daemon should shut down.
    pub fn handle_line(&self, line: &str) -> (Option<String>, bool) {
        let line = line.trim();
        if line.is_empty() {
            return (None, false);
        }
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        let env = match parse_request(line) {
            Ok(env) => env,
            Err((id, err)) => {
                self.counters.errors.fetch_add(1, Ordering::Relaxed);
                return (Some(err_line(&id, &err)), false);
            }
        };
        if matches!(env.kind, RequestKind::Shutdown) {
            let code = self.finalize();
            let reply = QueryReply {
                text: if code == 0 {
                    "shutdown: memo saved\n".into()
                } else {
                    "shutdown: DEGRADED (memo save failed; WAL retained)\n".into()
                },
                extra: vec![("exit_code".into(), Value::Int(code as i64))],
                ..QueryReply::default()
            };
            return (Some(ok_line(&env.id, env.req_name(), &reply)), true);
        }
        let result = match env.coalesce_key() {
            Some(key) => self.coalesced_query(key, &env),
            None => self.run_query(&env),
        };
        match result {
            Ok(reply) => (Some(ok_line(&env.id, env.req_name(), &reply)), false),
            Err(err) => {
                self.counters.errors.fetch_add(1, Ordering::Relaxed);
                (Some(err_line(&env.id, &err)), false)
            }
        }
    }

    /// Final save + exit code; idempotent (a TCP shutdown racing stdin
    /// EOF performs one save). `0` clean, `1` when any save failed.
    pub fn finalize(&self) -> i32 {
        let mut code_slot = lock_unpoisoned(&self.exit_code);
        if let Some(code) = *code_slot {
            return code;
        }
        self.shutdown.store(true, Ordering::SeqCst);
        let mut lane = lock_unpoisoned(&self.lane);
        self.save_lane(&mut lane);
        let code = i32::from(lane.save_failed);
        *code_slot = Some(code);
        code
    }

    /// Whether a shutdown request has been processed.
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

/// One NDJSON connection loop over any buffered reader/writer pair.
/// Returns `true` when the peer asked for shutdown.
fn serve_connection<R: BufRead, W: Write>(svc: &Service, reader: R, mut writer: W) -> bool {
    for line in reader.lines() {
        let Ok(line) = line else { break };
        let (response, quit) = svc.handle_line(&line);
        if let Some(r) = response {
            if writeln!(writer, "{r}").and_then(|_| writer.flush()).is_err() {
                break;
            }
        }
        if quit {
            return true;
        }
        if svc.is_shutdown() {
            break;
        }
    }
    false
}

/// Accept loop of the TCP transport: non-blocking accept polled against
/// the shutdown flag, one thread per connection. A `shutdown` request on
/// a TCP connection finalizes and exits the whole process (stdin cannot
/// be unblocked portably).
fn serve_tcp(svc: Arc<Service>, listener: std::net::TcpListener) {
    listener
        .set_nonblocking(true)
        .expect("set_nonblocking on listener");
    loop {
        if svc.is_shutdown() {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let svc = Arc::clone(&svc);
                std::thread::spawn(move || {
                    let reader = std::io::BufReader::new(match stream.try_clone() {
                        Ok(s) => s,
                        Err(_) => return,
                    });
                    if serve_connection(&svc, reader, &stream) {
                        let code = svc.finalize();
                        std::process::exit(code);
                    }
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(25));
            }
            Err(_) => return,
        }
    }
}

/// Run the daemon to completion on the current thread: bind the optional
/// TCP listener, then serve stdin/stdout until a `shutdown` request or
/// EOF. Returns the process exit code.
pub fn serve(board: BoardConfig, cfg: ServeConfig) -> anyhow::Result<i32> {
    run(Service::new(board, cfg)?)
}

/// [`serve`] with a prebuilt service — lets callers distinguish
/// construction failures (memo load) from runtime ones (bind).
pub fn run(svc: Service) -> anyhow::Result<i32> {
    let listen = svc.cfg.listen.clone();
    let svc = Arc::new(svc);
    if let Some(addr) = listen {
        let listener = std::net::TcpListener::bind(&addr)
            .map_err(|e| anyhow::anyhow!("serve: cannot listen on {addr}: {e}"))?;
        // Tests parse this line to discover an OS-assigned port.
        eprintln!("serve: listening on {}", listener.local_addr()?);
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || serve_tcp(svc, listener));
    }
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    if serve_connection(&svc, stdin.lock(), stdout.lock()) {
        return Ok(svc.finalize());
    }
    // stdin closed without a shutdown request: if a TCP shutdown already
    // ran, report its code; otherwise treat EOF as a graceful shutdown.
    Ok(svc.finalize())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn service() -> Service {
        Service::new(BoardConfig::zynq706(), ServeConfig::default()).unwrap()
    }

    fn get_u64(v: &crate::util::json::Value, key: &str) -> u64 {
        v.get(key).and_then(|x| x.as_u64()).unwrap()
    }

    #[test]
    fn estimate_then_repeat_hits_the_memo_with_identical_response() {
        let svc = service();
        let req = r#"{"id":1,"req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]}"#;
        let (first, quit) = svc.handle_line(req);
        assert!(!quit);
        let first = first.unwrap();
        let (second, _) = svc.handle_line(req);
        let second = second.unwrap();
        assert_eq!(first, second, "hit must be bitwise identical to the evaluation");
        let v = parse(&second).unwrap();
        assert_eq!(get_u64(&v, "evaluated"), 0);
        assert_eq!(get_u64(&v, "l2_hits"), 1);
        assert_eq!(svc.evaluated(), 1, "one evaluation total across both");
    }

    #[test]
    fn malformed_lines_answer_with_the_cli_error_taxonomy_and_keep_serving() {
        let svc = service();
        let (bad, quit) = svc.handle_line("this is not json");
        assert!(!quit);
        let bad = parse(&bad.unwrap()).unwrap();
        assert_eq!(bad.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(get_u64(&bad, "code"), 1);
        let (unknown, _) = svc.handle_line(r#"{"id":7,"req":"frobnicate"}"#);
        let unknown = parse(&unknown.unwrap()).unwrap();
        assert_eq!(get_u64(&unknown, "code"), 2);
        assert_eq!(
            unknown.get("id").and_then(|v| v.as_i64()),
            Some(7),
            "errors still correlate by id"
        );
        let (ping, _) = svc.handle_line(r#"{"req":"ping"}"#);
        let ping = parse(&ping.unwrap()).unwrap();
        assert_eq!(ping.get("ok").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(svc.errors(), 2);
    }

    #[test]
    fn stats_reports_cumulative_counters_and_gc_runs_in_place() {
        let svc = service();
        svc.handle_line(r#"{"req":"estimate","app":"matmul","n":128,"accel":["mxm64:U8"]}"#);
        svc.handle_line(r#"{"req":"estimate","app":"matmul","n":128,"accel":["mxm64:U8"]}"#);
        let (stats, _) = svc.handle_line(r#"{"req":"memo","action":"stats"}"#);
        let stats = parse(&stats.unwrap()).unwrap();
        assert_eq!(get_u64(&stats, "contexts"), 1);
        assert_eq!(get_u64(&stats, "total_evaluated"), 1);
        assert_eq!(get_u64(&stats, "requests"), 3);
        let (gc, _) = svc.handle_line(r#"{"req":"memo","action":"gc","max_bytes":0,"app_floor":1}"#);
        let gc = parse(&gc.unwrap()).unwrap();
        assert_eq!(
            get_u64(&gc, "evicted_contexts"),
            0,
            "the per-app floor protects the only context even under a zero budget"
        );
    }

    #[test]
    fn shutdown_line_finalizes_and_requests_exit() {
        let svc = service();
        let (resp, quit) = svc.handle_line(r#"{"id":9,"req":"shutdown"}"#);
        assert!(quit);
        let v = parse(&resp.unwrap()).unwrap();
        assert_eq!(v.get("ok").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(v.get("exit_code").and_then(|x| x.as_i64()), Some(0));
        assert!(svc.is_shutdown());
        assert_eq!(svc.finalize(), 0, "finalize is idempotent");
    }
}
