//! # zynq-estimator
//!
//! Reproduction of *"Coarse-Grain Performance Estimator for Heterogeneous
//! Parallel Computing Architectures like Zynq All-Programmable SoC"*
//! (Jiménez-González et al., 2015) as a three-layer Rust + JAX + Pallas
//! stack. See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! the paper-vs-measured record.
//!
//! Layer map:
//! * `coordinator` — OmpSs-equivalent task model, dependence tracking,
//!   trace elaboration (§IV) and scheduling policies.
//! * `sim` — discrete-event engine + the coarse-grain estimator model.
//! * `board` — detailed Zynq board emulator ("real execution" stand-in).
//! * `hls` — analytic Vivado-HLS latency/resource model + feasibility.
//! * `apps` — the paper's applications (matmul, cholesky) + extras.
//! * `trace` — basic-trace JSON-lines IO, DOT export, Paraver writer.
//! * `runtime` — PJRT execution of the AOT-compiled JAX/Pallas kernels.
//! * `config` — board/co-design TOML configs.
//! * `metrics` — speedup tables, trend agreement, report rendering.
//! * `util` — PRNG, stats, JSON substrate.

pub mod apps;
pub mod board;
pub mod cli;
pub mod config;
pub mod experiments;
pub mod coordinator;
pub mod dse;
pub mod hls;
pub mod metrics;
pub mod power;
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod util;
