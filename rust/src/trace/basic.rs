//! Basic task-trace interchange format — the §IV record, as JSON lines.
//!
//! Line 1 is a header object carrying the app name and the kernel table
//! (name, targets, workload profile). Every following line is one task
//! instance exactly as the paper's instrumented sequential binary records
//! it: "task number, creation time and elapsed execution time in cycles in
//! the CPU based machine, number of dependences of the task, and for each
//! dependence: the data dependence memory address and a label indicating
//! the direction".

use std::io::{BufRead, Write};

use crate::coordinator::task::{
    Dep, Dir, KernelDecl, KernelProfile, TaskProgram, Targets,
};
use crate::util::json::{self, arr, obj, Value};

/// Serialize a program to JSON-lines trace text.
pub fn write_trace(program: &TaskProgram) -> String {
    let mut out = String::new();
    let kernels: Vec<Value> = program
        .kernels
        .iter()
        .map(|k| {
            obj(vec![
                ("name", k.name.as_str().into()),
                ("smp", k.targets.smp.into()),
                ("fpga", k.targets.fpga.into()),
                ("flops", k.profile.flops.into()),
                ("inner_trip", k.profile.inner_trip.into()),
                ("in_bytes", k.profile.in_bytes.into()),
                ("out_bytes", k.profile.out_bytes.into()),
                ("dtype_bytes", (k.profile.dtype_bytes as u64).into()),
                ("divsqrt", k.profile.divsqrt.into()),
            ])
        })
        .collect();
    out.push_str(
        &obj(vec![
            ("app", program.app_name.as_str().into()),
            ("kernels", arr(kernels)),
        ])
        .to_json(),
    );
    out.push('\n');
    for t in &program.tasks {
        let deps: Vec<Value> = t
            .deps
            .iter()
            .map(|d| {
                obj(vec![
                    ("addr", d.addr.into()),
                    ("len", d.len.into()),
                    ("dir", d.dir.as_str().into()),
                ])
            })
            .collect();
        out.push_str(
            &obj(vec![
                ("task", t.id.into()),
                ("kernel", (t.kernel as u64).into()),
                ("create_ns", t.creation_ns.into()),
                ("cycles", t.smp_cycles.into()),
                ("deps", arr(deps)),
            ])
            .to_json(),
        );
        out.push('\n');
    }
    out
}

/// Parse a JSON-lines trace back into a program.
pub fn read_trace(text: &str) -> anyhow::Result<TaskProgram> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty trace"))?;
    let h = json::parse(header).map_err(|e| anyhow::anyhow!("header: {e}"))?;
    let mut program = TaskProgram::new(
        h.get("app")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("header missing 'app'"))?,
    );
    for k in h
        .get("kernels")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow::anyhow!("header missing 'kernels'"))?
    {
        let name = k
            .get("name")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("kernel missing name"))?;
        let field = |f: &str| -> anyhow::Result<u64> {
            k.get(f)
                .and_then(Value::as_u64)
                .ok_or_else(|| anyhow::anyhow!("kernel '{name}' missing '{f}'"))
        };
        program.add_kernel(KernelDecl {
            name: name.to_string(),
            targets: Targets {
                smp: k.get("smp").and_then(Value::as_bool).unwrap_or(false),
                fpga: k.get("fpga").and_then(Value::as_bool).unwrap_or(false),
            },
            profile: KernelProfile {
                flops: field("flops")?,
                inner_trip: field("inner_trip")?,
                in_bytes: field("in_bytes")?,
                out_bytes: field("out_bytes")?,
                dtype_bytes: field("dtype_bytes")? as u8,
                divsqrt: k.get("divsqrt").and_then(Value::as_bool).unwrap_or(false),
            },
        });
    }
    for (lineno, line) in lines.enumerate() {
        let v = json::parse(line)
            .map_err(|e| anyhow::anyhow!("task line {}: {e}", lineno + 2))?;
        let kernel = v
            .get("kernel")
            .and_then(Value::as_u64)
            .ok_or_else(|| anyhow::anyhow!("task missing kernel"))? as u16;
        if kernel as usize >= program.kernels.len() {
            anyhow::bail!("task references unknown kernel {kernel}");
        }
        let cycles = v
            .get("cycles")
            .and_then(Value::as_u64)
            .ok_or_else(|| anyhow::anyhow!("task missing cycles"))?;
        let mut deps = Vec::new();
        for d in v
            .get("deps")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow::anyhow!("task missing deps"))?
        {
            deps.push(Dep {
                addr: d
                    .get("addr")
                    .and_then(Value::as_u64)
                    .ok_or_else(|| anyhow::anyhow!("dep missing addr"))?,
                len: d
                    .get("len")
                    .and_then(Value::as_u64)
                    .ok_or_else(|| anyhow::anyhow!("dep missing len"))?,
                dir: Dir::parse(
                    d.get("dir")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow::anyhow!("dep missing dir"))?,
                )
                .ok_or_else(|| anyhow::anyhow!("bad dep dir"))?,
            });
        }
        let id = program.add_task(kernel, cycles, deps);
        if let Some(c) = v.get("create_ns").and_then(Value::as_u64) {
            program.tasks[id as usize].creation_ns = c;
        }
    }
    Ok(program)
}

/// Write a trace to a file.
pub fn save(program: &TaskProgram, path: &std::path::Path) -> anyhow::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(write_trace(program).as_bytes())?;
    Ok(())
}

/// Load a trace from a file (streaming-friendly: reads line by line).
pub fn load(path: &std::path::Path) -> anyhow::Result<TaskProgram> {
    let f = std::fs::File::open(path)?;
    let mut text = String::new();
    for line in std::io::BufReader::new(f).lines() {
        text.push_str(&line?);
        text.push('\n');
    }
    read_trace(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::Matmul;
    use crate::config::BoardConfig;

    #[test]
    fn roundtrip_matmul_trace() {
        let b = BoardConfig::zynq706();
        let p = Matmul::new(256, 64).build_program(&b);
        let text = write_trace(&p);
        let p2 = read_trace(&text).unwrap();
        assert_eq!(p.app_name, p2.app_name);
        assert_eq!(p.kernels.len(), p2.kernels.len());
        assert_eq!(p.tasks.len(), p2.tasks.len());
        for (a, c) in p.tasks.iter().zip(&p2.tasks) {
            assert_eq!(a.kernel, c.kernel);
            assert_eq!(a.smp_cycles, c.smp_cycles);
            assert_eq!(a.deps, c.deps);
        }
        assert_eq!(p.kernels[0].profile, p2.kernels[0].profile);
    }

    #[test]
    fn rejects_malformed() {
        assert!(read_trace("").is_err());
        assert!(read_trace("{\"app\":\"x\"}\n").is_err()); // no kernels
        let ok_header = r#"{"app":"x","kernels":[{"name":"k","smp":true,"fpga":false,"flops":1,"inner_trip":1,"in_bytes":1,"out_bytes":1,"dtype_bytes":4,"divsqrt":false}]}"#;
        assert!(read_trace(&format!("{ok_header}\n{{\"task\":0}}\n")).is_err());
        assert!(read_trace(&format!(
            "{ok_header}\n{{\"task\":0,\"kernel\":9,\"cycles\":1,\"deps\":[]}}\n"
        ))
        .is_err()); // unknown kernel
    }

    #[test]
    fn file_roundtrip() {
        let b = BoardConfig::zynq706();
        let p = Matmul::new(128, 64).build_program(&b);
        let dir = std::env::temp_dir().join("zynq_est_test_trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        save(&p, &path).unwrap();
        let p2 = load(&path).unwrap();
        assert_eq!(p.tasks.len(), p2.tasks.len());
        std::fs::remove_file(&path).ok();
    }
}
