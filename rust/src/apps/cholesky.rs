//! Tiled Cholesky decomposition — the paper's Fig. 4 application.
//!
//! ```c
//! #pragma omp target device(fpga,smp)
//! #pragma omp task in([BS*BS]A) inout([BS*BS]C)
//! void dsyrk(double *A, double *C, int BS);
//! #pragma omp task inout([BS*BS]A)                 // SMP only!
//! void dpotrf(double *A, int t, int BS);
//! #pragma omp target device(fpga,smp)
//! #pragma omp task in([BS*BS]A) inout([BS*BS]B)
//! void dtrsm(double *A, double *B, int t, int BS);
//! #pragma omp target device(fpga,smp)
//! #pragma omp task in([BS*BS]A,[BS*BS]B) inout([BS*BS]C)
//! void dgemm(double *A, double *B, double *C, int t, int BS);
//!
//! void chol_ll(double **AA, int t, int NB, int BS) {
//!   for (k = 0; k < NB; k++) {
//!     for (j = 0; j < k; j++)  dsyrk(AA[j*NB+k], AA[k*NB+k], BS);
//!     dpotrf(AA[k*NB+k], t, BS);
//!     for (i = k+1; i < NB; i++)
//!       for (j = 0; j < k; j++)
//!         dgemm(AA[j*NB+i], AA[j*NB+k], AA[k*NB+i], t, BS);
//!     for (i = k+1; i < NB; i++) dtrsm(AA[k*NB+k], AA[k*NB+i], t, BS);
//!   }
//! }
//! ```
//!
//! Three of the four kernels are annotated for SMP *and* FPGA; `dpotrf` is
//! SMP-only ("the fourth one has not been considered to be mapped to the
//! FPGA by the programmer", §V). The paper's experiment is double
//! precision with 64×64 blocks; the complex interleaved dependency graph
//! (Fig. 8) is exactly what makes run-time analysis necessary.

use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::task::{
    Dep, KernelDecl, KernelProfile, TaskProgram, Targets,
};

use super::{smp_cycles_model, ExperimentSet};

/// "Full resources" unroll: the accelerator maximizes fabric usage and
/// nothing else fits (§VI's FR-dgemm / FR-dsyrk / FR-dtrsm variants).
pub const UNROLL_FR: u32 = 44;
/// Pair unroll: two accelerators of this size fit together.
pub const UNROLL_PAIR: u32 = 16;

const A_BASE: u64 = 0x4000_0000;

#[derive(Clone, Copy, Debug)]
/// Tiled left-looking Cholesky factorization (paper Fig. 4).
pub struct Cholesky {
    /// Matrix dimension (elements). 512 in the reproduction runs.
    pub n: u64,
    /// Block dimension — fixed at 64 in the paper's evaluation.
    pub bs: u64,
}

impl Cholesky {
    /// An `n`×`n` problem with `bs`×`bs` tiles (`n` divisible by `bs`).
    pub fn new(n: u64, bs: u64) -> Self {
        assert!(n % bs == 0, "matrix size must be a multiple of block size");
        Self { n, bs }
    }

    /// Number of tile blocks per side.
    pub fn nb(&self) -> u64 {
        self.n / self.bs
    }

    fn tile_bytes(&self) -> u64 {
        self.bs * self.bs * 8 // double precision
    }

    fn addr(&self, row: u64, col: u64) -> u64 {
        A_BASE + (row * self.nb() + col) * self.tile_bytes()
    }

    /// Kernel profiles. FLOP counts are the standard ones for BS×BS tiles;
    /// `inner_trip` is the pipelined-loop iteration count HLS sees.
    pub fn profiles(&self) -> [(&'static str, Targets, KernelProfile); 4] {
        let bs = self.bs;
        let tile = self.tile_bytes();
        [
            (
                "dgemm",
                Targets::BOTH,
                KernelProfile {
                    flops: 2 * bs * bs * bs,
                    inner_trip: bs * bs * bs,
                    in_bytes: 3 * tile, // A, B in + C inout
                    out_bytes: tile,
                    dtype_bytes: 8,
                    divsqrt: false,
                },
            ),
            (
                "dsyrk",
                Targets::BOTH,
                KernelProfile {
                    flops: bs * bs * bs,
                    inner_trip: bs * bs * bs / 2,
                    in_bytes: 2 * tile, // A in + C inout
                    out_bytes: tile,
                    dtype_bytes: 8,
                    divsqrt: false,
                },
            ),
            (
                "dtrsm",
                Targets::BOTH,
                KernelProfile {
                    flops: bs * bs * bs,
                    inner_trip: bs * bs * bs / 2,
                    in_bytes: 2 * tile, // A in + B inout
                    out_bytes: tile,
                    dtype_bytes: 8,
                    divsqrt: true, // triangular solve: division recurrence
                },
            ),
            (
                "dpotrf",
                Targets::SMP, // not mapped to the FPGA by the programmer
                KernelProfile {
                    flops: bs * bs * bs / 3,
                    inner_trip: bs * bs * bs / 6,
                    in_bytes: tile,
                    out_bytes: tile,
                    dtype_bytes: 8,
                    divsqrt: true, // sqrt + division on the diagonal
                },
            ),
        ]
    }

    /// Build the task program — the instrumented sequential run's trace.
    pub fn build_program(&self, board: &BoardConfig) -> TaskProgram {
        let mut p = TaskProgram::new(&format!("cholesky{}-bs{}", self.n, self.bs));
        let mut ids = [0u16; 4];
        let mut cycles = [0u64; 4];
        for (i, (name, targets, profile)) in self.profiles().into_iter().enumerate() {
            cycles[i] = smp_cycles_model(&profile, board);
            ids[i] = p.add_kernel(KernelDecl {
                name: name.to_string(),
                targets,
                profile,
            });
        }
        let [dgemm, dsyrk, dtrsm, dpotrf] = [ids[0], ids[1], ids[2], ids[3]];
        let [c_gemm, c_syrk, c_trsm, c_potrf] = [cycles[0], cycles[1], cycles[2], cycles[3]];
        let nb = self.nb();
        let tb = self.tile_bytes();
        for k in 0..nb {
            for j in 0..k {
                // dsyrk(AA[j*NB+k] in, AA[k*NB+k] inout)
                p.add_task(
                    dsyrk,
                    c_syrk,
                    vec![
                        Dep::input(self.addr(j, k), tb),
                        Dep::inout(self.addr(k, k), tb),
                    ],
                );
            }
            // dpotrf(AA[k*NB+k] inout)
            p.add_task(dpotrf, c_potrf, vec![Dep::inout(self.addr(k, k), tb)]);
            for i in (k + 1)..nb {
                for j in 0..k {
                    // dgemm(AA[j*NB+i] in, AA[j*NB+k] in, AA[k*NB+i] inout)
                    p.add_task(
                        dgemm,
                        c_gemm,
                        vec![
                            Dep::input(self.addr(j, i), tb),
                            Dep::input(self.addr(j, k), tb),
                            Dep::inout(self.addr(k, i), tb),
                        ],
                    );
                }
            }
            for i in (k + 1)..nb {
                // dtrsm(AA[k*NB+k] in, AA[k*NB+i] inout)
                p.add_task(
                    dtrsm,
                    c_trsm,
                    vec![
                        Dep::input(self.addr(k, k), tb),
                        Dep::inout(self.addr(k, i), tb),
                    ],
                );
            }
        }
        p
    }
}

/// The six co-designs of Fig. 9: three "full resources" single-accelerator
/// variants and the three feasible two-accelerator combinations of the
/// FPGA-annotated kernels (dgemm, dsyrk, dtrsm); dpotrf always on SMP.
pub fn fig9_codesigns() -> Vec<CoDesign> {
    vec![
        CoDesign::new("FR-dgemm").with_accel("dgemm", UNROLL_FR),
        CoDesign::new("FR-dsyrk").with_accel("dsyrk", UNROLL_FR),
        CoDesign::new("FR-dtrsm").with_accel("dtrsm", UNROLL_FR),
        CoDesign::new("dgemm+dgemm")
            .with_accel("dgemm", UNROLL_PAIR)
            .with_accel("dgemm", UNROLL_PAIR),
        CoDesign::new("dgemm+dsyrk")
            .with_accel("dgemm", UNROLL_PAIR)
            .with_accel("dsyrk", UNROLL_PAIR),
        CoDesign::new("dgemm+dtrsm")
            .with_accel("dgemm", UNROLL_PAIR)
            .with_accel("dtrsm", UNROLL_PAIR),
    ]
}

/// The Fig. 9 experiment set.
pub fn fig9_experiment() -> ExperimentSet {
    ExperimentSet {
        app: "cholesky".into(),
        codesigns: fig9_codesigns(),
        baseline: "".into(), // normalized to the measured slowest
    }
}

/// Expected task-instance counts for NB blocks (closed forms).
pub fn expected_counts(nb: u64) -> (u64, u64, u64, u64) {
    let dpotrf = nb;
    let dsyrk = nb * (nb - 1) / 2;
    let dtrsm = nb * (nb - 1) / 2;
    // sum_k k*(nb-k-1)
    let dgemm: u64 = (0..nb).map(|k| k * (nb - k - 1)).sum();
    (dgemm, dsyrk, dtrsm, dpotrf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::deps::DepGraph;

    #[test]
    fn task_counts_match_closed_form() {
        let b = BoardConfig::zynq706();
        let app = Cholesky::new(512, 64); // NB = 8
        let p = app.build_program(&b);
        let h = p.instance_histogram();
        let (g, s, t, pf) = expected_counts(8);
        assert_eq!(h["dgemm"] as u64, g);
        assert_eq!(h["dsyrk"] as u64, s);
        assert_eq!(h["dtrsm"] as u64, t);
        assert_eq!(h["dpotrf"] as u64, pf);
        assert_eq!(g, 56);
        assert_eq!(s, 28);
        assert!(p.validate().is_empty());
    }

    #[test]
    fn dpotrf_is_smp_only() {
        let b = BoardConfig::zynq706();
        let p = Cholesky::new(256, 64).build_program(&b);
        let k = p.kernel_id("dpotrf").unwrap();
        assert!(p.kernel(k).targets.smp);
        assert!(!p.kernel(k).targets.fpga);
    }

    #[test]
    fn fig8_graph_nb4_structure() {
        // Fig. 8 shows the NB=4 dependency graph: potrf(0) -> 3 trsm ->
        // gemms/syrks of later panels, etc.
        let b = BoardConfig::zynq706();
        let p = Cholesky::new(256, 64).build_program(&b);
        let g = DepGraph::build(&p);
        assert!(g.respects_program_order());
        // First task (k=0) is dpotrf on the first diagonal block; it is a
        // root.
        assert!(g.roots().contains(&0));
        // The graph is deep: at least 3 levels per panel times NB-ish.
        assert!(g.depth() >= 7, "depth = {}", g.depth());
        // dgemm count for NB=4 is 0+2+2... sum k(nb-k-1) for nb=4: 0*3 +
        // 1*2 + 2*1 + 3*0 = 4
        assert_eq!(expected_counts(4).0, 4);
    }

    #[test]
    fn dependency_chain_potrf_trsm() {
        // dpotrf(k,k) must precede every dtrsm of panel k (reads A[k,k]).
        let b = BoardConfig::zynq706();
        let p = Cholesky::new(256, 64).build_program(&b);
        let g = DepGraph::build(&p);
        let potrf0 = 0u32; // first task at k=0
        let succs = &g.succs[potrf0 as usize];
        // NB-1 = 3 dtrsm tasks read the k=0 diagonal.
        assert!(succs.len() >= 3, "potrf successors: {succs:?}");
    }

    #[test]
    fn fr_variants_exclusive_pairs_feasible() {
        use crate::hls::{CostModel, FpgaPart};
        let b = BoardConfig::zynq706();
        let cm = CostModel::from_board(&b);
        let part = FpgaPart::xc7z045();
        let app = Cholesky::new(512, 64);
        let gemm = &app.profiles()[0].2;
        let fr = cm.estimate("dgemm", gemm, UNROLL_FR).resources;
        let pair = cm.estimate("dgemm", gemm, UNROLL_PAIR).resources;
        assert!(part.fits(&[fr]), "FR variant must fit alone");
        assert!(!part.fits(&[fr, pair]), "FR leaves no room for a second accel");
        assert!(part.fits(&[pair, pair]), "two pair variants must fit");
    }

    #[test]
    fn fig9_set_is_complete() {
        let cds = fig9_codesigns();
        assert_eq!(cds.len(), 6);
        assert_eq!(cds.iter().filter(|c| c.accels.len() == 1).count(), 3);
        assert_eq!(cds.iter().filter(|c| c.accels.len() == 2).count(), 3);
        // every pair includes dgemm (the paper's combinations)
        for cd in cds.iter().filter(|c| c.accels.len() == 2) {
            assert!(cd.accels.iter().any(|a| a.kernel == "dgemm"));
        }
    }
}
