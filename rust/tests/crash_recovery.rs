//! Crash-safety integration suite: interrupted journaled sweeps must
//! resume bit-identically, poisoned points must quarantine independently
//! of worker scheduling, corrupt persistent artifacts must be moved aside
//! (never half-loaded), and a genuinely killed process must recover via
//! `dse --resume`.
//!
//! This suite is the one place that arms **real** faultpoint sites
//! (`sweep.round`, `eval.point`, `memo.save`, `memo.load`, `board.toml`):
//! faultpoint state is process-global, so real-site arming lives here, in
//! its own test process, never in lib unit tests. Tests serialize on a
//! local mutex because the harness runs them on concurrent threads.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use zynq_estimator::apps::matmul::Matmul;
use zynq_estimator::config::BoardConfig;
use zynq_estimator::dse::{
    enumerate_pruned, DsePoint, DseSpace, EvalMemo, KernelSpace, Objective, OrderMode, PruneStats,
    RecoverySession, SweepCheckpoint, SweepContext, SweepJournal,
};
use zynq_estimator::hls::FpgaPart;
use zynq_estimator::util::faultpoint;
use zynq_estimator::util::Rng;

static FAULT_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // A panicking fault test (that is the point of some of them) must not
    // wedge the rest of the suite.
    FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("zynq_crashrec_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn assert_bitwise_ranking(label: &str, a: &[DsePoint], b: &[DsePoint]) {
    assert_eq!(a.len(), b.len(), "{label}: ranking length diverged");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.codesign.name, y.codesign.name, "{label}: rank {i}");
        assert_eq!(x.est_ms.to_bits(), y.est_ms.to_bits(), "{label}: rank {i}");
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "{label}: rank {i}");
        assert_eq!(x.edp.to_bits(), y.edp.to_bits(), "{label}: rank {i}");
        assert_eq!(x.fabric_util.to_bits(), y.fabric_util.to_bits(), "{label}: rank {i}");
    }
}

/// Run a journaled recoverable sweep to completion and save the memo;
/// returns the ranking, the stats and the saved file's bytes.
fn recoverable_run(
    ctx: &SweepContext<'_>,
    space: &DseSpace,
    path: &Path,
    workers: usize,
    resume: bool,
) -> (Vec<DsePoint>, PruneStats, Vec<u8>) {
    let (mut memo, recovered) = EvalMemo::load_with_recovery(path).unwrap();
    let mut session = RecoverySession::open(path, recovered, resume).unwrap();
    let (points, stats) = ctx
        .explore_warm_recoverable(
            space,
            &mut memo,
            Objective::Time,
            workers,
            OrderMode::Ranked,
            &mut session,
        )
        .unwrap();
    drop(session);
    memo.save(path).unwrap();
    (points, stats, std::fs::read(path).unwrap())
}

/// Run a journaled sweep with `sweep.round@k!error` armed. Returns `true`
/// when the injected fault fired (the sweep was interrupted after round
/// `k` committed); `false` when the sweep outran the fault and completed
/// (in which case the memo is saved, exactly like an uninterrupted run).
fn interrupted_run(
    ctx: &SweepContext<'_>,
    space: &DseSpace,
    path: &Path,
    workers: usize,
    k: u64,
) -> bool {
    let guard = faultpoint::arm(&format!("sweep.round@{k}!error")).unwrap();
    let (mut memo, recovered) = EvalMemo::load_with_recovery(path).unwrap();
    let mut session = RecoverySession::open(path, recovered, false).unwrap();
    let res = ctx.explore_warm_recoverable(
        space,
        &mut memo,
        Objective::Time,
        workers,
        OrderMode::Ranked,
        &mut session,
    );
    drop(guard);
    drop(session);
    match res {
        Err(e) => {
            assert!(
                format!("{e:#}").contains("sweep.round"),
                "unexpected failure (not the injected fault): {e:#}"
            );
            true
        }
        Ok(_) => {
            memo.save(path).unwrap();
            false
        }
    }
}

#[test]
fn interrupted_sweep_resumes_bit_identical_for_any_worker_count() {
    let _g = lock();
    faultpoint::disarm_all();
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(128, 64).build_program(&board);
    let space = DseSpace::from_program(&program).with_mixed();
    let ctx = SweepContext::for_space(&program, &board, &part, &space);

    let ref_dir = tmpdir("resume_ref");
    let ref_path = ref_dir.join("memo.json");
    let (ref_pts, _, ref_bytes) = recoverable_run(&ctx, &space, &ref_path, 2, false);
    assert!(!ref_pts.is_empty());
    assert!(
        !SweepJournal::wal_path(&ref_path).exists(),
        "a successful save must delete the journal"
    );
    assert!(
        !SweepCheckpoint::ckpt_path(&ref_path).exists(),
        "a successful save must delete the checkpoint"
    );

    for k in [1u64, 2] {
        for workers in [1usize, 2, 4] {
            let d = tmpdir(&format!("resume_k{k}_w{workers}"));
            let path = d.join("memo.json");
            let fired = interrupted_run(&ctx, &space, &path, workers, k);
            if k == 1 {
                assert!(fired, "any non-empty sweep commits a first round");
            }
            if fired {
                assert!(!path.exists(), "the crash predates the first save");
                assert!(SweepJournal::wal_path(&path).exists());
                assert!(SweepCheckpoint::ckpt_path(&path).exists());
                let (pts, _, bytes) = recoverable_run(&ctx, &space, &path, workers, true);
                assert_bitwise_ranking(&format!("k={k} workers={workers}"), &ref_pts, &pts);
                assert_eq!(
                    bytes, ref_bytes,
                    "k={k} workers={workers}: resumed memo is not bit-identical"
                );
            } else {
                assert_eq!(std::fs::read(&path).unwrap(), ref_bytes);
            }
            std::fs::remove_dir_all(&d).ok();
        }
    }

    // A second crash *during the resume* must still recover: interrupt at
    // round 1, resume with round 1 armed again (it fires in the resumed
    // run), then resume once more to completion.
    let d = tmpdir("resume_twice");
    let path = d.join("memo.json");
    assert!(interrupted_run(&ctx, &space, &path, 2, 1));
    {
        let guard = faultpoint::arm("sweep.round@1!error").unwrap();
        let (mut memo, recovered) = EvalMemo::load_with_recovery(&path).unwrap();
        let mut session = RecoverySession::open(&path, recovered, true).unwrap();
        let res = ctx.explore_warm_recoverable(
            &space,
            &mut memo,
            Objective::Time,
            2,
            OrderMode::Ranked,
            &mut session,
        );
        drop(guard);
        assert!(res.is_err(), "the re-armed fault must interrupt the resume too");
    }
    let (pts, _, bytes) = recoverable_run(&ctx, &space, &path, 2, true);
    assert_bitwise_ranking("second-crash resume", &ref_pts, &pts);
    assert_eq!(bytes, ref_bytes, "second-crash resume memo diverged");
    std::fs::remove_dir_all(&d).ok();
    std::fs::remove_dir_all(&ref_dir).ok();
}

#[test]
fn prop_resume_identity_on_random_spaces() {
    // The acceptance proptest: on randomized mixed/homogeneous spaces and
    // across worker counts, crash-at-round-1 + resume must reproduce the
    // uninterrupted ranking and memo file bit for bit.
    let _g = lock();
    faultpoint::disarm_all();
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(128, 64).build_program(&board);
    let pool = [4u32, 8, 16, 32, 64];
    for i in 0..3u64 {
        let seed = 0xC4A5_0000u64 + i;
        let mut rng = Rng::new(seed);
        let kernels = program
            .kernels
            .iter()
            .filter(|kern| kern.targets.fpga)
            .map(|kern| {
                let n_unrolls = rng.gen_range(2, 5) as usize;
                let mut unrolls: Vec<u32> = Vec::new();
                while unrolls.len() < n_unrolls {
                    let u = pool[rng.gen_range(0, pool.len() as u64) as usize];
                    if !unrolls.contains(&u) {
                        unrolls.push(u);
                    }
                }
                KernelSpace {
                    kernel: kern.name.clone(),
                    unrolls,
                    max_instances: rng.gen_range(1, 3) as u32,
                    try_smp: kern.targets.smp && rng.next_f64() < 0.5,
                }
            })
            .collect();
        let space = DseSpace {
            kernels,
            mixed: rng.next_f64() < 0.6,
        };
        let ctx = SweepContext::for_space(&program, &board, &part, &space);
        let ref_dir = tmpdir(&format!("prop_ref_{i}"));
        let (ref_pts, _, ref_bytes) =
            recoverable_run(&ctx, &space, &ref_dir.join("memo.json"), 2, false);
        for workers in [1usize, 3] {
            let d = tmpdir(&format!("prop_{i}_w{workers}"));
            let path = d.join("memo.json");
            if interrupted_run(&ctx, &space, &path, workers, 1) {
                let (pts, _, bytes) = recoverable_run(&ctx, &space, &path, workers, true);
                assert_bitwise_ranking(&format!("seed {seed} workers={workers}"), &ref_pts, &pts);
                assert_eq!(bytes, ref_bytes, "seed {seed} workers={workers}: memo diverged");
            } else {
                // Degenerate space (no evaluations, no rounds): the run
                // completed; it must still match the reference.
                assert_eq!(
                    std::fs::read(&path).unwrap(),
                    ref_bytes,
                    "seed {seed} workers={workers}: memo diverged"
                );
            }
            std::fs::remove_dir_all(&d).ok();
        }
        std::fs::remove_dir_all(&ref_dir).ok();
    }
}

#[test]
fn torn_wal_tail_is_dropped_on_recovery() {
    let _g = lock();
    faultpoint::disarm_all();
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(128, 64).build_program(&board);
    let space = DseSpace::from_program(&program).with_mixed();
    let ctx = SweepContext::for_space(&program, &board, &part, &space);

    let ref_dir = tmpdir("torn_ref");
    let (ref_pts, _, ref_bytes) =
        recoverable_run(&ctx, &space, &ref_dir.join("memo.json"), 2, false);

    let d = tmpdir("torn");
    let path = d.join("memo.json");
    assert!(interrupted_run(&ctx, &space, &path, 2, 1));
    // Simulate the torn write of the crash itself: a partial JSON line
    // with no trailing newline appended to the journal.
    let wal = SweepJournal::wal_path(&path);
    let mut text = std::fs::read_to_string(&wal).unwrap();
    text.push_str("{\"t\":\"pt\",\"fp\":\"00000000dead");
    std::fs::write(&wal, &text).unwrap();

    let (memo, recovered) = EvalMemo::load_with_recovery(&path).unwrap();
    let rec = recovered.expect("committed rounds must be recovered despite the torn tail");
    assert!(rec.rounds >= 1 && rec.n_points() > 0);
    drop(memo);

    let (pts, _, bytes) = recoverable_run(&ctx, &space, &path, 2, true);
    assert_bitwise_ranking("torn-tail resume", &ref_pts, &pts);
    assert_eq!(bytes, ref_bytes, "torn-tail resume memo diverged");
    std::fs::remove_dir_all(&d).ok();
    std::fs::remove_dir_all(&ref_dir).ok();
}

#[test]
fn memo_save_fault_preserves_the_previous_file() {
    let _g = lock();
    faultpoint::disarm_all();
    let d = tmpdir("savefault");
    let path = d.join("memo.json");
    let memo = EvalMemo::new();
    memo.save(&path).unwrap();
    let v1 = std::fs::read(&path).unwrap();
    // A journal sibling left by an in-flight sweep must survive a failed
    // save too (save only deletes the sidecars after the atomic rename).
    let wal = SweepJournal::wal_path(&path);
    std::fs::write(&wal, "{\"t\":\"hdr\"}\n").unwrap();

    let guard = faultpoint::arm("memo.save!error").unwrap();
    let err = memo.save(&path).unwrap_err();
    drop(guard);
    assert!(format!("{err:#}").contains("memo.save"), "{err:#}");
    assert_eq!(std::fs::read(&path).unwrap(), v1, "previous memo clobbered");
    assert!(wal.exists(), "failed save must not delete the journal");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn corrupt_memo_generations_are_quarantined_with_a_cap() {
    let _g = lock();
    faultpoint::disarm_all();
    let d = tmpdir("quarantine");
    let path = d.join("memo.json");
    for i in 0..10u32 {
        std::fs::write(&path, format!("corrupt generation {i}")).unwrap();
        let memo = EvalMemo::load_or_new(&path).unwrap();
        drop(memo);
        assert!(!path.exists(), "corrupt memo must be moved aside");
    }
    let baks: Vec<String> = std::fs::read_dir(&d)
        .unwrap()
        .flatten()
        .filter_map(|e| e.file_name().to_str().map(String::from))
        .filter(|n| n.contains(".bak."))
        .collect();
    assert!(baks.len() <= zynq_estimator::util::persist::QUARANTINE_CAP, "{baks:?}");
    assert!(
        baks.iter().any(|n| n.ends_with(".bak.10")),
        "the newest generation must be retained: {baks:?}"
    );
    assert!(
        !baks.iter().any(|n| n.ends_with(".bak.1")),
        "the oldest generations must be evicted: {baks:?}"
    );
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn poisoned_point_is_quarantined_identically_for_any_worker_count() {
    let _g = lock();
    faultpoint::disarm_all();
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(128, 64).build_program(&board);
    let space = DseSpace::from_program(&program).with_mixed();
    let ctx = SweepContext::for_space(&program, &board, &part, &space);
    let (cands, _) = enumerate_pruned(&ctx, &space);
    assert!(cands.len() > 1, "space too small for the poison test");
    // Candidate 0 is always in the first FIFO round, so it is evaluated
    // (never bound-cut) regardless of worker count.
    let target = cands[0].name.clone();
    let tag = faultpoint::str_tag(&target);

    let mut reference: Option<(Vec<DsePoint>, PruneStats)> = None;
    for workers in [1usize, 2, 4] {
        let guard = faultpoint::arm(&format!("eval.point#{tag:x}!panic")).unwrap();
        let (pts, stats) =
            ctx.explore_pruned_with(&space, Objective::Time, workers, OrderMode::Fifo);
        drop(guard);
        assert_eq!(stats.poisoned, 1, "workers={workers}: {stats:?}");
        assert!(
            pts.iter().all(|p| p.codesign.name != target),
            "workers={workers}: poisoned point must be excluded from the ranking"
        );
        match &reference {
            None => reference = Some((pts, stats)),
            Some((ref_pts, ref_stats)) => {
                assert_eq!(&stats, ref_stats, "workers={workers}");
                assert_bitwise_ranking(&format!("poison workers={workers}"), ref_pts, &pts);
            }
        }
    }
    // Disarmed, the same point evaluates normally again.
    let (clean, clean_stats) = ctx.explore_pruned_with(&space, Objective::Time, 2, OrderMode::Fifo);
    assert_eq!(clean_stats.poisoned, 0, "{clean_stats:?}");
    assert!(clean.iter().any(|p| p.codesign.name == target));
}

#[test]
fn worker_reuse_after_a_poisoned_evaluation_is_bit_identical() {
    // The simulator-reuse contract behind poison isolation: a worker whose
    // evaluation panicked is reset (or rebuilt) before its next point, and
    // every later result must be bit-identical to a fresh worker's.
    let _g = lock();
    faultpoint::disarm_all();
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(128, 64).build_program(&board);
    let space = DseSpace::from_program(&program).with_mixed();
    let ctx = SweepContext::for_space(&program, &board, &part, &space);
    let (cands, _) = enumerate_pruned(&ctx, &space);
    assert!(cands.len() > 1);

    let fresh = ctx.worker().evaluate(&cands[1]);
    let fresh0 = ctx.worker().evaluate(&cands[0]);

    let mut w = ctx.worker();
    assert!(
        w.evaluate(&cands[0]).map(|p| p.est_ms.to_bits())
            == fresh0.as_ref().map(|p| p.est_ms.to_bits()),
        "pre-poison evaluation diverged from fresh"
    );
    let tag = faultpoint::str_tag(&cands[1].name);
    let guard = faultpoint::arm(&format!("eval.point#{tag:x}!panic")).unwrap();
    let poisoned = catch_unwind(AssertUnwindSafe(|| w.evaluate(&cands[1])));
    drop(guard);
    assert!(poisoned.is_err(), "the armed point must panic");

    // The same worker, reused after the panic, reproduces the fresh
    // results bit for bit — `Simulator::reset_owned` rewinds everything.
    match (w.evaluate(&cands[1]), fresh) {
        (Some(a), Some(b)) => assert_bitwise_ranking("reuse cands[1]", &[b], &[a]),
        (a, b) => assert_eq!(a.is_none(), b.is_none(), "runnability diverged"),
    }
    match (w.evaluate(&cands[0]), fresh0) {
        (Some(a), Some(b)) => assert_bitwise_ranking("reuse cands[0]", &[b], &[a]),
        (a, b) => assert_eq!(a.is_none(), b.is_none(), "runnability diverged"),
    }
}

#[test]
fn board_toml_faultpoint_gates_ingestion() {
    let _g = lock();
    faultpoint::disarm_all();
    let guard = faultpoint::arm("board.toml!error").unwrap();
    let err = BoardConfig::from_toml("name = \"x\"").unwrap_err();
    assert!(format!("{err:#}").contains("board.toml"), "{err:#}");
    drop(guard);
    assert!(BoardConfig::from_toml("name = \"x\"").is_ok());
}

#[test]
fn cli_fault_recovery_study_and_exit_codes() {
    let _g = lock();
    faultpoint::disarm_all();
    let argv = |parts: &[&str]| -> Vec<String> { parts.iter().map(|s| s.to_string()).collect() };
    // The CLI fault-recovery study: every interrupted sweep must recover
    // bit-identically (exit 0).
    let study = argv(&["fault-recovery", "--n", "128", "--workers", "2"]);
    let code = zynq_estimator::cli::run(&study).unwrap();
    assert_eq!(code, 0, "fault-recovery study reported a divergence");
    // An injected memo-load fault surfaces as corrupt input: exit code 3.
    let d = tmpdir("cli_exit3");
    let memo = d.join("memo.json").display().to_string();
    let faulty = argv(&[
        "dse", "--app", "matmul", "--n", "64", "--memo", &memo, "--faults", "memo.load!error",
    ]);
    let code = zynq_estimator::cli::run(&faulty).unwrap();
    assert_eq!(code, 3, "injected load fault must map to the corrupt-input exit code");
    std::fs::remove_dir_all(&d).ok();
    faultpoint::disarm_all();
}

#[test]
fn aborted_process_resumes_bit_identical_through_the_cli() {
    // The real thing: a child process killed mid-sweep (process abort —
    // the stand-in for kill -9), then `dse --resume` in a new process.
    // The final memo file and the rendered ranking table must be bitwise
    // identical to a never-killed control run.
    let _g = lock();
    let exe = env!("CARGO_BIN_EXE_zynq-estimator");
    let d = tmpdir("abort_cli");
    let control = d.join("control.json");
    let crashed = d.join("crashed.json");
    let base = [
        "dse", "--app", "matmul", "--n", "128", "--mixed", "--order", "ranked", "--workers", "2",
    ];

    let run = |memo: &Path, extra: &[&str], faults: Option<&str>| {
        let mut cmd = std::process::Command::new(exe);
        cmd.args(base);
        cmd.arg("--memo");
        cmd.arg(memo);
        cmd.args(extra);
        match faults {
            Some(f) => cmd.env("ZYNQ_FAULTS", f),
            None => cmd.env_remove("ZYNQ_FAULTS"),
        };
        cmd.output().unwrap()
    };

    let ctrl = run(&control, &[], None);
    assert!(ctrl.status.success(), "{}", String::from_utf8_lossy(&ctrl.stderr));

    let killed = run(&crashed, &[], Some("sweep.round@1!abort"));
    assert!(!killed.status.success(), "the armed abort must kill the child");
    assert!(
        SweepJournal::wal_path(&crashed).exists(),
        "the killed sweep must leave its journal behind"
    );
    assert!(!crashed.exists(), "the crash predates the first save");

    let resumed = run(&crashed, &["--resume"], None);
    assert!(resumed.status.success(), "{}", String::from_utf8_lossy(&resumed.stderr));
    assert_eq!(
        std::fs::read(&control).unwrap(),
        std::fs::read(&crashed).unwrap(),
        "resumed memo is not bit-identical to the control run"
    );
    // The ranked table (between the '== DSE:' banner and the stats line)
    // must match exactly; timing lines outside it are nondeterministic.
    let table = |out: &[u8]| -> String {
        String::from_utf8_lossy(out)
            .lines()
            .skip_while(|l| !l.starts_with("== DSE:"))
            .take_while(|l| !l.starts_with("pruning:"))
            .collect::<Vec<_>>()
            .join("\n")
    };
    let (t1, t2) = (table(&ctrl.stdout), table(&resumed.stdout));
    assert!(t1.starts_with("== DSE:"), "control output missing the table");
    assert_eq!(t1, t2, "resumed ranking table diverged");
    std::fs::remove_dir_all(&d).ok();
}
