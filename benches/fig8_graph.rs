//! Fig. 8 regeneration: the cholesky task dependency graph for NB = 4
//! (DOT format), plus dependence-tracker throughput on the full-size app.

use zynq_estimator::apps::cholesky::{expected_counts, Cholesky};
use zynq_estimator::config::BoardConfig;
use zynq_estimator::coordinator::deps::DepGraph;
use zynq_estimator::experiments;
use zynq_estimator::util::bench::{bench, black_box};

fn main() {
    let board = BoardConfig::zynq706();
    let dot = experiments::fig8(4, &board);
    std::fs::create_dir_all("out").unwrap();
    std::fs::write("out/fig8_cholesky_nb4.dot", &dot).unwrap();

    let (g, s, t, p) = expected_counts(4);
    println!("=== Fig. 8: cholesky task dependency graph, NB = 4 ===");
    println!("  tasks: {} dgemm, {s} dsyrk, {t} dtrsm, {p} dpotrf = {}", g, g + s + t + p);
    let app = Cholesky::new(256, 64);
    let prog = app.build_program(&board);
    let graph = DepGraph::build(&prog);
    println!(
        "  edges: {}   depth: {}   max width: {}",
        graph.edge_count(),
        graph.depth(),
        graph.max_level_width()
    );
    println!("  wrote out/fig8_cholesky_nb4.dot (render: dot -Tpng)\n");

    // Dependence-tracker throughput (the Nanos++-equivalent hot path).
    let big = Cholesky::new(2048, 64).build_program(&board); // NB=32: 6544 tasks
    println!("dependence tracking at scale: {} tasks", big.tasks.len());
    bench("DepGraph::build (cholesky NB=32)", 3, 50, || {
        black_box(DepGraph::build(&big));
    });
}
