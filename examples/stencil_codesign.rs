//! Stencil co-design study — the extension app: a blocked Jacobi sweep
//! whose halo-exchange dependence pattern differs from both matmul's
//! accumulation chains and cholesky's panel graph.
//!
//! Demonstrates the general-programmer workflow on a *new* application:
//! 1. declare the kernels + task granularity (the OmpSs annotations),
//! 2. let the DSE enumerate every feasible accelerator allocation,
//! 3. read the Paraver-style bottleneck analysis for the winner.
//!
//! Run: `cargo run --release --example stencil_codesign [-- --n 512 --sweeps 8]`

use zynq_estimator::apps::stencil::Stencil;
use zynq_estimator::cli::Args;
use zynq_estimator::config::BoardConfig;
use zynq_estimator::dse::{explore, DseSpace, Objective};
use zynq_estimator::hls::FpgaPart;
use zynq_estimator::metrics::utilization_report;
use zynq_estimator::sim::estimate;
use zynq_estimator::trace::{paraver, prv_analyze};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let n = args.u64_or("n", 512)?;
    let sweeps = args.u64_or("sweeps", 8)? as u32;
    let board = BoardConfig::zynq706();

    // 1. The application.
    let app = Stencil::new(n, 64, sweeps);
    let program = app.build_program(&board);
    println!(
        "stencil {n}x{n}, {sweeps} sweeps -> {} tasks of kernel '{}'\n",
        program.tasks.len(),
        app.kernel_name()
    );

    // 2. Explore every feasible co-design, ranked by time.
    let space = DseSpace::from_program(&program);
    let points = explore(&program, &board, &FpgaPart::xc7z045(), &space, Objective::Time)?;
    println!("{}", zynq_estimator::dse::render(&points, 8, Objective::Time));
    let best = &points[0].codesign;

    // 3. Simulate the winner and analyze its bottleneck like Fig. 7.
    let res = estimate(&program, best, &board)?;
    print!("{}", utilization_report(&res));
    let prv = paraver::to_prv(&program, &board, &res);
    let row = paraver::to_row(&board, &res);
    let analysis = prv_analyze::analyze(&prv, Some(&row))?;
    println!("\n{}", analysis.render());
    Ok(())
}
