//! Warm-start soundness properties: memo reuse, perturbed-space and
//! sibling-board warm sweeps, and the ordered bound-guided rounds must all
//! return the bit-identical best point and time-energy Pareto front of the
//! cold exhaustive sweep, for any worker count — on randomized and
//! mixed-variant spaces. Uses the repository's seeded forall harness (no
//! external proptest crate), same style as `prune_soundness.rs`.

use zynq_estimator::apps::matmul::Matmul;
use zynq_estimator::board::BoardSpace;
use zynq_estimator::config::BoardConfig;
use zynq_estimator::coordinator::task::TaskProgram;
use zynq_estimator::dse::{
    pareto_front_coords as front_coords, warm, CrossBoardSweep, DseSpace, EvalMemo, KernelSpace,
    Objective, OrderMode, SweepContext,
};
use zynq_estimator::hls::FpgaPart;
use zynq_estimator::util::Rng;

fn forall(iters: u64, base_seed: u64, f: impl Fn(u64, &mut Rng)) {
    for i in 0..iters {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        f(seed, &mut rng);
    }
}

/// Random matmul space: random unroll subsets (saturated variants
/// included, arming the dominance cut), 1-2 instances, random smp and a
/// random mixed-variant flag.
fn random_space(rng: &mut Rng, program: &TaskProgram) -> DseSpace {
    let pool = [4u32, 8, 16, 32, 64, 128];
    let kernels = program
        .kernels
        .iter()
        .filter(|k| k.targets.fpga)
        .map(|k| {
            let n_unrolls = rng.gen_range(2, 5) as usize;
            let mut unrolls: Vec<u32> = Vec::new();
            while unrolls.len() < n_unrolls {
                let u = pool[rng.gen_range(0, pool.len() as u64) as usize];
                if !unrolls.contains(&u) {
                    unrolls.push(u);
                }
            }
            KernelSpace {
                kernel: k.name.clone(),
                unrolls,
                max_instances: rng.gen_range(1, 3) as u32,
                try_smp: k.targets.smp && rng.next_f64() < 0.5,
            }
        })
        .collect();
    DseSpace {
        kernels,
        mixed: rng.next_f64() < 0.6,
    }
}

fn assert_same_best_and_front(
    seed: u64,
    label: &str,
    reference: &[zynq_estimator::dse::DsePoint],
    candidate: &[zynq_estimator::dse::DsePoint],
) {
    assert_eq!(
        reference.is_empty(),
        candidate.is_empty(),
        "seed {seed}: {label}: emptiness diverged"
    );
    if reference.is_empty() {
        return;
    }
    assert_eq!(
        reference[0].est_ms.to_bits(),
        candidate[0].est_ms.to_bits(),
        "seed {seed}: {label}: best diverged ({} vs {})",
        reference[0].codesign.name,
        candidate[0].codesign.name
    );
    assert_eq!(
        front_coords(reference),
        front_coords(candidate),
        "seed {seed}: {label}: Pareto front diverged"
    );
}

#[test]
fn prop_memo_reuse_is_exact_and_complete() {
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(256, 64).build_program(&board);
    forall(6, 0x3A9E, |seed, rng| {
        let space = random_space(rng, &program);
        let ctx = SweepContext::for_space(&program, &board, &part, &space);
        let exhaustive = ctx.explore(&space, Objective::Time, 2);
        let mut memo = EvalMemo::new();
        let (first, first_stats) =
            ctx.explore_warm(&space, &mut memo, Objective::Time, 2, OrderMode::Ranked);
        assert_same_best_and_front(seed, "warm-first", &exhaustive, &first);
        assert_eq!(first_stats.memo_hits, 0, "seed {seed}");
        // Second sweep over the identical space: zero evaluations, every
        // returned point a memo hit, full ranking bit-identical.
        let (second, second_stats) =
            ctx.explore_warm(&space, &mut memo, Objective::Time, 2, OrderMode::Ranked);
        assert_eq!(second_stats.evaluated, 0, "seed {seed}: {second_stats:?}");
        assert_eq!(
            second_stats.memo_hits as usize,
            first.len(),
            "seed {seed}: {second_stats:?}"
        );
        assert_eq!(second.len(), first.len(), "seed {seed}");
        for (a, b) in second.iter().zip(&first) {
            assert_eq!(a.codesign.name, b.codesign.name, "seed {seed}");
            assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "seed {seed}");
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "seed {seed}");
            assert_eq!(a.edp.to_bits(), b.edp.to_bits(), "seed {seed}");
        }
    });
}

#[test]
fn prop_memo_hits_are_bit_identical_to_fresh_evaluation() {
    // The "verified on mismatch-able keys" clause: every recorded memo
    // entry must reproduce a fresh simulation bit for bit, and a context
    // with any ingredient changed must not hit at all.
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(256, 64).build_program(&board);
    forall(4, 0xBEEF, |seed, rng| {
        let space = random_space(rng, &program);
        let ctx = SweepContext::for_space(&program, &board, &part, &space);
        let mut memo = EvalMemo::new();
        ctx.explore_warm(&space, &mut memo, Objective::Time, 2, OrderMode::Ranked);
        let fp = warm::context_fingerprint(&ctx);
        let mut worker = ctx.worker();
        let mut checked = 0u32;
        for cd in ctx.enumerate(&space) {
            let Some(hit) = memo.lookup(fp, &warm::codesign_key(&cd)) else {
                continue;
            };
            let fresh = worker.evaluate(&cd).expect("memoized point must be runnable");
            assert_eq!(hit.est_ms.to_bits(), fresh.est_ms.to_bits(), "seed {seed}: {}", cd.name);
            assert_eq!(
                hit.energy_j.to_bits(),
                fresh.energy_j.to_bits(),
                "seed {seed}: {}",
                cd.name
            );
            assert_eq!(hit.edp.to_bits(), fresh.edp.to_bits(), "seed {seed}: {}", cd.name);
            checked += 1;
        }
        assert!(checked > 0, "seed {seed}: no memo entries to verify");
        // Mismatch-able keys: a perturbed board yields a different
        // fingerprint, so the same co-design keys must all miss.
        let mut other_board = board.clone();
        other_board.dma_bw_mbps += 1.0;
        let other_program = Matmul::new(256, 64).build_program(&other_board);
        let other_ctx = SweepContext::for_space(&other_program, &other_board, &part, &space);
        let other_fp = warm::context_fingerprint(&other_ctx);
        assert_ne!(fp, other_fp, "seed {seed}");
        for cd in other_ctx.enumerate(&space) {
            assert!(
                memo.lookup(other_fp, &warm::codesign_key(&cd)).is_none(),
                "seed {seed}: stale hit for {} on a perturbed board",
                cd.name
            );
        }
    });
}

#[test]
fn prop_perturbed_space_warm_sweeps_stay_lossless() {
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(256, 64).build_program(&board);
    forall(5, 0x7E27, |seed, rng| {
        // Base space builds the memo; an independently random space (same
        // program/board/part context) re-sweeps warm against it.
        let base = random_space(rng, &program);
        let base_ctx = SweepContext::for_space(&program, &board, &part, &base);
        let mut memo = EvalMemo::new();
        base_ctx.explore_warm(&base, &mut memo, Objective::Time, 2, OrderMode::Ranked);

        let perturbed = random_space(rng, &program);
        let ctx = SweepContext::for_space(&program, &board, &part, &perturbed);
        let exhaustive = ctx.explore(&perturbed, Objective::Time, 3);
        let mut trial = memo.clone();
        let (warm_pts, warm_stats) =
            ctx.explore_warm(&perturbed, &mut trial, Objective::Time, 3, OrderMode::Ranked);
        assert_same_best_and_front(seed, "perturbed-warm", &exhaustive, &warm_pts);
        assert_eq!(
            warm_stats.evaluated + warm_stats.memo_hits,
            warm_pts.len() as u64,
            "seed {seed}: {warm_stats:?}"
        );
        // Determinism: warm output and stats identical for any worker
        // count (fresh memo clones so the hit set matches).
        for workers in [1, 4] {
            let mut again = memo.clone();
            let (pts, stats) = ctx.explore_warm(
                &perturbed,
                &mut again,
                Objective::Time,
                workers,
                OrderMode::Ranked,
            );
            assert_eq!(stats, warm_stats, "seed {seed}: workers={workers}");
            assert_eq!(pts.len(), warm_pts.len(), "seed {seed}: workers={workers}");
            for (a, b) in pts.iter().zip(&warm_pts) {
                assert_eq!(a.codesign.name, b.codesign.name, "seed {seed}: workers={workers}");
                assert_eq!(
                    a.est_ms.to_bits(),
                    b.est_ms.to_bits(),
                    "seed {seed}: workers={workers}"
                );
            }
        }
    });
}

#[test]
fn prop_ordered_rounds_stay_lossless_in_every_mode() {
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(256, 64).build_program(&board);
    forall(5, 0x0D3A, |seed, rng| {
        let space = random_space(rng, &program);
        let ctx = SweepContext::for_space(&program, &board, &part, &space);
        let exhaustive = ctx.explore(&space, Objective::Time, 2);
        for order in [OrderMode::Fifo, OrderMode::BoundAsc, OrderMode::Ranked] {
            let (pts, stats) = ctx.explore_pruned_with(&space, Objective::Time, 2, order);
            assert_same_best_and_front(seed, &format!("{order:?}"), &exhaustive, &pts);
            assert_eq!(
                stats.evaluated as usize,
                pts.len(),
                "seed {seed}: {order:?}: {stats:?}"
            );
            assert_eq!(stats.memo_hits, 0, "seed {seed}: {order:?}");
            // Worker-count determinism per mode.
            let (serial, serial_stats) = ctx.explore_pruned_with(&space, Objective::Time, 1, order);
            assert_eq!(serial_stats, stats, "seed {seed}: {order:?}");
            for (a, b) in serial.iter().zip(&pts) {
                assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "seed {seed}: {order:?}");
            }
        }
        // BoundAsc through the ordered entry point must reproduce the
        // historical explore_pruned exactly (points and stats).
        let (via_order, order_stats) =
            ctx.explore_pruned_with(&space, Objective::Time, 2, OrderMode::BoundAsc);
        let (classic, classic_stats) = ctx.explore_pruned(&space, Objective::Time, 2);
        assert_eq!(order_stats, classic_stats, "seed {seed}");
        assert_eq!(via_order.len(), classic.len(), "seed {seed}");
        for (a, b) in via_order.iter().zip(&classic) {
            assert_eq!(a.codesign.name, b.codesign.name, "seed {seed}");
            assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "seed {seed}");
        }
    });
}

#[test]
fn prop_sibling_board_seeding_keeps_per_board_results_exact() {
    let axis = BoardSpace::resolve(&["zynq702", "zynq706"]).unwrap();
    let programs: Vec<TaskProgram> = axis
        .targets
        .iter()
        .map(|t| Matmul::new(256, 64).build_program(&t.board))
        .collect();
    forall(5, 0x51B5, |seed, rng| {
        let space = random_space(rng, &programs[0]);
        let mut sweep = CrossBoardSweep::new();
        for (t, p) in axis.targets.iter().zip(&programs) {
            sweep.push(&t.name, "matmul", p, &t.board, &t.part, space.clone());
        }
        let exhaustive = sweep.explore(Objective::Time, 2);
        let mut memo = EvalMemo::new();
        let warm_results = sweep.explore_pruned_warm(&mut memo, Objective::Time, 2);
        // Per-board exactness (the sibling prior only orders, never cuts)
        // and, as a consequence, exactness of the merged front.
        let mut merged_e = Vec::new();
        let mut merged_w = Vec::new();
        for (e, w) in exhaustive.iter().zip(&warm_results) {
            assert_same_best_and_front(
                seed,
                &format!("sibling-{}", e.board),
                &e.points,
                &w.points,
            );
            merged_e.extend(e.points.iter().cloned());
            merged_w.extend(w.points.iter().cloned());
        }
        assert_eq!(
            front_coords(&merged_e),
            front_coords(&merged_w),
            "seed {seed}: merged front diverged"
        );
        // Unchanged axis, same memo: nothing re-simulates.
        let again = sweep.explore_pruned_warm(&mut memo, Objective::Time, 2);
        for (w, a) in warm_results.iter().zip(&again) {
            assert_eq!(a.stats.evaluated, 0, "seed {seed}: {:?}", a.stats);
            assert_eq!(a.stats.memo_hits as usize, w.points.len(), "seed {seed}");
        }
    });
}

#[test]
fn prop_cross_size_kernel_memo_warm_is_exact_and_deterministic() {
    // The kernel-sub-memo satellite contract: a sweep warm-started across
    // problem sizes (level-1 hits only — the level-2 contexts differ)
    // returns the bit-identical best point and time-energy Pareto front
    // of the cold sweep, and its *full ranking* is bit-identical for any
    // worker count.
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let small = Matmul::new(128, 64).build_program(&board);
    let large = Matmul::new(256, 64).build_program(&board);
    forall(5, 0xC125, |seed, rng| {
        let space = random_space(rng, &small);
        let small_ctx = SweepContext::for_space(&small, &board, &part, &space);
        let mut memo = EvalMemo::new();
        small_ctx.explore_warm(&space, &mut memo, Objective::Time, 2, OrderMode::Ranked);

        // The large size primes its HLS cache entirely from the memo:
        // both sizes share kernel profiles, so every space variant hits.
        let large_ctx = SweepContext::for_space_warm(&large, &board, &part, &space, &memo);
        assert!(
            large_ctx.kernel_memo_hits() > 0,
            "seed {seed}: cross-size prime must hit the kernel sub-memo"
        );
        let cold = large_ctx.explore(&space, Objective::Time, 2);
        let mut trial = memo.clone();
        let (warm, warm_stats) =
            large_ctx.explore_warm(&space, &mut trial, Objective::Time, 2, OrderMode::Ranked);
        assert_same_best_and_front(seed, "cross-size-warm", &cold, &warm);
        assert_eq!(
            warm_stats.memo_hits, 0,
            "seed {seed}: sizes must not share level-2 entries"
        );
        assert_eq!(
            warm_stats.kernel_hits,
            large_ctx.kernel_memo_hits() as u64,
            "seed {seed}: stats must surface the level-1 hits"
        );
        // Full-ranking bitwise determinism across worker counts (fresh
        // memo clones so the hit/prior state matches).
        for workers in [1, 4] {
            let mut again = memo.clone();
            let (pts, stats) = large_ctx.explore_warm(
                &space,
                &mut again,
                Objective::Time,
                workers,
                OrderMode::Ranked,
            );
            assert_eq!(stats, warm_stats, "seed {seed}: workers={workers}");
            assert_eq!(pts.len(), warm.len(), "seed {seed}: workers={workers}");
            for (a, b) in pts.iter().zip(&warm) {
                assert_eq!(a.codesign.name, b.codesign.name, "seed {seed}: workers={workers}");
                assert_eq!(
                    a.est_ms.to_bits(),
                    b.est_ms.to_bits(),
                    "seed {seed}: workers={workers}"
                );
                assert_eq!(
                    a.energy_j.to_bits(),
                    b.energy_j.to_bits(),
                    "seed {seed}: workers={workers}"
                );
            }
            // The saved memo is bit-deterministic too (level-1 statistics
            // aggregate order-independently).
            assert_eq!(again.to_json(), trial.to_json(), "seed {seed}: workers={workers}");
        }
    });
}

#[test]
fn prop_from_json_rejects_truncated_and_tampered_payloads() {
    // Build a real two-level memo document, then attack it: every strict
    // prefix must fail to parse (never half-load), and targeted
    // version/fingerprint tampering must be rejected.
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(128, 64).build_program(&board);
    let space = DseSpace::from_program(&program);
    let ctx = SweepContext::for_space(&program, &board, &part, &space);
    let mut memo = EvalMemo::new();
    ctx.explore_warm(&space, &mut memo, Objective::Time, 2, OrderMode::Ranked);
    let text = memo.to_json();
    assert!(EvalMemo::from_json(&text).is_ok());
    // Truncations at pseudo-random byte offsets (the document is ASCII).
    forall(1, 0x7000, |_seed, rng| {
        for _ in 0..64 {
            let cut = rng.gen_range(0, text.len() as u64) as usize;
            assert!(
                EvalMemo::from_json(&text[..cut]).is_err(),
                "truncation at {cut} of {} must be rejected",
                text.len()
            );
        }
    });
    // Version tampering: schema and estimator mismatches both refuse.
    let v1 = text.replacen("\"version\":2", "\"version\":1", 1);
    assert_ne!(v1, text, "fixture must contain the version field");
    assert!(EvalMemo::from_json(&v1).is_err());
    let v999 = text.replacen("\"version\":2", "\"version\":999", 1);
    assert!(EvalMemo::from_json(&v999).is_err());
    let foreign = text.replacen(
        &format!("\"estimator\":\"{}\"", env!("CARGO_PKG_VERSION")),
        "\"estimator\":\"0.0.0\"",
        1,
    );
    assert_ne!(foreign, text, "fixture must contain the estimator field");
    assert!(EvalMemo::from_json(&foreign).is_err());
    // A non-hex fingerprint is structural corruption, not data.
    let bad_fp = text.replacen("\"fp\":\"", "\"fp\":\"zz", 1);
    assert!(EvalMemo::from_json(&bad_fp).is_err());
}

#[test]
fn suite_warm_matches_standalone_and_second_run_hits() {
    // The warm suite path: multi-job warm rounds in one shared pool must
    // be bit-identical, per app, to standalone warm sweeps, and a second
    // run over the unchanged suite must evaluate zero points.
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let matmul = Matmul::new(256, 64).build_program(&board);
    let cholesky = zynq_estimator::apps::cholesky::Cholesky::new(256, 64).build_program(&board);
    let programs: Vec<(&str, &zynq_estimator::coordinator::task::TaskProgram)> =
        vec![("matmul", &matmul), ("cholesky", &cholesky)];

    let mut suite = zynq_estimator::dse::SweepSuite::new();
    for (name, program) in &programs {
        suite.push(name, program, &board, &part, DseSpace::from_program(program));
    }
    let mut memo = EvalMemo::new();
    let first = suite.explore_pruned_warm(&mut memo, Objective::Time, 2, OrderMode::Ranked);
    // Per-app bitwise identity to a standalone warm sweep from the same
    // cold state (the first suite run has no priors — the memo was empty
    // at setup — so standalone fresh-memo runs see identical state).
    for (r, (name, program)) in first.iter().zip(&programs) {
        let space = DseSpace::from_program(program);
        let ctx = SweepContext::for_space(program, &board, &part, &space);
        let mut solo_memo = EvalMemo::new();
        let (solo, solo_stats) =
            ctx.explore_warm(&space, &mut solo_memo, Objective::Time, 2, OrderMode::Ranked);
        assert_eq!(r.stats.evaluated, solo_stats.evaluated, "{name}");
        assert_eq!(r.points.len(), solo.len(), "{name}");
        for (a, b) in r.points.iter().zip(&solo) {
            assert_eq!(a.codesign.name, b.codesign.name, "{name}");
            assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "{name}");
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{name}");
        }
    }
    // Second warm run: all level-2 hits, zero simulations, bit-identical.
    let second = suite.explore_pruned_warm(&mut memo, Objective::Time, 2, OrderMode::Ranked);
    for (f, s) in first.iter().zip(&second) {
        assert_eq!(s.stats.evaluated, 0, "{}: {:?}", f.name, s.stats);
        assert_eq!(s.stats.memo_hits as usize, f.points.len(), "{}", f.name);
        for (a, b) in s.points.iter().zip(&f.points) {
            assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "{}", f.name);
        }
    }
    // Worker-count determinism of the shared-pool warm rounds.
    let mut memo1 = EvalMemo::new();
    let serial = suite.explore_pruned_warm(&mut memo1, Objective::Time, 1, OrderMode::Ranked);
    for (a, b) in first.iter().zip(&serial) {
        assert_eq!(a.stats, b.stats, "{}", a.name);
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.est_ms.to_bits(), y.est_ms.to_bits(), "{}", a.name);
        }
    }
    // The exhaustive warm suite honours the same memo: every feasible
    // runnable candidate is served or simulated, and a repeat serves all.
    let mut ex_memo = EvalMemo::new();
    let ex_cold = suite.explore(Objective::Time, 2);
    let ex_first = suite.explore_warm(&mut ex_memo, Objective::Time, 2);
    let ex_second = suite.explore_warm(&mut ex_memo, Objective::Time, 2);
    for ((c, f), s) in ex_cold.iter().zip(&ex_first).zip(&ex_second) {
        assert_eq!(c.points.len(), f.points.len(), "{}", c.name);
        for (a, b) in c.points.iter().zip(&f.points) {
            assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "{}", c.name);
        }
        assert_eq!(s.stats.evaluated, 0, "{}: {:?}", c.name, s.stats);
        assert_eq!(s.stats.memo_hits as usize, c.points.len(), "{}", c.name);
        for (a, b) in s.points.iter().zip(&f.points) {
            assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "{}", c.name);
        }
    }
}

#[test]
fn mixed_pruned_enumeration_matches_the_exhaustive_candidate_set() {
    // On mixed spaces without dominated variants, the pruned candidate
    // list must equal the exhaustive enumeration, element for element —
    // the subsequence/order contract `enumerate_pruned` documents.
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(256, 64).build_program(&board);
    let space = DseSpace::from_program(&program).with_mixed();
    let ctx = SweepContext::for_space(&program, &board, &part, &space);
    let (cands, stats) = zynq_estimator::dse::enumerate_pruned(&ctx, &space);
    let exhaustive = ctx.enumerate(&space);
    assert_eq!(stats.feasible_points as usize, exhaustive.len());
    assert_eq!(stats.dominance_cut, 0, "{stats:?}");
    assert_eq!(cands.len(), exhaustive.len());
    for (a, b) in cands.iter().zip(&exhaustive) {
        assert_eq!(a, b);
    }
    // And the space really is combinatorially larger than homogeneous.
    let homogeneous = DseSpace::from_program(&program);
    assert!(exhaustive.len() > ctx.enumerate(&homogeneous).len());
}
