//! Integration tests of the PJRT runtime against the real AOT artifacts.
//!
//! Requires the `pjrt` feature (real xla backend) AND `make artifacts` to
//! have run (skipped otherwise, so `cargo test` stays green on a fresh
//! checkout before the Python step).
#![cfg(feature = "pjrt")]

use zynq_estimator::runtime::{reference, Runtime};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("mxm64.hlo.txt").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("artifacts/ missing; run `make artifacts` first — skipping");
                return;
            }
        }
    };
}

fn rng_tile(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = zynq_estimator::util::Rng::new(seed);
    (0..n * n).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
}

#[test]
fn mxm64_matches_reference() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let (a, b, c) = (rng_tile(1, 64), rng_tile(2, 64), rng_tile(3, 64));
    let out = rt.run_mxm("mxm64", 64, &a, &b, &c).unwrap();
    let mut expect = c.clone();
    reference::mxm_block(64, &a, &b, &mut expect);
    assert!(reference::max_abs_diff(&out, &expect) < 1e-3);
}

#[test]
fn mxm128_matches_reference() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let (a, b, c) = (rng_tile(4, 128), rng_tile(5, 128), rng_tile(6, 128));
    let out = rt.run_mxm("mxm128", 128, &a, &b, &c).unwrap();
    let mut expect = c.clone();
    reference::mxm_block(128, &a, &b, &mut expect);
    assert!(reference::max_abs_diff(&out, &expect) < 1e-3);
}

#[test]
fn cholesky_kernels_satisfy_identities() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let bs = 64usize;
    let dims = [bs as i64, bs as i64];
    let a = rng_tile(7, bs);
    let b = rng_tile(8, bs);
    let c = rng_tile(9, bs);

    // dgemm64: out = c - a @ b^T
    let out = rt
        .run_f32("dgemm64", &[(&a, &dims), (&b, &dims), (&c, &dims)])
        .unwrap();
    let mut bt = vec![0f32; bs * bs];
    for i in 0..bs {
        for j in 0..bs {
            bt[i * bs + j] = b[j * bs + i];
        }
    }
    let mut ab = vec![0f32; bs * bs];
    reference::mxm_block(bs, &a, &bt, &mut ab);
    let expect: Vec<f32> = c.iter().zip(&ab).map(|(x, y)| x - y).collect();
    assert!(reference::max_abs_diff(&out, &expect) < 1e-2);

    // dsyrk64: out = c - a @ a^T
    let out = rt.run_f32("dsyrk64", &[(&a, &dims), (&c, &dims)]).unwrap();
    let mut at = vec![0f32; bs * bs];
    for i in 0..bs {
        for j in 0..bs {
            at[i * bs + j] = a[j * bs + i];
        }
    }
    let mut aat = vec![0f32; bs * bs];
    reference::mxm_block(bs, &a, &at, &mut aat);
    let expect: Vec<f32> = c.iter().zip(&aat).map(|(x, y)| x - y).collect();
    assert!(reference::max_abs_diff(&out, &expect) < 1e-2);

    // dpotrf64 then dtrsm64: L @ L^T == SPD(a); (trsm out) @ L^T == b.
    // SPD tile: a @ a^T + bs * I.
    let mut spd = vec![0f32; bs * bs];
    reference::mxm_block(bs, &a, &at, &mut spd);
    for i in 0..bs {
        spd[i * bs + i] += bs as f32;
    }
    let l = rt.run_f32("dpotrf64", &[(&spd, &dims)]).unwrap();
    // check L lower-triangular and L L^T == spd
    for i in 0..bs {
        for j in (i + 1)..bs {
            assert!(l[i * bs + j].abs() < 1e-3, "upper triangle not zero");
        }
    }
    let mut lt = vec![0f32; bs * bs];
    for i in 0..bs {
        for j in 0..bs {
            lt[i * bs + j] = l[j * bs + i];
        }
    }
    let mut llt = vec![0f32; bs * bs];
    reference::mxm_block(bs, &l, &lt, &mut llt);
    let scale = bs as f32;
    let rel: f32 = llt
        .iter()
        .zip(&spd)
        .map(|(x, y)| (x - y).abs() / scale)
        .fold(0.0, f32::max);
    assert!(rel < 1e-2, "L L^T reconstruction error {rel}");

    let x = rt.run_f32("dtrsm64", &[(&l, &dims), (&b, &dims)]).unwrap();
    let mut xlt = vec![0f32; bs * bs];
    reference::mxm_block(bs, &x, &lt, &mut xlt);
    assert!(reference::max_abs_diff(&xlt, &b) < 1e-2);
}

#[test]
fn jacobi_kernel_averages() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let bs = 64usize;
    let dims = [bs as i64, bs as i64];
    let ts: Vec<Vec<f32>> = (0..5).map(|i| rng_tile(20 + i, bs)).collect();
    let inputs: Vec<(&[f32], &[i64])> = ts.iter().map(|t| (t.as_slice(), &dims[..])).collect();
    let out = rt.run_f32("jacobi64", &inputs).unwrap();
    for i in 0..bs * bs {
        let expect = (ts[0][i] + ts[1][i] + ts[2][i] + ts[3][i] + ts[4][i]) / 5.0;
        assert!((out[i] - expect).abs() < 1e-4);
    }
}

#[test]
fn fused_matmul512_matches_blocked_reference() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let n = 512usize;
    let a = rng_tile(31, n);
    let b = rng_tile(32, n);
    let dims = [n as i64, n as i64];
    let out = rt.run_f32("matmul512", &[(&a, &dims), (&b, &dims)]).unwrap();
    let mut expect = vec![0f32; n * n];
    reference::blocked_matmul(n, 128, &a, &b, &mut expect);
    // Relative tolerance: K = 512 accumulations.
    let max = expect.iter().fold(0f32, |m, x| m.max(x.abs()));
    let diff = reference::max_abs_diff(&out, &expect);
    assert!(diff / max < 1e-3, "relative diff {}", diff / max);
}

#[test]
fn runtime_lists_artifacts() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let avail = rt.available();
    for stem in ["mxm64", "mxm128", "dgemm64", "dsyrk64", "dtrsm64", "dpotrf64"] {
        assert!(avail.iter().any(|s| s == stem), "missing {stem}");
    }
    assert!(!rt.platform().is_empty());
}

#[test]
fn unknown_artifact_errors_cleanly() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    assert!(rt.load("no_such_kernel").is_err());
}

#[test]
fn bf16_variant_loads_and_roughly_matches() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let (a, b, c) = (rng_tile(41, 128), rng_tile(42, 128), rng_tile(43, 128));
    let out = rt.run_mxm("mxm128_bf16", 128, &a, &b, &c).unwrap();
    let mut expect = c.clone();
    reference::mxm_block(128, &a, &b, &mut expect);
    // bf16 multiply: ~2-3 significant digits.
    let max = expect.iter().fold(0f32, |m, x| m.max(x.abs()));
    let rel = reference::max_abs_diff(&out, &expect) / max;
    assert!(rel < 0.05, "bf16 rel err {rel}");
}

#[test]
fn kernel_timing_is_positive_and_ordered() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let t64 = rt.time_kernel_ms("mxm64", 64, 3, 5).unwrap();
    let t128 = rt.time_kernel_ms("mxm128", 128, 3, 5).unwrap();
    assert!(t64 > 0.0 && t128 > 0.0);
    // 8x the FLOPs: the 128 tile should be slower. Integration tests run
    // concurrently, so keep the margin generous — only the ordering must
    // hold, not the exact ratio.
    assert!(t128 > t64, "t128 {t128} vs t64 {t64}");
}
