//! Design-space exploration — the paper defers this ("a design space
//! exploration strategy should be analyzed to reduce the amount of
//! possible solutions", §I; "explore different design space exploration
//! strategies", §VII). Because the estimator evaluates a configuration in
//! milliseconds, plain enumeration over the feasible co-design space is
//! practical for the paper's app sizes; that is what this module does,
//! with multi-objective ranking (time / energy / EDP) and a Pareto front.

use std::collections::BTreeMap;

use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::task::TaskProgram;
use crate::hls::{CostModel, FpgaPart, Resources};
use crate::power::PowerModel;
use crate::sim::estimate;

/// Exploration space for one kernel.
#[derive(Clone, Debug)]
pub struct KernelSpace {
    pub kernel: String,
    /// Candidate unroll factors (HLS variants).
    pub unrolls: Vec<u32>,
    /// Maximum number of accelerator instances to consider.
    pub max_instances: u32,
    /// Whether to also consider "+ smp" heterogeneous execution.
    pub try_smp: bool,
}

/// The whole space: one entry per FPGA-capable kernel.
#[derive(Clone, Debug, Default)]
pub struct DseSpace {
    pub kernels: Vec<KernelSpace>,
}

impl DseSpace {
    /// Derive a default space from a program: every FPGA-annotated kernel,
    /// unrolls {8, 16, 32, 64}, up to 2 instances, optional smp.
    pub fn from_program(program: &TaskProgram) -> Self {
        let kernels = program
            .kernels
            .iter()
            .filter(|k| k.targets.fpga)
            .map(|k| KernelSpace {
                kernel: k.name.clone(),
                unrolls: vec![8, 16, 32, 64],
                max_instances: 2,
                try_smp: k.targets.smp,
            })
            .collect();
        Self { kernels }
    }
}

/// Ranking objective.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    Time,
    Energy,
    Edp,
}

impl Objective {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "time" => Some(Objective::Time),
            "energy" => Some(Objective::Energy),
            "edp" => Some(Objective::Edp),
            _ => None,
        }
    }
}

/// One evaluated design point.
#[derive(Clone, Debug)]
pub struct DsePoint {
    pub codesign: CoDesign,
    pub est_ms: f64,
    pub energy_j: f64,
    pub edp: f64,
    pub fabric_util: f64,
}

impl DsePoint {
    pub fn score(&self, obj: Objective) -> f64 {
        match obj {
            Objective::Time => self.est_ms,
            Objective::Energy => self.energy_j,
            Objective::Edp => self.edp,
        }
    }
}

/// Enumerate feasible co-designs over the space (resource-pruned).
pub fn enumerate(
    program: &TaskProgram,
    board: &BoardConfig,
    part: &FpgaPart,
    space: &DseSpace,
) -> Vec<CoDesign> {
    let cm = CostModel::from_board(board);
    // Per-kernel options: (accel list, smp flag).
    let mut per_kernel: Vec<Vec<(Vec<(String, u32)>, bool)>> = Vec::new();
    for ks in &space.kernels {
        let kid = match program.kernel_id(&ks.kernel) {
            Some(k) => k,
            None => continue,
        };
        let profile = &program.kernel(kid).profile;
        let mut opts: Vec<(Vec<(String, u32)>, bool)> = vec![(Vec::new(), false)];
        for &u in &ks.unrolls {
            let res = cm.estimate(&ks.kernel, profile, u).resources;
            // Quick per-kernel prune: even alone it must fit.
            if !part.fits(&[res]) {
                continue;
            }
            for count in 1..=ks.max_instances {
                let accels: Vec<(String, u32)> =
                    (0..count).map(|_| (ks.kernel.clone(), u)).collect();
                opts.push((accels.clone(), false));
                if ks.try_smp {
                    opts.push((accels, true));
                }
            }
        }
        per_kernel.push(opts);
    }

    // Cartesian product with feasibility pruning.
    let mut out = Vec::new();
    let mut idx = vec![0usize; per_kernel.len()];
    loop {
        // Assemble the candidate.
        let mut cd = CoDesign::new("dse");
        for (ki, &i) in idx.iter().enumerate() {
            let (accels, smp) = &per_kernel[ki][i];
            for (k, u) in accels {
                cd = cd.with_accel(k, *u);
            }
            if *smp {
                cd = cd.with_smp(&space.kernels[ki].kernel);
            }
        }
        // Feasibility: total resources fit.
        let resources: Vec<Resources> = cd
            .accels
            .iter()
            .map(|a| {
                let kid = program.kernel_id(&a.kernel).unwrap();
                cm.estimate(&a.kernel, &program.kernel(kid).profile, a.unroll)
                    .resources
            })
            .collect();
        if part.fits(&resources) {
            cd.name = describe(&cd);
            out.push(cd);
        }
        // Advance the odometer.
        let mut carry = true;
        for (ki, i) in idx.iter_mut().enumerate() {
            if !carry {
                break;
            }
            *i += 1;
            if *i < per_kernel[ki].len() {
                carry = false;
            } else {
                *i = 0;
            }
        }
        if carry {
            break;
        }
    }
    out
}

fn describe(cd: &CoDesign) -> String {
    if cd.accels.is_empty() {
        return "smp-only".to_string();
    }
    let mut counts: BTreeMap<String, u32> = BTreeMap::new();
    for a in &cd.accels {
        *counts.entry(format!("{}:U{}", a.kernel, a.unroll)).or_insert(0) += 1;
    }
    let mut s = counts
        .iter()
        .map(|(k, c)| format!("{c}x{k}"))
        .collect::<Vec<_>>()
        .join(" + ");
    if !cd.smp_kernels.is_empty() {
        s.push_str(" +smp");
    }
    s
}

/// Evaluate every feasible point and rank by the objective.
pub fn explore(
    program: &TaskProgram,
    board: &BoardConfig,
    part: &FpgaPart,
    space: &DseSpace,
    objective: Objective,
) -> anyhow::Result<Vec<DsePoint>> {
    let cm = CostModel::from_board(board);
    let pm = PowerModel::default();
    let mut points = Vec::new();
    for cd in enumerate(program, board, part, space) {
        // Skip configurations where some kernel has nowhere to run.
        let Ok(res) = estimate(program, &cd, board) else {
            continue;
        };
        let resources: Vec<Resources> = cd
            .accels
            .iter()
            .map(|a| {
                let kid = program.kernel_id(&a.kernel).unwrap();
                cm.estimate(&a.kernel, &program.kernel(kid).profile, a.unroll)
                    .resources
            })
            .collect();
        let util = part.utilization(&resources);
        let energy = pm.energy(&res, &resources, util, board.fabric_freq_mhz);
        points.push(DsePoint {
            codesign: cd,
            est_ms: res.makespan_ms(),
            energy_j: energy.total_j(),
            edp: energy.edp(),
            fabric_util: util,
        });
    }
    points.sort_by(|a, b| a.score(objective).partial_cmp(&b.score(objective)).unwrap());
    Ok(points)
}

/// Indices of the time-energy Pareto-optimal points.
pub fn pareto_front(points: &[DsePoint]) -> Vec<usize> {
    let mut front = Vec::new();
    for (i, p) in points.iter().enumerate() {
        let dominated = points.iter().enumerate().any(|(j, q)| {
            j != i
                && q.est_ms <= p.est_ms
                && q.energy_j <= p.energy_j
                && (q.est_ms < p.est_ms || q.energy_j < p.energy_j)
        });
        if !dominated {
            front.push(i);
        }
    }
    front
}

/// Render the exploration as a table.
pub fn render(points: &[DsePoint], top: usize, objective: Objective) -> String {
    let front = pareto_front(points);
    let mut out = format!(
        "== DSE: {} feasible co-designs, ranked by {:?} (P = time-energy Pareto)\n",
        points.len(),
        objective
    );
    out.push_str(&format!(
        "{:>4} {:>2}  {:36} {:>10} {:>10} {:>12} {:>6}\n",
        "#", "", "co-design", "time (ms)", "energy (J)", "EDP (mJ*s)", "util"
    ));
    for (i, p) in points.iter().take(top).enumerate() {
        out.push_str(&format!(
            "{:>4} {:>2}  {:36} {:>10.2} {:>10.3} {:>12.3} {:>5.0}%\n",
            i + 1,
            if front.contains(&i) { "P" } else { "" },
            p.codesign.name,
            p.est_ms,
            p.energy_j,
            p.edp * 1e3,
            p.fabric_util * 100.0
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{cholesky::Cholesky, matmul::Matmul};

    #[test]
    fn enumerate_prunes_infeasible() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 128).build_program(&board);
        let space = DseSpace {
            kernels: vec![KernelSpace {
                kernel: "mxm128".into(),
                unrolls: vec![64, 128],
                max_instances: 2,
                try_smp: true,
            }],
        };
        let cds = enumerate(&p, &board, &FpgaPart::xc7z045(), &space);
        // 2x U128 must be pruned (paper feasibility); smp-only kept.
        assert!(cds.iter().any(|c| c.accels.is_empty()));
        assert!(!cds
            .iter()
            .any(|c| c.accel_count_for("mxm128") == 2
                && c.accels.iter().all(|a| a.unroll == 128)));
        assert!(cds.iter().any(|c| c.accel_count_for("mxm128") == 1
            && c.accels[0].unroll == 128));
    }

    #[test]
    fn explore_matmul_beats_the_papers_fixed_set() {
        // The paper's programmer only considered one full-unroll 128x128
        // accelerator (two do not fit). The DSE discovers a point outside
        // that fixed set: *two half-unroll* 128-block accelerators — they
        // fit, and because input DMA channels scale with accelerators
        // (Fig. 3), they outperform the single U128 instance. Exactly the
        // kind of result §I/§VII say automated exploration should bring.
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 128).build_program(&board);
        let space = DseSpace {
            kernels: vec![KernelSpace {
                kernel: "mxm128".into(),
                unrolls: vec![32, 64, 128],
                max_instances: 2,
                try_smp: true,
            }],
        };
        let pts = explore(&p, &board, &FpgaPart::xc7z045(), &space, Objective::Time).unwrap();
        assert!(!pts.is_empty());
        let best = &pts[0];
        // FPGA-only wins (never "+smp" under the greedy policy).
        assert!(best.codesign.smp_kernels.is_empty(), "{}", best.codesign.name);
        // And it beats the paper's choice (1x U128).
        let paper_choice = pts
            .iter()
            .find(|pt| {
                pt.codesign.accel_count_for("mxm128") == 1
                    && pt.codesign.accels[0].unroll == 128
                    && pt.codesign.smp_kernels.is_empty()
            })
            .expect("paper's co-design must be in the space");
        assert!(
            best.est_ms <= paper_choice.est_ms,
            "DSE best {} ({:.1} ms) must be <= paper choice ({:.1} ms)",
            best.codesign.name,
            best.est_ms,
            paper_choice.est_ms
        );
        assert_eq!(
            best.codesign.accel_count_for("mxm128"),
            2,
            "expected the 2x half-unroll discovery, got {}",
            best.codesign.name
        );
    }

    #[test]
    fn cholesky_default_space_explores_pairs() {
        let board = BoardConfig::zynq706();
        let p = Cholesky::new(256, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        // dpotrf is SMP-only, so the space covers dgemm/dsyrk/dtrsm.
        assert_eq!(space.kernels.len(), 3);
        let pts = explore(&p, &board, &FpgaPart::xc7z045(), &space, Objective::Edp).unwrap();
        assert!(pts.len() > 10, "space too small: {}", pts.len());
        // EDP ordering is monotone in score.
        for w in pts.windows(2) {
            assert!(w[0].edp <= w[1].edp);
        }
    }

    #[test]
    fn pareto_front_is_nondominated() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let pts = explore(&p, &board, &FpgaPart::xc7z045(), &space, Objective::Time).unwrap();
        let front = pareto_front(&pts);
        assert!(!front.is_empty());
        for &i in &front {
            for (j, q) in pts.iter().enumerate() {
                if j == i {
                    continue;
                }
                let p_i = &pts[i];
                assert!(
                    !(q.est_ms < p_i.est_ms && q.energy_j < p_i.energy_j),
                    "front point {i} dominated by {j}"
                );
            }
        }
    }

    #[test]
    fn render_lists_points() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let pts = explore(&p, &board, &FpgaPart::xc7z045(), &space, Objective::Time).unwrap();
        let s = render(&pts, 10, Objective::Time);
        assert!(s.contains("feasible co-designs"));
        assert!(s.contains("mxm64"));
    }
}
