//! The paper's full matmul study (§VI): Fig. 5 estimator-vs-real sweep,
//! Fig. 6 analysis-time comparison and Fig. 7 Paraver trace export, in one
//! run.
//!
//! Run: `cargo run --release --example matmul_codesign [-- --n 512]`

use zynq_estimator::cli::Args;
use zynq_estimator::config::BoardConfig;
use zynq_estimator::experiments;
use zynq_estimator::util::fmt_secs;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let n = args.u64_or("n", 512)?;
    let board = BoardConfig::zynq706();

    // Fig. 5 — the six co-designs under both models.
    let table = experiments::fig5(n, &board, experiments::BOARD_REPS)?;
    println!(
        "{}",
        table.render(&format!("Fig. 5: matmul {n}x{n} — estimator vs board emulator"))
    );

    // Fig. 7 — Paraver traces of the four configurations the paper plots.
    let out = std::path::PathBuf::from("out/paraver");
    let stems = experiments::fig7(n, &board, &out)?;
    println!("Fig. 7: Paraver bundles (load in wxparaver):");
    for s in &stems {
        println!("  {}.prv", s.display());
    }
    println!();

    // Fig. 6 — minutes vs hours.
    let (meth, trad) = experiments::analysis_time_matmul(n, &board)?;
    println!("Fig. 6: analysis time (both axes log-scale in the paper)");
    println!("  methodology (measured wall-clock): {}", fmt_secs(meth));
    println!("  traditional hw generation (model): {}", fmt_secs(trad));
    println!("  => {:.0}x faster co-design decisions", trad / meth);
    Ok(())
}
