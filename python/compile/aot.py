"""AOT lowering: jit + lower every Layer-2 function to HLO *text* and write
``artifacts/<stem>.hlo.txt`` for the Rust PJRT runtime.

HLO text — NOT ``lowered.compile()`` or proto ``.serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids that the pinned xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (what
``make artifacts`` runs). Lowering is pure tracing; nothing executes here.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_specs():
    """(stem, fn, example_args) for every artifact the runtime loads."""
    return [
        # mxmBlock at the paper's two granularities (Fig. 5 sweep).
        ("mxm64", model.mxm_block_fn, (f32(64, 64),) * 3),
        ("mxm128", model.mxm_block_fn, (f32(128, 128),) * 3),
        # MXU-native bf16 variant (dtype A/B study; see kernels/mxm.py).
        ("mxm128_bf16", model.mxm_block_bf16_fn, (f32(128, 128),) * 3),
        # Cholesky tile family, BS = 64 (Fig. 9 sweep).
        ("dgemm64", model.gemm_fn, (f32(64, 64),) * 3),
        ("dsyrk64", model.syrk_fn, (f32(64, 64),) * 2),
        ("dtrsm64", model.trsm_fn, (f32(64, 64),) * 2),
        ("dpotrf64", model.potrf_fn, (f32(64, 64),)),
        # Stencil tile.
        ("jacobi64", model.jacobi_fn, (f32(64, 64),) * 5),
        # Fused L2 whole-matrix model (BlockSpec HBM->VMEM schedule demo).
        ("matmul512", model.matmul_full, (f32(512, 512), f32(512, 512))),
    ]


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for stem, fn, args in artifact_specs():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{stem}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[stem] = {
            "bytes": len(text),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "inputs": [list(a.shape) for a in args],
        }
        print(f"  {stem:12} {len(text):>9} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    print(f"lowering artifacts to {args.out_dir} (jax {jax.__version__})")
    lower_all(args.out_dir)
    return 0


if __name__ == "__main__":
    sys.exit(main())
