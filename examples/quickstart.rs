//! Quickstart: the five-minute co-design loop the paper promises.
//!
//! Builds the OmpSs-equivalent task program for a tiled matmul, asks the
//! coarse-grain estimator about two candidate hardware/software
//! partitionings, and prints which one to synthesize — the decision that
//! would otherwise cost two bitstream generations (hours).
//!
//! Run: `cargo run --release --example quickstart`

use zynq_estimator::apps::matmul::{Matmul, UNROLL_128, UNROLL_64};
use zynq_estimator::config::{BoardConfig, CoDesign};
use zynq_estimator::metrics::utilization_report;
use zynq_estimator::sim::estimate;

fn main() -> anyhow::Result<()> {
    // 1. The board we target (ZC706 preset; load a TOML for other boards).
    let board = BoardConfig::zynq706();

    // 2. Two candidate co-designs for a 512x512 single-precision matmul.
    let candidates = [
        (
            Matmul::new(512, 64),
            CoDesign::new("two 64x64 accelerators")
                .with_accel("mxm64", UNROLL_64)
                .with_accel("mxm64", UNROLL_64),
        ),
        (
            Matmul::new(512, 128),
            CoDesign::new("one 128x128 accelerator").with_accel("mxm128", UNROLL_128),
        ),
    ];

    // 3. Estimate both. Each run simulates the OmpSs runtime scheduling
    //    every task (creation, DMA submit, transfers, compute) on the
    //    Zynq device model.
    let mut best: Option<(f64, &str)> = None;
    for (app, cd) in &candidates {
        let program = app.build_program(&board);
        let res = estimate(&program, cd, &board)?;
        println!("--- {} (block {}x{})", cd.name, app.bs, app.bs);
        print!("{}", utilization_report(&res));
        let ms = res.makespan_ms();
        if best.map(|(b, _)| ms < b).unwrap_or(true) {
            best = Some((ms, &cd.name));
        }
        println!();
    }

    let (ms, name) = best.unwrap();
    println!("=> synthesize: {name}  (estimated {ms:.1} ms)");
    println!("   (the paper's answer too: coarse blocks on the FPGA only)");
    Ok(())
}
