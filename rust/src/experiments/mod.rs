//! Experiment harnesses — one entry point per paper figure. The CLI,
//! benches and examples all call through here so the numbers in
//! EXPERIMENTS.md regenerate from a single implementation.

pub mod fault_recovery;
pub mod robustness;

use std::time::Instant;

use crate::apps::{cholesky, lu, matmul};
use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::task::TaskProgram;
use crate::hls::{CostModel, FpgaPart, SynthesisTimeModel};
use crate::metrics::{ConfigRow, SpeedupTable};
use crate::sim::{dma, emulate_mean_ms, estimate};

/// Default board-emulator repetitions (the paper averages 10 real runs).
pub const BOARD_REPS: u32 = 10;

/// Run one (program, co-design) under both models.
pub fn run_pair(
    program: &TaskProgram,
    cd: &CoDesign,
    board: &BoardConfig,
    reps: u32,
) -> anyhow::Result<ConfigRow> {
    let est = estimate(program, cd, board)?;
    let real = emulate_mean_ms(program, cd, board, reps)?;
    Ok(ConfigRow {
        name: cd.name.clone(),
        estimator_ms: est.makespan_ms(),
        board_ms: real,
    })
}

/// Fig. 5 — matmul estimator-vs-real across the six co-designs.
pub fn fig5(n: u64, board: &BoardConfig, reps: u32) -> anyhow::Result<SpeedupTable> {
    let mut rows = Vec::new();
    for (cd, app) in matmul::fig5_cases(n) {
        let program = app.build_program(board);
        rows.push(run_pair(&program, &cd, board, reps)?);
    }
    Ok(SpeedupTable::build(rows))
}

/// Fig. 9 — cholesky estimator-vs-real across the six co-designs.
pub fn fig9(n: u64, board: &BoardConfig, reps: u32) -> anyhow::Result<SpeedupTable> {
    let app = cholesky::Cholesky::new(n, 64);
    let program = app.build_program(board);
    let mut rows = Vec::new();
    for cd in cholesky::fig9_codesigns() {
        rows.push(run_pair(&program, &cd, board, reps)?);
    }
    Ok(SpeedupTable::build(rows))
}

/// Extension: the LU study (same shape as Fig. 9, for the tiled LU app).
pub fn lu_study(n: u64, board: &BoardConfig, reps: u32) -> anyhow::Result<SpeedupTable> {
    let app = lu::Lu::new(n, 64);
    let program = app.build_program(board);
    let mut rows = Vec::new();
    for cd in lu::study_codesigns() {
        rows.push(run_pair(&program, &cd, board, reps)?);
    }
    Ok(SpeedupTable::build(rows))
}

/// Extension: cross-board study — the same application swept on the
/// paper's ZC706 and on a Zynq UltraScale+ (ZU9EG), showing how the
/// co-design decision shifts with the platform (the paper's §I outlook).
/// Returns (board name, best co-design, best ms) per platform.
///
/// The candidate set is fixed (the Fig. 5 six plus the "2acc 128" point
/// the ZC706 cannot fit), but evaluation runs on the board axis: each
/// platform of the [`BoardSpace`](crate::board::BoardSpace) gets its own
/// shared [`SweepContext`](crate::dse::SweepContext) per candidate
/// program, and per-part feasibility decides what each board may even
/// consider. Decision rows are bit-identical to the historical
/// fixed-loop implementation (regression-tested in
/// `rust/tests/cross_board_determinism.rs`).
pub fn cross_board_matmul(n: u64) -> anyhow::Result<Vec<(String, String, f64)>> {
    use crate::board::BoardSpace;
    use crate::dse::SweepContext;
    let axis = BoardSpace::resolve(&["zynq706", "zynq-ultrascale"])?;
    let mut out = Vec::new();
    for target in &axis.targets {
        let mut best: Option<(String, f64)> = None;
        // Fig. 5 set plus the point only the bigger part can fit; the
        // candidate order matches the historical loop so strict-improve
        // tie-breaking is preserved.
        let mut cases = matmul::fig5_cases(n);
        cases.push((
            crate::config::CoDesign::new("2acc 128")
                .with_accel("mxm128", matmul::UNROLL_128)
                .with_accel("mxm128", matmul::UNROLL_128),
            matmul::Matmul::new(n, 128),
        ));
        for (cd, app) in cases {
            let program = app.build_program(&target.board);
            let ctx = SweepContext::new(&program, &target.board, target.part.clone());
            // Feasibility differs per part: skip what does not fit.
            let Ok(res) = ctx.estimate(&cd) else {
                continue;
            };
            let ms = res.makespan_ms();
            if best.as_ref().map(|(_, b)| ms < *b).unwrap_or(true) {
                best = Some((cd.name.clone(), ms));
            }
        }
        let (name, ms) = best.unwrap();
        out.push((target.board.name.clone(), name, ms));
    }
    Ok(out)
}

/// Fig. 3 — DMA speedup (2 accels vs 1) for 512 KB and 1024 KB, inputs vs
/// outputs, under both models.
pub fn fig3(board: &BoardConfig) -> Vec<(String, dma::DmaSpeedup, dma::DmaSpeedup)> {
    [512 * 1024u64, 1024 * 1024]
        .into_iter()
        .map(|bytes| {
            (
                format!("{} KB", bytes / 1024),
                dma::fig3_estimator(board, bytes, 2),
                dma::fig3_board(board, bytes, 2),
            )
        })
        .collect()
}

/// Fig. 6 — analysis time of the methodology (measured wall-clock of this
/// toolchain) vs the traditional hardware-generation flow (synthesis-time
/// model). Returns `(methodology_secs, traditional_secs)`.
pub fn analysis_time_matmul(n: u64, board: &BoardConfig) -> anyhow::Result<(f64, f64)> {
    let t0 = Instant::now();
    let _table = fig5(n, board, BOARD_REPS)?;
    let methodology = t0.elapsed().as_secs_f64();

    let cm = CostModel::from_board(board);
    let part = FpgaPart::xc7z045();
    let m64 = matmul::Matmul::new(n, 64);
    let m128 = matmul::Matmul::new(n, 128);
    let a64 = cm
        .estimate("mxm64", &m64.profile(), matmul::UNROLL_64)
        .resources;
    let a128 = cm
        .estimate("mxm128", &m128.profile(), matmul::UNROLL_128)
        .resources;
    // Bitstreams needed by the Fig. 5 set (the +smp variants share them).
    let traditional = SynthesisTimeModel::default().total_seconds(
        &part,
        &[vec![a64], vec![a64, a64], vec![a128]],
    );
    Ok((methodology, traditional))
}

/// §VI cholesky productivity claim: six bitstreams vs < 10 min of
/// methodology. Returns `(methodology_secs, traditional_secs)`.
pub fn analysis_time_cholesky(n: u64, board: &BoardConfig) -> anyhow::Result<(f64, f64)> {
    let t0 = Instant::now();
    let _table = fig9(n, board, BOARD_REPS)?;
    let methodology = t0.elapsed().as_secs_f64();

    let cm = CostModel::from_board(board);
    let part = FpgaPart::xc7z045();
    let app = cholesky::Cholesky::new(n, 64);
    let profiles = app.profiles();
    let res = |name: &str, unroll: u32| {
        let p = profiles.iter().find(|(n, _, _)| *n == name).unwrap();
        cm.estimate(name, &p.2, unroll).resources
    };
    let fr = cholesky::UNROLL_FR;
    let pr = cholesky::UNROLL_PAIR;
    let traditional = SynthesisTimeModel::default().total_seconds(
        &part,
        &[
            vec![res("dgemm", fr)],
            vec![res("dsyrk", fr)],
            vec![res("dtrsm", fr)],
            vec![res("dgemm", pr), res("dgemm", pr)],
            vec![res("dgemm", pr), res("dsyrk", pr)],
            vec![res("dgemm", pr), res("dtrsm", pr)],
        ],
    );
    Ok((methodology, traditional))
}

/// DSE sweep latency on an app's default space: the seed-style serial
/// rebuild-everything loop vs the shared-`SweepContext` parallel engine.
/// Returns `(baseline_secs, sweep_secs, n_points)`; both paths produce the
/// identical ranked point list (asserted here, measured by the Fig. 6
/// bench).
pub fn dse_sweep_latency(
    program: &TaskProgram,
    board: &BoardConfig,
    workers: usize,
) -> anyhow::Result<(f64, f64, usize)> {
    use crate::dse::{sweep, DseSpace, Objective, SweepContext};
    let space = DseSpace::from_program(program);
    let part = FpgaPart::xc7z045();

    let t0 = Instant::now();
    let baseline =
        sweep::explore_rebuild_baseline(program, board, &part, &space, Objective::Time)?;
    let baseline_secs = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let ctx = SweepContext::for_space(program, board, &part, &space);
    let points = ctx.explore(&space, Objective::Time, workers);
    let sweep_secs = t1.elapsed().as_secs_f64();

    anyhow::ensure!(
        points.len() == baseline.len(),
        "sweep point-count mismatch: {} vs {}",
        points.len(),
        baseline.len()
    );
    for (a, b) in points.iter().zip(&baseline) {
        anyhow::ensure!(
            a.codesign.name == b.codesign.name && a.est_ms == b.est_ms,
            "sweep ranking diverged from the serial baseline at '{}'",
            b.codesign.name
        );
    }
    Ok((baseline_secs, sweep_secs, points.len()))
}

/// Per-application record of one suite-sweep comparison run.
#[derive(Clone, Debug)]
pub struct SuiteAppLatency {
    /// Application name (matmul, cholesky, lu, stencil).
    pub name: String,
    /// Candidates the exhaustive sweep evaluates.
    pub feasible: u64,
    /// Candidates the pruned sweep actually simulated.
    pub evaluated: u64,
    /// Candidates skipped by the lower-bound cut.
    pub bound_cut: u64,
    /// Feasible candidates never enumerated (dominated variants).
    pub dominance_cut: u64,
    /// Best co-design (identical under both sweeps — asserted).
    pub best: String,
}

/// Result of [`dse_suite_latency`]: wall time of the exhaustive vs pruned
/// batched suite sweep plus the per-application point accounting.
#[derive(Clone, Debug)]
pub struct SuiteLatency {
    /// Worker-pool size used for both passes.
    pub workers: usize,
    /// Wall time of the exhaustive shared-pool suite sweep (seconds).
    pub exhaustive_s: f64,
    /// Wall time of the bound-guided pruned suite sweep (seconds).
    pub pruned_s: f64,
    /// Per-application accounting.
    pub apps: Vec<SuiteAppLatency>,
}

/// Batched multi-program DSE sweep latency: the matmul/cholesky/lu/stencil
/// suite swept exhaustively and with bound-guided pruning, both through one
/// shared `SweepSuite` worker pool. Asserts, per application, that the
/// pruned sweep reproduces the exhaustive best point and time-energy
/// Pareto front while evaluating strictly fewer points — the losslessness
/// contract of `dse::prune` — and returns the counts the bench reports.
pub fn dse_suite_latency(
    n: u64,
    board: &BoardConfig,
    workers: usize,
) -> anyhow::Result<SuiteLatency> {
    use crate::dse::{pareto_front_coords, DseSpace, Objective, SweepSuite};

    let part = FpgaPart::xc7z045();
    let programs: Vec<(&str, TaskProgram)> = crate::apps::SUITE_APPS
        .into_iter()
        .map(|app| Ok((app, crate::apps::build_app_program(app, n, 64, board)?)))
        .collect::<anyhow::Result<_>>()?;
    let mut suite = SweepSuite::new();
    for (name, program) in &programs {
        suite.push(name, program, board, &part, DseSpace::from_program(program));
    }

    let t0 = Instant::now();
    let exhaustive = suite.explore(Objective::Time, workers);
    let exhaustive_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let pruned = suite.explore_pruned(Objective::Time, workers);
    let pruned_s = t1.elapsed().as_secs_f64();

    let mut apps = Vec::new();
    for (e, p) in exhaustive.iter().zip(&pruned) {
        anyhow::ensure!(!e.points.is_empty(), "{}: empty exhaustive sweep", e.name);
        anyhow::ensure!(
            e.points[0].est_ms.to_bits() == p.points[0].est_ms.to_bits(),
            "{}: pruned best diverged ({} vs {})",
            e.name,
            e.points[0].codesign.name,
            p.points[0].codesign.name
        );
        anyhow::ensure!(
            pareto_front_coords(&e.points) == pareto_front_coords(&p.points),
            "{}: pruned Pareto front diverged",
            e.name
        );
        anyhow::ensure!(
            p.stats.evaluated < p.stats.feasible_points,
            "{}: pruning evaluated {} of {} points (expected strictly fewer)",
            e.name,
            p.stats.evaluated,
            p.stats.feasible_points
        );
        apps.push(SuiteAppLatency {
            name: e.name.clone(),
            feasible: p.stats.feasible_points,
            evaluated: p.stats.evaluated,
            bound_cut: p.stats.bound_cut,
            dominance_cut: p.stats.dominance_cut,
            best: e.points[0].codesign.name.clone(),
        });
    }
    Ok(SuiteLatency {
        workers,
        exhaustive_s,
        pruned_s,
        apps,
    })
}

/// Per-application record of one warm-start comparison run (see
/// [`warm_start_latency`]). All counts refer to the same mixed-variant
/// space; the four sweep modes return the identical best point and
/// time-energy Pareto front (asserted by the harness) and differ only in
/// how many candidates they had to simulate.
#[derive(Clone, Debug)]
pub struct WarmAppRow {
    /// Application name.
    pub name: String,
    /// Feasible candidates of the mixed-variant space.
    pub feasible: u64,
    /// Candidates surviving enumeration (dominance + resource cuts).
    pub enumerated: u64,
    /// Simulated by the cold FIFO-ordered pruned sweep (the baseline).
    pub fifo_evaluated: u64,
    /// Simulated by the cold bound-ascending pruned sweep (PR-2 default).
    pub bound_evaluated: u64,
    /// Simulated by the cold cheap-feature ranked pruned sweep.
    pub ranked_evaluated: u64,
    /// Simulated by the *second* warm sweep over the identical space
    /// (zero when the memo round-trips — asserted).
    pub warm_evaluated: u64,
    /// Memo hits of the second warm sweep.
    pub memo_hits: u64,
    /// Bound cuts of the second warm sweep attributable to the seeded
    /// frontier.
    pub seeded_cut: u64,
    /// Best co-design (identical under every mode — asserted).
    pub best: String,
}

/// Result of [`warm_start_latency`]: wall times of the cold-FIFO,
/// cold-ranked and warm (second-run) sweeps plus per-app accounting.
#[derive(Clone, Debug)]
pub struct WarmStartLatency {
    /// Worker-pool size used for every pass.
    pub workers: usize,
    /// Wall time of the cold FIFO-ordered pruned sweep (seconds).
    pub fifo_s: f64,
    /// Wall time of the cold ranked pruned sweep (seconds).
    pub ranked_s: f64,
    /// Wall time of the warm second sweep (seconds).
    pub warm_s: f64,
    /// Per-application accounting.
    pub apps: Vec<WarmAppRow>,
}

/// Warm-start / ordered DSE latency on **mixed-variant** spaces — the
/// combinatorial regime the ISSUE stresses the warm layer against.
///
/// Sweeps matmul (at `n`) and cholesky (at `n.min(256)` — the mixed
/// cholesky space is cubic in the per-kernel option count) through four
/// pruned modes: cold FIFO order, cold bound-ascending order, cold
/// cheap-feature ranked order, and a warm second run against the
/// [`EvalMemo`](crate::dse::EvalMemo) a first warm run populated.
/// Asserts, per application, that every mode returns the bit-identical
/// best point and time-energy Pareto front, and that the warm second run
/// simulates **zero** points — the exactness and zero-re-evaluation
/// contracts of `dse::warm`.
pub fn warm_start_latency(
    n: u64,
    board: &BoardConfig,
    workers: usize,
) -> anyhow::Result<WarmStartLatency> {
    use crate::dse::{pareto_front_coords, DseSpace, EvalMemo, Objective, OrderMode, SweepContext};
    let part = FpgaPart::xc7z045();
    let programs: Vec<(&str, TaskProgram)> = vec![
        ("matmul", crate::apps::build_app_program("matmul", n, 64, board)?),
        (
            "cholesky",
            crate::apps::build_app_program("cholesky", n.min(256), 64, board)?,
        ),
    ];
    let mut apps = Vec::new();
    let mut fifo_s = 0.0;
    let mut ranked_s = 0.0;
    let mut warm_s = 0.0;
    for (name, program) in &programs {
        let space = DseSpace::from_program(program).with_mixed();
        let ctx = SweepContext::for_space(program, board, &part, &space);

        let t0 = Instant::now();
        let (fifo, fifo_stats) =
            ctx.explore_pruned_with(&space, Objective::Time, workers, OrderMode::Fifo);
        fifo_s += t0.elapsed().as_secs_f64();
        let (bound, bound_stats) =
            ctx.explore_pruned_with(&space, Objective::Time, workers, OrderMode::BoundAsc);
        let t1 = Instant::now();
        let (ranked, ranked_stats) =
            ctx.explore_pruned_with(&space, Objective::Time, workers, OrderMode::Ranked);
        ranked_s += t1.elapsed().as_secs_f64();

        let mut memo = EvalMemo::new();
        let (first, _) =
            ctx.explore_warm(&space, &mut memo, Objective::Time, workers, OrderMode::Ranked);
        let t2 = Instant::now();
        let (warm, warm_stats) =
            ctx.explore_warm(&space, &mut memo, Objective::Time, workers, OrderMode::Ranked);
        warm_s += t2.elapsed().as_secs_f64();

        // Exactness across every mode: identical best point + front.
        for (label, pts) in [
            ("bound", &bound),
            ("ranked", &ranked),
            ("warm-first", &first),
            ("warm-second", &warm),
        ] {
            anyhow::ensure!(!pts.is_empty(), "{name}/{label}: empty sweep");
            anyhow::ensure!(
                pts[0].est_ms.to_bits() == fifo[0].est_ms.to_bits(),
                "{name}/{label}: best diverged ({} vs {})",
                pts[0].codesign.name,
                fifo[0].codesign.name
            );
            anyhow::ensure!(
                pareto_front_coords(pts) == pareto_front_coords(&fifo),
                "{name}/{label}: Pareto front diverged"
            );
        }
        // The zero-re-evaluation contract of the memo.
        anyhow::ensure!(
            warm_stats.evaluated == 0,
            "{name}: warm second run simulated {} points",
            warm_stats.evaluated
        );
        anyhow::ensure!(
            fifo_stats.evaluated > 0 && warm_stats.memo_hits > 0,
            "{name}: degenerate space"
        );
        apps.push(WarmAppRow {
            name: name.to_string(),
            feasible: fifo_stats.feasible_points,
            enumerated: fifo_stats.enumerated(),
            fifo_evaluated: fifo_stats.evaluated,
            bound_evaluated: bound_stats.evaluated,
            ranked_evaluated: ranked_stats.evaluated,
            warm_evaluated: warm_stats.evaluated,
            memo_hits: warm_stats.memo_hits,
            seeded_cut: warm_stats.seeded_cut,
            best: fifo[0].codesign.name.clone(),
        });
    }
    Ok(WarmStartLatency {
        workers,
        fifo_s,
        ranked_s,
        warm_s,
        apps,
    })
}

/// One row of the perturbed-space warm-start robustness study.
#[derive(Clone, Debug)]
pub struct PerturbedWarmRow {
    /// Perturbation label.
    pub label: String,
    /// Simulated by the cold pruned sweep of the perturbed space.
    pub cold_evaluated: u64,
    /// Simulated by the warm sweep (memo from the *base* space).
    pub warm_evaluated: u64,
    /// Points the warm sweep reused from the base-space memo.
    pub memo_hits: u64,
}

/// Perturbed-space robustness of the warm-start layer: build a memo by
/// sweeping matmul's mixed-variant base space, then re-sweep perturbed
/// variants of the space (dropped / added unroll variants, a third
/// instance slot, the homogeneous restriction, and the identical space)
/// warm against a clone of that memo. Asserts, per perturbation, that the
/// warm sweep returns the bit-identical best point and time-energy Pareto
/// front to a cold pruned sweep of the same perturbed space — overlap is
/// *reused*, never allowed to bias the result — and that the identical
/// space re-evaluates nothing.
pub fn warm_perturbed_study(
    n: u64,
    board: &BoardConfig,
    workers: usize,
) -> anyhow::Result<Vec<PerturbedWarmRow>> {
    use crate::dse::{pareto_front_coords, DseSpace, EvalMemo, Objective, OrderMode, SweepContext};
    let part = FpgaPart::xc7z045();
    let program = crate::apps::build_app_program("matmul", n, 64, board)?;
    let base = DseSpace::from_program(&program).with_mixed();
    let base_ctx = SweepContext::for_space(&program, board, &part, &base);
    let mut memo = EvalMemo::new();
    base_ctx.explore_warm(&base, &mut memo, Objective::Time, workers, OrderMode::Ranked);

    let mut spaces: Vec<(String, DseSpace)> = vec![("identical".into(), base.clone())];
    let mut dropped = base.clone();
    dropped.kernels[0].unrolls.retain(|&u| u != 8);
    spaces.push(("drop-u8".into(), dropped));
    let mut added = base.clone();
    added.kernels[0].unrolls.push(128);
    spaces.push(("add-u128".into(), added));
    let mut wider = base.clone();
    wider.kernels[0].max_instances += 1;
    spaces.push(("third-instance".into(), wider));
    let mut homogeneous = base.clone();
    homogeneous.mixed = false;
    spaces.push(("homogeneous".into(), homogeneous));

    let mut rows = Vec::new();
    for (label, space) in &spaces {
        let ctx = SweepContext::for_space(&program, board, &part, space);
        let (cold, cold_stats) = ctx.explore_pruned(space, Objective::Time, workers);
        let mut trial = memo.clone();
        let (warm, warm_stats) =
            ctx.explore_warm(space, &mut trial, Objective::Time, workers, OrderMode::Ranked);
        anyhow::ensure!(!cold.is_empty(), "{label}: empty sweep");
        anyhow::ensure!(
            cold[0].est_ms.to_bits() == warm[0].est_ms.to_bits(),
            "{label}: warm best diverged ({} vs {})",
            cold[0].codesign.name,
            warm[0].codesign.name
        );
        anyhow::ensure!(
            pareto_front_coords(&cold) == pareto_front_coords(&warm),
            "{label}: warm Pareto front diverged"
        );
        if label == "identical" {
            anyhow::ensure!(
                warm_stats.evaluated == 0,
                "identical space re-simulated {} points",
                warm_stats.evaluated
            );
        }
        rows.push(PerturbedWarmRow {
            label: label.clone(),
            cold_evaluated: cold_stats.evaluated,
            warm_evaluated: warm_stats.evaluated,
            memo_hits: warm_stats.memo_hits,
        });
    }
    Ok(rows)
}

/// Result of [`warm_cross_size_study`] — the kernel-sub-memo cross-size
/// warm start, pinned by `bench_baselines/BENCH_warm.json`.
#[derive(Clone, Debug)]
pub struct CrossSizeWarmRow {
    /// Problem size that recorded the memo.
    pub small_n: u64,
    /// Problem size swept warm from it.
    pub large_n: u64,
    /// Level-1 hits: HLS reports served from the kernel sub-memo while
    /// priming the large-size context (one per `(kernel, unroll)` pair of
    /// the space — the sizes share kernel profiles).
    pub kernel_hits: u64,
    /// Level-2 hits of the warm large-size sweep — **zero** by
    /// construction (different task traces, different context), asserted.
    pub memo_hits: u64,
    /// Candidates the large-size warm sweep ordered by a level-1
    /// occupancy prior.
    pub prior_ordered: u64,
    /// Points the warm large-size sweep simulated.
    pub warm_evaluated: u64,
    /// Points the cold pruned large-size sweep simulated.
    pub cold_evaluated: u64,
    /// Best co-design of the large size (identical warm and cold —
    /// asserted).
    pub best: String,
}

/// Cross-size warm start through the **kernel sub-memo**: sweep matmul at
/// a small problem size to record the memo, then sweep a larger size warm
/// against it. The two sizes share no level-2 context (their task traces
/// differ), but their kernels fingerprint identically, so the large sweep
/// primes its HLS cache entirely from the memo and draws ranked-ordering
/// priors from the recorded occupancy statistics. Asserts the exactness
/// contract — the warm large-size sweep returns the bit-identical best
/// point and time-energy Pareto front of the cold pruned (and hence the
/// exhaustive) sweep — plus `memo_hits == 0` and `kernel_hits` = the
/// space's variant count.
pub fn warm_cross_size_study(
    board: &BoardConfig,
    workers: usize,
) -> anyhow::Result<CrossSizeWarmRow> {
    use crate::dse::{
        pareto_front_coords, DseSpace, EvalMemo, Objective, OrderMode, SweepContext,
    };
    let part = FpgaPart::xc7z045();
    let (small_n, large_n) = (256u64, 512u64);
    let small = crate::apps::build_app_program("matmul", small_n, 64, board)?;
    let small_space = DseSpace::from_program(&small).with_mixed();
    let small_ctx = SweepContext::for_space(&small, board, &part, &small_space);
    let mut memo = EvalMemo::new();
    small_ctx.explore_warm(&small_space, &mut memo, Objective::Time, workers, OrderMode::Ranked);

    let large = crate::apps::build_app_program("matmul", large_n, 64, board)?;
    let large_space = DseSpace::from_program(&large).with_mixed();
    let cold_ctx = SweepContext::for_space(&large, board, &part, &large_space);
    let (cold, cold_stats) = cold_ctx.explore_pruned(&large_space, Objective::Time, workers);

    let warm_ctx = SweepContext::for_space_warm(&large, board, &part, &large_space, &memo);
    let kernel_hits = warm_ctx.kernel_memo_hits() as u64;
    let (warm, warm_stats) = warm_ctx.explore_warm(
        &large_space,
        &mut memo,
        Objective::Time,
        workers,
        OrderMode::Ranked,
    );

    anyhow::ensure!(
        kernel_hits > 0,
        "cross-size prime must hit the kernel sub-memo"
    );
    anyhow::ensure!(
        warm_stats.kernel_hits == kernel_hits,
        "stats must surface the level-1 hits: {warm_stats:?}"
    );
    anyhow::ensure!(
        warm_stats.memo_hits == 0,
        "different problem sizes must not share level-2 entries: {warm_stats:?}"
    );
    anyhow::ensure!(!cold.is_empty() && !warm.is_empty(), "empty sweep");
    anyhow::ensure!(
        cold[0].est_ms.to_bits() == warm[0].est_ms.to_bits(),
        "cross-size warm best diverged ({} vs {})",
        cold[0].codesign.name,
        warm[0].codesign.name
    );
    anyhow::ensure!(
        pareto_front_coords(&cold) == pareto_front_coords(&warm),
        "cross-size warm Pareto front diverged"
    );
    Ok(CrossSizeWarmRow {
        small_n,
        large_n,
        kernel_hits,
        memo_hits: warm_stats.memo_hits,
        prior_ordered: warm_stats.prior_ordered,
        warm_evaluated: warm_stats.evaluated,
        cold_evaluated: cold_stats.evaluated,
        best: cold[0].codesign.name.clone(),
    })
}

/// Result of [`cross_board_dse`]: wall times of the three cross-board
/// sweep modes plus the pruned per-(board, app) results and the winner
/// tables.
#[derive(Clone, Debug)]
pub struct CrossBoardLatency {
    /// Worker-pool size used for every pass.
    pub workers: usize,
    /// Wall time of the exhaustive cross-board sweep (seconds).
    pub exhaustive_s: f64,
    /// Wall time of the per-board-lossless pruned sweep (seconds).
    pub pruned_s: f64,
    /// Wall time of the cross-board-incumbent pruned sweep (seconds).
    pub global_s: f64,
    /// Per-(board, app) pruned results (per-board lossless mode).
    pub results: Vec<crate::dse::CrossBoardResult>,
    /// Per-(board, app) results of the incumbent (global-cut) mode.
    pub global_results: Vec<crate::dse::CrossBoardResult>,
    /// Per-application "which board wins at which time budget" tables.
    pub winners: Vec<(String, Vec<crate::dse::BudgetRow>)>,
    /// The same decision on the energy-budget axis (fastest point within
    /// an energy envelope).
    pub energy_winners: Vec<(String, Vec<crate::dse::BudgetRow>)>,
    /// And on the fabric-area axis (fastest point within a utilization
    /// cap — the part-cost question).
    pub area_winners: Vec<(String, Vec<crate::dse::BudgetRow>)>,
}

/// Cross-board DSE harness: sweep `apps` (any of matmul|cholesky|lu|
/// stencil) over every platform of `boards`, exhaustively and with both
/// pruned modes, all through one shared worker pool. Asserts the
/// losslessness contracts — per (board, app), the per-board-frontier
/// pruned sweep reproduces the exhaustive best point and time-energy
/// Pareto front; per app, the incumbent mode reproduces the merged
/// cross-board front — and returns the timings plus the winner tables.
pub fn cross_board_dse(
    n: u64,
    boards: &crate::board::BoardSpace,
    apps: &[&str],
    workers: usize,
) -> anyhow::Result<CrossBoardLatency> {
    use crate::dse::{board_winner_table, pareto_front_coords, Objective};

    let programs = crate::dse::cross::build_axis_programs(boards, apps, n, 64)?;
    let sweep = crate::dse::cross::sweep_from_programs(boards, &programs);

    let t0 = Instant::now();
    let exhaustive = sweep.explore(Objective::Time, workers);
    let exhaustive_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let pruned = sweep.explore_pruned(Objective::Time, workers);
    let pruned_s = t1.elapsed().as_secs_f64();
    let t2 = Instant::now();
    let global = sweep.explore_pruned_global(Objective::Time, workers);
    let global_s = t2.elapsed().as_secs_f64();

    // Per-board losslessness of the default pruned mode.
    for (e, p) in exhaustive.iter().zip(&pruned) {
        anyhow::ensure!(
            !e.points.is_empty(),
            "{}@{}: empty exhaustive sweep",
            e.app,
            e.board
        );
        anyhow::ensure!(
            e.points[0].est_ms.to_bits() == p.points[0].est_ms.to_bits(),
            "{}@{}: pruned best diverged",
            e.app,
            e.board
        );
        anyhow::ensure!(
            pareto_front_coords(&e.points) == pareto_front_coords(&p.points),
            "{}@{}: pruned per-board Pareto front diverged",
            e.app,
            e.board
        );
    }
    // Global (merged-front) losslessness of the incumbent mode.
    for app in apps {
        let merge = |rs: &[crate::dse::CrossBoardResult]| {
            let mut all: Vec<crate::dse::DsePoint> = Vec::new();
            for r in rs.iter().filter(|r| r.app == *app) {
                all.extend(r.points.iter().cloned());
            }
            all
        };
        anyhow::ensure!(
            pareto_front_coords(&merge(&exhaustive)) == pareto_front_coords(&merge(&global)),
            "{app}: cross-board incumbent broke the merged Pareto front"
        );
    }

    let winners = board_winner_table(&pruned);
    let energy_winners =
        crate::dse::board_winner_table_for(&pruned, crate::dse::BudgetAxis::Energy);
    let area_winners = crate::dse::board_winner_table_for(&pruned, crate::dse::BudgetAxis::Area);
    Ok(CrossBoardLatency {
        workers,
        exhaustive_s,
        pruned_s,
        global_s,
        results: pruned,
        global_results: global,
        winners,
        energy_winners,
        area_winners,
    })
}

/// Fig. 7 — write Paraver bundles for the four matmul configurations the
/// paper visualizes. Returns the written stems.
pub fn fig7(
    n: u64,
    board: &BoardConfig,
    outdir: &std::path::Path,
) -> anyhow::Result<Vec<std::path::PathBuf>> {
    std::fs::create_dir_all(outdir)?;
    let wanted = ["1acc 128", "2acc 64", "2acc 64 + smp", "1acc 128 + smp"];
    let mut stems = Vec::new();
    for (cd, app) in matmul::fig5_cases(n) {
        if !wanted.contains(&cd.name.as_str()) {
            continue;
        }
        let program = app.build_program(board);
        let res = estimate(&program, &cd, board)?;
        let stem = outdir.join(cd.name.replace([' ', '+'], "_"));
        crate::trace::paraver::save_bundle(&program, board, &res, &stem)?;
        stems.push(stem);
    }
    Ok(stems)
}

/// Fig. 8 — DOT export of the cholesky dependency graph (NB blocks).
pub fn fig8(nb: u64, board: &BoardConfig) -> String {
    let app = cholesky::Cholesky::new(nb * 64, 64);
    let program = app.build_program(board);
    let graph = crate::coordinator::deps::DepGraph::build(&program);
    crate::trace::dot::to_dot(&program, &graph)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_trends_match_paper() {
        let board = BoardConfig::zynq706();
        let t = fig5(512, &board, 3).unwrap();
        // Core claims of §VI for matmul:
        // 1. estimator and real execution agree on the best co-design;
        let best = &t.rows[t.best_estimator()].name;
        assert!(t.best_agrees(), "{}", t.render("fig5"));
        // 2. the best co-design is 128x128 blocks on FPGA only;
        assert_eq!(best, "1acc 128", "{}", t.render("fig5"));
        // 3. the slowest is "1acc 128 + smp" (the paper normalizes to it);
        let est_slowest = t
            .rows
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.estimator_ms.partial_cmp(&b.1.estimator_ms).unwrap())
            .unwrap();
        assert_eq!(est_slowest.1.name, "1acc 128 + smp", "{}", t.render("fig5"));
        // 4. trends agree strongly.
        assert!(
            t.trend_agreement() >= 0.7,
            "tau = {}\n{}",
            t.trend_agreement(),
            t.render("fig5")
        );
    }

    #[test]
    fn fig9_trends_match_paper() {
        let board = BoardConfig::zynq706();
        let t = fig9(512, &board, 3).unwrap();
        assert!(t.best_agrees(), "{}", t.render("fig9"));
        // dgemm must be in the winning combination (it dominates the task
        // count); the paper's winner is a two-accelerator dgemm mix.
        let best = &t.rows[t.best_estimator()].name;
        assert!(best.contains("dgemm"), "{}", t.render("fig9"));
        assert!(
            t.trend_agreement() >= 0.7,
            "tau = {}\n{}",
            t.trend_agreement(),
            t.render("fig9")
        );
        // FR-dgemm beats the other FR variants (it offloads the dominant
        // kernel).
        let ms = |name: &str| {
            t.rows
                .iter()
                .find(|r| r.name == name)
                .unwrap()
                .estimator_ms
        };
        assert!(ms("FR-dgemm") < ms("FR-dsyrk"));
        assert!(ms("FR-dgemm") < ms("FR-dtrsm"));
    }

    #[test]
    fn fig3_rows() {
        let board = BoardConfig::zynq706();
        let rows = fig3(&board);
        assert_eq!(rows.len(), 2);
        for (_, est, brd) in rows {
            assert!((est.input_speedup - 2.0).abs() < 1e-9);
            assert!(brd.input_speedup > 1.6 && brd.input_speedup < 2.0);
            assert!((est.output_speedup - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn analysis_time_speedup_over_two_orders() {
        // §VII: "speedups of more than two orders of magnitude (minutes vs
        // days)". Our simulator is much faster than the paper's, so the
        // ratio is even larger; assert the >100x claim.
        let board = BoardConfig::zynq706();
        let (meth, trad) = analysis_time_matmul(512, &board).unwrap();
        assert!(meth > 0.0);
        assert!(trad / meth > 100.0, "speedup = {}", trad / meth);
        assert!(trad > 10.0 * 3600.0, "traditional must be > 10 h");
    }

    #[test]
    fn lu_study_trends_agree() {
        let board = BoardConfig::zynq706();
        let t = lu_study(512, &board, 3).unwrap();
        assert!(t.best_agrees(), "{}", t.render("lu"));
        assert!(t.trend_agreement() >= 0.7, "{}", t.render("lu"));
    }

    #[test]
    fn cross_board_decision_shifts() {
        let rows = cross_board_matmul(512).unwrap();
        assert_eq!(rows.len(), 2);
        let (z7, us) = (&rows[0], &rows[1]);
        assert_eq!(z7.0, "zynq706");
        // On the ZC706 the winner is the single 128 accelerator (2x does
        // not fit); on the UltraScale+ the infeasible-on-ZC706 "2acc 128"
        // wins — the decision is platform-dependent, which is exactly why
        // the estimator must model the platform.
        assert_eq!(z7.1, "1acc 128");
        assert_eq!(us.1, "2acc 128", "us+ winner: {} ({} ms)", us.1, us.2);
        assert!(us.2 < z7.2, "US+ must be faster outright");
    }

    #[test]
    fn cross_board_dse_is_lossless_and_ranks_boards() {
        let boards = crate::board::BoardSpace::resolve(&["zynq702", "zynq706"]).unwrap();
        // The harness itself asserts per-board and merged-front
        // losslessness; here we check the shape of the answer.
        let r = cross_board_dse(256, &boards, &["matmul"], 2).unwrap();
        assert_eq!(r.results.len(), 2);
        assert_eq!(r.winners.len(), 1);
        let (app, rows) = &r.winners[0];
        assert_eq!(app, "matmul");
        assert!(!rows.is_empty());
        // The incumbent mode can only skip more, never evaluate more.
        let ev = |rs: &[crate::dse::CrossBoardResult]| {
            rs.iter().map(|x| x.stats.evaluated).sum::<u64>()
        };
        assert!(ev(&r.global_results) <= ev(&r.results));
    }

    #[test]
    fn dse_sweep_latency_paths_agree() {
        let board = BoardConfig::zynq706();
        let program = matmul::Matmul::new(256, 64).build_program(&board);
        // The harness itself asserts baseline/sweep ranking equality.
        let (base_s, sweep_s, points) = dse_sweep_latency(&program, &board, 2).unwrap();
        assert!(points > 0);
        assert!(base_s > 0.0 && sweep_s > 0.0);
    }

    #[test]
    fn warm_start_latency_round_trips_the_memo() {
        // The harness itself asserts best/front equality across all four
        // orders and the zero-re-evaluation warm contract; here we check
        // the accounting shape.
        let board = BoardConfig::zynq706();
        let r = warm_start_latency(256, &board, 2).unwrap();
        assert_eq!(r.apps.len(), 2);
        for a in &r.apps {
            assert_eq!(a.warm_evaluated, 0, "{a:?}");
            assert!(a.memo_hits > 0, "{a:?}");
            assert!(a.fifo_evaluated > 0, "{a:?}");
            assert!(a.enumerated <= a.feasible, "{a:?}");
        }
    }

    #[test]
    fn warm_perturbed_study_reuses_overlap_exactly() {
        let board = BoardConfig::zynq706();
        let rows = warm_perturbed_study(256, &board, 2).unwrap();
        assert_eq!(rows.len(), 5);
        let identical = &rows[0];
        assert_eq!(identical.label, "identical");
        assert_eq!(identical.warm_evaluated, 0, "{identical:?}");
        assert!(identical.memo_hits > 0);
        // Every perturbed space overlaps the base space somewhere, so the
        // memo must land hits in each of them.
        for r in &rows {
            assert!(r.memo_hits > 0, "{r:?}");
        }
    }

    #[test]
    fn cross_board_budget_tables_cover_all_axes() {
        let boards = crate::board::BoardSpace::resolve(&["zynq702", "zynq706"]).unwrap();
        let r = cross_board_dse(256, &boards, &["matmul"], 2).unwrap();
        assert_eq!(r.energy_winners.len(), 1);
        assert_eq!(r.area_winners.len(), 1);
        assert!(!r.energy_winners[0].1.is_empty());
        assert!(!r.area_winners[0].1.is_empty());
    }

    #[test]
    fn dse_suite_latency_prunes_losslessly() {
        // The harness itself asserts pruned best/front equality and the
        // strictly-fewer-evaluations contract per app.
        let board = BoardConfig::zynq706();
        let r = dse_suite_latency(256, &board, 2).unwrap();
        assert_eq!(r.apps.len(), 4);
        assert!(r.exhaustive_s > 0.0 && r.pruned_s > 0.0);
        let evaluated: u64 = r.apps.iter().map(|a| a.evaluated).sum();
        let feasible: u64 = r.apps.iter().map(|a| a.feasible).sum();
        assert!(evaluated < feasible, "{evaluated} vs {feasible}");
        assert!(r.apps.iter().any(|a| a.bound_cut > 0));
    }

    #[test]
    fn fig8_dot_generates() {
        let board = BoardConfig::zynq706();
        let dot = fig8(4, &board);
        assert!(dot.contains("digraph"));
        assert!(dot.contains("dpotrf"));
    }
}
