//! Task model — the OmpSs-equivalent front-end types.
//!
//! In the paper the programmer annotates C functions with
//! `#pragma omp target device(fpga,smp)` and `#pragma omp task in(...)
//! inout(...)`; Mercurium then emits an instrumented sequential binary whose
//! execution produces the *basic task trace* (§IV): one record per task
//! instance with its name, creation time, SMP cost and dependence list.
//!
//! Here the same information is carried by [`KernelDecl`] (the annotated
//! function: name, allowed targets, workload profile) and [`TaskInstance`]
//! (one dynamic instance: creation timestamp, SMP cycles, dependences).
//! Applications in `apps/` build a [`TaskProgram`] — the moral equivalent of
//! running the instrumented binary.

use std::collections::BTreeMap;

/// Dynamic task instance id (dense, in trace order).
pub type TaskId = u32;
/// Kernel (task type) id — index into [`TaskProgram::kernels`].
pub type KernelId = u16;

/// Dependence direction, as in the OmpSs clauses `in`, `out`, `inout`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dir {
    /// Read (`in` clause).
    In,
    /// Write (`out` clause).
    Out,
    /// Read-modify-write (`inout` clause).
    InOut,
}

impl Dir {
    /// Whether the clause reads (`in` / `inout`).
    pub fn reads(self) -> bool {
        matches!(self, Dir::In | Dir::InOut)
    }
    /// Whether the clause writes (`out` / `inout`).
    pub fn writes(self) -> bool {
        matches!(self, Dir::Out | Dir::InOut)
    }
    /// The OmpSs clause keyword.
    pub fn as_str(self) -> &'static str {
        match self {
            Dir::In => "in",
            Dir::Out => "out",
            Dir::InOut => "inout",
        }
    }
    /// Parse an OmpSs clause keyword.
    pub fn parse(s: &str) -> Option<Dir> {
        match s {
            "in" => Some(Dir::In),
            "out" => Some(Dir::Out),
            "inout" => Some(Dir::InOut),
            _ => None,
        }
    }
}

/// A data dependence: base address + length + direction, exactly the record
/// the paper's instrumented binary emits per dependence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dep {
    /// Base address (the dependence tracker's matching key).
    pub addr: u64,
    /// Length in bytes (transfer accounting only).
    pub len: u64,
    /// Clause direction.
    pub dir: Dir,
}

impl Dep {
    /// An `in` dependence.
    pub fn input(addr: u64, len: u64) -> Self {
        Self { addr, len, dir: Dir::In }
    }
    /// An `out` dependence.
    pub fn output(addr: u64, len: u64) -> Self {
        Self { addr, len, dir: Dir::Out }
    }
    /// An `inout` dependence.
    pub fn inout(addr: u64, len: u64) -> Self {
        Self { addr, len, dir: Dir::InOut }
    }
}

/// Device classes a kernel may be annotated with
/// (`#pragma omp target device(...)`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Targets {
    /// May run on the ARM cores.
    pub smp: bool,
    /// May run on an FPGA accelerator.
    pub fpga: bool,
}

impl Targets {
    /// SMP-only annotation.
    pub const SMP: Targets = Targets { smp: true, fpga: false };
    /// FPGA-only annotation.
    pub const FPGA: Targets = Targets { smp: false, fpga: true };
    /// Heterogeneous annotation (`device(fpga,smp)`).
    pub const BOTH: Targets = Targets { smp: true, fpga: true };
}

/// Workload characterization of a kernel, consumed by the cost models
/// (the analytic stand-ins for `gettimeofday` on the ARM and for the Vivado
/// HLS report on the fabric side).
#[derive(Clone, Debug, PartialEq)]
pub struct KernelProfile {
    /// Total floating-point operations per task instance.
    pub flops: u64,
    /// Iterations of the innermost (pipelined) loop per task instance —
    /// the quantity Vivado HLS's `II × trip` latency estimate hinges on.
    pub inner_trip: u64,
    /// Bytes DMA-transferred *to* the accelerator per instance
    /// (`in` + `inout` footprint).
    pub in_bytes: u64,
    /// Bytes DMA-transferred *from* the accelerator per instance
    /// (`out` + `inout` footprint).
    pub out_bytes: u64,
    /// Element width (4 = single, 8 = double). The paper's cholesky is
    /// double precision; its cost weights are preserved even though the
    /// compiled PJRT artifacts are f32 (see DESIGN.md §1 substitution 3).
    pub dtype_bytes: u8,
    /// Division / sqrt on the critical recurrence path (dtrsm, dpotrf):
    /// lengthens the HLS pipeline II and the ARM per-flop cost.
    pub divsqrt: bool,
}

impl KernelProfile {
    /// Arithmetic intensity in FLOP/byte over the DMA traffic.
    pub fn arith_intensity(&self) -> f64 {
        let bytes = (self.in_bytes + self.out_bytes).max(1);
        self.flops as f64 / bytes as f64
    }
}

/// A task type — the annotated function.
#[derive(Clone, Debug)]
pub struct KernelDecl {
    /// Kernel (function) name.
    pub name: String,
    /// Devices the programmer annotated (`device(fpga,smp)`).
    pub targets: Targets,
    /// Workload characterization for the cost models.
    pub profile: KernelProfile,
}

/// One dynamic task instance — one record of the basic trace (§IV).
#[derive(Clone, Debug)]
pub struct TaskInstance {
    /// Dense instance id, trace order.
    pub id: TaskId,
    /// The instance's kernel.
    pub kernel: KernelId,
    /// Creation timestamp (ns) in the sequential instrumented run. Only the
    /// order matters to the simulator; kept for trace fidelity.
    pub creation_ns: u64,
    /// Elapsed execution cycles on the ARM core in the instrumented run
    /// (or from the SMP cost model when generated synthetically).
    pub smp_cycles: u64,
    /// Dependence clauses of this instance.
    pub deps: Vec<Dep>,
}

/// A full application: kernel table + dynamic task trace, in sequential
/// program order. The moral equivalent of "instrumented binary output".
#[derive(Clone, Debug, Default)]
pub struct TaskProgram {
    /// Application name.
    pub app_name: String,
    /// Kernel (task type) table.
    pub kernels: Vec<KernelDecl>,
    /// Dynamic task instances, sequential program order.
    pub tasks: Vec<TaskInstance>,
}

impl TaskProgram {
    /// An empty program.
    pub fn new(app_name: &str) -> Self {
        Self {
            app_name: app_name.to_string(),
            kernels: Vec::new(),
            tasks: Vec::new(),
        }
    }

    /// Register a kernel declaration, returning its id. Names must be
    /// unique; re-registering a name returns the existing id.
    pub fn add_kernel(&mut self, decl: KernelDecl) -> KernelId {
        if let Some((i, _)) = self
            .kernels
            .iter()
            .enumerate()
            .find(|(_, k)| k.name == decl.name)
        {
            return i as KernelId;
        }
        self.kernels.push(decl);
        (self.kernels.len() - 1) as KernelId
    }

    /// Look up a kernel id by name.
    pub fn kernel_id(&self, name: &str) -> Option<KernelId> {
        self.kernels
            .iter()
            .position(|k| k.name == name)
            .map(|i| i as KernelId)
    }

    /// The declaration behind a kernel id.
    pub fn kernel(&self, id: KernelId) -> &KernelDecl {
        &self.kernels[id as usize]
    }

    /// Append a task instance (id is assigned densely in program order,
    /// creation_ns defaults to the instance index — sequential order).
    pub fn add_task(&mut self, kernel: KernelId, smp_cycles: u64, deps: Vec<Dep>) -> TaskId {
        let id = self.tasks.len() as TaskId;
        self.tasks.push(TaskInstance {
            id,
            kernel,
            creation_ns: id as u64,
            smp_cycles,
            deps,
        });
        id
    }

    /// Count of task instances per kernel name (reporting).
    pub fn instance_histogram(&self) -> BTreeMap<String, usize> {
        let mut h = BTreeMap::new();
        for t in &self.tasks {
            *h.entry(self.kernels[t.kernel as usize].name.clone())
                .or_insert(0) += 1;
        }
        h
    }

    /// Total serial SMP cycles over all tasks (the 1-core lower bound used
    /// to sanity-check simulated makespans).
    pub fn total_smp_cycles(&self) -> u64 {
        self.tasks.iter().map(|t| t.smp_cycles).sum()
    }

    /// Validate internal consistency; returns a list of problems.
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        for (i, t) in self.tasks.iter().enumerate() {
            if t.id as usize != i {
                errs.push(format!("task #{i} has non-dense id {}", t.id));
            }
            if t.kernel as usize >= self.kernels.len() {
                errs.push(format!("task #{i} references unknown kernel {}", t.kernel));
                continue;
            }
            let k = &self.kernels[t.kernel as usize];
            if !k.targets.smp && !k.targets.fpga {
                errs.push(format!("kernel '{}' has no targets", k.name));
            }
            if t.deps.is_empty() {
                errs.push(format!("task #{i} ({}) has no dependences", k.name));
            }
            for d in &t.deps {
                if d.len == 0 {
                    errs.push(format!("task #{i} ({}) has zero-length dep", k.name));
                }
            }
        }
        errs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> KernelProfile {
        KernelProfile {
            flops: 2 * 64 * 64 * 64,
            inner_trip: 64 * 64 * 64,
            in_bytes: 3 * 64 * 64 * 4,
            out_bytes: 64 * 64 * 4,
            dtype_bytes: 4,
            divsqrt: false,
        }
    }

    #[test]
    fn dir_semantics() {
        assert!(Dir::In.reads() && !Dir::In.writes());
        assert!(!Dir::Out.reads() && Dir::Out.writes());
        assert!(Dir::InOut.reads() && Dir::InOut.writes());
        for d in [Dir::In, Dir::Out, Dir::InOut] {
            assert_eq!(Dir::parse(d.as_str()), Some(d));
        }
        assert_eq!(Dir::parse("bogus"), None);
    }

    #[test]
    fn kernel_registration_dedups() {
        let mut p = TaskProgram::new("t");
        let k1 = p.add_kernel(KernelDecl {
            name: "mxm".into(),
            targets: Targets::BOTH,
            profile: profile(),
        });
        let k2 = p.add_kernel(KernelDecl {
            name: "mxm".into(),
            targets: Targets::BOTH,
            profile: profile(),
        });
        assert_eq!(k1, k2);
        assert_eq!(p.kernels.len(), 1);
        assert_eq!(p.kernel_id("mxm"), Some(k1));
        assert_eq!(p.kernel_id("nope"), None);
    }

    #[test]
    fn task_ids_dense_and_ordered() {
        let mut p = TaskProgram::new("t");
        let k = p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::SMP,
            profile: profile(),
        });
        for i in 0..10 {
            let id = p.add_task(k, 100, vec![Dep::inout(0x1000, 64)]);
            assert_eq!(id, i);
        }
        assert!(p.validate().is_empty());
        assert_eq!(p.total_smp_cycles(), 1000);
    }

    #[test]
    fn validate_catches_problems() {
        let mut p = TaskProgram::new("t");
        let k = p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets { smp: false, fpga: false },
            profile: profile(),
        });
        p.add_task(k, 1, vec![]);
        p.add_task(k, 1, vec![Dep::input(0x0, 0)]);
        let errs = p.validate();
        assert!(errs.iter().any(|e| e.contains("no targets")));
        assert!(errs.iter().any(|e| e.contains("no dependences")));
        assert!(errs.iter().any(|e| e.contains("zero-length")));
    }

    #[test]
    fn arith_intensity() {
        let p = profile();
        let ai = p.arith_intensity();
        // 524288 flops / (49152 in + 16384 out) bytes = 8
        assert!((ai - 8.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_counts() {
        let mut p = TaskProgram::new("t");
        let a = p.add_kernel(KernelDecl {
            name: "a".into(),
            targets: Targets::SMP,
            profile: profile(),
        });
        let b = p.add_kernel(KernelDecl {
            name: "b".into(),
            targets: Targets::SMP,
            profile: profile(),
        });
        p.add_task(a, 1, vec![Dep::inout(0, 4)]);
        p.add_task(a, 1, vec![Dep::inout(0, 4)]);
        p.add_task(b, 1, vec![Dep::inout(4, 4)]);
        let h = p.instance_histogram();
        assert_eq!(h["a"], 2);
        assert_eq!(h["b"], 1);
    }
}
