//! Simulated time: picosecond-resolution timestamps and clock domains.
//!
//! The Zynq APSoC has (at least) three relevant clock domains — the ARM
//! Cortex-A9 PS clock (667 MHz on the Z-7045/ZC706), the programmable-logic
//! fabric clock produced by Vivado HLS (100–150 MHz for the paper's
//! generation), and the DMA/AXI interconnect. Mixing "cycles" across domains
//! is the classic source of estimator bugs, so all engine time is carried in
//! integer **picoseconds** and converted at the edges.

/// Simulated time in picoseconds. u64 covers ~213 days of simulated time.
pub type Ps = u64;

/// Picoseconds per nanosecond.
pub const PS_PER_NS: u64 = 1_000;
/// Picoseconds per microsecond.
pub const PS_PER_US: u64 = 1_000_000;
/// Picoseconds per millisecond.
pub const PS_PER_MS: u64 = 1_000_000_000;
/// Picoseconds per second.
pub const PS_PER_S: u64 = 1_000_000_000_000;

/// A clock domain with a frequency in MHz.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Clock {
    /// Frequency in MHz.
    pub freq_mhz: f64,
}

impl Clock {
    /// A clock domain at `freq_mhz` (must be positive).
    pub fn new(freq_mhz: f64) -> Self {
        assert!(freq_mhz > 0.0, "clock frequency must be positive");
        Self { freq_mhz }
    }

    /// Clock period in picoseconds (fractional; callers round per-interval,
    /// not per-cycle, to keep error bounded).
    #[inline]
    pub fn period_ps(&self) -> f64 {
        1e6 / self.freq_mhz
    }

    /// Convert a cycle count in this domain to picoseconds (rounded to the
    /// nearest ps over the whole interval).
    #[inline]
    pub fn cycles_to_ps(&self, cycles: u64) -> Ps {
        (cycles as f64 * self.period_ps()).round() as Ps
    }

    /// Convert picoseconds to cycles in this domain (ceiling: an interval
    /// occupies the cycle it ends in).
    #[inline]
    pub fn ps_to_cycles(&self, ps: Ps) -> u64 {
        (ps as f64 / self.period_ps()).ceil() as u64
    }
}

/// Convert microseconds (f64, used by config files) to picoseconds.
#[inline]
pub fn us_to_ps(us: f64) -> Ps {
    (us * PS_PER_US as f64).round() as Ps
}

/// Convert picoseconds to fractional milliseconds (reporting).
#[inline]
pub fn ps_to_ms(ps: Ps) -> f64 {
    ps as f64 / PS_PER_MS as f64
}

/// Convert picoseconds to fractional microseconds (reporting).
#[inline]
pub fn ps_to_us(ps: Ps) -> f64 {
    ps as f64 / PS_PER_US as f64
}

/// Time to move `bytes` at `mb_per_s` (decimal MB/s, the unit DMA and AXI
/// bandwidths are quoted in), in picoseconds.
#[inline]
pub fn transfer_ps(bytes: u64, mb_per_s: f64) -> Ps {
    assert!(mb_per_s > 0.0);
    (bytes as f64 / (mb_per_s * 1e6) * PS_PER_S as f64).round() as Ps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_period() {
        let c = Clock::new(100.0);
        assert_eq!(c.period_ps(), 10_000.0); // 100 MHz = 10 ns
        assert_eq!(c.cycles_to_ps(1_000), 10_000_000); // 1000 cycles = 10 us
    }

    #[test]
    fn arm_clock_rounding_is_bounded() {
        // 667 MHz has a non-integer ps period (1499.25 ps); converting a
        // large interval at once keeps the rounding error < 1 ps total.
        let c = Clock::new(667.0);
        let ps = c.cycles_to_ps(667_000_000); // 1 s worth of cycles
        assert!((ps as i64 - PS_PER_S as i64).abs() <= 1);
    }

    #[test]
    fn cycles_roundtrip() {
        let c = Clock::new(125.0);
        for cycles in [0u64, 1, 7, 1000, 123_456_789] {
            let ps = c.cycles_to_ps(cycles);
            assert_eq!(c.ps_to_cycles(ps), cycles);
        }
    }

    #[test]
    fn transfer_time() {
        // 1 MB at 400 MB/s = 2.5 ms
        assert_eq!(transfer_ps(1_000_000, 400.0), 2_500 * PS_PER_US);
        // 0 bytes is instantaneous
        assert_eq!(transfer_ps(0, 400.0), 0);
    }

    #[test]
    fn unit_helpers() {
        assert_eq!(us_to_ps(1.5), 1_500_000);
        assert_eq!(ps_to_ms(PS_PER_MS), 1.0);
        assert_eq!(ps_to_us(PS_PER_US * 3), 3.0);
    }

    #[test]
    #[should_panic]
    fn zero_clock_panics() {
        Clock::new(0.0);
    }
}
