//! Dependence tracking — the Nanos++-runtime-equivalent substrate.
//!
//! OmpSs computes task dependences at run time from the `in`/`out`/`inout`
//! clause addresses: a reader depends on the last writer of the address, a
//! writer additionally waits for every reader since that writer (OmpSs does
//! not rename storage, so WAR/WAW serialize). Matching is by *base address*,
//! as in the paper's trace records and the Nanos++ implementation of that
//! era; lengths are carried for transfer-size accounting, not for overlap
//! analysis.
//!
//! `build` runs in O(tasks + edges) amortized via an address → (last writer,
//! readers-since) map, the same structure Nanos++ keeps per dependence
//! address.

use std::collections::HashMap;

use crate::util::fxhash::FxHashMap;

use super::task::{TaskId, TaskProgram};

/// The task DAG implied by the program's sequential dependence declarations.
#[derive(Clone, Debug, Default)]
pub struct DepGraph {
    /// Predecessors of each task (deduplicated, ascending).
    pub preds: Vec<Vec<TaskId>>,
    /// Successors of each task (deduplicated, ascending).
    pub succs: Vec<Vec<TaskId>>,
}

#[derive(Default)]
struct AddrState {
    last_writer: Option<TaskId>,
    readers_since_write: Vec<TaskId>,
}

impl DepGraph {
    /// Build the DAG from a program's trace in sequential order.
    pub fn build(program: &TaskProgram) -> Self {
        let n = program.tasks.len();
        let mut preds: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        let mut state: FxHashMap<u64, AddrState> = FxHashMap::default();

        for t in &program.tasks {
            let tid = t.id;
            for d in &t.deps {
                let st = state.entry(d.addr).or_default();
                if d.dir.reads() {
                    if let Some(w) = st.last_writer {
                        preds[tid as usize].push(w);
                    }
                }
                if d.dir.writes() {
                    // WAR: wait for all readers since the last write.
                    for &r in &st.readers_since_write {
                        if r != tid {
                            preds[tid as usize].push(r);
                        }
                    }
                    // WAW: wait for the previous writer (covered already if
                    // this task also reads, but push and dedup below).
                    if let Some(w) = st.last_writer {
                        preds[tid as usize].push(w);
                    }
                }
                // Update the address state *after* computing edges so a
                // task never depends on itself through a single clause.
                if d.dir.writes() {
                    st.last_writer = Some(tid);
                    st.readers_since_write.clear();
                }
                if d.dir.reads() {
                    st.readers_since_write.push(tid);
                }
            }
            let p = &mut preds[tid as usize];
            p.sort_unstable();
            p.dedup();
            p.retain(|&x| x != tid);
        }

        let mut succs: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for (tid, ps) in preds.iter().enumerate() {
            for &p in ps {
                succs[p as usize].push(tid as TaskId);
            }
        }
        for s in &mut succs {
            s.sort_unstable();
            s.dedup();
        }
        DepGraph { preds, succs }
    }

    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.preds.len()
    }

    /// Whether the graph has no tasks.
    pub fn is_empty(&self) -> bool {
        self.preds.is_empty()
    }

    /// Total dependence edges.
    pub fn edge_count(&self) -> usize {
        self.preds.iter().map(|p| p.len()).sum()
    }

    /// Source tasks (no predecessors).
    pub fn roots(&self) -> Vec<TaskId> {
        (0..self.len() as TaskId)
            .filter(|&t| self.preds[t as usize].is_empty())
            .collect()
    }

    /// Verify the DAG is consistent with sequential order: every edge goes
    /// from a lower id to a higher id (trace order is a topological order).
    pub fn respects_program_order(&self) -> bool {
        self.preds
            .iter()
            .enumerate()
            .all(|(t, ps)| ps.iter().all(|&p| (p as usize) < t))
    }

    /// Critical-path length under per-task weights: the absolute lower
    /// bound on makespan with unlimited resources. O(V + E) because trace
    /// order is topological.
    pub fn critical_path(&self, weight: &dyn Fn(TaskId) -> u64) -> u64 {
        let n = self.len();
        let mut finish = vec![0u64; n];
        let mut best = 0u64;
        for t in 0..n {
            let start = self.preds[t]
                .iter()
                .map(|&p| finish[p as usize])
                .max()
                .unwrap_or(0);
            finish[t] = start + weight(t as TaskId);
            best = best.max(finish[t]);
        }
        best
    }

    /// Number of tasks on the longest chain (unit weights).
    pub fn depth(&self) -> u64 {
        self.critical_path(&|_| 1)
    }

    /// Maximum width of the DAG: an upper bound estimate of exploitable
    /// parallelism, computed as the largest antichain layer by longest-path
    /// level (exact for level-structured graphs like blocked matmul).
    pub fn max_level_width(&self) -> usize {
        let n = self.len();
        let mut level = vec![0usize; n];
        let mut width: HashMap<usize, usize> = HashMap::new();
        for t in 0..n {
            let l = self.preds[t]
                .iter()
                .map(|&p| level[p as usize] + 1)
                .max()
                .unwrap_or(0);
            level[t] = l;
            *width.entry(l).or_insert(0) += 1;
        }
        width.values().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Dep, KernelDecl, KernelProfile, Targets};

    fn prog() -> TaskProgram {
        let mut p = TaskProgram::new("t");
        p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::SMP,
            profile: KernelProfile {
                flops: 1,
                inner_trip: 1,
                in_bytes: 4,
                out_bytes: 4,
                dtype_bytes: 4,
                divsqrt: false,
            },
        });
        p
    }

    #[test]
    fn raw_dependence() {
        let mut p = prog();
        p.add_task(0, 1, vec![Dep::output(0x100, 4)]); // t0 writes
        p.add_task(0, 1, vec![Dep::input(0x100, 4)]); // t1 reads
        let g = DepGraph::build(&p);
        assert_eq!(g.preds[1], vec![0]);
        assert_eq!(g.succs[0], vec![1]);
        assert_eq!(g.roots(), vec![0]);
    }

    #[test]
    fn war_and_waw_serialize() {
        let mut p = prog();
        p.add_task(0, 1, vec![Dep::output(0x100, 4)]); // t0 W
        p.add_task(0, 1, vec![Dep::input(0x100, 4)]); // t1 R
        p.add_task(0, 1, vec![Dep::input(0x100, 4)]); // t2 R
        p.add_task(0, 1, vec![Dep::output(0x100, 4)]); // t3 W: waits t1,t2 (WAR) + t0 (WAW)
        let g = DepGraph::build(&p);
        assert_eq!(g.preds[3], vec![0, 1, 2]);
        // t1, t2 are independent of each other (concurrent readers)
        assert!(g.preds[2].is_empty() || g.preds[2] == vec![0]);
        assert_eq!(g.preds[1], vec![0]);
        assert_eq!(g.preds[2], vec![0]);
    }

    #[test]
    fn inout_chain_serializes() {
        let mut p = prog();
        for _ in 0..5 {
            p.add_task(0, 1, vec![Dep::inout(0x200, 4)]);
        }
        let g = DepGraph::build(&p);
        for t in 1..5usize {
            assert_eq!(g.preds[t], vec![(t - 1) as TaskId]);
        }
        assert_eq!(g.depth(), 5);
        assert_eq!(g.max_level_width(), 1);
    }

    #[test]
    fn independent_addresses_are_parallel() {
        let mut p = prog();
        for i in 0..8u64 {
            p.add_task(0, 1, vec![Dep::inout(0x1000 + i * 64, 64)]);
        }
        let g = DepGraph::build(&p);
        assert_eq!(g.edge_count(), 0);
        assert_eq!(g.depth(), 1);
        assert_eq!(g.max_level_width(), 8);
    }

    #[test]
    fn matmul_accumulation_pattern() {
        // C[i,j] accumulated over k: tasks on the same C block serialize,
        // different C blocks run in parallel.
        let mut p = prog();
        let nb = 3u64;
        for k in 0..nb {
            for i in 0..nb {
                for j in 0..nb {
                    let a = 0x10_000 + (i * nb + k) * 64;
                    let b = 0x20_000 + (k * nb + j) * 64;
                    let c = 0x30_000 + (i * nb + j) * 64;
                    p.add_task(
                        0,
                        1,
                        vec![Dep::input(a, 64), Dep::input(b, 64), Dep::inout(c, 64)],
                    );
                }
            }
        }
        let g = DepGraph::build(&p);
        assert!(g.respects_program_order());
        // Depth = nb (accumulation chain per C block)
        assert_eq!(g.depth(), nb as u64);
        // Width >= nb*nb (all C blocks of one k-slice in parallel)
        assert!(g.max_level_width() >= (nb * nb) as usize);
    }

    #[test]
    fn critical_path_weighted() {
        let mut p = prog();
        p.add_task(0, 1, vec![Dep::output(0x1, 4)]);
        p.add_task(0, 1, vec![Dep::input(0x1, 4), Dep::output(0x2, 4)]);
        p.add_task(0, 1, vec![Dep::input(0x2, 4)]);
        p.add_task(0, 1, vec![Dep::inout(0x99, 4)]); // independent
        let g = DepGraph::build(&p);
        let w: Vec<u64> = vec![10, 20, 30, 5];
        assert_eq!(g.critical_path(&|t| w[t as usize]), 60);
    }

    #[test]
    fn self_dependence_never_created() {
        let mut p = prog();
        // A task that reads and writes the same address through two clauses.
        p.add_task(0, 1, vec![Dep::input(0x5, 4), Dep::output(0x5, 4)]);
        let g = DepGraph::build(&p);
        assert!(g.preds[0].is_empty());
    }
}
