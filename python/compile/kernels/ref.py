"""Pure-jnp oracles for every Layer-1 kernel.

These are the correctness references the Pallas kernels are validated
against at build time (pytest + hypothesis). They are *not* lowered into
artifacts; only the `kernels/*.py` implementations are.

Tile conventions (row-major, square BS x BS, f32 unless stated):
  * mxm_block:   C' = A @ B + C          (the paper's mxmBlock, Fig. 1)
  * gemm_tile:   C' = C - A @ B^T        (cholesky trailing update)
  * syrk_tile:   C' = C - A @ A^T        (cholesky diagonal update)
  * trsm_tile:   B' = B @ L^-T           (right solve against the lower
                                          factor's transpose)
  * potrf_tile:  L  = cholesky(A)        (lower factor)
  * jacobi_tile: O  = (C + N + S + W + E) / 5   (5-point blocked stencil)

The paper's cholesky kernels are double precision; the compiled artifacts
are f32 (MXU-friendly; see DESIGN.md section 1, substitution 3) and the
oracles follow the artifact dtype.
"""

import jax.numpy as jnp
import jax.scipy.linalg as jsl


def mxm_block(a, b, c):
    """The paper's mxmBlock: C += A @ B."""
    return a @ b + c


def gemm_tile(a, b, c):
    """Cholesky trailing-panel update: C -= A @ B^T."""
    return c - a @ b.T


def syrk_tile(a, c):
    """Cholesky diagonal update: C -= A @ A^T."""
    return c - a @ a.T


def trsm_tile(l, b):
    """Triangular solve B := B L^-T (right side, lower, transposed)."""
    # Solve X L^T = B  <=>  L X^T = B^T.
    x_t = jsl.solve_triangular(l, b.T, lower=True)
    return x_t.T


def potrf_tile(a):
    """Lower Cholesky factor of an SPD tile."""
    return jnp.linalg.cholesky(a)


def jacobi_tile(c, n, s, w, e):
    """Blocked 5-point Jacobi sweep body (tile-granular approximation)."""
    return (c + n + s + w + e) / 5.0


def make_spd(x, eps=1e-3):
    """Turn an arbitrary square tile into a well-conditioned SPD matrix."""
    n = x.shape[0]
    return x @ x.T + (n + eps) * jnp.eye(n, dtype=x.dtype)


def blocked_matmul(a, b, bs):
    """Full blocked matmul reference (the paper's Fig. 1 driver)."""
    n = a.shape[0]
    assert n % bs == 0
    nb = n // bs
    c = jnp.zeros_like(a)
    for k in range(nb):
        for i in range(nb):
            for j in range(nb):
                ai = a[i * bs:(i + 1) * bs, k * bs:(k + 1) * bs]
                bj = b[k * bs:(k + 1) * bs, j * bs:(j + 1) * bs]
                cij = c[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs]
                c = c.at[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs].set(
                    mxm_block(ai, bj, cij)
                )
    return c
