//! PJRT runtime — loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from Rust. Python never runs
//! on this path: the artifacts are plain HLO *text* (see
//! /opt/xla-example/README.md — serialized protos from jax >= 0.5 are
//! rejected by xla_extension 0.5.1), compiled once per process by the PJRT
//! CPU client and cached.
//!
//! The end-to-end example (`examples/e2e_matmul.rs`) uses this to actually
//! *execute* the application whose schedule the estimator predicted —
//! numerically validating the kernels while the simulator supplies the
//! Zynq timing.
//!
//! The backend is gated behind the `pjrt` cargo feature, wired as an
//! optional path dependency on `vendor/xla`. That directory ships as an
//! API-compatible **placeholder** crate, so `cargo build --features pjrt`
//! resolves and compiles from a clean checkout: against the placeholder,
//! [`Runtime::new`] fails at run time with a message pointing at the
//! vendoring story (drop the real `xla_extension` bindings over
//! `vendor/xla/` to enable actual execution — see README.md). Without the
//! feature this module instead exposes its own API-compatible [`Runtime`]
//! stub with the same clean degradation, so the CLI `measure` command,
//! the e2e example and the integration tests never fail to build either
//! way.

pub mod executor;

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use anyhow::{anyhow, Result};

    /// A compiled kernel executable with its I/O contract.
    pub struct KernelExe {
        /// Artifact stem.
        pub name: String,
        exe: xla::PjRtLoadedExecutable,
        /// Expected input ranks/sizes, purely informational.
        pub path: PathBuf,
    }

    /// Registry of compiled kernels, keyed by artifact stem
    /// (`artifacts/mxm64.hlo.txt` → `"mxm64"`). Compilation happens once per
    /// kernel; execution is thread-safe behind the client.
    pub struct Runtime {
        client: xla::PjRtClient,
        kernels: Mutex<HashMap<String, KernelExe>>,
        artifacts_dir: PathBuf,
    }

    impl Runtime {
        /// Create a CPU PJRT runtime rooted at an artifacts directory.
        pub fn new(artifacts_dir: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
            Ok(Self {
                client,
                kernels: Mutex::new(HashMap::new()),
                artifacts_dir: artifacts_dir.to_path_buf(),
            })
        }

        /// PJRT platform name (e.g. `cpu`).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// List artifact stems available on disk.
        pub fn available(&self) -> Vec<String> {
            let mut v = Vec::new();
            if let Ok(dir) = std::fs::read_dir(&self.artifacts_dir) {
                for e in dir.flatten() {
                    let name = e.file_name().to_string_lossy().to_string();
                    if let Some(stem) = name.strip_suffix(".hlo.txt") {
                        v.push(stem.to_string());
                    }
                }
            }
            v.sort();
            v
        }

        /// Load + compile a kernel (no-op if already compiled).
        pub fn load(&self, name: &str) -> Result<()> {
            let mut kernels = self.kernels.lock().unwrap();
            if kernels.contains_key(name) {
                return Ok(());
            }
            let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            kernels.insert(
                name.to_string(),
                KernelExe {
                    name: name.to_string(),
                    exe,
                    path,
                },
            );
            Ok(())
        }

        /// Execute a kernel on f32 input buffers (each a flattened `[n, n]`
        /// tile). Returns the first output, flattened. The artifacts are
        /// lowered with `return_tuple=True`, so the result is a 1-tuple.
        pub fn run_f32(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            self.load(name)?;
            let kernels = self.kernels.lock().unwrap();
            let k = kernels
                .get(name)
                .ok_or_else(|| anyhow!("kernel '{name}' not loaded"))?;
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let lit = xla::Literal::vec1(data)
                    .reshape(dims)
                    .map_err(|e| anyhow!("reshape input: {e:?}"))?;
                literals.push(lit);
            }
            let result = k
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?;
            let out = result
                .to_tuple1()
                .map_err(|e| anyhow!("untuple: {e:?}"))?;
            out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
        }

        /// Convenience: square-tile matmul-accumulate artifact
        /// `c' = a @ b + c` over `[bs, bs]` f32 tiles.
        pub fn run_mxm(
            &self,
            name: &str,
            bs: usize,
            a: &[f32],
            b: &[f32],
            c: &[f32],
        ) -> Result<Vec<f32>> {
            let dims = [bs as i64, bs as i64];
            anyhow::ensure!(
                a.len() == bs * bs && b.len() == bs * bs && c.len() == bs * bs,
                "tile size mismatch"
            );
            self.run_f32(name, &[(a, &dims), (b, &dims), (c, &dims)])
        }

        /// Wall-clock one kernel execution (min over `reps`, milliseconds).
        /// This is the repository's analogue of the paper's gettimeofday
        /// instrumentation: `trace --measure` uses the *measured ratios*
        /// between kernels instead of the analytic SMP model, so the basic
        /// trace carries empirical relative costs exactly as an instrumented
        /// sequential run would.
        pub fn time_kernel_ms(
            &self,
            name: &str,
            bs: usize,
            n_inputs: usize,
            reps: u32,
        ) -> Result<f64> {
            self.load(name)?;
            let dims = [bs as i64, bs as i64];
            let tile: Vec<f32> = (0..bs * bs).map(|i| (i % 97) as f32 * 0.013).collect();
            let inputs: Vec<(&[f32], &[i64])> =
                (0..n_inputs).map(|_| (tile.as_slice(), &dims[..])).collect();
            // Warm-up (compile caches, allocator).
            self.run_f32(name, &inputs)?;
            let mut best = f64::INFINITY;
            for _ in 0..reps.max(1) {
                let t = std::time::Instant::now();
                self.run_f32(name, &inputs)?;
                best = best.min(t.elapsed().as_secs_f64() * 1e3);
            }
            Ok(best)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_backend::{KernelExe, Runtime};

#[cfg(not(feature = "pjrt"))]
mod pjrt_stub {
    use std::path::Path;

    use anyhow::{anyhow, Result};

    fn unavailable() -> anyhow::Error {
        anyhow!(
            "PJRT runtime unavailable: built without the `pjrt` feature \
             (the vendored `xla` crate is not in this build)"
        )
    }

    /// API-compatible stand-in used when the `pjrt` feature is off: every
    /// entry point reports the missing backend instead of failing to link.
    pub struct Runtime;

    impl Runtime {
        /// Always fails: the backend is not compiled in.
        pub fn new(_artifacts_dir: &Path) -> Result<Self> {
            Err(unavailable())
        }

        /// A placeholder platform name.
        pub fn platform(&self) -> String {
            "pjrt-unavailable".to_string()
        }

        /// Always empty (no artifacts without a backend).
        pub fn available(&self) -> Vec<String> {
            Vec::new()
        }

        /// Always fails: the backend is not compiled in.
        pub fn load(&self, _name: &str) -> Result<()> {
            Err(unavailable())
        }

        /// Always fails: the backend is not compiled in.
        pub fn run_f32(&self, _name: &str, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            Err(unavailable())
        }

        /// Always fails: the backend is not compiled in.
        pub fn run_mxm(
            &self,
            _name: &str,
            _bs: usize,
            _a: &[f32],
            _b: &[f32],
            _c: &[f32],
        ) -> Result<Vec<f32>> {
            Err(unavailable())
        }

        /// Always fails: the backend is not compiled in.
        pub fn time_kernel_ms(
            &self,
            _name: &str,
            _bs: usize,
            _n_inputs: usize,
            _reps: u32,
        ) -> Result<f64> {
            Err(unavailable())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::Runtime;

/// Pure-Rust reference implementations used to validate PJRT outputs in
/// the e2e example and tests.
pub mod reference {
    /// `c += a @ b` on `bs×bs` row-major f32 tiles.
    pub fn mxm_block(bs: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        for i in 0..bs {
            for k in 0..bs {
                let av = a[i * bs + k];
                for j in 0..bs {
                    c[i * bs + j] += av * b[k * bs + j];
                }
            }
        }
    }

    /// Full blocked matmul driver mirroring the paper's Fig. 1 loop nest.
    pub fn blocked_matmul(n: usize, bs: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        let nb = n / bs;
        let mut ta = vec![0f32; bs * bs];
        let mut tb = vec![0f32; bs * bs];
        let mut tc = vec![0f32; bs * bs];
        for k in 0..nb {
            for i in 0..nb {
                for j in 0..nb {
                    copy_tile(n, bs, a, i, k, &mut ta);
                    copy_tile(n, bs, b, k, j, &mut tb);
                    copy_tile(n, bs, c, i, j, &mut tc);
                    mxm_block(bs, &ta, &tb, &mut tc);
                    paste_tile(n, bs, c, i, j, &tc);
                }
            }
        }
    }

    /// Copy block `(bi, bj)` of an `n`×`n` row-major matrix into a tile.
    pub fn copy_tile(n: usize, bs: usize, m: &[f32], bi: usize, bj: usize, tile: &mut [f32]) {
        for r in 0..bs {
            let src = (bi * bs + r) * n + bj * bs;
            tile[r * bs..(r + 1) * bs].copy_from_slice(&m[src..src + bs]);
        }
    }

    /// Write a tile back into block `(bi, bj)` of an `n`×`n` matrix.
    pub fn paste_tile(n: usize, bs: usize, m: &mut [f32], bi: usize, bj: usize, tile: &[f32]) {
        for r in 0..bs {
            let dst = (bi * bs + r) * n + bj * bs;
            m[dst..dst + bs].copy_from_slice(&tile[r * bs..(r + 1) * bs]);
        }
    }

    /// Max absolute difference.
    pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::reference::*;

    #[test]
    fn reference_mxm_block() {
        // 2x2: [[1,2],[3,4]] @ [[1,1],[1,1]] + 0 = [[3,3],[7,7]]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [1.0, 1.0, 1.0, 1.0];
        let mut c = [0.0; 4];
        mxm_block(2, &a, &b, &mut c);
        assert_eq!(c, [3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn blocked_matches_flat() {
        let n = 8;
        let bs = 4;
        let a: Vec<f32> = (0..n * n).map(|i| (i % 7) as f32 * 0.5).collect();
        let b: Vec<f32> = (0..n * n).map(|i| (i % 5) as f32 - 2.0).collect();
        let mut c_blocked = vec![0f32; n * n];
        blocked_matmul(n, bs, &a, &b, &mut c_blocked);
        // Flat reference.
        let mut c_flat = vec![0f32; n * n];
        for i in 0..n {
            for k in 0..n {
                for j in 0..n {
                    c_flat[i * n + j] += a[i * n + k] * b[k * n + j];
                }
            }
        }
        assert!(max_abs_diff(&c_blocked, &c_flat) < 1e-4);
    }

    #[test]
    fn tile_copy_paste_roundtrip() {
        let n = 8;
        let bs = 4;
        let m: Vec<f32> = (0..n * n).map(|i| i as f32).collect();
        let mut tile = vec![0f32; bs * bs];
        copy_tile(n, bs, &m, 1, 1, &mut tile);
        let mut m2 = m.clone();
        paste_tile(n, bs, &mut m2, 1, 1, &tile);
        assert_eq!(m, m2);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_backend() {
        let err = super::Runtime::new(std::path::Path::new("artifacts")).err().unwrap();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
