//! Pruning-soundness properties: on randomized small spaces, the pruned
//! sweep (`SweepContext::explore_pruned`) must return the same best point
//! and the same time-energy Pareto front as the exhaustive sweep
//! (`SweepContext::explore`) while never evaluating more points — the
//! losslessness contract of `dse::prune`. Uses the repository's seeded
//! forall harness (no external proptest crate), same style as
//! `proptests.rs`.

use zynq_estimator::apps::{cholesky::Cholesky, matmul::Matmul};
use zynq_estimator::config::BoardConfig;
use zynq_estimator::coordinator::task::{
    Dep, KernelDecl, KernelProfile, TaskProgram, Targets,
};
use zynq_estimator::dse::{
    pareto_front_coords as front_coords, DseSpace, KernelSpace, Objective, SweepContext,
};
use zynq_estimator::hls::FpgaPart;
use zynq_estimator::util::Rng;

fn forall(iters: u64, base_seed: u64, f: impl Fn(u64, &mut Rng)) {
    for i in 0..iters {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        f(seed, &mut rng);
    }
}

/// Randomize a space over a program's FPGA-capable kernels: random unroll
/// subsets (including factors past pipeline saturation for small trip
/// counts, which is what arms the dominance cut), 1-2 instances, random
/// "+ smp" consideration, and a random mixed-variant flag (heterogeneous
/// per-instance unrolls — the combinatorial regime the cuts are
/// stress-tested against).
fn random_space(rng: &mut Rng, program: &TaskProgram) -> DseSpace {
    let pool = [4u32, 8, 16, 32, 64, 128];
    let kernels = program
        .kernels
        .iter()
        .filter(|k| k.targets.fpga)
        .map(|k| {
            let n_unrolls = rng.gen_range(2, 5) as usize;
            let mut unrolls: Vec<u32> = Vec::new();
            while unrolls.len() < n_unrolls {
                let u = pool[rng.gen_range(0, pool.len() as u64) as usize];
                if !unrolls.contains(&u) {
                    unrolls.push(u);
                }
            }
            KernelSpace {
                kernel: k.name.clone(),
                unrolls,
                max_instances: rng.gen_range(1, 3) as u32,
                try_smp: k.targets.smp && rng.next_f64() < 0.5,
            }
        })
        .collect();
    DseSpace {
        kernels,
        mixed: rng.next_f64() < 0.4,
    }
}

/// A synthetic program whose kernels have small pipelined trip counts, so
/// unrolls beyond saturation are strictly dominated (more cycles, more
/// area) — the regime the dominance cut exists for.
fn tiny_trip_program(rng: &mut Rng) -> TaskProgram {
    let mut p = TaskProgram::new("tiny");
    let n_kernels = rng.gen_range(1, 3);
    for k in 0..n_kernels {
        p.add_kernel(KernelDecl {
            name: format!("t{k}"),
            targets: if rng.next_f64() < 0.5 {
                Targets::BOTH
            } else {
                Targets::FPGA
            },
            profile: KernelProfile {
                flops: rng.gen_range(100, 2_000),
                inner_trip: rng.gen_range(20, 120),
                in_bytes: rng.gen_range(2_048, 32_768),
                out_bytes: rng.gen_range(1_024, 16_384),
                dtype_bytes: 4,
                divsqrt: false,
            },
        });
    }
    let n_tasks = rng.gen_range(4, 25);
    for i in 0..n_tasks {
        let kernel = rng.gen_range(0, n_kernels) as u16;
        p.add_task(
            kernel,
            rng.gen_range(10_000, 500_000),
            vec![Dep::inout(0x1000 + (i % 6) * 0x1000, 4_096)],
        );
    }
    p
}

fn check_lossless(
    seed: u64,
    ctx: &SweepContext<'_>,
    space: &DseSpace,
    objective: Objective,
) {
    let exhaustive = ctx.explore(space, objective, 2);
    let (pruned, stats) = ctx.explore_pruned(space, objective, 2);
    assert_eq!(
        stats.evaluated as usize,
        pruned.len(),
        "seed {seed}: stats disagree with results"
    );
    assert!(
        stats.evaluated <= stats.feasible_points,
        "seed {seed}: {stats:?}"
    );
    assert_eq!(
        stats.feasible_points as usize,
        ctx.enumerate(space).len(),
        "seed {seed}: feasible accounting"
    );
    if exhaustive.is_empty() {
        assert!(pruned.is_empty(), "seed {seed}");
        return;
    }
    assert!(!pruned.is_empty(), "seed {seed}: pruned away everything");
    assert_eq!(
        exhaustive[0].score(objective).to_bits(),
        pruned[0].score(objective).to_bits(),
        "seed {seed}: best point diverged ({} vs {})",
        exhaustive[0].codesign.name,
        pruned[0].codesign.name
    );
    assert_eq!(
        front_coords(&exhaustive),
        front_coords(&pruned),
        "seed {seed}: Pareto front diverged"
    );
}

#[test]
fn prop_pruned_sweep_lossless_on_app_spaces() {
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let matmul = Matmul::new(256, 64).build_program(&board);
    let cholesky = Cholesky::new(192, 64).build_program(&board);
    let objectives = [Objective::Time, Objective::Energy, Objective::Edp];
    forall(8, 0x5C07, |seed, rng| {
        for program in [&matmul, &cholesky] {
            let space = random_space(rng, program);
            let ctx = SweepContext::for_space(program, &board, &part, &space);
            let objective = objectives[(seed % 3) as usize];
            check_lossless(seed, &ctx, &space, objective);
        }
    });
}

#[test]
fn prop_pruned_sweep_lossless_with_dominated_variants() {
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let objectives = [Objective::Time, Objective::Energy, Objective::Edp];
    forall(10, 0xD0_17, |seed, rng| {
        let program = tiny_trip_program(rng);
        let space = random_space(rng, &program);
        let ctx = SweepContext::for_space(&program, &board, &part, &space);
        let objective = objectives[(seed % 3) as usize];
        check_lossless(seed, &ctx, &space, objective);
    });
}

#[test]
fn prop_pruned_sweep_deterministic_across_worker_counts() {
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Cholesky::new(256, 64).build_program(&board);
    let space = DseSpace::from_program(&program);
    let ctx = SweepContext::for_space(&program, &board, &part, &space);
    let (base, base_stats) = ctx.explore_pruned(&space, Objective::Time, 1);
    assert!(base_stats.bound_cut > 0, "{base_stats:?}");
    for workers in [2, 3, 8] {
        let (pts, stats) = ctx.explore_pruned(&space, Objective::Time, workers);
        assert_eq!(stats, base_stats, "workers={workers}");
        assert_eq!(pts.len(), base.len(), "workers={workers}");
        for (a, b) in pts.iter().zip(&base) {
            assert_eq!(a.codesign.name, b.codesign.name, "workers={workers}");
            assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "workers={workers}");
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "workers={workers}");
            assert_eq!(a.edp.to_bits(), b.edp.to_bits(), "workers={workers}");
        }
    }
}

#[test]
fn suite_results_bit_identical_to_standalone_sweeps() {
    // The batched shared-pool suite must not change any application's
    // output relative to sweeping it alone — exhaustive and pruned.
    use zynq_estimator::dse::SweepSuite;
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let programs = vec![
        ("matmul", Matmul::new(256, 64).build_program(&board)),
        ("cholesky", Cholesky::new(256, 64).build_program(&board)),
    ];
    let mut suite = SweepSuite::new();
    for (name, program) in &programs {
        suite.push(name, program, &board, &part, DseSpace::from_program(program));
    }
    for workers in [1, 4] {
        let batched = suite.explore(Objective::Time, workers);
        let batched_pruned = suite.explore_pruned(Objective::Time, workers);
        for (i, (_, program)) in programs.iter().enumerate() {
            let space = DseSpace::from_program(program);
            let ctx = SweepContext::for_space(program, &board, &part, &space);
            let alone = ctx.explore(&space, Objective::Time, workers);
            assert_eq!(alone.len(), batched[i].points.len(), "workers={workers}");
            for (a, b) in alone.iter().zip(&batched[i].points) {
                assert_eq!(a.codesign.name, b.codesign.name, "workers={workers}");
                assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "workers={workers}");
                assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "workers={workers}");
            }
            let (alone_pruned, alone_stats) = ctx.explore_pruned(&space, Objective::Time, workers);
            assert_eq!(alone_stats, batched_pruned[i].stats, "workers={workers}");
            assert_eq!(
                alone_pruned.len(),
                batched_pruned[i].points.len(),
                "workers={workers}"
            );
            for (a, b) in alone_pruned.iter().zip(&batched_pruned[i].points) {
                assert_eq!(a.codesign.name, b.codesign.name, "workers={workers}");
                assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "workers={workers}");
            }
        }
    }
}
