"""Layer-1 Pallas kernels for the paper's Cholesky tile family (Fig. 4).

The paper annotates dgemm / dsyrk / dtrsm with ``device(fpga,smp)`` and
keeps dpotrf on the SMP. The artifact dtype is f32 (DESIGN.md section 1,
substitution 3); names keep the paper's d-prefixed labels.

TPU mapping (DESIGN.md section 4):
  * dgemm / dsyrk are MXU work — one `jnp.dot` per 64x64 tile (a quarter
    MXU pass; the paper's BS=64 granularity under-fills the systolic array
    exactly as it under-fills a full-resources HLS datapath);
  * dtrsm keeps its sequential column recurrence — expressed with a
    `fori_loop` over columns inside VMEM, the analogue of the II=4 HLS
    pipeline the fabric pays for the same dependence;
  * dpotrf is SMP-only in the paper; its artifact exists for the runtime's
    numeric end-to-end validation and uses an unblocked column loop.

All kernels interpret=True (CPU PJRT has no Mosaic).
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

INTERPRET = True


# --- dgemm: C' = C - A @ B^T ------------------------------------------------

def _gemm_kernel(a_ref, b_ref, c_ref, o_ref):
    o_ref[...] = c_ref[...] - jnp.dot(
        a_ref[...], b_ref[...].T, preferred_element_type=jnp.float32
    )


def gemm_tile(a, b, c):
    bs = a.shape[0]
    return pl.pallas_call(
        _gemm_kernel,
        out_shape=jax.ShapeDtypeStruct((bs, bs), jnp.float32),
        interpret=INTERPRET,
    )(a, b, c)


# --- dsyrk: C' = C - A @ A^T -------------------------------------------------

def _syrk_kernel(a_ref, c_ref, o_ref):
    o_ref[...] = c_ref[...] - jnp.dot(
        a_ref[...], a_ref[...].T, preferred_element_type=jnp.float32
    )


def syrk_tile(a, c):
    bs = a.shape[0]
    return pl.pallas_call(
        _syrk_kernel,
        out_shape=jax.ShapeDtypeStruct((bs, bs), jnp.float32),
        interpret=INTERPRET,
    )(a, c)


# --- dtrsm: B' = B @ L^-T ----------------------------------------------------

def _trsm_kernel(l_ref, b_ref, o_ref):
    """Forward substitution, column by column, inside VMEM.

    Solves X L^T = B. Column j of X: x_j = (b_j - sum_{i<j} X_i L[j,i]) /
    L[j,j]. The j-loop is the sequential recurrence the fabric pipeline
    pays II=4 for; here it serializes `bs` VMEM-resident vector ops.
    """
    l = l_ref[...]
    b = b_ref[...]
    bs = b.shape[0]

    def col(j, x):
        # acc = X[:, :j] @ L[j, :j]^T computed as a masked full matvec to
        # keep shapes static.
        mask = (jnp.arange(bs) < j).astype(b.dtype)
        lj = l[j, :] * mask
        acc = x @ lj
        xj = (b[:, j] - acc) / l[j, j]
        return x.at[:, j].set(xj)

    o_ref[...] = lax.fori_loop(0, bs, col, jnp.zeros_like(b))


def trsm_tile(l, b):
    bs = b.shape[0]
    return pl.pallas_call(
        _trsm_kernel,
        out_shape=jax.ShapeDtypeStruct((bs, bs), jnp.float32),
        interpret=INTERPRET,
    )(l, b)


# --- dpotrf: L = chol(A) -----------------------------------------------------

def potrf_tile(a):
    """Unblocked Cholesky via a column fori_loop (plain HLO ops only, so
    the artifact loads in the pinned XLA runtime — no Cholesky custom
    call). Not a Pallas kernel: the paper keeps dpotrf on the SMP, so this
    is Layer-2 jnp used only for end-to-end numeric validation."""
    a = jnp.asarray(a)  # numpy inputs must not be indexed with tracers
    bs = a.shape[0]
    idx = jnp.arange(bs)

    def col(j, l):
        # l[j, j] = sqrt(a[j, j] - sum_{k<j} l[j, k]^2)
        mask = (idx < j).astype(a.dtype)
        row_j = l[j, :] * mask
        djj = jnp.sqrt(a[j, j] - row_j @ row_j)
        # below-diagonal column j
        sub = (l * mask[None, :]) @ row_j  # rows dot row_j over k<j
        colj = (a[:, j] - sub) / djj
        keep_low = (idx > j).astype(a.dtype)
        new_col = colj * keep_low + jnp.where(idx == j, djj, 0.0)
        return l.at[:, j].set(new_col)

    return lax.fori_loop(0, bs, col, jnp.zeros_like(a))
