//! Fig. 6 regeneration: analysis time of the methodology (measured) vs the
//! traditional hardware-generation flow (modelled), for the matmul
//! configuration set; §VI's cholesky productivity claim alongside.
//!
//! Paper shape to hold: traditional > 10 h (matmul) / ~1.5 days
//! (cholesky); methodology minutes; gap > 2 orders of magnitude.

use zynq_estimator::config::BoardConfig;
use zynq_estimator::experiments;
use zynq_estimator::util::fmt_secs;

fn main() {
    let board = BoardConfig::zynq706();

    println!("=== Fig. 6: analysis time (the paper plots this log-scale) ===");
    let (meth, trad) = experiments::analysis_time_matmul(512, &board).unwrap();
    println!("matmul set:");
    println!("  methodology (measured wall-clock):   {}", fmt_secs(meth));
    println!("  traditional flow (synthesis model):  {}", fmt_secs(trad));
    println!("  speedup: {:.0}x   (paper: >10 h vs <5 min)", trad / meth);

    let (meth_c, trad_c) = experiments::analysis_time_cholesky(512, &board).unwrap();
    println!("cholesky set (§VI productivity):");
    println!("  methodology (measured wall-clock):   {}", fmt_secs(meth_c));
    println!("  traditional flow (synthesis model):  {}", fmt_secs(trad_c));
    println!(
        "  speedup: {:.0}x   (paper: ~1.5 days vs <10 min)",
        trad_c / meth_c
    );
    println!(
        "\nheadline (§VII): both gaps exceed two orders of magnitude: {}",
        trad / meth > 100.0 && trad_c / meth_c > 100.0
    );
}
