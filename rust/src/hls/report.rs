//! HLS report structures — the information the paper extracts from Vivado
//! HLS for each annotated kernel (§IV): estimated compute cycles and
//! estimated input/output transfer cycles, plus the resource usage the
//! feasibility analysis needs.

use crate::sim::time::{Clock, Ps};

/// Resource vector of one synthesized accelerator (7-series primitives).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Resources {
    /// Look-up tables.
    pub luts: u64,
    /// Flip-flops.
    pub ffs: u64,
    /// DSP48 slices.
    pub dsps: u64,
    /// BRAM counted in 18 Kb halves (a BRAM36 = 2 × BRAM18).
    pub bram18: u64,
}

impl Resources {
    /// The empty resource vector.
    pub const ZERO: Resources = Resources {
        luts: 0,
        ffs: 0,
        dsps: 0,
        bram18: 0,
    };

    /// Component-wise sum.
    pub fn add(&self, o: &Resources) -> Resources {
        Resources {
            luts: self.luts + o.luts,
            ffs: self.ffs + o.ffs,
            dsps: self.dsps + o.dsps,
            bram18: self.bram18 + o.bram18,
        }
    }

    /// Component-wise `<=` against a budget.
    pub fn fits_in(&self, budget: &Resources) -> bool {
        self.luts <= budget.luts
            && self.ffs <= budget.ffs
            && self.dsps <= budget.dsps
            && self.bram18 <= budget.bram18
    }

    /// Highest fractional utilization across resource classes w.r.t. a
    /// budget (the quantity place-and-route difficulty tracks).
    pub fn max_utilization(&self, budget: &Resources) -> f64 {
        [
            self.luts as f64 / budget.luts as f64,
            self.ffs as f64 / budget.ffs as f64,
            self.dsps as f64 / budget.dsps as f64,
            self.bram18 as f64 / budget.bram18 as f64,
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }
}

/// One kernel variant's synthesis estimate — the stand-in for the Vivado
/// HLS report the paper's toolchain parses.
#[derive(Clone, Debug, PartialEq)]
pub struct HlsReport {
    /// Kernel the variant implements.
    pub kernel: String,
    /// Unroll factor of the variant.
    pub unroll: u32,
    /// Achieved initiation interval of the pipelined innermost loop.
    pub ii: u32,
    /// Pipeline depth (fill/flush latency), cycles.
    pub depth: u32,
    /// Estimated compute cycles per task invocation (fabric clock).
    pub compute_cycles: u64,
    /// Achieved fabric clock after HLS scheduling, MHz.
    pub fmax_mhz: f64,
    /// Estimated cycles to DMA the inputs in (fabric clock domain).
    pub in_cycles: u64,
    /// Estimated cycles to DMA the outputs back (fabric clock domain).
    pub out_cycles: u64,
    /// Resource usage of the synthesized accelerator.
    pub resources: Resources,
}

impl HlsReport {
    /// The fabric clock domain the variant achieved.
    pub fn clock(&self) -> Clock {
        Clock::new(self.fmax_mhz)
    }

    /// Compute-only latency in picoseconds.
    pub fn compute_ps(&self) -> Ps {
        self.clock().cycles_to_ps(self.compute_cycles)
    }

    /// Input-transfer latency in picoseconds.
    pub fn in_ps(&self) -> Ps {
        self.clock().cycles_to_ps(self.in_cycles)
    }

    /// Output-transfer latency in picoseconds.
    pub fn out_ps(&self) -> Ps {
        self.clock().cycles_to_ps(self.out_cycles)
    }

    /// Render in the style of a Vivado HLS synthesis summary (human
    /// consumption; the `hls` CLI subcommand prints this).
    pub fn render(&self) -> String {
        format!(
            "== Vivado HLS-style report: {} (U{})\n\
             * Timing: target clock {:.1} MHz\n\
             * Latency: compute {} cycles (II={}, depth={})\n\
             *          xfer-in {} cycles, xfer-out {} cycles\n\
             * Utilization: {} DSP48E, {} BRAM18K, {} LUT, {} FF\n",
            self.kernel,
            self.unroll,
            self.fmax_mhz,
            self.compute_cycles,
            self.ii,
            self.depth,
            self.in_cycles,
            self.out_cycles,
            self.resources.dsps,
            self.resources.bram18,
            self.resources.luts,
            self.resources.ffs,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resources_algebra() {
        let a = Resources {
            luts: 100,
            ffs: 200,
            dsps: 10,
            bram18: 4,
        };
        let b = a.add(&a);
        assert_eq!(b.dsps, 20);
        let budget = Resources {
            luts: 1000,
            ffs: 1000,
            dsps: 25,
            bram18: 100,
        };
        assert!(a.fits_in(&budget));
        assert!(b.fits_in(&budget));
        assert!(!b.add(&a).fits_in(&budget)); // 30 dsps > 25
        assert!((b.max_utilization(&budget) - 0.8).abs() < 1e-12); // 20/25
    }

    #[test]
    fn report_latency_conversion() {
        let r = HlsReport {
            kernel: "k".into(),
            unroll: 1,
            ii: 1,
            depth: 10,
            compute_cycles: 125_000, // 1 ms at 125 MHz
            fmax_mhz: 125.0,
            in_cycles: 12_500, // 100 us
            out_cycles: 1_250, // 10 us
            resources: Resources::ZERO,
        };
        assert_eq!(r.compute_ps(), 1_000_000_000);
        assert_eq!(r.in_ps(), 100_000_000);
        assert_eq!(r.out_ps(), 10_000_000);
        assert!(r.render().contains("DSP48E"));
    }
}
