//! Simulation substrate: time/clock domains, the discrete-event engine,
//! the DMA transfer model and the coarse-grain estimator timing model.
//!
//! The high-level entry points are [`estimate`] and [`emulate`]: run one
//! (program, co-design) pair under the coarse-grain estimator or under the
//! detailed board emulator respectively.

pub mod dma;
pub mod engine;
pub mod estimator;
pub mod time;

use crate::board::BoardModel;
use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::deps::DepGraph;
use crate::coordinator::elaborate::ElabProgram;
use crate::coordinator::sched::Policy;
use crate::coordinator::task::TaskProgram;
use crate::hls::FpgaPart;

pub use engine::{
    resolve_codesign, AccelInstance, DeltaPlan, DeviceLabel, SegKind, Segment, SimCheckpoint,
    SimResult, Simulator, TaskCtx, TimingModel,
};
pub use estimator::EstimatorModel;

/// Run a program under a co-design with an arbitrary timing model.
pub fn simulate(
    program: &TaskProgram,
    codesign: &CoDesign,
    board: &BoardConfig,
    part: &FpgaPart,
    policy: Policy,
    timing: &mut dyn TimingModel,
) -> anyhow::Result<SimResult> {
    let graph = DepGraph::build(program);
    let elab = ElabProgram::build(program, &graph);
    let (accels, smp_eligible) = resolve_codesign(program, codesign, board, part)?;
    let sim = Simulator::new(program, &elab, board, &accels, &smp_eligible, policy);
    Ok(sim.run(timing))
}

/// Run under the coarse-grain estimator (the paper's tool).
pub fn estimate(
    program: &TaskProgram,
    codesign: &CoDesign,
    board: &BoardConfig,
) -> anyhow::Result<SimResult> {
    let mut model = EstimatorModel::new(board);
    simulate(
        program,
        codesign,
        board,
        &FpgaPart::xc7z045(),
        Policy::Greedy,
        &mut model,
    )
}

/// Run under the detailed board emulator (the "real execution" stand-in).
pub fn emulate(
    program: &TaskProgram,
    codesign: &CoDesign,
    board: &BoardConfig,
) -> anyhow::Result<SimResult> {
    let mut model = BoardModel::new(board);
    simulate(
        program,
        codesign,
        board,
        &FpgaPart::xc7z045(),
        Policy::Greedy,
        &mut model,
    )
}

/// Run the board emulator `reps` times with distinct seeds and return the
/// mean makespan in ms — mirroring the paper's "average elapsed execution
/// time of 10 application executions".
///
/// The program analysis (dependence graph, elaboration, co-design
/// resolution) is shared across the repetitions, and the recording runs
/// reuse one [`Simulator`] — including its segment buffer, handed back via
/// [`Simulator::recycle_segments`] between runs — so a 10-rep board
/// average allocates its timeline storage once instead of ten times. The
/// per-rep results are bit-identical to running [`emulate`] with the same
/// seeded board (regression-tested below): only `emu.seed` varies between
/// repetitions and the engine itself never reads the emulator parameters.
pub fn emulate_mean_ms(
    program: &TaskProgram,
    codesign: &CoDesign,
    board: &BoardConfig,
    reps: u32,
) -> anyhow::Result<f64> {
    let graph = DepGraph::build(program);
    let elab = ElabProgram::build(program, &graph);
    let (accels, smp_eligible) =
        resolve_codesign(program, codesign, board, &FpgaPart::xc7z045())?;
    let mut sim = Simulator::new(program, &elab, board, &accels, &smp_eligible, Policy::Greedy);
    let mut total = 0.0;
    for i in 0..reps {
        let mut b = board.clone();
        b.emu.seed = board.emu.seed.wrapping_add(i as u64 * 0x9E37_79B9);
        let mut model = BoardModel::new(&b);
        if i > 0 {
            sim.reset(&accels, &smp_eligible);
        }
        let r = sim.run_mut(&mut model);
        total += r.makespan_ms();
        sim.recycle_segments(r.segments);
    }
    Ok(total / reps as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::Matmul;

    #[test]
    fn pooled_board_mean_matches_per_run_emulation() {
        // The simulator-reuse + segment-pool path must reproduce the naive
        // "fresh emulate() per rep" mean bit for bit.
        let board = BoardConfig::zynq706();
        let program = Matmul::new(256, 64).build_program(&board);
        let cd = crate::config::CoDesign::new("2acc")
            .with_accel("mxm64", 32)
            .with_accel("mxm64", 32);
        let reps = 4;
        let mut total = 0.0;
        for i in 0..reps {
            let mut b = board.clone();
            b.emu.seed = board.emu.seed.wrapping_add(i as u64 * 0x9E37_79B9);
            total += emulate(&program, &cd, &b).unwrap().makespan_ms();
        }
        let naive = total / reps as f64;
        let pooled = emulate_mean_ms(&program, &cd, &board, reps as u32).unwrap();
        assert_eq!(naive.to_bits(), pooled.to_bits());
    }

    #[test]
    fn pooled_board_runs_keep_segment_recording_on() {
        // emulate_mean_ms is a *recording* loop (the board emulator is the
        // stand-in for real execution, whose traces Fig. 7 visualizes):
        // each rep must still produce a full timeline.
        let board = BoardConfig::zynq706();
        let program = Matmul::new(256, 64).build_program(&board);
        let cd = crate::config::CoDesign::new("1acc").with_accel("mxm64", 32);
        let r = emulate(&program, &cd, &board).unwrap();
        assert!(!r.segments.is_empty());
        assert!(emulate_mean_ms(&program, &cd, &board, 2).unwrap() > 0.0);
    }
}
