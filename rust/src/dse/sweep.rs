//! Zero-rebuild, parallel DSE sweep engine.
//!
//! The seed exploration loop paid O(points × tasks) redundant work: every
//! enumerated co-design rebuilt the dependence graph and elaborated
//! program from scratch (`sim::estimate` → `DepGraph::build` +
//! `ElabProgram::build`), re-ran the HLS cost model for every
//! (kernel, unroll) it touched, and evaluated points one after another.
//! CEDR (Mack et al., 2022) and the hardware-HEFT scheduler work (Fusco et
//! al., 2022) both separate one-time program analysis from
//! per-configuration scheduling; [`SweepContext`] is that separation here:
//!
//! * the [`DepGraph`] and [`ElabProgram`] are built **once** per program
//!   and shared (immutably) by every evaluation;
//! * HLS reports are memoized per `(kernel, unroll)` — [`SweepContext::prime`]
//!   fills the cache for a [`DseSpace`] up front so a sweep performs zero
//!   duplicate cost-model calls;
//! * point evaluation shards across `std::thread::scope` workers (keeping
//!   the repository's zero-external-dependency style). Each worker keeps
//!   one [`Simulator`] alive and [`Simulator::reset`]s it per point, so the
//!   event heap, ready queues and predecessor counters are allocated once
//!   per worker, not once per point, and segment recording is disabled
//!   because ranking needs only makespan + busy accounting.
//!
//! Determinism: candidates are evaluated under a work-stealing index
//! cursor, results are keyed by candidate index and merged in enumeration
//! order, and the final ranking uses the same stable sort as the serial
//! path — so `explore` returns a bit-identical `Vec<DsePoint>` for any
//! worker count (asserted by `rust/tests/sweep_determinism.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::deps::DepGraph;
use crate::coordinator::elaborate::ElabProgram;
use crate::coordinator::sched::Policy;
use crate::coordinator::task::{KernelId, TaskProgram};
use crate::hls::{CostModel, FpgaPart, HlsReport, Resources};
use crate::power::PowerModel;
use crate::sim::engine::{AccelInstance, Simulator};
use crate::sim::{EstimatorModel, SimResult};
use crate::util::fxhash::FxHashMap;

use super::{describe, DsePoint, DseSpace, Objective};

/// Number of evaluation workers to use by default: one per available core.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Shared, immutable evaluation context for one (program, board, part)
/// triple: dependence graph, elaborated program and memoized HLS reports.
/// Build it once, then run any number of enumerations / explorations /
/// single-point estimates against it.
pub struct SweepContext<'p> {
    pub program: &'p TaskProgram,
    pub board: &'p BoardConfig,
    pub part: FpgaPart,
    pub graph: DepGraph,
    pub elab: ElabProgram,
    cost: CostModel,
    power: PowerModel,
    /// Memoized `(kernel, unroll) → HlsReport`.
    reports: FxHashMap<(KernelId, u32), HlsReport>,
}

impl<'p> SweepContext<'p> {
    /// Build the one-time program analysis (graph + elaboration). The HLS
    /// cache starts empty; call [`SweepContext::prime`] with the space you
    /// are about to sweep.
    pub fn new(program: &'p TaskProgram, board: &'p BoardConfig, part: FpgaPart) -> Self {
        let graph = DepGraph::build(program);
        let elab = ElabProgram::build(program, &graph);
        SweepContext {
            program,
            board,
            part,
            graph,
            elab,
            cost: CostModel::from_board(board),
            power: PowerModel::default(),
            reports: FxHashMap::default(),
        }
    }

    /// Convenience constructor: build and prime for `space` in one step.
    pub fn for_space(
        program: &'p TaskProgram,
        board: &'p BoardConfig,
        part: &FpgaPart,
        space: &DseSpace,
    ) -> Self {
        let mut ctx = Self::new(program, board, part.clone());
        ctx.prime(space);
        ctx
    }

    /// Memoize the HLS report of every `(kernel, unroll)` pair the space
    /// can touch, so the sweep itself performs zero cost-model calls.
    pub fn prime(&mut self, space: &DseSpace) {
        for ks in &space.kernels {
            let Some(kid) = self.program.kernel_id(&ks.kernel) else {
                continue;
            };
            for &u in &ks.unrolls {
                if self.reports.contains_key(&(kid, u)) {
                    continue;
                }
                let r = self
                    .cost
                    .estimate(&ks.kernel, &self.program.kernel(kid).profile, u);
                self.reports.insert((kid, u), r);
            }
        }
    }

    /// Number of memoized HLS reports (bench/diagnostic).
    pub fn cached_reports(&self) -> usize {
        self.reports.len()
    }

    /// The HLS report for a variant: cache hit, or an on-the-fly estimate
    /// for variants outside the primed space (same numbers either way —
    /// the cost model is deterministic).
    pub fn report_for(&self, kid: KernelId, kernel: &str, unroll: u32) -> HlsReport {
        match self.reports.get(&(kid, unroll)) {
            Some(r) => r.clone(),
            None => self
                .cost
                .estimate(kernel, &self.program.kernel(kid).profile, unroll),
        }
    }

    /// Resource vector only (avoids cloning the report's strings on hit).
    pub fn resources_for(&self, kid: KernelId, kernel: &str, unroll: u32) -> Resources {
        match self.reports.get(&(kid, unroll)) {
            Some(r) => r.resources,
            None => {
                self.cost
                    .estimate(kernel, &self.program.kernel(kid).profile, unroll)
                    .resources
            }
        }
    }

    /// Resolve a co-design against the program using the memoized reports —
    /// the cached equivalent of [`crate::sim::resolve_codesign`], with the
    /// same feasibility checks and error conditions.
    pub fn resolve(&self, codesign: &CoDesign) -> anyhow::Result<(Vec<AccelInstance>, Vec<bool>)> {
        let mut accels = Vec::with_capacity(codesign.accels.len());
        for spec in &codesign.accels {
            let kid = self.program.kernel_id(&spec.kernel).ok_or_else(|| {
                anyhow::anyhow!("co-design accel '{}' not in program", spec.kernel)
            })?;
            if !self.program.kernel(kid).targets.fpga {
                anyhow::bail!(
                    "kernel '{}' is not annotated with target device(fpga)",
                    spec.kernel
                );
            }
            accels.push(AccelInstance {
                kernel: kid,
                report: self.report_for(kid, &spec.kernel, spec.unroll),
            });
        }
        let resources: Vec<Resources> = accels.iter().map(|a| a.report.resources).collect();
        if !self.part.fits(&resources) {
            anyhow::bail!(
                "co-design '{}' does not fit {} (utilization {:.0}%)",
                codesign.name,
                self.part.name,
                self.part.utilization(&resources) * 100.0
            );
        }
        let mut smp_eligible = Vec::with_capacity(self.program.kernels.len());
        for (kid, k) in self.program.kernels.iter().enumerate() {
            let has_accel = accels.iter().any(|a| a.kernel as usize == kid);
            let eligible = if has_accel {
                k.targets.smp && codesign.allows_smp(&k.name)
            } else {
                k.targets.smp
            };
            if !eligible && !has_accel {
                anyhow::bail!(
                    "kernel '{}' can run nowhere under co-design '{}'",
                    k.name,
                    codesign.name
                );
            }
            smp_eligible.push(eligible);
        }
        Ok((accels, smp_eligible))
    }

    /// One-shot coarse-grain estimate of a co-design against the shared
    /// context — equals `sim::estimate` on the same inputs, without
    /// rebuilding the graph/elaboration. For many points, prefer
    /// [`SweepContext::worker`] which also reuses the simulator buffers.
    pub fn estimate(&self, codesign: &CoDesign) -> anyhow::Result<SimResult> {
        let (accels, smp) = self.resolve(codesign)?;
        let mut sim = Simulator::new(
            self.program,
            &self.elab,
            self.board,
            &accels,
            &smp,
            Policy::Greedy,
        );
        let mut model = EstimatorModel::new(self.board);
        Ok(sim.run_mut(&mut model))
    }

    /// Enumerate feasible co-designs over the space (resource-pruned),
    /// identical to the seed `dse::enumerate` but with every resource
    /// vector served from the memoized reports.
    pub fn enumerate(&self, space: &DseSpace) -> Vec<CoDesign> {
        // Per-kernel options: (accel list, smp flag), parallel to the
        // surviving KernelSpace entries.
        let mut per_kernel: Vec<Vec<(Vec<(String, u32)>, bool)>> = Vec::new();
        let mut kspaces: Vec<&super::KernelSpace> = Vec::new();
        for ks in &space.kernels {
            let Some(kid) = self.program.kernel_id(&ks.kernel) else {
                continue;
            };
            let mut opts: Vec<(Vec<(String, u32)>, bool)> = vec![(Vec::new(), false)];
            for &u in &ks.unrolls {
                let res = self.resources_for(kid, &ks.kernel, u);
                // Quick per-kernel prune: even alone it must fit.
                if !self.part.fits(&[res]) {
                    continue;
                }
                for count in 1..=ks.max_instances {
                    let accels: Vec<(String, u32)> =
                        (0..count).map(|_| (ks.kernel.clone(), u)).collect();
                    opts.push((accels.clone(), false));
                    if ks.try_smp {
                        opts.push((accels, true));
                    }
                }
            }
            per_kernel.push(opts);
            kspaces.push(ks);
        }

        // Cartesian product with feasibility pruning.
        let mut out = Vec::new();
        let mut idx = vec![0usize; per_kernel.len()];
        let mut resources: Vec<Resources> = Vec::new();
        loop {
            // Assemble the candidate.
            let mut cd = CoDesign::new("dse");
            for (ki, &i) in idx.iter().enumerate() {
                let (accels, smp) = &per_kernel[ki][i];
                for (k, u) in accels {
                    cd = cd.with_accel(k, *u);
                }
                if *smp {
                    cd = cd.with_smp(&kspaces[ki].kernel);
                }
            }
            // Feasibility: total resources fit.
            resources.clear();
            for a in &cd.accels {
                let kid = self.program.kernel_id(&a.kernel).unwrap();
                resources.push(self.resources_for(kid, &a.kernel, a.unroll));
            }
            if self.part.fits(&resources) {
                cd.name = describe(&cd);
                out.push(cd);
            }
            // Advance the odometer.
            let mut carry = true;
            for (ki, i) in idx.iter_mut().enumerate() {
                if !carry {
                    break;
                }
                *i += 1;
                if *i < per_kernel[ki].len() {
                    carry = false;
                } else {
                    *i = 0;
                }
            }
            if carry {
                break;
            }
        }
        out
    }

    /// A reusable evaluation worker: one simulator + one timing model,
    /// reset per point. Create one per thread.
    pub fn worker<'c>(&'c self) -> SweepWorker<'c, 'p> {
        let mut sim = Simulator::new(
            self.program,
            &self.elab,
            self.board,
            &[],
            &[],
            Policy::Greedy,
        );
        // Ranking needs only makespan + busy accounting.
        sim.set_record_segments(false);
        SweepWorker {
            ctx: self,
            sim,
            model: EstimatorModel::new(self.board),
        }
    }

    /// Turn a finished simulation into a ranked design point.
    fn point_from(&self, codesign: &CoDesign, res: &SimResult) -> DsePoint {
        let resources: Vec<Resources> = codesign
            .accels
            .iter()
            .map(|a| {
                let kid = self.program.kernel_id(&a.kernel).unwrap();
                self.resources_for(kid, &a.kernel, a.unroll)
            })
            .collect();
        let util = self.part.utilization(&resources);
        let energy = self
            .power
            .energy(res, &resources, util, self.board.fabric_freq_mhz);
        DsePoint {
            codesign: codesign.clone(),
            est_ms: res.makespan_ms(),
            energy_j: energy.total_j(),
            edp: energy.edp(),
            fabric_util: util,
        }
    }

    /// Evaluate a candidate list across `workers` threads with
    /// deterministic (enumeration-order) output. Points whose co-design
    /// cannot run (some kernel has nowhere to execute) are skipped, as in
    /// the serial path.
    pub fn evaluate_all(&self, cands: &[CoDesign], workers: usize) -> Vec<DsePoint> {
        let n = cands.len();
        let workers = workers.max(1).min(n.max(1));
        if workers <= 1 {
            let mut w = self.worker();
            return cands.iter().filter_map(|cd| w.evaluate(cd)).collect();
        }
        let next = AtomicUsize::new(0);
        let mut indexed: Vec<(usize, DsePoint)> = Vec::with_capacity(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    s.spawn(|| {
                        let mut w = self.worker();
                        let mut out: Vec<(usize, DsePoint)> = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            if let Some(p) = w.evaluate(&cands[i]) {
                                out.push((i, p));
                            }
                        }
                        out
                    })
                })
                .collect();
            for h in handles {
                indexed.extend(h.join().expect("sweep worker panicked"));
            }
        });
        // Restore enumeration order so ranking ties break exactly like the
        // serial path (the score sort below is stable).
        indexed.sort_unstable_by_key(|e| e.0);
        indexed.into_iter().map(|(_, p)| p).collect()
    }

    /// Enumerate + evaluate + rank. Bit-identical output for any worker
    /// count, including `workers == 1`.
    pub fn explore(
        &self,
        space: &DseSpace,
        objective: Objective,
        workers: usize,
    ) -> Vec<DsePoint> {
        let cands = self.enumerate(space);
        let mut points = self.evaluate_all(&cands, workers);
        points.sort_by(|a, b| a.score(objective).partial_cmp(&b.score(objective)).unwrap());
        points
    }
}

/// Worker-local evaluation state: a [`Simulator`] whose buffers persist
/// across points (reset per co-design) and an estimator timing model.
pub struct SweepWorker<'c, 'p> {
    ctx: &'c SweepContext<'p>,
    sim: Simulator<'c>,
    model: EstimatorModel,
}

impl<'c, 'p> SweepWorker<'c, 'p> {
    /// Evaluate one co-design; `None` if it cannot run (skipped point).
    pub fn evaluate(&mut self, codesign: &CoDesign) -> Option<DsePoint> {
        let (accels, smp) = self.ctx.resolve(codesign).ok()?;
        // `resolve` already built owned instances: hand them to the
        // simulator instead of copying them a second time.
        self.sim.reset_owned(accels, smp);
        let res = self.sim.run_mut(&mut self.model);
        Some(self.ctx.point_from(codesign, &res))
    }
}

/// The seed *evaluation* path, kept for benchmarking and equivalence
/// testing: rebuilds the dependence graph and elaborated program for
/// **every** point (inside `sim::estimate`) and re-runs the HLS cost model
/// per point — exactly what `SweepContext` eliminates. (Candidate
/// enumeration goes through the shared wrapper, so both paths sweep the
/// identical candidate list; the timed difference is per-point
/// evaluation, which dominates.)
pub fn explore_rebuild_baseline(
    program: &TaskProgram,
    board: &BoardConfig,
    part: &FpgaPart,
    space: &DseSpace,
    objective: Objective,
) -> anyhow::Result<Vec<DsePoint>> {
    let cm = CostModel::from_board(board);
    let pm = PowerModel::default();
    let mut points = Vec::new();
    for cd in super::enumerate(program, board, part, space) {
        // Skip configurations where some kernel has nowhere to run.
        let Ok(res) = crate::sim::estimate(program, &cd, board) else {
            continue;
        };
        let resources: Vec<Resources> = cd
            .accels
            .iter()
            .map(|a| {
                let kid = program.kernel_id(&a.kernel).unwrap();
                cm.estimate(&a.kernel, &program.kernel(kid).profile, a.unroll)
                    .resources
            })
            .collect();
        let util = part.utilization(&resources);
        let energy = pm.energy(&res, &resources, util, board.fabric_freq_mhz);
        points.push(DsePoint {
            codesign: cd,
            est_ms: res.makespan_ms(),
            energy_j: energy.total_j(),
            edp: energy.edp(),
            fabric_util: util,
        });
    }
    points.sort_by(|a, b| a.score(objective).partial_cmp(&b.score(objective)).unwrap());
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::Matmul;
    use crate::dse::KernelSpace;

    fn space() -> DseSpace {
        DseSpace {
            kernels: vec![KernelSpace {
                kernel: "mxm64".into(),
                unrolls: vec![8, 16, 32],
                max_instances: 2,
                try_smp: true,
            }],
        }
    }

    #[test]
    fn context_enumeration_matches_free_function() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let part = FpgaPart::xc7z045();
        let sp = space();
        let ctx = SweepContext::for_space(&p, &board, &part, &sp);
        let a = ctx.enumerate(&sp);
        let b = super::super::enumerate(&p, &board, &part, &sp);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn prime_fills_the_cache() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let sp = space();
        let mut ctx = SweepContext::new(&p, &board, FpgaPart::xc7z045());
        assert_eq!(ctx.cached_reports(), 0);
        ctx.prime(&sp);
        assert_eq!(ctx.cached_reports(), 3);
        // Idempotent.
        ctx.prime(&sp);
        assert_eq!(ctx.cached_reports(), 3);
        // Cache hits equal fresh estimates.
        let kid = p.kernel_id("mxm64").unwrap();
        let cached = ctx.report_for(kid, "mxm64", 16);
        let fresh = CostModel::from_board(&board).estimate("mxm64", &p.kernel(kid).profile, 16);
        assert_eq!(cached, fresh);
        // Uncached unrolls fall through to the cost model.
        let off_space = ctx.report_for(kid, "mxm64", 64);
        let fresh64 = CostModel::from_board(&board).estimate("mxm64", &p.kernel(kid).profile, 64);
        assert_eq!(off_space, fresh64);
    }

    #[test]
    fn cached_estimate_matches_sim_estimate() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let ctx = SweepContext::new(&p, &board, FpgaPart::xc7z045());
        let cd = CoDesign::new("2acc").with_accel("mxm64", 32).with_accel("mxm64", 32);
        let a = ctx.estimate(&cd).unwrap();
        let b = crate::sim::estimate(&p, &cd, &board).unwrap();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.device_busy, b.device_busy);
        // Infeasible co-designs error through both paths.
        let huge = CoDesign::new("huge")
            .with_accel("mxm64", 512)
            .with_accel("mxm64", 512);
        assert!(ctx.estimate(&huge).is_err());
        assert!(crate::sim::estimate(&p, &huge, &board).is_err());
    }

    #[test]
    fn explore_matches_rebuild_baseline() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let part = FpgaPart::xc7z045();
        let sp = space();
        let ctx = SweepContext::for_space(&p, &board, &part, &sp);
        let baseline =
            explore_rebuild_baseline(&p, &board, &part, &sp, Objective::Time).unwrap();
        for workers in [1, 2, 4] {
            let pts = ctx.explore(&sp, Objective::Time, workers);
            assert_eq!(pts.len(), baseline.len(), "workers={workers}");
            for (a, b) in pts.iter().zip(&baseline) {
                assert_eq!(a.codesign.name, b.codesign.name, "workers={workers}");
                assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "workers={workers}");
                assert_eq!(
                    a.energy_j.to_bits(),
                    b.energy_j.to_bits(),
                    "workers={workers}"
                );
            }
        }
    }
}
