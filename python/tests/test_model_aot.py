"""Layer-2 shape/composition checks and AOT lowering validation.

The lowering test is the build-time gate of the interchange contract: every
artifact must produce parseable HLO text with the expected entry signature
(the Rust runtime asserts nothing further at load time — a text change that
breaks here would break `make artifacts`).
"""

import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref


def test_cholesky_full_composes():
    rng = np.random.default_rng(5)
    x = rng.standard_normal((128, 128)).astype(np.float32)
    a = np.asarray(ref.make_spd(jnp.asarray(x)))
    (l,) = model.cholesky_full(a)
    l = np.asarray(l)
    assert np.allclose(np.triu(l, 1), 0.0, atol=1e-5)
    np.testing.assert_allclose(l @ l.T, a, rtol=2e-2, atol=2e-1)


def test_matmul_full_matches_numpy():
    rng = np.random.default_rng(9)
    a = rng.standard_normal((256, 256)).astype(np.float32)
    b = rng.standard_normal((256, 256)).astype(np.float32)
    (c,) = model.matmul_full(a, b)
    np.testing.assert_allclose(c, a @ b, rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("stem,fn,args", aot.artifact_specs(),
                         ids=[s[0] for s in aot.artifact_specs()])
def test_artifact_lowers_to_hlo_text(stem, fn, args):
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Tuple return (the rust side unwraps with to_tuple1).
    assert "ROOT" in text


def test_lower_all_writes_manifest():
    with tempfile.TemporaryDirectory() as d:
        manifest = aot.lower_all(d)
        assert set(manifest) == {s[0] for s in aot.artifact_specs()}
        for stem in manifest:
            assert os.path.exists(os.path.join(d, f"{stem}.hlo.txt"))
        assert os.path.exists(os.path.join(d, "manifest.json"))


def test_artifact_numerics_via_jit():
    """Executing the jitted fns (interpret-mode pallas) matches oracles —
    the same computation the artifacts freeze."""
    rng = np.random.default_rng(13)
    a, b, c = (rng.standard_normal((64, 64)).astype(np.float32) for _ in range(3))
    (out,) = jax.jit(model.mxm_block_fn)(a, b, c)
    np.testing.assert_allclose(out, a @ b + c, rtol=1e-3, atol=1e-3)
    (out,) = jax.jit(model.gemm_fn)(a, b, c)
    np.testing.assert_allclose(out, c - a @ b.T, rtol=1e-3, atol=1e-3)
