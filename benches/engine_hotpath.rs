//! L3 hot-path benchmark — the §Perf target: the estimator must simulate
//! millions of tasks per second so that whole co-design sweeps stay in the
//! "coffee break" regime the paper promises even for much larger apps.
//!
//! Measures: event-engine throughput (tasks/s) for large synthetic
//! programs — fresh-simulator-per-run (the seed path) vs the
//! reset-reuse/no-segment sweep path — dependence-tracker build rate, and
//! end-to-end DSE sweep latency (serial rebuild vs parallel shared
//! context).
//!
//! Emits `BENCH_engine.json` (via `util::json`) so the perf trajectory is
//! tracked across PRs.

use zynq_estimator::apps::{cholesky::Cholesky, matmul::Matmul};
use zynq_estimator::config::{BoardConfig, CoDesign};
use zynq_estimator::coordinator::deps::DepGraph;
use zynq_estimator::coordinator::elaborate::ElabProgram;
use zynq_estimator::coordinator::sched::Policy;
use zynq_estimator::dse::{default_workers, DseSpace, SweepContext};
use zynq_estimator::experiments;
use zynq_estimator::hls::FpgaPart;
use zynq_estimator::sim::engine::{resolve_codesign, Simulator};
use zynq_estimator::sim::EstimatorModel;
use zynq_estimator::util::bench::{bench, black_box, BenchStats};
use zynq_estimator::util::json::{arr, obj, Value};

fn stat_record(stats: &BenchStats, tasks: usize) -> Value {
    obj(vec![
        ("name", stats.name.clone().into()),
        ("iters", stats.iters.into()),
        ("mean_ms", stats.mean_ms.into()),
        ("stdev_ms", stats.stdev_ms.into()),
        ("min_ms", stats.min_ms.into()),
        ("tasks", tasks.into()),
        (
            "mtasks_per_sec",
            if tasks > 0 && stats.min_ms > 0.0 {
                (tasks as f64 / (stats.min_ms / 1e3) / 1e6).into()
            } else {
                Value::Null
            },
        ),
    ])
}

fn main() {
    let board = BoardConfig::zynq706();
    let mut records: Vec<Value> = Vec::new();

    // Large workloads: matmul NB=16 (4096 tasks) and NB=24 (13824 tasks),
    // cholesky NB=40 (12340 tasks).
    for (name, program, cd) in [
        (
            "matmul NB=16 (4096 tasks, 2acc+smp)",
            Matmul::new(1024, 64).build_program(&board),
            CoDesign::new("2acc+smp")
                .with_accel("mxm64", 32)
                .with_accel("mxm64", 32)
                .with_smp("mxm64"),
        ),
        (
            "matmul NB=24 (13824 tasks, 2acc)",
            Matmul::new(1536, 64).build_program(&board),
            CoDesign::new("2acc")
                .with_accel("mxm64", 32)
                .with_accel("mxm64", 32),
        ),
        (
            "cholesky NB=40 (12341 tasks, dgemm+dtrsm)",
            Cholesky::new(2560, 64).build_program(&board),
            CoDesign::new("pair")
                .with_accel("dgemm", 16)
                .with_accel("dtrsm", 16),
        ),
    ] {
        let n_tasks = program.tasks.len();
        let graph = DepGraph::build(&program);
        let elab = ElabProgram::build(&program, &graph);
        let (accels, smp) =
            resolve_codesign(&program, &cd, &board, &FpgaPart::xc7z045()).unwrap();

        // Seed path: a fresh simulator (all buffers allocated) per run.
        let fresh = bench(&format!("simulate fresh {name}"), 2, 20, || {
            let sim = Simulator::new(&program, &elab, &board, &accels, &smp, Policy::Greedy);
            let mut model = EstimatorModel::new(&board);
            black_box(sim.run(&mut model));
        });
        println!(
            "    -> {:.2} M simulated tasks/s (fresh)",
            n_tasks as f64 / (fresh.min_ms / 1e3) / 1e6
        );
        records.push(stat_record(&fresh, n_tasks));

        // Sweep path: one simulator reset per run, no segment recording.
        let mut sim = Simulator::new(&program, &elab, &board, &accels, &smp, Policy::Greedy);
        sim.set_record_segments(false);
        let mut model = EstimatorModel::new(&board);
        let reused = bench(&format!("simulate reuse {name}"), 2, 20, || {
            sim.reset(&accels, &smp);
            black_box(sim.run_mut(&mut model));
        });
        println!(
            "    -> {:.2} M simulated tasks/s (reset-reuse, no segments)\n",
            n_tasks as f64 / (reused.min_ms / 1e3) / 1e6
        );
        records.push(stat_record(&reused, n_tasks));
    }

    // Dependence tracking and program generation rates.
    let big = Matmul::new(1536, 64).build_program(&board);
    let s = bench("DepGraph::build (13824 tasks)", 2, 20, || {
        black_box(DepGraph::build(&big));
    });
    records.push(stat_record(&s, big.tasks.len()));
    let s = bench("Matmul::build_program (13824 tasks)", 2, 20, || {
        black_box(Matmul::new(1536, 64).build_program(&board));
    });
    records.push(stat_record(&s, big.tasks.len()));

    // End-to-end DSE sweep: seed serial rebuild vs parallel shared context.
    let workers = default_workers();
    let chol = Cholesky::new(512, 64).build_program(&board);
    let (base_s, sweep_s, points) =
        experiments::dse_sweep_latency(&chol, &board, workers).unwrap();
    println!(
        "sweep cholesky n=512: {points} points, serial-rebuild {base_s:.3} s, parallel({workers}) {sweep_s:.3} s, speedup {:.1}x",
        base_s / sweep_s.max(1e-12)
    );
    records.push(obj(vec![
        ("name", "dse sweep cholesky n=512".into()),
        ("points", points.into()),
        ("workers", workers.into()),
        ("serial_rebuild_s", base_s.into()),
        ("parallel_s", sweep_s.into()),
        ("speedup", (base_s / sweep_s.max(1e-12)).into()),
    ]));

    // Incremental re-simulation: the exhaustive cholesky sweep evaluated
    // point-by-point from scratch vs through the neighbor-chain delta path
    // (serial on both sides, so the comparison isolates the reuse). The
    // counters and the `*_ok` gates are deterministic — chains are a pure
    // function of the candidate list — only the `_s` keys track the runner.
    let space = DseSpace::from_program(&chol);
    let ctx = SweepContext::for_space(&chol, &board, &FpgaPart::xc7z045(), &space);
    let cands = ctx.enumerate(&space);
    let t0 = std::time::Instant::now();
    let mut w = ctx.worker();
    let mut scratch = Vec::new();
    for cd in &cands {
        if let Some(p) = w.evaluate(cd) {
            scratch.push(p);
        }
    }
    let scratch_s = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let (delta_pts, stats) = ctx.evaluate_all_with_stats(&cands, 1);
    let delta_s = t0.elapsed().as_secs_f64();
    let bit_identical = scratch.len() == delta_pts.len()
        && scratch
            .iter()
            .zip(&delta_pts)
            .all(|(a, b)| a.est_ms.to_bits() == b.est_ms.to_bits());
    let rate = stats.reuse_rate();
    let suffix = stats.suffix_fraction();
    println!(
        "incremental cholesky n=512: {} points, scratch {scratch_s:.3} s, delta {delta_s:.3} s \
         ({:.2}x), reuse {}/{} ({:.1}%), suffix fraction {suffix:.3}",
        cands.len(),
        scratch_s / delta_s.max(1e-12),
        stats.hits,
        stats.hits + stats.fallbacks,
        100.0 * rate,
    );
    assert!(bit_identical, "delta sweep diverged from the scratch oracle");
    assert!(
        rate >= 0.30,
        "delta reuse rate {rate:.3} below the 30% floor ({stats:?})"
    );
    assert!(
        suffix < 1.0,
        "reused prefixes must shrink the replayed suffix ({stats:?})"
    );
    records.push(obj(vec![
        ("name", "incremental dse cholesky n=512".into()),
        ("points", cands.len().into()),
        ("delta_hits", stats.hits.into()),
        ("delta_fallbacks", stats.fallbacks.into()),
        ("delta_rate", rate.into()),
        ("suffix_fraction", suffix.into()),
        ("delta_rate_ok", (rate >= 0.30).into()),
        ("suffix_lt_1", (suffix < 1.0).into()),
        ("bit_identical", bit_identical.into()),
        ("scratch_s", scratch_s.into()),
        ("delta_s", delta_s.into()),
        ("speedup", (scratch_s / delta_s.max(1e-12)).into()),
    ]));

    let out = arr(records).to_json();
    match std::fs::write("BENCH_engine.json", &out) {
        Ok(()) => println!("wrote BENCH_engine.json ({} bytes)", out.len()),
        Err(e) => eprintln!("could not write BENCH_engine.json: {e}"),
    }
}
