//! Result analysis: normalized speedup tables (the paper's Fig. 5 / Fig. 9
//! presentation), estimator-vs-board trend agreement, device utilization
//! and report rendering. Submodules: `bounds` (makespan lower bounds),
//! `export` (CSV/JSON figure data).

pub mod bounds;
pub mod export;

use crate::sim::engine::{DeviceLabel, SimResult};
use crate::util::kendall_tau;

/// One configuration's timing under both models.
#[derive(Clone, Debug)]
pub struct ConfigRow {
    /// Configuration name.
    pub name: String,
    /// Coarse-grain estimator makespan, ms.
    pub estimator_ms: f64,
    /// Board-emulator mean makespan, ms.
    pub board_ms: f64,
}

/// A Fig.5/Fig.9-style table: per-configuration speedups normalized to the
/// slowest configuration of each column (the paper normalizes "with
/// respect to the slowest case").
#[derive(Clone, Debug)]
pub struct SpeedupTable {
    /// Per-configuration timings.
    pub rows: Vec<ConfigRow>,
    /// Estimator speedups, normalized to the slowest configuration.
    pub est_speedup: Vec<f64>,
    /// Board speedups, normalized to the slowest configuration.
    pub board_speedup: Vec<f64>,
}

impl SpeedupTable {
    /// Build the table and its normalized speedup columns.
    pub fn build(rows: Vec<ConfigRow>) -> Self {
        assert!(!rows.is_empty());
        let est_slowest = rows
            .iter()
            .map(|r| r.estimator_ms)
            .fold(f64::MIN, f64::max);
        let board_slowest = rows.iter().map(|r| r.board_ms).fold(f64::MIN, f64::max);
        let est_speedup = rows.iter().map(|r| est_slowest / r.estimator_ms).collect();
        let board_speedup = rows.iter().map(|r| board_slowest / r.board_ms).collect();
        Self {
            rows,
            est_speedup,
            board_speedup,
        }
    }

    /// Kendall rank correlation between the two speedup columns — the
    /// quantitative version of the paper's "the same speedup trends".
    pub fn trend_agreement(&self) -> f64 {
        kendall_tau(&self.est_speedup, &self.board_speedup)
    }

    /// Index of the best configuration under each model. The paper's core
    /// claim is that these agree.
    pub fn best_estimator(&self) -> usize {
        argmax(&self.est_speedup)
    }

    /// Index of the best configuration under the board model.
    pub fn best_board(&self) -> usize {
        argmax(&self.board_speedup)
    }

    /// Whether both models pick the same best configuration.
    pub fn best_agrees(&self) -> bool {
        self.best_estimator() == self.best_board()
    }

    /// Render an ASCII version of the figure: two bars per configuration.
    pub fn render(&self, title: &str) -> String {
        let mut out = format!("== {title}\n");
        let width = self
            .rows
            .iter()
            .map(|r| r.name.len())
            .max()
            .unwrap_or(10)
            .max(10);
        let max_speedup = self
            .est_speedup
            .iter()
            .chain(&self.board_speedup)
            .fold(1.0f64, |a, &b| a.max(b));
        out.push_str(&format!(
            "{:width$}  {:>9}  {:>9}  {:>7}  {:>7}\n",
            "config", "est (ms)", "real (ms)", "est x", "real x"
        ));
        for (i, r) in self.rows.iter().enumerate() {
            out.push_str(&format!(
                "{:width$}  {:>9.2}  {:>9.2}  {:>7.2}  {:>7.2}  ",
                r.name, r.estimator_ms, r.board_ms, self.est_speedup[i], self.board_speedup[i]
            ));
            let bar = |v: f64| "#".repeat(((v / max_speedup) * 30.0).round() as usize);
            out.push_str(&format!(
                "E|{:<30}  R|{}\n",
                bar(self.est_speedup[i]),
                bar(self.board_speedup[i])
            ));
        }
        out.push_str(&format!(
            "trend agreement (Kendall tau): {:+.3}; best config agrees: {} ({} vs {})\n",
            self.trend_agreement(),
            self.best_agrees(),
            self.rows[self.best_estimator()].name,
            self.rows[self.best_board()].name,
        ));
        out
    }
}

fn argmax(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Per-device utilization summary of one simulation.
pub fn utilization_report(result: &SimResult) -> String {
    let mut devs: Vec<(&DeviceLabel, &u64)> = result.device_busy.iter().collect();
    devs.sort_by_key(|(d, _)| **d);
    let mut out = format!(
        "makespan {:.3} ms | {} tasks on SMP, {} on FPGA\n",
        result.makespan_ms(),
        result.tasks_on_smp,
        result.tasks_on_accel
    );
    for (d, busy) in devs {
        let pct = if result.makespan > 0 {
            *busy as f64 / result.makespan as f64 * 100.0
        } else {
            0.0
        };
        out.push_str(&format!(
            "  {:16} busy {:>6.1}%  ({:.3} ms)\n",
            d.display(&result.accel_kernels),
            pct,
            crate::sim::time::ps_to_ms(*busy)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows() -> Vec<ConfigRow> {
        vec![
            ConfigRow {
                name: "a".into(),
                estimator_ms: 100.0,
                board_ms: 140.0,
            },
            ConfigRow {
                name: "b".into(),
                estimator_ms: 50.0,
                board_ms: 80.0,
            },
            ConfigRow {
                name: "c".into(),
                estimator_ms: 25.0,
                board_ms: 50.0,
            },
        ]
    }

    #[test]
    fn speedups_normalized_to_slowest() {
        let t = SpeedupTable::build(rows());
        assert_eq!(t.est_speedup, vec![1.0, 2.0, 4.0]);
        assert_eq!(t.board_speedup, vec![1.0, 1.75, 2.8]);
    }

    #[test]
    fn trend_agreement_perfect_here() {
        let t = SpeedupTable::build(rows());
        assert_eq!(t.trend_agreement(), 1.0);
        assert!(t.best_agrees());
        assert_eq!(t.best_estimator(), 2);
    }

    #[test]
    fn disagreement_detected() {
        let mut r = rows();
        r[2].board_ms = 200.0; // board says c is slowest
        let t = SpeedupTable::build(r);
        assert!(t.trend_agreement() < 1.0);
        assert!(!t.best_agrees());
    }

    #[test]
    fn render_contains_all_configs() {
        let t = SpeedupTable::build(rows());
        let s = t.render("Fig test");
        for name in ["a", "b", "c"] {
            assert!(s.contains(name));
        }
        assert!(s.contains("Kendall"));
    }
}
