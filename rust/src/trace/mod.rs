//! Trace IO: the basic task trace of §IV (JSON lines), Graphviz DOT export
//! of the dependency graph (Fig. 8) and the Paraver bundle writer (Fig. 7).

pub mod basic;
pub mod dot;
pub mod paraver;
pub mod prv_analyze;
pub mod validate;

pub use basic::{load, read_trace, save, write_trace};
pub use dot::to_dot;
