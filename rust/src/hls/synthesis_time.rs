//! Synthesis / place-and-route wall-clock model — used by the Fig. 6
//! comparison ("analysis time of our methodology vs hardware generation
//! time of the traditional design cycle").
//!
//! The traditional flow pays, per co-design: Vivado HLS C-synthesis for
//! each accelerator, logic synthesis, and place-and-route of the full
//! design. P&R time grows super-linearly with fabric utilization (router
//! congestion), which is why the paper's "full resources" cholesky variants
//! cost a day and a half for six configurations.
//!
//! Calibration targets (§VI): matmul full analysis "> 10 hours" for its
//! configuration set; cholesky "one day and a half" for its six
//! configurations. The model below hits both with one parameter set — see
//! `tests::paper_calibration_*`.

use super::report::Resources;
use super::resources::FpgaPart;

/// Wall-clock model of the traditional hardware-generation cycle.
#[derive(Clone, Debug)]
pub struct SynthesisTimeModel {
    /// Vivado HLS C-synthesis per accelerator kernel (seconds). The paper
    /// quotes "few seconds"–minutes; HLS of a full kernel ~2 min.
    pub hls_per_accel_s: f64,
    /// Fixed logic-synthesis + bitgen overhead per bitstream (seconds).
    pub synth_base_s: f64,
    /// Place-and-route time at 100% utilization (seconds); scaled by
    /// utilization^gamma.
    pub par_full_s: f64,
    /// Congestion exponent.
    pub gamma: f64,
    /// System integration / project wiring per bitstream (seconds) —
    /// "creating the hardware design and integrating it" (§VI).
    pub integration_s: f64,
}

impl Default for SynthesisTimeModel {
    fn default() -> Self {
        Self {
            hls_per_accel_s: 120.0,
            synth_base_s: 1_500.0,  // ~25 min synthesis + bitgen
            par_full_s: 30_000.0,   // ~8.3 h P&R at full utilization
            gamma: 1.3,
            integration_s: 1_200.0, // ~20 min project integration
        }
    }
}

impl SynthesisTimeModel {
    /// Wall-clock seconds to generate one bitstream containing the given
    /// accelerators on `part`.
    pub fn bitstream_seconds(&self, part: &FpgaPart, accels: &[Resources]) -> f64 {
        if accels.is_empty() {
            return 0.0; // pure-SMP configurations need no bitstream
        }
        let util = part.utilization(accels).min(1.0);
        self.hls_per_accel_s * accels.len() as f64
            + self.synth_base_s
            + self.integration_s
            + self.par_full_s * util.powf(self.gamma)
    }

    /// Total traditional-flow seconds for a set of co-design bitstreams.
    /// Co-designs that differ only in "+ smp" share a bitstream — the
    /// caller must pass deduplicated accelerator sets, as the paper does
    /// ("we only count the hardware generation of the different
    /// accelerators and combinations").
    pub fn total_seconds(&self, part: &FpgaPart, bitstreams: &[Vec<Resources>]) -> f64 {
        bitstreams
            .iter()
            .map(|b| self.bitstream_seconds(part, b))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BoardConfig;
    use crate::coordinator::task::KernelProfile;
    use crate::hls::cost_model::CostModel;

    fn mxm_profile(bs: u64) -> KernelProfile {
        KernelProfile {
            flops: 2 * bs * bs * bs,
            inner_trip: bs * bs * bs,
            in_bytes: 3 * bs * bs * 4,
            out_bytes: bs * bs * 4,
            dtype_bytes: 4,
            divsqrt: false,
        }
    }

    #[test]
    fn empty_design_is_free() {
        let m = SynthesisTimeModel::default();
        assert_eq!(m.bitstream_seconds(&FpgaPart::xc7z045(), &[]), 0.0);
    }

    #[test]
    fn more_utilization_is_slower() {
        let m = SynthesisTimeModel::default();
        let part = FpgaPart::xc7z045();
        let cm = CostModel::from_board(&BoardConfig::zynq706());
        let small = cm.estimate("mxm64", &mxm_profile(64), 8).resources;
        let big = cm.estimate("mxm128", &mxm_profile(128), 128).resources;
        assert!(
            m.bitstream_seconds(&part, &[big]) > m.bitstream_seconds(&part, &[small])
        );
    }

    #[test]
    fn paper_calibration_matmul_over_10_hours() {
        // The matmul analysis set needs bitstreams for {1acc64, 2acc64,
        // 1acc128}; the paper reports the full hardware generation at
        // "more than 10 hours".
        let m = SynthesisTimeModel::default();
        let part = FpgaPart::xc7z045();
        let cm = CostModel::from_board(&BoardConfig::zynq706());
        let a64 = cm.estimate("mxm64", &mxm_profile(64), 32).resources;
        let a128 = cm.estimate("mxm128", &mxm_profile(128), 128).resources;
        let total = m.total_seconds(
            &part,
            &[vec![a64], vec![a64, a64], vec![a128]],
        );
        let hours = total / 3600.0;
        assert!(hours > 10.0, "matmul traditional flow = {hours:.1} h, want > 10");
        assert!(hours < 24.0, "matmul traditional flow = {hours:.1} h, implausibly high");
    }

    #[test]
    fn paper_calibration_cholesky_day_and_a_half() {
        // Six cholesky bitstreams (three FR + three pairs) ≈ 1.5 days.
        let m = SynthesisTimeModel::default();
        let part = FpgaPart::xc7z045();
        let cm = CostModel::from_board(&BoardConfig::zynq706());
        let bs = 64u64;
        let dp = |flops: u64, trip: u64, inb: u64, outb: u64, div: bool| KernelProfile {
            flops,
            inner_trip: trip,
            in_bytes: inb,
            out_bytes: outb,
            dtype_bytes: 8,
            divsqrt: div,
        };
        let tile = bs * bs * 8;
        let gemm = dp(2 * bs * bs * bs, bs * bs * bs, 3 * tile, tile, false);
        let syrk = dp(bs * bs * bs, bs * bs * bs / 2, 2 * tile, tile, false);
        let trsm = dp(bs * bs * bs, bs * bs * bs / 2, 2 * tile, tile, true);
        let fr = 44u32; // full-resource dp unroll (fits alone)
        let pair = 16u32;
        let bitstreams = vec![
            vec![cm.estimate("dgemm", &gemm, fr).resources],
            vec![cm.estimate("dsyrk", &syrk, fr).resources],
            vec![cm.estimate("dtrsm", &trsm, fr).resources],
            vec![
                cm.estimate("dgemm", &gemm, pair).resources,
                cm.estimate("dgemm", &gemm, pair).resources,
            ],
            vec![
                cm.estimate("dgemm", &gemm, pair).resources,
                cm.estimate("dsyrk", &syrk, pair).resources,
            ],
            vec![
                cm.estimate("dgemm", &gemm, pair).resources,
                cm.estimate("dtrsm", &trsm, pair).resources,
            ],
        ];
        let days = m.total_seconds(&part, &bitstreams) / 86_400.0;
        assert!(
            days > 1.0 && days < 2.2,
            "cholesky traditional flow = {days:.2} days, want ~1.5"
        );
    }
}
