//! Opt-in per-phase profiling for the CLI (`dse --profile`).
//!
//! Costs one relaxed atomic load when disabled. When enabled, named scopes
//! ([`scope`]) accumulate wall-clock time and a hit count into a global
//! table, and [`report`] renders the breakdown to one writer (the CLI
//! points it at stderr so `--json` output stays clean). Wall-clock numbers
//! are diagnostic only — everything CI gates on is a deterministic counter
//! (see `util::bench_check`); the profile exists so a human can see where
//! a sweep's time went (enumerate / prune / simulate / memo-io) without
//! reaching for an external profiler.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

static ENABLED: AtomicBool = AtomicBool::new(false);
static PHASES: Mutex<Vec<(String, Duration, u64)>> = Mutex::new(Vec::new());

/// Turn the profiler on (idempotent). There is deliberately no `disable`:
/// the CLI enables it once per process, before any timed phase runs.
pub fn enable() {
    ENABLED.store(true, Ordering::Relaxed);
}

/// Whether `--profile` is active.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Accumulates its scope's wall time into the named phase on drop.
pub struct Guard {
    name: &'static str,
    start: Instant,
}

impl Drop for Guard {
    fn drop(&mut self) {
        let dt = self.start.elapsed();
        let mut phases = PHASES.lock().unwrap();
        if let Some(row) = phases.iter_mut().find(|(n, _, _)| n == self.name) {
            row.1 += dt;
            row.2 += 1;
        } else {
            phases.push((self.name.to_string(), dt, 1));
        }
    }
}

/// Time a phase: hold the returned guard for the phase's duration. `None`
/// (no timing, no lock) when the profiler is off, so call sites stay free
/// on the default path.
pub fn scope(name: &'static str) -> Option<Guard> {
    if !enabled() {
        return None;
    }
    Some(Guard {
        name,
        start: Instant::now(),
    })
}

/// Drop all accumulated phases (tests; the CLI never needs it).
pub fn reset() {
    PHASES.lock().unwrap().clear();
}

/// Render the accumulated breakdown, longest phase first, plus any extra
/// caller-provided lines (e.g. the delta-reuse rate, which is a counter
/// ratio rather than a timing).
pub fn report(out: &mut dyn std::io::Write, extra: &[String]) -> std::io::Result<()> {
    let mut phases = PHASES.lock().unwrap().clone();
    phases.sort_by(|a, b| b.1.cmp(&a.1));
    let total: Duration = phases.iter().map(|p| p.1).sum();
    writeln!(out, "--- profile ({:.3} s timed) ---", total.as_secs_f64())?;
    for (name, dt, hits) in &phases {
        let pct = if total.as_nanos() > 0 {
            100.0 * dt.as_secs_f64() / total.as_secs_f64()
        } else {
            0.0
        };
        writeln!(
            out,
            "{name:<12} {:>9.3} s  {pct:>5.1}%  ({hits} call{})",
            dt.as_secs_f64(),
            if *hits == 1 { "" } else { "s" }
        )?;
    }
    for line in extra {
        writeln!(out, "{line}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_scope_is_free_and_enabled_scope_accumulates() {
        // Off by default: no guard, nothing recorded.
        reset();
        assert!(scope("idle").is_none());
        enable();
        {
            let _g = scope("phase-a");
            let _h = scope("phase-a");
        }
        {
            let _g = scope("phase-b");
        }
        let mut buf = Vec::new();
        report(&mut buf, &["extra: 1".into()]).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("phase-a"), "missing phase-a in:\n{s}");
        assert!(s.contains("2 calls"), "phase-a hit twice in:\n{s}");
        assert!(s.contains("phase-b"));
        assert!(s.contains("extra: 1"));
        reset();
    }
}
