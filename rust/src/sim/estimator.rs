//! The coarse-grain performance estimator's timing model — the paper's
//! contribution. Deliberately ignores memory hierarchy, contention,
//! coherence and OS effects (§VI: "our estimator does not consider memory
//! hierarchy aspects like cache coherence and pinning of memory pages,
//! neither memory contention"): every cost is a clean closed form over the
//! basic trace, the HLS report and the board parameters.

use crate::config::BoardConfig;
use crate::sim::engine::{TaskCtx, TimingModel};
use crate::sim::time::{transfer_ps, us_to_ps, Clock, Ps};

/// Deterministic coarse-grain cost model.
#[derive(Clone, Debug)]
pub struct EstimatorModel {
    smp_clock: Clock,
}

impl EstimatorModel {
    /// Bind the model to a board's SMP clock.
    pub fn new(board: &BoardConfig) -> Self {
        Self {
            smp_clock: board.smp_clock(),
        }
    }
}

impl TimingModel for EstimatorModel {
    fn needs_coherence(&self) -> bool {
        false // §VI: the coarse-grain estimator ignores cache coherence
    }

    fn replay_safe(&self) -> bool {
        // Every cost below is a closed form over (task, report, board) —
        // no PRNG, no history — so a checkpointed suffix replay sees
        // exactly the costs a scratch run would (the engine's delta path
        // depends on this; see `Simulator::resume_mut`).
        true
    }

    fn creation_ps(&mut self, board: &BoardConfig) -> Ps {
        us_to_ps(board.task_creation_us)
    }

    fn smp_compute_ps(&mut self, ctx: &TaskCtx, _board: &BoardConfig) -> Ps {
        // The basic trace carries the measured (or modelled) ARM cycles.
        self.smp_clock
            .cycles_to_ps(ctx.program.tasks[ctx.task as usize].smp_cycles)
    }

    fn accel_occupancy_ps(
        &mut self,
        ctx: &TaskCtx,
        board: &BoardConfig,
        input_in_occupancy: bool,
    ) -> Ps {
        let report = ctx
            .report
            .expect("accel occupancy requires an HLS report");
        let compute = report.compute_ps();
        if input_in_occupancy {
            // §IV: "the time associated with a task running in a hardware
            // accelerator device can be seen as the time of the input data
            // DMA transfer plus the computation time".
            compute + transfer_ps(ctx.xfers.bytes_in, board.dma_bw_mbps)
        } else {
            compute
        }
    }

    fn submit_ps(&mut self, n_transfers: u32, board: &BoardConfig) -> Ps {
        us_to_ps(board.dma_submit_us) * n_transfers as Ps
    }

    fn dma_ps(&mut self, bytes: u64, _ctx: &TaskCtx, board: &BoardConfig) -> Ps {
        transfer_ps(bytes, board.dma_bw_mbps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Dep, KernelDecl, KernelProfile, Targets, TaskProgram};

    fn fixture() -> (TaskProgram, BoardConfig) {
        let mut p = TaskProgram::new("t");
        let k = p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::BOTH,
            profile: KernelProfile {
                flops: 1000,
                inner_trip: 1000,
                in_bytes: 4000,
                out_bytes: 2000,
                dtype_bytes: 4,
                divsqrt: false,
            },
        });
        p.add_task(k, 667_000, vec![Dep::inout(0x10, 2000)]); // 1 ms at 667 MHz
        (p, BoardConfig::zynq706())
    }

    fn ctx(p: &TaskProgram) -> TaskCtx<'_> {
        TaskCtx {
            task: 0,
            kernel: 0,
            program: p,
            xfers: crate::coordinator::elaborate::Xfers {
                n_in: 1,
                n_out: 1,
                bytes_in: 4000,
                bytes_out: 2000,
            },
            report: None,
            accels_for_kernel: 1,
            active_dma_streams: 0,
            cross_device_inputs: 0,
            now: 0,
        }
    }

    #[test]
    fn smp_cost_follows_trace_cycles() {
        let (p, b) = fixture();
        let mut m = EstimatorModel::new(&b);
        let c = ctx(&p);
        let ps = m.smp_compute_ps(&c, &b);
        // 667000 cycles at 667 MHz = 1 ms
        assert!((ps as i64 - 1_000_000_000).abs() < 1000);
    }

    #[test]
    fn submit_scales_with_transfer_count() {
        let (_p, b) = fixture();
        let mut m = EstimatorModel::new(&b);
        assert_eq!(m.submit_ps(3, &b), 3 * us_to_ps(b.dma_submit_us));
        assert_eq!(m.submit_ps(0, &b), 0);
    }

    #[test]
    fn dma_matches_bandwidth() {
        let (p, b) = fixture();
        let mut m = EstimatorModel::new(&b);
        let c = ctx(&p);
        // 400 MB/s: 4000 bytes = 10 us
        assert_eq!(m.dma_ps(4_000_000, &c, &b), us_to_ps(10_000.0));
    }

    #[test]
    fn estimator_is_deterministic() {
        let (p, b) = fixture();
        let mut m1 = EstimatorModel::new(&b);
        let mut m2 = EstimatorModel::new(&b);
        let c = ctx(&p);
        for _ in 0..5 {
            assert_eq!(m1.smp_compute_ps(&c, &b), m2.smp_compute_ps(&c, &b));
            assert_eq!(m1.creation_ps(&b), m2.creation_ps(&b));
        }
    }
}
