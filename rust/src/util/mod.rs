//! Small self-contained utilities: deterministic PRNG, statistics helpers,
//! and a minimal JSON substrate (`json`).
//!
//! The repository builds fully offline against the vendored crate set of the
//! `xla` crate, so general-purpose dependencies (serde, rand, ...) are
//! implemented here as first-class substrates instead.

pub mod bench;
pub mod bench_check;
pub mod faultpoint;
pub mod fnv;
pub mod fxhash;
pub mod json;
pub mod persist;
pub mod profile;

/// SplitMix64 — used to seed the main generator and as a cheap standalone
/// stream. Reference: Steele, Lea, Flood. "Fast splittable pseudorandom
/// number generators" (OOPSLA'14).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed a SplitMix64 stream.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the repository's deterministic PRNG. Every stochastic
/// component (board-emulator jitter, property-test generators, synthetic
/// workloads) takes an explicit seed so runs are reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator (any seed is fine, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)` (requires `lo < hi`).
    #[inline]
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; simple and fine
    /// for the jitter models).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.gen_range(0, (i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0.0 for < 2 samples).
pub fn stdev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (0.0 for empty); does not require sorted input.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Kendall rank correlation (tau-a) between two equally-long score vectors.
/// Used by the sweep harness to quantify "same speedup trends" between the
/// coarse-grain estimator and the board emulator.
pub fn kendall_tau(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let mut concordant = 0i64;
    let mut discordant = 0i64;
    for i in 0..n {
        for j in (i + 1)..n {
            let da = a[i] - a[j];
            let db = b[i] - b[j];
            let s = da * db;
            if s > 0.0 {
                concordant += 1;
            } else if s < 0.0 {
                discordant += 1;
            }
        }
    }
    let pairs = (n * (n - 1) / 2) as f64;
    (concordant - discordant) as f64 / pairs
}

/// Format picoseconds as a human-readable duration.
pub fn fmt_ps(ps: u64) -> String {
    let ns = ps as f64 / 1e3;
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.4} s", ns / 1e9)
    }
}

/// Format seconds compactly (used by the Fig-6 analysis-time report).
pub fn fmt_secs(s: f64) -> String {
    if s < 60.0 {
        format!("{s:.1} s")
    } else if s < 3600.0 {
        format!("{:.1} min", s / 60.0)
    } else if s < 86400.0 {
        format!("{:.2} h", s / 3600.0)
    } else {
        format!("{:.2} days", s / 86400.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_streams_differ_by_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn rng_f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.gen_range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..50_000).map(|_| r.next_gaussian()).collect();
        assert!(mean(&xs).abs() < 0.02);
        assert!((stdev(&xs) - 1.0).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn stats_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!((stdev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138).abs() < 1e-3);
    }

    #[test]
    fn kendall_tau_extremes() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 30.0, 40.0];
        let c = [40.0, 30.0, 20.0, 10.0];
        assert_eq!(kendall_tau(&a, &b), 1.0);
        assert_eq!(kendall_tau(&a, &c), -1.0);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ps(500), "0.5 ns");
        assert!(fmt_ps(1_500_000).contains("us"));
        assert!(fmt_secs(7200.0).contains('h'));
        assert!(fmt_secs(200_000.0).contains("days"));
    }
}
