//! Chaos conformance suite for `zynq-estimator serve` under load and
//! failure: floods past the admission limits, hostile and oversized
//! request lines, abrupt client disconnects, injected connection and
//! save faults, SIGTERM mid-session. The invariants pinned here are the
//! overload contract's:
//!
//! * every request a transport accepts is answered by exactly one
//!   response line — structured error or result, never silence, never a
//!   desynced stream;
//! * shedding load (`OVERLOADED`), expiring deadlines (`TIMEOUT`) and
//!   read-only degradation (`DEGRADED`) are structured responses, not
//!   process deaths;
//! * no chaos run ever corrupts the memo: whatever was saved stays
//!   loadable and byte-identical to an unfaulted session's save.
//!
//! Like `service_conformance`, everything runs black-box against the
//! real binary; faults arrive through `ZYNQ_FAULTS`, exactly as a
//! deployment would inject them.

use std::io::{BufRead, BufReader, Read, Write};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};

use zynq_estimator::util::json::{parse, Value};
use zynq_estimator::util::Rng;

const EXE: &str = env!("CARGO_BIN_EXE_zynq-estimator");

const EST_A: &str = r#"{"id":1,"req":"estimate","app":"matmul","n":256,"bs":64,"accel":["mxm64:U32"]}"#;
const EST_B: &str = r#"{"id":2,"req":"estimate","app":"matmul","n":256,"bs":64,"accel":["mxm64:U16"]}"#;
const LU_A: &str = r#"{"id":3,"req":"estimate","app":"lu","n":256,"bs":64,"accel":["trsm_row:U16"]}"#;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("zynq_chaos_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// One daemon child with its NDJSON pipe pair (stdio transport).
struct Daemon {
    child: Child,
    stdin: Option<ChildStdin>,
    stdout: BufReader<ChildStdout>,
}

impl Daemon {
    fn spawn(args: &[&str], faults: Option<&str>) -> Daemon {
        let mut cmd = Command::new(EXE);
        cmd.arg("serve").args(args);
        cmd.stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::null());
        match faults {
            Some(f) => cmd.env("ZYNQ_FAULTS", f),
            None => cmd.env_remove("ZYNQ_FAULTS"),
        };
        let mut child = cmd.spawn().expect("spawn serve daemon");
        let stdin = child.stdin.take().unwrap();
        let stdout = BufReader::new(child.stdout.take().unwrap());
        Daemon {
            child,
            stdin: Some(stdin),
            stdout,
        }
    }

    /// Send one request line, read one response line. `None` when the
    /// daemon died instead of answering.
    fn request(&mut self, line: &str) -> Option<Value> {
        let stdin = self.stdin.as_mut().expect("stdin already closed");
        if writeln!(stdin, "{line}").and_then(|_| stdin.flush()).is_err() {
            return None;
        }
        let mut buf = String::new();
        match self.stdout.read_line(&mut buf) {
            Ok(0) | Err(_) => None,
            Ok(_) => Some(parse(buf.trim_end()).expect("response must be one JSON object")),
        }
    }

    fn wait(mut self) -> std::process::ExitStatus {
        drop(self.stdin.take());
        self.child.wait().expect("wait on daemon")
    }
}

fn shutdown_clean(mut daemon: Daemon) {
    let resp = daemon.request(r#"{"req":"shutdown"}"#).expect("shutdown ack");
    assert!(is_ok(&resp), "{resp:?}");
    assert_eq!(resp.get("exit_code").and_then(|v| v.as_i64()), Some(0));
    let status = daemon.wait();
    assert!(status.success(), "clean shutdown must exit 0: {status:?}");
}

fn is_ok(v: &Value) -> bool {
    v.get("ok").and_then(|x| x.as_bool()) == Some(true)
}

fn u(v: &Value, key: &str) -> u64 {
    v.get(key)
        .and_then(|x| x.as_u64())
        .unwrap_or_else(|| panic!("missing u64 field '{key}' in {v:?}"))
}

fn kind(v: &Value) -> Option<&str> {
    v.get("kind").and_then(|x| x.as_str())
}

/// Spawn `serve --listen 127.0.0.1:0 <args>` and parse the bound
/// address off stderr (port 0 always — fixed ports collide across
/// parallel CI jobs). stdin and the stderr reader stay alive with the
/// caller so the child never sees a closed pipe.
fn spawn_tcp(
    args: &[&str],
    faults: Option<&str>,
) -> (
    Child,
    ChildStdin,
    String,
    BufReader<std::process::ChildStderr>,
) {
    let mut cmd = Command::new(EXE);
    cmd.arg("serve").args(args).args(["--listen", "127.0.0.1:0"]);
    cmd.stdin(Stdio::piped())
        .stdout(Stdio::null())
        .stderr(Stdio::piped());
    match faults {
        Some(f) => cmd.env("ZYNQ_FAULTS", f),
        None => cmd.env_remove("ZYNQ_FAULTS"),
    };
    let mut child = cmd.spawn().expect("spawn TCP daemon");
    let stdin = child.stdin.take().unwrap();
    let mut stderr = BufReader::new(child.stderr.take().unwrap());
    let addr = loop {
        let mut line = String::new();
        assert!(
            stderr.read_line(&mut line).unwrap() > 0,
            "daemon exited before announcing its listener"
        );
        if let Some(rest) = line.trim().strip_prefix("serve: listening on ") {
            break rest.to_string();
        }
    };
    (child, stdin, addr, stderr)
}

/// One TCP client: send a line, read a line.
struct TcpClient {
    stream: std::net::TcpStream,
    reader: BufReader<std::net::TcpStream>,
}

impl TcpClient {
    fn connect(addr: &str) -> TcpClient {
        let stream = std::net::TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        TcpClient { stream, reader }
    }

    /// `None` when the connection died instead of answering.
    fn request(&mut self, line: &str) -> Option<Value> {
        if writeln!(&mut self.stream, "{line}").is_err() {
            return None;
        }
        let mut buf = String::new();
        match self.reader.read_line(&mut buf) {
            Ok(0) | Err(_) => None,
            Ok(_) => Some(parse(buf.trim_end()).expect("response must be one JSON object")),
        }
    }
}

/// Request templates the garbage generator mutates — every daemon
/// request shape except `shutdown` (a mutation that survived as a valid
/// shutdown would end the session mid-property).
const TEMPLATES: [&str; 6] = [
    EST_A,
    r#"{"id":4,"req":"energy","app":"matmul","n":256,"bs":64,"accel":["mxm64:U32"]}"#,
    r#"{"id":5,"req":"memo","action":"stats"}"#,
    r#"{"id":6,"req":"ping"}"#,
    r#"{"id":7,"req":"health"}"#,
    r#"{"id":8,"req":"batch","items":[{"id":"a","req":"estimate","app":"matmul","accel":["mxm64:U32"]}]}"#,
];

/// Structural junk spliced into lines (no `\n` — a newline would split
/// the line into two requests and void the one-in/one-out accounting).
const TOKENS: [&str; 10] = [
    "{", "}", "[", "]", "\"", "\\", ",", "null", "1e308", "\u{0}",
];

/// Mutate one template into a line: byte flips, truncation, token
/// splices, or replacement with pure printable garbage. Deterministic
/// per (seed, case).
fn garbage_line(rng: &mut Rng) -> String {
    if rng.next_u64() % 4 == 0 {
        // Pure garbage: random printable ASCII, never valid JSON.
        let len = 1 + (rng.next_u64() % 120) as usize;
        return (0..len)
            .map(|_| (b' ' + (rng.next_u64() % 94) as u8) as char)
            .filter(|&c| c != '\n')
            .collect();
    }
    let mut line: Vec<u8> = TEMPLATES[(rng.next_u64() % TEMPLATES.len() as u64) as usize]
        .as_bytes()
        .to_vec();
    for _ in 0..1 + rng.next_u64() % 3 {
        match rng.next_u64() % 3 {
            0 if !line.is_empty() => {
                let i = (rng.next_u64() % line.len() as u64) as usize;
                line[i] = b' ' + (rng.next_u64() % 94) as u8;
            }
            1 if !line.is_empty() => {
                let i = (rng.next_u64() % line.len() as u64) as usize;
                line.truncate(i);
            }
            _ => {
                let tok = TOKENS[(rng.next_u64() % TOKENS.len() as u64) as usize];
                let at = (rng.next_u64() % (line.len() as u64 + 1)) as usize;
                line.splice(at..at, tok.bytes());
            }
        }
    }
    String::from_utf8_lossy(&line).into_owned()
}

#[test]
fn garbage_lines_each_get_exactly_one_structured_response_and_never_desync() {
    // The property (seeded forall, black-box): ANY garbage line — JSON
    // or not, truncated or spliced — gets exactly one response object;
    // error responses carry a code in the documented taxonomy; and a
    // correlated ping between cases proves the stream never skewed by
    // even one line.
    let mut daemon = Daemon::spawn(&[], None);
    let mut rng = Rng::new(0xC4A0_5EED);
    for case in 0..150u64 {
        let line = garbage_line(&mut rng);
        if line.trim().is_empty() {
            continue; // blank lines are legitimately ignored, not answered
        }
        let resp = daemon
            .request(&line)
            .unwrap_or_else(|| panic!("case {case}: daemon died on {line:?}"));
        if !is_ok(&resp) {
            let code = u(&resp, "code");
            assert!(
                (1..=6).contains(&code),
                "case {case}: code {code} outside the taxonomy for {line:?}"
            );
        }
        if case % 10 == 9 {
            let probe = format!(r#"{{"id":{case},"req":"ping"}}"#);
            let pong = daemon.request(&probe).expect("ping after garbage");
            assert!(is_ok(&pong), "case {case}: {pong:?}");
            assert_eq!(
                pong.get("id").and_then(|v| v.as_u64()),
                Some(case),
                "case {case}: stream desynced (wrong id echoed)"
            );
        }
    }
    shutdown_clean(daemon);
}

#[test]
fn oversized_lines_are_shed_without_desyncing_the_stream() {
    let mut daemon = Daemon::spawn(&["--max-line-bytes", "4096"], None);
    // 64 KiB of junk on one line: one OVERLOADED response, bounded
    // memory, and the very next request parses normally.
    let huge = "x".repeat(64 * 1024);
    let resp = daemon.request(&huge).expect("oversized must be answered");
    assert!(!is_ok(&resp));
    assert_eq!(u(&resp, "code"), 5);
    assert_eq!(kind(&resp), Some("OVERLOADED"));
    assert!(u(&resp, "retry_after_ms") >= 1);
    // A line over the limit that *would* have been valid JSON is shed
    // the same way — the parser never sees it.
    let padded = format!("{EST_A}{}", " ".repeat(8 * 1024));
    let resp = daemon.request(&padded).expect("padded line answered");
    assert_eq!(u(&resp, "code"), 5);
    // Stream still in sync: a real request works.
    let est = daemon.request(EST_A).expect("estimate after oversized");
    assert!(is_ok(&est), "{est:?}");
    shutdown_clean(daemon);
}

#[test]
fn deadline_timeouts_are_structured_and_leave_warm_answers_served() {
    let mut daemon = Daemon::spawn(&[], None);
    // Cold + impossible deadline: structured TIMEOUT, nothing evaluated.
    let cold = r#"{"id":1,"req":"estimate","app":"matmul","n":256,"bs":64,"accel":["mxm64:U32"],"deadline_ms":0}"#;
    let resp = daemon.request(cold).unwrap();
    assert!(!is_ok(&resp));
    assert_eq!(u(&resp, "code"), 4);
    assert_eq!(kind(&resp), Some("TIMEOUT"));
    // Warm the point without a deadline, then the same impossible
    // deadline succeeds — memo hits need no evaluation budget.
    let warm = daemon.request(EST_A).unwrap();
    assert!(is_ok(&warm), "{warm:?}");
    let hit = daemon.request(cold).unwrap();
    assert!(is_ok(&hit), "warm point must beat a zero deadline: {hit:?}");
    assert_eq!(u(&hit, "evaluated"), 0);
    // A dse sweep under a zero deadline cancels at the first round
    // barrier instead of running to completion.
    let dse = r#"{"id":2,"req":"dse","app":"matmul","n":128,"top":3,"deadline_ms":0}"#;
    let resp = daemon.request(dse).unwrap();
    assert_eq!(u(&resp, "code"), 4, "{resp:?}");
    assert_eq!(kind(&resp), Some("TIMEOUT"));
    shutdown_clean(daemon);
}

#[test]
fn flooded_daemon_sheds_load_with_structured_overloads_and_stays_up() {
    // Tiny limits + six concurrent clients hammering cold estimates:
    // every request gets exactly one response; each is either a result
    // or OVERLOADED-with-backoff; the daemon then serves normally.
    let (mut child, stdin, addr, _stderr) = spawn_tcp(
        &["--max-inflight", "1", "--max-queue", "1", "--workers", "2"],
        None,
    );
    let handles: Vec<_> = (0..6u64)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = TcpClient::connect(&addr);
                let mut answered = 0u64;
                let mut shed = 0u64;
                for i in 0..10u64 {
                    let n = 64 + 64 * ((c * 10 + i) % 8); // a few distinct points
                    let req = format!(
                        r#"{{"id":{i},"req":"estimate","app":"matmul","n":{n},"bs":64,"accel":["mxm64:U32"]}}"#
                    );
                    let resp = client
                        .request(&req)
                        .unwrap_or_else(|| panic!("client {c}: no response to request {i}"));
                    assert_eq!(
                        resp.get("id").and_then(|v| v.as_u64()),
                        Some(i),
                        "client {c}: stream desynced"
                    );
                    if is_ok(&resp) {
                        answered += 1;
                    } else {
                        assert_eq!(u(&resp, "code"), 5, "client {c}: {resp:?}");
                        assert_eq!(kind(&resp), Some("OVERLOADED"));
                        assert!(u(&resp, "retry_after_ms") >= 1);
                        shed += 1;
                    }
                }
                (answered, shed)
            })
        })
        .collect();
    let totals: Vec<(u64, u64)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let answered: u64 = totals.iter().map(|t| t.0).sum();
    let shed: u64 = totals.iter().map(|t| t.1).sum();
    assert_eq!(answered + shed, 60, "every request must be accounted for");

    // Probes bypass admission even under pressure, and after the flood a
    // bounded retry loop must land a real answer.
    let mut client = TcpClient::connect(&addr);
    let health = client.request(r#"{"req":"health"}"#).unwrap();
    assert!(is_ok(&health), "{health:?}");
    if shed > 0 {
        assert!(u(&health, "overloaded") >= shed, "{health:?}");
    }
    let mut landed = false;
    for _ in 0..100 {
        let resp = client.request(EST_A).unwrap();
        if is_ok(&resp) {
            landed = true;
            break;
        }
        assert_eq!(u(&resp, "code"), 5);
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(landed, "a lone client must eventually be admitted");
    let ack = client.request(r#"{"req":"shutdown"}"#).unwrap();
    assert!(is_ok(&ack), "{ack:?}");
    let status = child.wait().unwrap();
    assert!(status.success(), "flood must not dirty the exit: {status:?}");
    drop(stdin);
}

#[test]
fn abrupt_disconnects_never_kill_the_daemon_or_poison_its_state() {
    let (mut child, stdin, addr, _stderr) = spawn_tcp(&["--workers", "2"], None);
    // Eight clients fire one request each and slam the connection shut
    // without reading the response — the write side sees a dead peer.
    let handles: Vec<_> = (0..8)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let stream = std::net::TcpStream::connect(&addr).unwrap();
                let req = if c % 2 == 0 { EST_A } else { LU_A };
                let _ = writeln!(&mut &stream, "{req}");
                drop(stream); // disconnect before the response
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // The daemon survives and serves a well-behaved client: the
    // disconnected requests either never ran (queued work dropped) or
    // completed into the memo — both observable states are consistent.
    let mut client = TcpClient::connect(&addr);
    let est = client.request(EST_A).expect("daemon must survive disconnects");
    assert!(is_ok(&est), "{est:?}");
    let lu = client.request(LU_A).unwrap();
    assert!(is_ok(&lu), "{lu:?}");
    let health = client.request(r#"{"req":"health"}"#).unwrap();
    assert!(is_ok(&health), "{health:?}");
    assert_eq!(u(&health, "inflight"), 0, "no request may leak its admission token");
    let ack = client.request(r#"{"req":"shutdown"}"#).unwrap();
    assert!(is_ok(&ack), "{ack:?}");
    assert!(child.wait().unwrap().success());
    drop(stdin);
}

#[test]
fn injected_connection_faults_end_one_connection_not_the_daemon() {
    // `conn.read` hit #1 is consumed by the stdio loop the moment the
    // daemon starts (its read loop runs the same faultpoint), so the
    // specs target hit #2 for reads; `conn.write` is only ever hit when
    // a response is written, and stdin stays silent here, so hit #1 of
    // it belongs to the first TCP response.
    let (mut child, stdin, addr, _stderr) =
        spawn_tcp(&[], Some("conn.read@2!error,conn.write@1!error"));
    // Give the stdio loop time to burn conn.read hit #1.
    std::thread::sleep(std::time::Duration::from_millis(100));

    // Connection A dies on the injected read fault before answering.
    let mut a = TcpClient::connect(&addr);
    assert!(
        a.request(r#"{"id":1,"req":"ping"}"#).is_none(),
        "connection A must be dropped by the read fault"
    );
    // Connection B processes its request, then the injected write fault
    // eats the response: the request ran, the connection died, the
    // daemon did not.
    let mut b = TcpClient::connect(&addr);
    assert!(
        b.request(r#"{"id":2,"req":"ping"}"#).is_none(),
        "connection B must be dropped by the write fault"
    );
    // Connection C sees a perfectly healthy daemon.
    let mut c = TcpClient::connect(&addr);
    let pong = c.request(r#"{"id":3,"req":"ping"}"#).expect("daemon survived");
    assert!(is_ok(&pong), "{pong:?}");
    let est = c.request(EST_A).unwrap();
    assert!(is_ok(&est), "{est:?}");
    let health = c.request(r#"{"req":"health"}"#).unwrap();
    assert_eq!(u(&health, "inflight"), 0, "dead connections must release their tokens");
    let ack = c.request(r#"{"req":"shutdown"}"#).unwrap();
    assert!(is_ok(&ack), "{ack:?}");
    assert!(child.wait().unwrap().success());
    drop(stdin);
}

#[test]
fn admission_faultpoint_rejects_one_request_with_overloaded() {
    // `queue.admit` is the hook CI's chaos job uses to force shedding
    // deterministically; the response must be indistinguishable from a
    // real capacity rejection.
    let mut daemon = Daemon::spawn(&[], Some("queue.admit!error"));
    let resp = daemon.request(EST_A).unwrap();
    assert!(!is_ok(&resp));
    assert_eq!(u(&resp, "code"), 5);
    assert_eq!(kind(&resp), Some("OVERLOADED"));
    // One-shot spec: the retry goes through and evaluates normally.
    let resp = daemon.request(EST_A).unwrap();
    assert!(is_ok(&resp), "{resp:?}");
    assert_eq!(u(&resp, "evaluated"), 1);
    shutdown_clean(daemon);
}

#[test]
fn tripped_save_breaker_serves_hits_read_only_and_recovers_on_restart() {
    let d = tmpdir("breaker");
    let memo_path = d.join("m.json");
    let memo = memo_path.display().to_string();
    // --breaker-threshold 1 + an injected one-shot save failure: the
    // first save (cadence 1 — right after the first evaluation) trips
    // the breaker into read-only mode.
    let mut daemon = Daemon::spawn(
        &[
            "--memo", &memo, "--save-every", "1", "--breaker-threshold", "1",
        ],
        Some("save.breaker!error"),
    );
    let cold = daemon.request(EST_A).unwrap();
    assert!(is_ok(&cold), "the evaluation itself must succeed: {cold:?}");
    assert_eq!(u(&cold, "evaluated"), 1);

    // Degraded mode: hits served, cold work and sweeps rejected.
    let health = daemon.request(r#"{"req":"health"}"#).unwrap();
    assert_eq!(
        health.get("degraded").and_then(|v| v.as_bool()),
        Some(true),
        "{health:?}"
    );
    let hit = daemon.request(EST_A).unwrap();
    assert!(is_ok(&hit), "memo hits must survive the breaker: {hit:?}");
    assert_eq!(u(&hit, "evaluated"), 0);
    let rejected = daemon.request(EST_B).unwrap();
    assert_eq!(u(&rejected, "code"), 6, "{rejected:?}");
    assert_eq!(kind(&rejected), Some("DEGRADED"));
    let sweep = daemon
        .request(r#"{"req":"dse","app":"matmul","n":128,"top":3}"#)
        .unwrap();
    assert_eq!(u(&sweep, "code"), 6, "sweeps evaluate cold points: {sweep:?}");

    // Shutdown: the injected fault is spent, so the final save lands —
    // but the session still reports its degraded history via exit 1.
    let ack = daemon.request(r#"{"req":"shutdown"}"#).unwrap();
    assert_eq!(ack.get("exit_code").and_then(|v| v.as_i64()), Some(1));
    let status = daemon.wait();
    assert!(!status.success(), "a session with failed saves exits 1");
    assert!(memo_path.exists(), "the recovered final save must land");

    // A faultless restart serves the saved point and evaluates the one
    // the breaker rejected; nothing was corrupted.
    let mut daemon = Daemon::spawn(&["--memo", &memo], None);
    assert_eq!(u(&daemon.request(EST_A).unwrap(), "evaluated"), 0);
    assert_eq!(u(&daemon.request(EST_B).unwrap(), "evaluated"), 1);
    let stats = daemon.request(r#"{"req":"memo","action":"stats"}"#).unwrap();
    assert_eq!(u(&stats, "points"), 2);
    shutdown_clean(daemon);
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn chaos_session_save_is_byte_identical_to_an_unfaulted_one() {
    // The memo-integrity pin: a session that weathered connection
    // faults and oversized lines must save byte-for-byte what a calm
    // session saves for the same admitted work. The faulted connection
    // dies before its request is read (conn.read fires at the top of
    // the loop), so the admitted work is identical by construction.
    let run_session = |dir: &str, faults: Option<&str>| -> Vec<u8> {
        let d = tmpdir(dir);
        let memo_path = d.join("m.json");
        let memo = memo_path.display().to_string();
        let (mut child, stdin, addr, _stderr) =
            spawn_tcp(&["--memo", &memo, "--max-line-bytes", "4096"], faults);
        std::thread::sleep(std::time::Duration::from_millis(100));
        if faults.is_some() {
            // A casualty connection (read fault) and an oversized line:
            // neither may perturb what the memo records.
            let mut dead = TcpClient::connect(&addr);
            assert!(dead.request(r#"{"req":"ping"}"#).is_none());
            let mut noisy = TcpClient::connect(&addr);
            let huge = "y".repeat(16 * 1024);
            assert_eq!(u(&noisy.request(&huge).unwrap(), "code"), 5);
        }
        let mut client = TcpClient::connect(&addr);
        for req in [EST_A, EST_B, LU_A] {
            let resp = client.request(req).unwrap();
            assert!(is_ok(&resp), "{resp:?}");
        }
        let ack = client.request(r#"{"req":"shutdown"}"#).unwrap();
        assert!(is_ok(&ack), "{ack:?}");
        assert!(child.wait().unwrap().success());
        drop(stdin);
        let bytes = std::fs::read(&memo_path).expect("memo saved");
        std::fs::remove_dir_all(&d).ok();
        bytes
    };
    let calm = run_session("integrity_calm", None);
    let chaotic = run_session("integrity_chaos", Some("conn.read@2!error"));
    assert_eq!(
        calm, chaotic,
        "connection chaos must never leak into the persisted memo"
    );
}

#[cfg(unix)]
#[test]
fn sigterm_drains_saves_and_exits_clean() {
    let d = tmpdir("sigterm");
    let memo_path = d.join("m.json");
    let memo = memo_path.display().to_string();
    let mut cmd = Command::new(EXE);
    cmd.args(["serve", "--memo", &memo]);
    cmd.stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped());
    cmd.env_remove("ZYNQ_FAULTS");
    let mut child = cmd.spawn().unwrap();
    let mut stdin = child.stdin.take().unwrap();
    let mut stdout = BufReader::new(child.stdout.take().unwrap());

    writeln!(stdin, "{EST_A}").unwrap();
    stdin.flush().unwrap();
    let mut line = String::new();
    stdout.read_line(&mut line).unwrap();
    let resp = parse(line.trim()).unwrap();
    assert!(is_ok(&resp), "{resp:?}");

    // SIGTERM with no work in flight: drain, save, exit 0. stdin stays
    // open — the signal, not EOF, must end the process.
    let status = Command::new("kill")
        .args(["-TERM", &child.id().to_string()])
        .status()
        .expect("run kill");
    assert!(status.success(), "kill -TERM failed");
    let status = child.wait().unwrap();
    assert!(status.success(), "drained daemon must exit 0: {status:?}");
    let mut err_text = String::new();
    child
        .stderr
        .take()
        .unwrap()
        .read_to_string(&mut err_text)
        .unwrap();
    assert!(
        err_text.contains("drained and saved (SIGTERM)"),
        "missing drain trace in stderr:\n{err_text}"
    );
    assert!(memo_path.exists(), "the drain must save the memo");
    drop(stdin);

    // The saved memo answers the point without re-evaluating.
    let mut daemon = Daemon::spawn(&["--memo", &memo], None);
    let warm = daemon.request(EST_A).unwrap();
    assert_eq!(u(&warm, "evaluated"), 0, "{warm:?}");
    shutdown_clean(daemon);
    std::fs::remove_dir_all(&d).ok();
}
