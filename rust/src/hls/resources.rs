//! Device resource budgets and co-design feasibility — the reason the paper
//! needs an estimator at all: not every accelerator combination fits the
//! programmable logic ("the hardware resource estimation for two
//! 128x128-block mxmBlock accelerators indicates that it is not feasible to
//! map them", §VI).

use super::report::Resources;

/// A programmable-logic part description.
#[derive(Clone, Debug, PartialEq)]
pub struct FpgaPart {
    /// Part name (e.g. `xc7z045`).
    pub name: String,
    /// Raw resource capacity of the part.
    pub budget: Resources,
    /// Fraction of the raw budget usable before place-and-route fails or
    /// timing collapses (routability headroom). Industry rule of thumb and
    /// what Vivado's utilization warnings track.
    pub routable_fraction: f64,
}

impl FpgaPart {
    /// Zynq-7045 (ZC706 board): Kintex-7-class fabric.
    /// 218,600 LUT / 437,200 FF / 545 BRAM36 (=1090 BRAM18) / 900 DSP48E1.
    pub fn xc7z045() -> Self {
        Self {
            name: "xc7z045".into(),
            budget: Resources {
                luts: 218_600,
                ffs: 437_200,
                dsps: 900,
                bram18: 1_090,
            },
            routable_fraction: 0.8,
        }
    }

    /// Zynq-7020 (smaller Zedboard-class part) — used by tests to check the
    /// feasibility logic generalizes.
    pub fn xc7z020() -> Self {
        Self {
            name: "xc7z020".into(),
            budget: Resources {
                luts: 53_200,
                ffs: 106_400,
                dsps: 220,
                bram18: 280,
            },
            routable_fraction: 0.8,
        }
    }

    /// Zynq UltraScale+ ZU9EG (ZCU102 board): 274,080 LUT / 548,160 FF /
    /// 912 BRAM36 (=1,824 BRAM18) / 2,520 DSP48E2.
    pub fn xczu9eg() -> Self {
        Self {
            name: "xczu9eg".into(),
            budget: Resources {
                luts: 274_080,
                ffs: 548_160,
                dsps: 2_520,
                bram18: 1_824,
            },
            routable_fraction: 0.8,
        }
    }

    /// Look a built-in part up by name (`xc7z045` | `xc7z020` | `xczu9eg`).
    /// Used by the board-space resolver so TOML board files can name their
    /// part (`[fabric] part = "xc7z020"`).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "xc7z045" => Some(Self::xc7z045()),
            "xc7z020" => Some(Self::xc7z020()),
            "xczu9eg" => Some(Self::xczu9eg()),
            _ => None,
        }
    }

    /// The budget after routability derating — what co-designs must fit in.
    pub fn effective_budget(&self) -> Resources {
        Resources {
            luts: (self.budget.luts as f64 * self.routable_fraction) as u64,
            ffs: (self.budget.ffs as f64 * self.routable_fraction) as u64,
            dsps: (self.budget.dsps as f64 * self.routable_fraction) as u64,
            bram18: (self.budget.bram18 as f64 * self.routable_fraction) as u64,
        }
    }

    /// Do the given accelerator resource vectors fit together?
    pub fn fits(&self, accels: &[Resources]) -> bool {
        let total = accels
            .iter()
            .fold(Resources::ZERO, |acc, r| acc.add(r));
        total.fits_in(&self.effective_budget())
    }

    /// Total utilization (max over classes, w.r.t. the *raw* budget) of a
    /// set of accelerators — drives the synthesis-time model.
    pub fn utilization(&self, accels: &[Resources]) -> f64 {
        let total = accels
            .iter()
            .fold(Resources::ZERO, |acc, r| acc.add(r));
        total.max_utilization(&self.budget)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn z7045_budget() {
        let p = FpgaPart::xc7z045();
        assert_eq!(p.budget.dsps, 900);
        let eff = p.effective_budget();
        assert_eq!(eff.dsps, 720);
        assert_eq!(eff.bram18, 872);
    }

    #[test]
    fn fits_is_additive() {
        let p = FpgaPart::xc7z045();
        let half = Resources {
            luts: 80_000,
            ffs: 100_000,
            dsps: 400,
            bram18: 300,
        };
        assert!(p.fits(&[half]));
        assert!(!p.fits(&[half, half])); // 800 dsps > 720 effective
    }

    #[test]
    fn utilization_tracks_max_class() {
        let p = FpgaPart::xc7z045();
        let r = Resources {
            luts: 0,
            ffs: 0,
            dsps: 450,
            bram18: 0,
        };
        assert!((p.utilization(&[r]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_codesign_fits() {
        assert!(FpgaPart::xc7z045().fits(&[]));
        assert_eq!(FpgaPart::xc7z045().utilization(&[]), 0.0);
    }
}
