//! DMA transfer model — the Fig. 3 behaviour.
//!
//! On the ZC706 environment the paper analyzes, *input* DMA transfers use
//! per-accelerator channels and scale with the number of accelerators,
//! while *output* transfers serialize on a shared resource ("the input
//! parameters seem to scale with the number of accelerators, but not the
//! output parameters", §IV). This module provides the closed-form transfer
//! model used both by the Fig. 3 microbenchmark and by the cost models, and
//! the contention-aware variant the board emulator layers on top.

use crate::config::BoardConfig;
use crate::sim::time::{transfer_ps, Ps};

/// Closed-form model of moving `bytes` of *input* data split evenly across
/// `accels` accelerators (one channel each when the platform scales).
pub fn input_transfer_ps(board: &BoardConfig, bytes: u64, accels: u32) -> Ps {
    assert!(accels >= 1);
    if board.dma_in_scales {
        // Parallel channels: wall-clock = largest share.
        transfer_ps(bytes.div_ceil(accels as u64), board.dma_bw_mbps)
    } else {
        transfer_ps(bytes, board.dma_bw_mbps)
    }
}

/// Closed-form model of moving `bytes` of *output* data produced by
/// `accels` accelerators.
pub fn output_transfer_ps(board: &BoardConfig, bytes: u64, accels: u32) -> Ps {
    assert!(accels >= 1);
    if board.dma_out_scales {
        transfer_ps(bytes.div_ceil(accels as u64), board.dma_bw_mbps)
    } else {
        // Shared channel: fully serialized regardless of accel count.
        transfer_ps(bytes, board.dma_bw_mbps)
    }
}

/// Contention-degraded bandwidth: `streams` concurrent transfers share the
/// memory ports, each seeing `bw / (1 + alpha * (streams - 1))`. This is
/// the detail the coarse-grain estimator deliberately ignores and the
/// board emulator charges.
pub fn contended_bw_mbps(bw_mbps: f64, alpha: f64, streams: u32) -> f64 {
    assert!(streams >= 1);
    bw_mbps / (1.0 + alpha * (streams as f64 - 1.0))
}

/// Board-emulator variant of [`input_transfer_ps`]: parallel channels, but
/// each channel's bandwidth degraded by port contention.
pub fn input_transfer_contended_ps(board: &BoardConfig, bytes: u64, accels: u32) -> Ps {
    assert!(accels >= 1);
    if board.dma_in_scales {
        let bw = contended_bw_mbps(board.dma_bw_mbps, board.emu.contention_alpha, accels);
        transfer_ps(bytes.div_ceil(accels as u64), bw)
    } else {
        transfer_ps(bytes, board.dma_bw_mbps)
    }
}

/// One row of the Fig. 3 microbenchmark: speedup of `accels` accelerators
/// vs 1 for a transfer of `bytes`, for inputs and outputs, under a model.
#[derive(Clone, Copy, Debug)]
pub struct DmaSpeedup {
    /// Transfer size, bytes.
    pub bytes: u64,
    /// Accelerator (channel) count compared against one.
    pub accels: u32,
    /// Input-transfer speedup of `accels` channels vs one.
    pub input_speedup: f64,
    /// Output-transfer speedup of `accels` channels vs one.
    pub output_speedup: f64,
}

/// Compute Fig. 3's rows under the *estimator* model (ideal scaling).
pub fn fig3_estimator(board: &BoardConfig, bytes: u64, accels: u32) -> DmaSpeedup {
    let in1 = input_transfer_ps(board, bytes, 1) as f64;
    let ink = input_transfer_ps(board, bytes, accels) as f64;
    let out1 = output_transfer_ps(board, bytes, 1) as f64;
    let outk = output_transfer_ps(board, bytes, accels) as f64;
    DmaSpeedup {
        bytes,
        accels,
        input_speedup: in1 / ink,
        output_speedup: out1 / outk,
    }
}

/// Compute Fig. 3's rows under the *board* model (contention included) —
/// the numbers the paper actually measured on the ZC706.
pub fn fig3_board(board: &BoardConfig, bytes: u64, accels: u32) -> DmaSpeedup {
    let in1 = input_transfer_contended_ps(board, bytes, 1) as f64;
    let ink = input_transfer_contended_ps(board, bytes, accels) as f64;
    let out1 = output_transfer_ps(board, bytes, 1) as f64;
    let outk = output_transfer_ps(board, bytes, accels) as f64;
    DmaSpeedup {
        bytes,
        accels,
        input_speedup: in1 / ink,
        output_speedup: out1 / outk,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn board() -> BoardConfig {
        BoardConfig::zynq706()
    }

    #[test]
    fn input_scales_output_does_not() {
        let b = board();
        let bytes = 512 * 1024;
        let s = fig3_estimator(&b, bytes, 2);
        assert!((s.input_speedup - 2.0).abs() < 1e-9, "ideal input scaling");
        assert!((s.output_speedup - 1.0).abs() < 1e-9, "output serialized");
    }

    #[test]
    fn fig3_board_trend_matches_paper() {
        // Paper Fig. 3: with 2 accelerators the input transfers speed up
        // close to 2x (but measurably below), outputs stay at ~1x, for both
        // 512 KB and 1024 KB.
        let b = board();
        for bytes in [512 * 1024, 1024 * 1024] {
            let s = fig3_board(&b, bytes, 2);
            assert!(
                s.input_speedup > 1.6 && s.input_speedup < 2.0,
                "input speedup {} out of the paper's band",
                s.input_speedup
            );
            assert!((s.output_speedup - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn contention_monotone_in_streams() {
        let mut last = f64::INFINITY;
        for k in 1..=8 {
            let bw = contended_bw_mbps(400.0, 0.2, k);
            assert!(bw < last || k == 1);
            last = bw;
        }
    }

    #[test]
    fn non_scaling_platform_input_serializes() {
        let mut b = board();
        b.dma_in_scales = false;
        let s = fig3_estimator(&b, 1 << 20, 4);
        assert!((s.input_speedup - 1.0).abs() < 1e-9);
    }

    #[test]
    fn scaling_output_platform() {
        let mut b = board();
        b.dma_out_scales = true;
        let s = fig3_estimator(&b, 1 << 20, 2);
        assert!((s.output_speedup - 2.0).abs() < 1e-9);
    }

    #[test]
    fn transfer_times_proportional_to_bytes() {
        let b = board();
        let t1 = input_transfer_ps(&b, 1 << 20, 1);
        let t2 = input_transfer_ps(&b, 2 << 20, 1);
        assert_eq!(t2, 2 * t1);
    }
}
