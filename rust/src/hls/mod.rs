//! High-level-synthesis substrate: the analytic stand-in for Vivado HLS
//! (latency + resource reports), the FPGA part budgets and feasibility
//! checks, and the traditional-flow synthesis-time model used by Fig. 6.
//!
//! See DESIGN.md §1 (substitution 2) for the calibration rationale.

pub mod cost_model;
pub mod report;
pub mod resources;
pub mod synthesis_time;

pub use cost_model::{kernel_fingerprint, CostModel};
pub use report::{HlsReport, Resources};
pub use resources::FpgaPart;
pub use synthesis_time::SynthesisTimeModel;
