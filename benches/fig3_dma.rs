//! Fig. 3 regeneration: speedup of 2 accelerators vs 1 for input/output
//! DMA transfers (512 KB and 1024 KB), plus model micro-timings.
//!
//! Paper shape to hold: inputs scale (close to 2x), outputs do not (~1x).

use zynq_estimator::config::BoardConfig;
use zynq_estimator::experiments;
use zynq_estimator::sim::dma;
use zynq_estimator::util::bench::{bench, black_box};

fn main() {
    let board = BoardConfig::zynq706();

    println!("=== Fig. 3: DMA speedup, 2 accelerators vs 1 ===");
    println!(
        "{:>10}  {:>12} {:>12}  {:>12} {:>12}",
        "size", "in est", "in board", "out est", "out board"
    );
    for (label, est, brd) in experiments::fig3(&board) {
        println!(
            "{label:>10}  {:>12.2} {:>12.2}  {:>12.2} {:>12.2}",
            est.input_speedup, brd.input_speedup, est.output_speedup, brd.output_speedup
        );
    }
    println!("paper: input ~2x (scales), output ~1x (shared channel)\n");

    // Extension sweep: 1-8 accelerators at 1 MB (beyond the paper's 2).
    println!("extension: input-transfer speedup vs accelerator count (1 MB)");
    for k in 1..=8u32 {
        let est = dma::fig3_estimator(&board, 1 << 20, k);
        let brd = dma::fig3_board(&board, 1 << 20, k);
        println!(
            "  {k} accel: est {:>5.2}x  board {:>5.2}x",
            est.input_speedup, brd.input_speedup
        );
    }
    println!();

    bench("dma::fig3_estimator (both sizes)", 10, 100, || {
        for bytes in [512 * 1024u64, 1024 * 1024] {
            black_box(dma::fig3_estimator(&board, bytes, 2));
        }
    });
    bench("dma::input_transfer_ps x 10k", 5, 50, || {
        let mut acc = 0u64;
        for i in 0..10_000u64 {
            acc = acc.wrapping_add(dma::input_transfer_ps(&board, 4096 + i, 2));
        }
        black_box(acc);
    });
}
