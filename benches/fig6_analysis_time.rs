//! Fig. 6 regeneration: analysis time of the methodology (measured) vs the
//! traditional hardware-generation flow (modelled), for the matmul
//! configuration set; §VI's cholesky productivity claim alongside.
//!
//! Paper shape to hold: traditional > 10 h (matmul) / ~1.5 days
//! (cholesky); methodology minutes; gap > 2 orders of magnitude.
//!
//! Extended with the DSE sweep-latency comparison: the seed serial
//! rebuild-everything loop vs the shared-`SweepContext` parallel engine
//! (target: >= 4x end-to-end on a 4-core host, with identical rankings —
//! the harness asserts equality before reporting times).

use zynq_estimator::apps::{cholesky::Cholesky, matmul::Matmul};
use zynq_estimator::config::BoardConfig;
use zynq_estimator::dse::default_workers;
use zynq_estimator::experiments;
use zynq_estimator::util::fmt_secs;

fn main() {
    let board = BoardConfig::zynq706();

    println!("=== Fig. 6: analysis time (the paper plots this log-scale) ===");
    let (meth, trad) = experiments::analysis_time_matmul(512, &board).unwrap();
    println!("matmul set:");
    println!("  methodology (measured wall-clock):   {}", fmt_secs(meth));
    println!("  traditional flow (synthesis model):  {}", fmt_secs(trad));
    println!("  speedup: {:.0}x   (paper: >10 h vs <5 min)", trad / meth);

    let (meth_c, trad_c) = experiments::analysis_time_cholesky(512, &board).unwrap();
    println!("cholesky set (§VI productivity):");
    println!("  methodology (measured wall-clock):   {}", fmt_secs(meth_c));
    println!("  traditional flow (synthesis model):  {}", fmt_secs(trad_c));
    println!(
        "  speedup: {:.0}x   (paper: ~1.5 days vs <10 min)",
        trad_c / meth_c
    );
    println!(
        "\nheadline (§VII): both gaps exceed two orders of magnitude: {}",
        trad / meth > 100.0 && trad_c / meth_c > 100.0
    );

    // --- DSE sweep latency: serial rebuild baseline vs parallel context ---
    let workers = default_workers();
    println!(
        "\n=== DSE sweep latency: seed serial rebuild vs shared-context parallel ({workers} workers) ==="
    );
    let mut all_hit_target = true;
    for (name, program) in [
        ("matmul   n=512 bs=64 ", Matmul::new(512, 64).build_program(&board)),
        ("cholesky n=512 bs=64 ", Cholesky::new(512, 64).build_program(&board)),
    ] {
        let (base_s, sweep_s, points) =
            experiments::dse_sweep_latency(&program, &board, workers).unwrap();
        let speedup = base_s / sweep_s.max(1e-12);
        all_hit_target &= speedup >= 4.0;
        println!(
            "{name} {points:>5} points   serial-rebuild {base_s:>8.3} s   parallel {sweep_s:>8.3} s   speedup {speedup:>5.1}x"
        );
    }
    println!(
        "sweep speedup target (>= 4x on a 4-core host, identical rankings): {}",
        if all_hit_target { "MET" } else { "not met on this host" }
    );
}
