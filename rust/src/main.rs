//! zynq-estimator CLI — the leader entrypoint. All command logic lives in
//! `zynq_estimator::cli` so tests, examples and benches reuse it.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match zynq_estimator::cli::run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
