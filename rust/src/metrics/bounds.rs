//! Makespan lower bounds — the analysis that tells a programmer *why* a
//! configuration is slow (and the invariant harness the property tests
//! lean on).
//!
//! Two classic bounds, evaluated against a concrete co-design:
//! * **critical-path bound**: the dependence chain under each task's best
//!   possible device time;
//! * **work bound per device class**: total work a class *must* execute
//!   divided by the number of servers, for SMP cores, each kernel's
//!   accelerators, and the shared output channel. Kernels that may run on
//!   **either** device class (accelerated *and* SMP-eligible) get a fluid
//!   bound instead: the summed best-case work of their tasks divided by
//!   the combined server count — no fixed assignment is assumed, so the
//!   bound stays valid however the scheduler splits them.
//!
//! The max of these is a valid lower bound for *any* schedule, so
//! `makespan >= bound` is asserted by the property tests, `makespan /
//! bound` tells the analyst how much scheduling slack remains, and
//! `dse::prune` uses the bound to skip candidates that provably cannot
//! improve on an already-evaluated point (which is why validity for
//! heterogeneous "+ smp" co-designs matters: an optimistic-but-invalid
//! bound would prune winners).

use crate::config::BoardConfig;
use crate::coordinator::deps::DepGraph;
use crate::coordinator::task::{TaskId, TaskProgram};
use crate::sim::engine::AccelInstance;
use crate::sim::time::{transfer_ps, us_to_ps, Ps};

/// The individual bounds (all in picoseconds).
#[derive(Clone, Debug)]
pub struct Bounds {
    /// Dependence-chain bound under best-case per-task device times.
    pub critical_path: Ps,
    /// Work bound of the busiest device class.
    pub device_work: Ps,
    /// Creation chain on the SMP (serialized task issue).
    pub creation_chain: Ps,
    /// Serialized output-DMA channel (if all tasks run on the FPGA).
    pub output_channel: Ps,
}

impl Bounds {
    /// The combined makespan lower bound: the max of the critical-path,
    /// device-work, creation-chain and output-channel bounds. Valid for
    /// any schedule the engine can produce, so `makespan >= lower_bound()`
    /// always holds.
    ///
    /// The output-channel term covers platforms whose output transfers
    /// serialize on one shared channel (`dma_out_scales == false`): every
    /// write of a task that can only execute on an accelerator must cross
    /// that channel, so their summed transfer time — at the full,
    /// uncontended bandwidth — is a valid bound too. On full-duplex
    /// platforms the term is zero.
    ///
    /// # Example
    ///
    /// ```
    /// use zynq_estimator::apps::matmul::Matmul;
    /// use zynq_estimator::config::{BoardConfig, CoDesign};
    /// use zynq_estimator::coordinator::deps::DepGraph;
    /// use zynq_estimator::hls::FpgaPart;
    /// use zynq_estimator::metrics::bounds::bounds;
    /// use zynq_estimator::sim::engine::resolve_codesign;
    ///
    /// let board = BoardConfig::zynq706();
    /// let program = Matmul::new(256, 64).build_program(&board);
    /// let graph = DepGraph::build(&program);
    /// let cd = CoDesign::new("1acc").with_accel("mxm64", 32);
    /// let (accels, smp) =
    ///     resolve_codesign(&program, &cd, &board, &FpgaPart::xc7z045()).unwrap();
    /// let b = bounds(&program, &graph, &board, &accels, &smp);
    /// let est = zynq_estimator::sim::estimate(&program, &cd, &board).unwrap();
    /// assert!(b.lower_bound() > 0);
    /// assert!(est.makespan >= b.lower_bound());
    /// ```
    pub fn lower_bound(&self) -> Ps {
        self.critical_path
            .max(self.device_work)
            .max(self.creation_chain)
            .max(self.output_channel)
    }
}

/// Compute bounds for a (program, accels) pair. `smp_eligible[k]` mirrors
/// the engine's device rules.
pub fn bounds(
    program: &TaskProgram,
    graph: &DepGraph,
    board: &BoardConfig,
    accels: &[AccelInstance],
    smp_eligible: &[bool],
) -> Bounds {
    let smp_clock = board.smp_clock();
    let n_kernels = program.kernels.len();
    let mut accel_count = vec![0u64; n_kernels];
    let mut accel_task_ps = vec![Ps::MAX; n_kernels];
    for a in accels {
        accel_count[a.kernel as usize] += 1;
        let t = a.report.compute_ps();
        accel_task_ps[a.kernel as usize] = accel_task_ps[a.kernel as usize].min(t);
    }

    // Best-case per-task time (used for the critical path).
    let best_case = |t: TaskId| -> Ps {
        let task = &program.tasks[t as usize];
        let k = task.kernel as usize;
        let smp = if smp_eligible[k] || accel_count[k] == 0 {
            smp_clock.cycles_to_ps(task.smp_cycles)
        } else {
            Ps::MAX
        };
        let acc = if accel_count[k] > 0 {
            // input DMA + compute is the occupancy; take compute only as
            // the optimistic bound.
            accel_task_ps[k]
        } else {
            Ps::MAX
        };
        smp.min(acc)
    };
    let critical_path = graph.critical_path(&best_case);

    // Per-class work bounds. A kernel's tasks fall into three regimes:
    // * no accelerator  -> they must run on the SMP cores;
    // * accelerator only (not SMP-eligible) -> they must occupy an
    //   accelerator for input DMA (when it rides the accel channel) plus
    //   compute;
    // * both devices -> no assignment can be assumed; each task occupies
    //   *some* device for at least its best-case time, and at most
    //   (accels + cores) devices serve the kernel, giving a fluid bound
    //   that is valid for any split.
    let mut smp_work = 0u128;
    let mut accel_work = vec![0u128; n_kernels];
    let mut hetero_work = vec![0u128; n_kernels];
    let mut out_bytes_total = 0u64;
    for task in &program.tasks {
        let k = task.kernel as usize;
        if accel_count[k] > 0 {
            let in_bytes: u64 = task
                .deps
                .iter()
                .filter(|d| d.dir.reads())
                .map(|d| d.len)
                .sum();
            // Input DMA occupies the accelerator only on platforms whose
            // input channels scale with the accelerators (ZC706, Fig. 3);
            // otherwise inputs ride the shared channel and the occupancy
            // is compute only.
            let occupancy = if board.dma_in_scales {
                accel_task_ps[k] + transfer_ps(in_bytes, board.dma_bw_mbps)
            } else {
                accel_task_ps[k]
            };
            if smp_eligible[k] {
                let smp_ps = smp_clock.cycles_to_ps(task.smp_cycles);
                hetero_work[k] += occupancy.min(smp_ps) as u128;
            } else {
                accel_work[k] += occupancy as u128;
                out_bytes_total += task
                    .deps
                    .iter()
                    .filter(|d| d.dir.writes())
                    .map(|d| d.len)
                    .sum::<u64>();
            }
        } else {
            smp_work += smp_clock.cycles_to_ps(task.smp_cycles) as u128;
        }
    }
    let mut device_work = (smp_work / board.smp_cores as u128) as Ps;
    for k in 0..n_kernels {
        if accel_count[k] == 0 {
            continue;
        }
        if smp_eligible[k] {
            let servers = accel_count[k] as u128 + board.smp_cores as u128;
            device_work = device_work.max((hetero_work[k] / servers) as Ps);
        } else {
            device_work = device_work.max((accel_work[k] / accel_count[k] as u128) as Ps);
        }
    }

    let creation_chain = us_to_ps(board.task_creation_us) * program.tasks.len() as Ps;
    let output_channel = if board.dma_out_scales {
        0
    } else {
        transfer_ps(out_bytes_total, board.dma_bw_mbps)
    };

    Bounds {
        critical_path,
        device_work,
        creation_chain,
        output_channel,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::{self, Matmul};
    use crate::hls::FpgaPart;
    use crate::sim::engine::resolve_codesign;
    use crate::sim::estimate;

    #[test]
    fn makespan_respects_lower_bound_all_fig5_configs() {
        let board = BoardConfig::zynq706();
        for (cd, app) in matmul::fig5_cases(512) {
            let p = app.build_program(&board);
            let g = DepGraph::build(&p);
            let (accels, smp) =
                resolve_codesign(&p, &cd, &board, &FpgaPart::xc7z045()).unwrap();
            let b = bounds(&p, &g, &board, &accels, &smp);
            let res = estimate(&p, &cd, &board).unwrap();
            assert!(
                res.makespan >= b.lower_bound(),
                "{}: makespan {} < bound {}",
                cd.name,
                res.makespan,
                b.lower_bound()
            );
            // The bound is useful for the FPGA-only configurations (the
            // greedy "+smp" runs sit far above any bound — that *is* the
            // paper's load-imbalance finding, not bound looseness).
            if cd.smp_kernels.is_empty() {
                assert!(
                    res.makespan < b.lower_bound() * 4,
                    "{}: bound too loose ({} vs {})",
                    cd.name,
                    res.makespan,
                    b.lower_bound()
                );
            }
        }
    }

    #[test]
    fn fpga_only_config_is_near_its_work_bound() {
        // 1acc 128: the accelerator work bound should explain most of the
        // makespan (the estimator schedules it almost back-to-back).
        let board = BoardConfig::zynq706();
        let app = Matmul::new(512, 128);
        let p = app.build_program(&board);
        let g = DepGraph::build(&p);
        let cd = crate::config::CoDesign::new("1acc128")
            .with_accel("mxm128", matmul::UNROLL_128);
        let (accels, smp) =
            resolve_codesign(&p, &cd, &board, &FpgaPart::xc7z045()).unwrap();
        let b = bounds(&p, &g, &board, &accels, &smp);
        let res = estimate(&p, &cd, &board).unwrap();
        let ratio = res.makespan as f64 / b.device_work as f64;
        assert!(
            ratio < 1.15,
            "device-work bound should be tight for FPGA-only: ratio {ratio}"
        );
    }
}
