"""Layer-1 Pallas kernel for the blocked Jacobi stencil app.

Tile-granular 5-point sweep: the task reads its centre tile plus the four
halo tiles and writes the updated centre. On TPU this is pure VPU
(element-wise) work with all six tiles VMEM-resident — the analogue of the
paper's BRAM-buffered streaming kernels that do not use the DSP MACs.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _jacobi_kernel(c_ref, n_ref, s_ref, w_ref, e_ref, o_ref):
    o_ref[...] = (
        c_ref[...] + n_ref[...] + s_ref[...] + w_ref[...] + e_ref[...]
    ) / 5.0


def jacobi_tile(c, n, s, w, e):
    bs = c.shape[0]
    return pl.pallas_call(
        _jacobi_kernel,
        out_shape=jax.ShapeDtypeStruct((bs, bs), jnp.float32),
        interpret=INTERPRET,
    )(c, n, s, w, e)
