//! # zynq-estimator
//!
//! Reproduction of *"Coarse-Grain Performance Estimator for Heterogeneous
//! Parallel Computing Architectures like Zynq All-Programmable SoC"*
//! (Jiménez-González et al., 2015) as a three-layer Rust + JAX + Pallas
//! stack, grown into a batch design-space-exploration system. See
//! ARCHITECTURE.md for the module map and dataflow, DESIGN.md for the
//! system inventory and EXPERIMENTS.md for the paper-vs-measured record.
//!
//! ## Layer map
//!
//! * [`coordinator`] — OmpSs-equivalent task model, run-time dependence
//!   tracking, trace elaboration (§IV) and scheduling policies.
//! * [`sim`] — discrete-event engine + the coarse-grain estimator model.
//! * [`board`] — detailed Zynq board emulator ("real execution" stand-in)
//!   and the board axis of the design space ([`board::BoardSpace`]).
//! * [`hls`] — analytic Vivado-HLS latency/resource model + feasibility.
//! * [`apps`] — the paper's applications (matmul, cholesky) + extras
//!   (lu, stencil).
//! * [`dse`] — co-design space enumeration and ranking: the shared-context
//!   parallel sweep engine ([`dse::sweep`]), the bound-guided pruned
//!   enumeration with selectable round ordering ([`dse::prune`]), the
//!   persistent warm-start evaluation memo ([`dse::warm`]), batched
//!   multi-program suites ([`dse::SweepSuite`]) and the cross-board sweep
//!   that makes the platform itself a swept axis
//!   ([`dse::CrossBoardSweep`]).
//! * [`trace`] — basic-trace JSON-lines IO, DOT export, Paraver writer.
//! * [`metrics`] — speedup tables, trend agreement, makespan lower bounds
//!   ([`metrics::bounds`]), report rendering and figure-data export.
//! * [`power`] — platform energy model (time / energy / EDP ranking).
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas kernels
//!   (behind the `pjrt` feature; an API-compatible stub otherwise).
//! * [`experiments`] — one harness per paper figure; the CLI, benches and
//!   examples all call through here.
//! * [`service`] — the estimator as a resident daemon: NDJSON
//!   request/response protocol, a memo-backed query core shared with the
//!   one-shot CLI (byte-identical answers by construction), in-flight
//!   query coalescing and WAL-journaled persistence (`serve` command).
//! * [`config`] — board/co-design TOML configs.
//! * [`cli`] — the `zynq-estimator` command-line tool.
//! * [`fuzz`] — deterministic mutation fuzzing of the byte-ingesting
//!   parsers (memo JSON, sweep journals, board TOML).
//! * [`util`] — PRNG, stats, bench harness, JSON substrate (the build is
//!   fully offline; no external general-purpose dependencies).
//!
//! ## Paper figures ↔ code
//!
//! | Paper artifact | Entry point | Bench |
//! |---|---|---|
//! | Fig. 3 (DMA scaling) | [`experiments::fig3`] | `benches/fig3_dma.rs` |
//! | Fig. 5 (matmul sweep) | [`experiments::fig5`] | `benches/fig5_matmul.rs` |
//! | Fig. 6 (analysis time) | [`experiments::analysis_time_matmul`] | `benches/fig6_analysis_time.rs` |
//! | Fig. 7 (Paraver) | [`experiments::fig7`] | `benches/fig7_paraver.rs` |
//! | Fig. 8 (task graph) | [`experiments::fig8`] | `benches/fig8_graph.rs` |
//! | Fig. 9 (cholesky sweep) | [`experiments::fig9`] | `benches/fig9_cholesky.rs` |
//! | §VII DSE outlook | [`dse::SweepContext::explore`], [`dse::SweepContext::explore_pruned`] | `benches/dse_suite.rs`, `benches/engine_hotpath.rs` |
//! | §I cross-board outlook | [`experiments::cross_board_dse`], [`dse::CrossBoardSweep`] | `benches/cross_board.rs` |
//!
//! ## Quick taste
//!
//! Sweep the matmul co-design space and print the winner (see
//! [`dse::SweepContext::explore`] and [`metrics::bounds::Bounds::lower_bound`]
//! for runnable doctest examples):
//!
//! ```text
//! cargo run --release -- dse --app matmul --n 512 --pruned
//! cargo run --release -- dse --suite            # all four apps, one pool
//! ```
#![warn(missing_docs)]

pub mod apps;
pub mod board;
pub mod cli;
pub mod config;
pub mod experiments;
pub mod coordinator;
pub mod dse;
pub mod fuzz;
pub mod hls;
pub mod metrics;
pub mod power;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod trace;
pub mod util;
