//! Scheduling policies — how a ready task picks a device.
//!
//! The paper evaluates the *availability-based* policy of the OmpSs runtime
//! of the time ("the OmpSs runtime can take care of scheduling different
//! instances of the kernel, when their dependences are ready, in both
//! resources based on availability") and observes in §VI that it "does not
//! help to improve the performance when running mxmBlock in both SMP and
//! FPGA" — a free SMP core greedily grabs tasks that the accelerator would
//! have finished sooner, creating load imbalance.
//!
//! [`Policy::Greedy`] reproduces that behaviour. [`Policy::Lookahead`] is
//! the paper's future-work heuristic ("look-ahead scheduling heuristics"):
//! an SMP core only steals an accelerator-capable task when the
//! accelerator backlog makes the SMP execution pay off. The ablation bench
//! compares the two.

use crate::sim::time::Ps;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Device-selection policy for ready tasks.
pub enum Policy {
    /// Nanos++ availability scheduling (the paper's measured policy): any
    /// free capable device takes the oldest ready task.
    Greedy,
    /// SMP steals an accelerator-capable task only if the estimated wait
    /// for an accelerator (backlog × per-task accel time) exceeds the SMP
    /// execution time. Models the paper's proposed look-ahead extension.
    Lookahead,
}

impl Policy {
    /// Parse a CLI policy name (`greedy` | `lookahead`).
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "greedy" => Some(Policy::Greedy),
            "lookahead" => Some(Policy::Lookahead),
            _ => None,
        }
    }

    /// The CLI name of the policy.
    pub fn as_str(&self) -> &'static str {
        match self {
            Policy::Greedy => "greedy",
            Policy::Lookahead => "lookahead",
        }
    }

    /// Decide whether an SMP core should execute an accelerator-capable
    /// ready task. `accel_backlog` = tasks queued for the kernel's
    /// accelerators (including in-flight), `accel_task_ps` = per-task
    /// accelerator occupancy, `accels` = number of accelerators serving the
    /// kernel, `smp_task_ps` = cost on this core.
    pub fn smp_should_take(
        &self,
        accel_backlog: usize,
        accel_task_ps: Ps,
        accels: u32,
        smp_task_ps: Ps,
    ) -> bool {
        match self {
            Policy::Greedy => true,
            Policy::Lookahead => {
                if accels == 0 {
                    return true;
                }
                // Expected completion if left to the accelerators: the task
                // waits behind the backlog split across `accels`.
                let wait = (accel_backlog as u64 + 1) * accel_task_ps / accels as u64;
                smp_task_ps < wait
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_always_takes() {
        assert!(Policy::Greedy.smp_should_take(0, 1_000, 2, u64::MAX as Ps));
    }

    #[test]
    fn lookahead_declines_when_accel_faster() {
        // Empty backlog, accel 10x faster: leave it to the accelerator.
        assert!(!Policy::Lookahead.smp_should_take(0, 100, 1, 1_000));
        // Deep backlog: stealing pays.
        assert!(Policy::Lookahead.smp_should_take(50, 100, 1, 1_000));
    }

    #[test]
    fn lookahead_without_accels_takes() {
        assert!(Policy::Lookahead.smp_should_take(0, 0, 0, 1_000));
    }

    #[test]
    fn parse_roundtrip() {
        for p in [Policy::Greedy, Policy::Lookahead] {
            assert_eq!(Policy::parse(p.as_str()), Some(p));
        }
        assert_eq!(Policy::parse("nope"), None);
    }
}
