//! Analytic Vivado-HLS cost model — the substitute for running Vivado HLS
//! 2013.2 on the extracted kernel C code (DESIGN.md §1, substitution 2).
//!
//! The estimator toolchain needs exactly what the paper reads out of the
//! HLS report: per-kernel compute cycles and input/output transfer cycles,
//! plus a resource vector for the feasibility analysis. This model derives
//! them from the kernel's [`KernelProfile`] and an unroll factor using the
//! standard HLS latency equation
//!
//! ```text
//! latency ≈ ceil(trip_count / unroll) × II + pipeline_depth
//! ```
//!
//! and 7-series floating-point operator costs (LogiCORE FP v7 era):
//! an f32 MAC ≈ 5 DSP48E1 (3 mul + 2 add), an f64 MAC ≈ 14 (11 + 3).
//! Division/sqrt recurrences (dtrsm, dpotrf) cannot pipeline at II=1 and
//! are modelled with II=4, matching the order of HLS's scheduling results
//! for feedback loops of that era.

use crate::config::BoardConfig;
use crate::coordinator::task::KernelProfile;
use crate::sim::time::transfer_ps;
use crate::util::fnv::Fnv;

use super::report::{HlsReport, Resources};

/// Stable fingerprint of a kernel *as the cost model sees it*: the kernel
/// name, its full workload profile, and the estimator version. Together
/// with an unroll factor and the two board-derived model constants
/// ([`CostModel::fabric_mhz`] and [`CostModel::dma_bw_mbps`]) this covers
/// **everything** an [`HlsReport`] depends on, so two programs whose
/// kernels fingerprint identically — e.g. two problem sizes of the same
/// blocked application, which share the per-block profile — can share
/// synthesis estimates bit for bit. This is the level-1 key of the
/// [`dse::warm`](crate::dse::warm) evaluation memo; the FPGA part is
/// deliberately *not* part of the key (reports are part-independent —
/// feasibility is checked downstream), which is what lets sibling boards
/// share kernel statistics.
pub fn kernel_fingerprint(kernel: &str, profile: &KernelProfile) -> u64 {
    let mut h = Fnv::new();
    h.str(env!("CARGO_PKG_VERSION"));
    h.str(kernel);
    h.u64(profile.flops);
    h.u64(profile.inner_trip);
    h.u64(profile.in_bytes);
    h.u64(profile.out_bytes);
    h.u64(profile.dtype_bytes as u64);
    h.bool(profile.divsqrt);
    h.finish()
}

/// DSPs per fused multiply-add datapath lane.
fn mac_dsps(dtype_bytes: u8) -> u64 {
    if dtype_bytes >= 8 {
        14 // f64: 11 (mul) + 3 (add)
    } else {
        5 // f32: 3 (mul) + 2 (add)
    }
}

/// LUTs per datapath lane (operator glue + partition muxing).
fn lane_luts(dtype_bytes: u8, divsqrt: bool) -> u64 {
    let base = if dtype_bytes >= 8 { 900 } else { 420 };
    // Divider/sqrt cores are LUT-heavy (no DSP mapping in that era).
    if divsqrt {
        base + 1_600
    } else {
        base
    }
}

/// The analytic model. Stateless; all inputs are explicit so property tests
/// can sweep it.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Fabric clock HLS targets (from the board config).
    pub fabric_mhz: f64,
    /// DMA bandwidth used to express transfer latencies in fabric cycles,
    /// as Vivado HLS does for the AXI master ports.
    pub dma_bw_mbps: f64,
}

impl CostModel {
    /// Bind the model to a board's fabric clock and DMA bandwidth.
    pub fn from_board(board: &BoardConfig) -> Self {
        Self {
            fabric_mhz: board.fabric_freq_mhz,
            dma_bw_mbps: board.dma_bw_mbps,
        }
    }

    /// Produce the HLS report for `kernel` at `unroll`.
    ///
    /// Panics if `unroll == 0`.
    pub fn estimate(&self, kernel: &str, profile: &KernelProfile, unroll: u32) -> HlsReport {
        assert!(unroll > 0, "unroll factor must be >= 1");
        let u = unroll as u64;

        // --- latency ---
        let ii: u32 = if profile.divsqrt { 4 } else { 1 };
        // Pipeline depth: FP add/mul chains ~8 stages, deeper with wider
        // reduction trees (log2(U) levels) and much deeper with div/sqrt.
        let depth: u32 = 8
            + 3 * (64 - (unroll as u64).leading_zeros()).saturating_sub(1)
            + if profile.divsqrt { 24 } else { 0 };
        let iterations = profile.inner_trip.div_ceil(u);
        let compute_cycles = iterations * ii as u64 + depth as u64;

        // --- transfers, expressed in fabric cycles as HLS reports them ---
        let period_ps = 1e6 / self.fabric_mhz;
        let in_cycles =
            (transfer_ps(profile.in_bytes, self.dma_bw_mbps) as f64 / period_ps).ceil() as u64;
        let out_cycles =
            (transfer_ps(profile.out_bytes, self.dma_bw_mbps) as f64 / period_ps).ceil() as u64;

        // --- resources ---
        let dsps = u * mac_dsps(profile.dtype_bytes) + 12; // +12: AXI/control
        let luts = 5_200 + u * lane_luts(profile.dtype_bytes, profile.divsqrt);
        let ffs = luts * 2; // FF/LUT ratio ~2 for pipelined FP datapaths
        // Local tile buffers, double-buffered, in 18Kb BRAMs (2,304 bytes
        // each); array partitioning for U-wide access forces >= U banks.
        let buffer_bytes = (profile.in_bytes + profile.out_bytes) * 2;
        let bram18 = buffer_bytes.div_ceil(2_304).max(u);

        HlsReport {
            kernel: kernel.to_string(),
            unroll,
            ii,
            depth,
            compute_cycles,
            fmax_mhz: self.fabric_mhz,
            in_cycles,
            out_cycles,
            resources: Resources {
                luts,
                ffs,
                dsps,
                bram18,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::resources::FpgaPart;

    fn mxm_profile(bs: u64) -> KernelProfile {
        KernelProfile {
            flops: 2 * bs * bs * bs,
            inner_trip: bs * bs * bs,
            in_bytes: 3 * bs * bs * 4,
            out_bytes: bs * bs * 4,
            dtype_bytes: 4,
            divsqrt: false,
        }
    }

    fn model() -> CostModel {
        CostModel::from_board(&BoardConfig::zynq706())
    }

    #[test]
    fn latency_decreases_with_unroll() {
        let m = model();
        let p = mxm_profile(64);
        let mut last = u64::MAX;
        for u in [1u32, 2, 4, 8, 16, 32, 64] {
            let r = m.estimate("mxm64", &p, u);
            assert!(r.compute_cycles < last, "unroll {u} did not help");
            last = r.compute_cycles;
        }
    }

    #[test]
    fn resources_increase_with_unroll() {
        let m = model();
        let p = mxm_profile(64);
        let r1 = m.estimate("mxm64", &p, 8);
        let r2 = m.estimate("mxm64", &p, 32);
        assert!(r2.resources.dsps > r1.resources.dsps);
        assert!(r2.resources.luts > r1.resources.luts);
    }

    #[test]
    fn paper_feasibility_one_128_fits_two_do_not() {
        // §VI: "the hardware resource estimation for two 128x128-block
        // mxmBlock accelerators indicates that it is not feasible".
        let m = model();
        let part = FpgaPart::xc7z045();
        let r128 = m.estimate("mxm128", &mxm_profile(128), 128);
        assert!(part.fits(&[r128.resources]), "one mxm128 must fit");
        assert!(
            !part.fits(&[r128.resources, r128.resources]),
            "two mxm128 must NOT fit"
        );
    }

    #[test]
    fn paper_feasibility_two_64_fit() {
        let m = model();
        let part = FpgaPart::xc7z045();
        let r64 = m.estimate("mxm64", &mxm_profile(64), 32);
        assert!(part.fits(&[r64.resources, r64.resources]));
    }

    #[test]
    fn divsqrt_kernels_pay_ii() {
        let m = model();
        let mut p = mxm_profile(64);
        let plain = m.estimate("k", &p, 16);
        p.divsqrt = true;
        let hard = m.estimate("k", &p, 16);
        assert_eq!(plain.ii, 1);
        assert_eq!(hard.ii, 4);
        assert!(hard.compute_cycles > 3 * plain.compute_cycles);
    }

    #[test]
    fn double_precision_burns_more_dsps() {
        let m = model();
        let mut p = mxm_profile(64);
        let sp = m.estimate("k", &p, 16);
        p.dtype_bytes = 8;
        let dp = m.estimate("k", &p, 16);
        assert!(dp.resources.dsps > 2 * sp.resources.dsps);
    }

    #[test]
    fn transfer_cycles_match_bandwidth() {
        let m = model();
        let p = mxm_profile(128); // in = 192 KiB
        let r = m.estimate("mxm128", &p, 64);
        // 196608 bytes at 400 MB/s = 491.52 us = 61440 cycles at 125 MHz
        assert_eq!(r.in_cycles, 61_440);
        assert_eq!(r.out_cycles, 20_480);
    }

    #[test]
    fn mxm128_latency_sane() {
        // 128^3 / 128 = 16384 iterations at II=1 + depth — near 131 us at
        // 125 MHz, the calibration point from DESIGN.md.
        let m = model();
        let r = m.estimate("mxm128", &mxm_profile(128), 128);
        let us = crate::sim::time::ps_to_us(r.compute_ps());
        assert!(us > 125.0 && us < 140.0, "mxm128 compute = {us} us");
    }

    #[test]
    fn bram_at_least_unroll_banks() {
        let m = model();
        let mut p = mxm_profile(64);
        p.in_bytes = 256; // tiny buffers
        p.out_bytes = 256;
        let r = m.estimate("k", &p, 32);
        assert!(r.resources.bram18 >= 32);
    }
}
