//! Bench-regression gate: compare a current `BENCH_*.json` against a
//! checked-in baseline under `bench_baselines/`.
//!
//! The baseline is the contract: every leaf it contains must exist in the
//! current document (walked by object key / array index), and must match —
//! numbers within a relative tolerance (a **zero** baseline means "exactly
//! zero", since a relative band around zero is meaningless), strings and
//! booleans exactly, `null` as a presence-only placeholder. Extra fields
//! in the current document are ignored, so benches can grow without
//! invalidating baselines.
//!
//! Wall-clock leaves — keys ending in `_s`, `_ms` or `_secs`, and the
//! machine-shape keys `workers` / `iters` — are skipped by default: they
//! track the runner, not the code. Ratio- and count-like leaves
//! (`speedup`, `feasible_points`, `tasks`, ...) are machine-independent
//! and are what the ±tolerance actually guards. Pass `strict_time` to
//! check everything, e.g. on a dedicated, stable perf runner.

use crate::util::json::Value;

/// Outcome of one baseline comparison.
#[derive(Debug, Default)]
pub struct CheckReport {
    /// Leaves that matched (path, note).
    pub passed: Vec<String>,
    /// Machine-dependent leaves present but not enforced.
    pub skipped: Vec<String>,
    /// Regressions / contract violations (path + reason).
    pub failures: Vec<String>,
}

impl CheckReport {
    /// Whether the current document honours the baseline.
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }

    /// Human-readable summary for the CI log.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.failures {
            out.push_str(&format!("FAIL  {f}\n"));
        }
        for s in &self.skipped {
            out.push_str(&format!("skip  {s}\n"));
        }
        out.push_str(&format!(
            "bench-check: {} checked, {} skipped, {} failed\n",
            self.passed.len(),
            self.skipped.len(),
            self.failures.len()
        ));
        out
    }
}

/// Does a leaf key name a wall-clock / machine-shape quantity?
fn machine_dependent(key: &str) -> bool {
    key.ends_with("_s")
        || key.ends_with("_ms")
        || key.ends_with("_secs")
        || key == "workers"
        || key == "iters"
}

/// Compare `current` against `baseline` (see module docs). `tolerance` is
/// the allowed relative deviation for numeric leaves (0.2 = ±20%).
pub fn compare(
    baseline: &Value,
    current: &Value,
    tolerance: f64,
    strict_time: bool,
) -> CheckReport {
    let mut report = CheckReport::default();
    walk(baseline, current, "$", "", tolerance, strict_time, &mut report);
    report
}

#[allow(clippy::too_many_arguments)]
fn walk(
    base: &Value,
    cur: &Value,
    path: &str,
    key: &str,
    tol: f64,
    strict_time: bool,
    report: &mut CheckReport,
) {
    match base {
        Value::Null => report.passed.push(format!("{path} (present)")),
        Value::Obj(map) => {
            for (k, bv) in map {
                let child = format!("{path}.{k}");
                match cur.get(k) {
                    Some(cv) => walk(bv, cv, &child, k, tol, strict_time, report),
                    None => report.failures.push(format!("{child}: missing in current")),
                }
            }
        }
        Value::Arr(items) => {
            let cur_items = cur.as_arr().unwrap_or(&[]);
            for (i, bv) in items.iter().enumerate() {
                let child = format!("{path}[{i}]");
                match cur_items.get(i) {
                    Some(cv) => walk(bv, cv, &child, key, tol, strict_time, report),
                    None => report.failures.push(format!("{child}: missing in current")),
                }
            }
        }
        Value::Str(s) => match cur.as_str() {
            Some(c) if c == s => report.passed.push(format!("{path} == \"{s}\"")),
            other => report.failures.push(format!(
                "{path}: expected \"{s}\", got {:?}",
                other.unwrap_or("<non-string>")
            )),
        },
        Value::Bool(b) => match cur.as_bool() {
            Some(c) if c == *b => report.passed.push(format!("{path} == {b}")),
            _ => report.failures.push(format!("{path}: expected {b}")),
        },
        Value::Int(_) | Value::Num(_) => {
            let b = base.as_f64().unwrap();
            if machine_dependent(key) && !strict_time {
                report.skipped.push(format!("{path} (machine-dependent)"));
                return;
            }
            match cur.as_f64() {
                None => report
                    .failures
                    .push(format!("{path}: expected a number near {b}")),
                // A relative tolerance is meaningless around zero: a zero
                // baseline is an exact-match contract (and says so).
                Some(c) if b == 0.0 => {
                    if c == 0.0 {
                        report.passed.push(format!("{path}: 0 (exact)"));
                    } else {
                        report.failures.push(format!(
                            "{path}: expected exactly 0 (zero baselines are exact), got {c}"
                        ));
                    }
                }
                Some(c) => {
                    let rel = (c - b).abs() / b.abs();
                    if rel <= tol {
                        report.passed.push(format!("{path}: {c} vs {b}"));
                    } else {
                        report.failures.push(format!(
                            "{path}: {c} deviates {:.0}% from baseline {b} (tolerance {:.0}%)",
                            rel * 100.0,
                            tol * 100.0
                        ));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn check(base: &str, cur: &str) -> CheckReport {
        compare(&parse(base).unwrap(), &parse(cur).unwrap(), 0.2, false)
    }

    #[test]
    fn within_tolerance_passes() {
        let r = check(
            r#"{"feasible_points": 100, "speedup": 2.0}"#,
            r#"{"feasible_points": 110, "speedup": 1.7}"#,
        );
        assert!(r.ok(), "{}", r.render());
        assert_eq!(r.passed.len(), 2);
    }

    #[test]
    fn beyond_tolerance_fails() {
        let r = check(r#"{"feasible_points": 100}"#, r#"{"feasible_points": 50}"#);
        assert!(!r.ok());
        assert!(r.render().contains("FAIL"));
    }

    #[test]
    fn zero_baseline_is_exact() {
        assert!(check(r#"{"dominance_cut": 0}"#, r#"{"dominance_cut": 0}"#).ok());
        let r = check(r#"{"dominance_cut": 0}"#, r#"{"dominance_cut": 1}"#);
        assert!(!r.ok());
        assert!(r.render().contains("exactly 0"), "{}", r.render());
    }

    #[test]
    fn missing_leaf_fails_and_extra_leaf_is_ignored() {
        let r = check(r#"{"a": 1}"#, r#"{"b": 1}"#);
        assert!(!r.ok());
        let r = check(r#"{"a": 1}"#, r#"{"a": 1, "b": 999}"#);
        assert!(r.ok());
    }

    #[test]
    fn wall_clock_keys_skipped_unless_strict() {
        let base = r#"{"exhaustive_s": 10.0, "mean_ms": 5.0, "workers": 8}"#;
        let cur = r#"{"exhaustive_s": 99.0, "mean_ms": 55.0, "workers": 2}"#;
        let r = check(base, cur);
        assert!(r.ok(), "{}", r.render());
        assert_eq!(r.skipped.len(), 3);
        let strict = compare(&parse(base).unwrap(), &parse(cur).unwrap(), 0.2, true);
        assert!(!strict.ok());
    }

    #[test]
    fn strings_null_and_arrays() {
        let base = r#"{"apps": [{"app": "matmul", "best": null}], "ok": true}"#;
        let r = check(base, r#"{"apps": [{"app": "matmul", "best": "2x"}], "ok": true}"#);
        assert!(r.ok(), "{}", r.render());
        // Wrong string, short array, wrong bool all fail.
        assert!(!check(base, r#"{"apps": [{"app": "lu", "best": 1}], "ok": true}"#).ok());
        assert!(!check(base, r#"{"apps": [], "ok": true}"#).ok());
        assert!(!check(base, r#"{"apps": [{"app": "matmul", "best": 0}], "ok": false}"#).ok());
    }
}
