//! Service layer: the estimator as a resident queryable daemon.
//!
//! Everything below the CLI already separated one-time analysis from
//! per-query work (sweep contexts, the two-level [`EvalMemo`]); this
//! module adds the missing top: a long-running process that keeps that
//! state warm across queries instead of rebuilding it per invocation —
//! the CEDR-style resident runtime applied to estimation. Three small
//! modules, strictly layered:
//!
//! * [`proto`] — the NDJSON wire protocol: request parsing into a typed
//!   [`RequestKind`], response serialization, the canonical coalescing
//!   key, and the error taxonomy (mirroring the CLI exit codes).
//! * [`query`] — the memo-backed query core shared verbatim by the
//!   one-shot CLI and the daemon, which is what makes daemon responses
//!   byte-identical to CLI stdout by construction.
//! * [`daemon`] — the [`Service`] runtime: shared memo behind one lock,
//!   in-flight coalescing, periodic WAL-journaled persistence, stdio and
//!   TCP transports.
//!
//! [`EvalMemo`]: crate::dse::EvalMemo

pub mod daemon;
pub mod proto;
pub mod query;

pub use daemon::{serve, ServeConfig, Service};
pub use proto::{
    parse_request, DseQuery, Envelope, GcSpec, PointQuery, QueryReply, RequestKind, ServiceError,
};
pub use query::{dse_query, point_query, space_for_codesign, PointOutcome};
