//! The OmpSs-runtime-equivalent coordinator: task model, run-time
//! dependence tracking, trace elaboration (§IV) and scheduling policies.
//!
//! This is the layer the paper's contribution lives in: the simulator
//! "implements the runtime of the OmpSs programming model" — tasks become
//! ready when their dependences are satisfied and run on whichever capable
//! device the policy selects.

pub mod deps;
pub mod elaborate;
pub mod sched;
pub mod task;

pub use deps::DepGraph;
pub use task::{
    Dep, Dir, KernelDecl, KernelId, KernelProfile, TaskId, TaskInstance, TaskProgram, Targets,
};
