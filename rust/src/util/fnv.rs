//! FNV-1a 64-bit hashing for *serialized* fingerprints.
//!
//! The repository's `FxHasher` (`util::fxhash`) is for in-memory hash
//! tables, where the exact hash values are an implementation detail. The
//! evaluation-memo layer (`dse::warm`) and the HLS kernel fingerprints
//! (`hls::kernel_fingerprint`) instead write hash values into a *file
//! format*, so the function is pinned here explicitly: FNV-1a with the
//! standard 64-bit offset basis and prime, fed length-prefixed strings and
//! little-endian scalars. Changing this function invalidates every
//! persisted fingerprint — bump `dse::warm::MEMO_SCHEMA_VERSION` if you
//! ever must.

/// Incremental FNV-1a 64-bit hasher.
#[derive(Clone, Debug)]
pub struct Fnv(u64);

impl Fnv {
    /// Start a hash at the FNV-1a 64-bit offset basis.
    pub fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    /// Fold raw bytes into the hash.
    pub fn bytes(&mut self, b: &[u8]) {
        for &x in b {
            self.0 ^= x as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    /// Fold a `u64` (little-endian bytes).
    pub fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    /// Fold an `f64` as its exact bit pattern.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Fold a length-prefixed string (prefixing makes `("ab","c")` and
    /// `("a","bc")` hash differently).
    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }

    /// Fold a boolean as one byte.
    pub fn bool(&mut self, b: bool) {
        self.bytes(&[b as u8]);
    }

    /// The current hash value.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // FNV-1a 64 reference values (empty string = offset basis, "a").
        assert_eq!(Fnv::new().finish(), 0xcbf2_9ce4_8422_2325);
        let mut h = Fnv::new();
        h.bytes(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn string_length_prefix_disambiguates() {
        let mut a = Fnv::new();
        a.str("ab");
        a.str("c");
        let mut b = Fnv::new();
        b.str("a");
        b.str("bc");
        assert_ne!(a.finish(), b.finish());
    }
}
