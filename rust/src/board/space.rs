//! The board axis of the design space — named platform candidates.
//!
//! The paper's §I outlook (and the cross-board study) makes the point that
//! the *platform* is part of the co-design decision: the best
//! hardware/software split shifts between a ZC702-class, a ZC706-class and
//! an UltraScale+-class device. A [`BoardSpace`] makes that axis explicit:
//! a list of named [`BoardTarget`]s — each a ([`BoardConfig`],
//! [`FpgaPart`]) pair — that the cross-board sweep
//! ([`crate::dse::CrossBoardSweep`]) expands into per-board evaluation
//! contexts.
//!
//! Targets resolve from:
//! * **built-in presets** by name: `zynq702`, `zynq706`, `zynq-ultrascale`;
//! * **TOML board files** (`configs/*.toml`): the usual [`BoardConfig`]
//!   keys plus an optional `[fabric] part = "xc7z020"` naming the FPGA
//!   part (default: `xc7z045`).

use std::path::Path;

use crate::config::BoardConfig;
use crate::hls::FpgaPart;

/// One platform candidate of the board axis: a board description and the
/// FPGA part its co-designs must fit.
#[derive(Clone, Debug)]
pub struct BoardTarget {
    /// Display name (CLI tables, result rows) — the board config's name.
    pub name: String,
    /// Platform description (clocks, DMA, runtime costs).
    pub board: BoardConfig,
    /// Programmable-logic budget of the platform.
    pub part: FpgaPart,
}

impl BoardTarget {
    /// Bundle a board with its part, named after the board.
    pub fn new(board: BoardConfig, part: FpgaPart) -> Self {
        Self {
            name: board.name.clone(),
            board,
            part,
        }
    }

    /// A built-in preset by name: `zynq702` (ZC702 / XC7Z020), `zynq706`
    /// (ZC706 / XC7Z045) or `zynq-ultrascale` (ZCU102-class / XCZU9EG).
    pub fn builtin(name: &str) -> Option<Self> {
        match name {
            "zynq702" => Some(Self::new(BoardConfig::zynq702(), FpgaPart::xc7z020())),
            "zynq706" => Some(Self::new(BoardConfig::zynq706(), FpgaPart::xc7z045())),
            "zynq-ultrascale" => Some(Self::new(
                BoardConfig::zynq_ultrascale(),
                FpgaPart::xczu9eg(),
            )),
            _ => None,
        }
    }

    /// Load a target from a TOML board file. The board keys follow
    /// [`BoardConfig::from_toml`]; the part comes from `[fabric] part`
    /// (a built-in part name, default `xc7z045`).
    pub fn from_toml_file(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Parse a target from TOML text (see [`BoardTarget::from_toml_file`]).
    pub fn from_toml(text: &str) -> anyhow::Result<Self> {
        let board = BoardConfig::from_toml(text)?;
        let doc = crate::config::toml::parse(text).map_err(|e| anyhow::anyhow!(e.to_string()))?;
        // `BoardConfig::from_toml` silently defaults a missing `name` to
        // "zynq706" — fine for `--board`, but an axis entry's name labels
        // every result row and is the duplicate key, so require it.
        anyhow::ensure!(
            doc.get("name").is_some(),
            "board-axis TOML files must set a `name` (it labels the result rows)"
        );
        let part_name = doc.str_or("fabric.part", "xc7z045");
        let part = FpgaPart::by_name(&part_name)
            .ok_or_else(|| anyhow::anyhow!("unknown FPGA part '{part_name}' in board file"))?;
        Ok(Self::new(board, part))
    }
}

/// The swept board axis: an ordered, de-duplicated list of targets.
#[derive(Clone, Debug, Default)]
pub struct BoardSpace {
    /// The platform candidates, in resolution order.
    pub targets: Vec<BoardTarget>,
}

impl BoardSpace {
    /// Resolve a list of tokens into targets. Each token is either a
    /// built-in preset name or a path to a TOML board file; tokens may
    /// themselves be comma-separated lists (the CLI passes `--boards
    /// zynq702,zynq706` through unsplit). Duplicate names are rejected —
    /// a board axis with two identical entries would double-count every
    /// candidate.
    pub fn resolve(tokens: &[&str]) -> anyhow::Result<Self> {
        let mut targets: Vec<BoardTarget> = Vec::new();
        for token in tokens.iter().flat_map(|t| t.split(',')) {
            let token = token.trim();
            if token.is_empty() {
                continue;
            }
            let target = match BoardTarget::builtin(token) {
                Some(t) => t,
                None if token.ends_with(".toml") => {
                    BoardTarget::from_toml_file(Path::new(token))?
                }
                None => anyhow::bail!(
                    "unknown board '{token}' (built-ins: zynq702|zynq706|zynq-ultrascale, \
                     or a path to a .toml board file)"
                ),
            };
            if targets.iter().any(|t| t.name == target.name) {
                anyhow::bail!("duplicate board '{}' in the board axis", target.name);
            }
            targets.push(target);
        }
        anyhow::ensure!(!targets.is_empty(), "the board axis is empty");
        Ok(Self { targets })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_presets_resolve() {
        let s = BoardSpace::resolve(&["zynq702,zynq706", "zynq-ultrascale"]).unwrap();
        assert_eq!(s.targets.len(), 3);
        assert_eq!(s.targets[0].name, "zynq702");
        assert_eq!(s.targets[0].part.name, "xc7z020");
        assert_eq!(s.targets[1].part.name, "xc7z045");
        assert_eq!(s.targets[2].part.name, "xczu9eg");
    }

    #[test]
    fn unknown_and_duplicate_boards_rejected() {
        assert!(BoardSpace::resolve(&["zynq9000"]).is_err());
        assert!(BoardSpace::resolve(&["zynq706", "zynq706"]).is_err());
        assert!(BoardSpace::resolve(&[""]).is_err());
    }

    #[test]
    fn toml_target_reads_part() {
        let t = BoardTarget::from_toml(
            "name = \"lab-z020\"\n[fabric]\nfreq_mhz = 100\npart = \"xc7z020\"\n",
        )
        .unwrap();
        assert_eq!(t.name, "lab-z020");
        assert_eq!(t.part.name, "xc7z020");
        assert_eq!(t.board.fabric_freq_mhz, 100.0);
        // Default part is the paper's.
        let d = BoardTarget::from_toml("name = \"x\"\n").unwrap();
        assert_eq!(d.part.name, "xc7z045");
        // Unknown parts are an error, not a silent default.
        assert!(BoardTarget::from_toml("name = \"x\"\n[fabric]\npart = \"xc9999\"\n").is_err());
        // A nameless board file would silently label rows "zynq706".
        assert!(BoardTarget::from_toml("[fabric]\npart = \"xc7z020\"\n").is_err());
    }
}
