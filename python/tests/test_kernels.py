"""Layer-1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes and value distributions; fixed-seed cases pin the
paper's exact granularities (64, 128).
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import chol, mxm, ref, stencil

RTOL = 1e-4
ATOL = 1e-4

# Tile sizes: the paper's granularities plus smaller powers of two to sweep
# shape handling. Hypothesis draws from these.
SIZES = [4, 8, 16, 32, 64, 128]


def tiles(draw, n_tiles, bs, lo=-2.0, hi=2.0, seed=None):
    rng = np.random.default_rng(seed)
    return [rng.uniform(lo, hi, size=(bs, bs)).astype(np.float32) for _ in range(n_tiles)]


@st.composite
def tile_case(draw, n_tiles):
    bs = draw(st.sampled_from(SIZES))
    seed = draw(st.integers(0, 2**32 - 1))
    return bs, tiles(draw, n_tiles, bs, seed=seed)


@given(tile_case(3))
@settings(max_examples=25, deadline=None)
def test_mxm_block_matches_ref(case):
    bs, (a, b, c) = case
    out = mxm.mxm_block(a, b, c)
    expect = ref.mxm_block(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    np.testing.assert_allclose(out, expect, rtol=RTOL, atol=ATOL)


@given(tile_case(3))
@settings(max_examples=25, deadline=None)
def test_gemm_tile_matches_ref(case):
    bs, (a, b, c) = case
    out = chol.gemm_tile(a, b, c)
    expect = ref.gemm_tile(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    np.testing.assert_allclose(out, expect, rtol=RTOL, atol=ATOL)


@given(tile_case(2))
@settings(max_examples=25, deadline=None)
def test_syrk_tile_matches_ref(case):
    bs, (a, c) = case
    out = chol.syrk_tile(a, c)
    expect = ref.syrk_tile(jnp.asarray(a), jnp.asarray(c))
    np.testing.assert_allclose(out, expect, rtol=RTOL, atol=ATOL)


@given(tile_case(2))
@settings(max_examples=15, deadline=None)
def test_trsm_tile_matches_ref(case):
    bs, (x, b) = case
    # Build a well-conditioned lower-triangular factor.
    l = np.asarray(ref.potrf_tile(ref.make_spd(jnp.asarray(x))))
    out = chol.trsm_tile(l, b)
    expect = ref.trsm_tile(jnp.asarray(l), jnp.asarray(b))
    np.testing.assert_allclose(out, expect, rtol=5e-3, atol=5e-3)
    # And the defining property: out @ l.T == b.
    np.testing.assert_allclose(np.asarray(out) @ l.T, b, rtol=5e-3, atol=5e-3)


@given(tile_case(1))
@settings(max_examples=15, deadline=None)
def test_potrf_tile_matches_ref(case):
    bs, (x,) = case
    a = np.asarray(ref.make_spd(jnp.asarray(x)))
    out = np.asarray(chol.potrf_tile(a))
    expect = np.asarray(ref.potrf_tile(jnp.asarray(a)))
    np.testing.assert_allclose(out, expect, rtol=5e-3, atol=5e-3)
    # Lower-triangular and reconstructs A.
    assert np.allclose(np.triu(out, 1), 0.0)
    np.testing.assert_allclose(out @ out.T, a, rtol=5e-3, atol=5e-3)


@given(tile_case(5))
@settings(max_examples=25, deadline=None)
def test_jacobi_tile_matches_ref(case):
    bs, ts = case
    out = stencil.jacobi_tile(*ts)
    expect = ref.jacobi_tile(*[jnp.asarray(t) for t in ts])
    np.testing.assert_allclose(out, expect, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("bs", [64, 128])
def test_paper_granularities_exact(bs):
    rng = np.random.default_rng(7)
    a, b, c = (rng.standard_normal((bs, bs)).astype(np.float32) for _ in range(3))
    out = mxm.mxm_block(a, b, c)
    np.testing.assert_allclose(out, a @ b + c, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("shape", [(256, 256, 256), (512, 256, 128)])
def test_matmul_tiled_full(shape):
    m, n, k = shape
    rng = np.random.default_rng(11)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    out = mxm.matmul_tiled(a, b, bm=128, bn=128, bk=128)
    np.testing.assert_allclose(out, a @ b, rtol=1e-3, atol=1e-2)


def test_blocked_matmul_ref_consistent():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((128, 128)).astype(np.float32)
    b = rng.standard_normal((128, 128)).astype(np.float32)
    out = ref.blocked_matmul(jnp.asarray(a), jnp.asarray(b), 64)
    np.testing.assert_allclose(out, a @ b, rtol=1e-3, atol=1e-2)


def test_bf16_variant_close_to_f32():
    """bf16 multiply / f32 accumulate: ~3 decimal digits of mantissa, so
    the tile result stays within a loose relative tolerance of f32."""
    rng = np.random.default_rng(21)
    bs = 64
    a, b, c = (rng.standard_normal((bs, bs)).astype(np.float32) for _ in range(3))
    out = mxm.mxm_block_bf16(a, b, c)
    expect = a @ b + c
    err = np.abs(np.asarray(out) - expect)
    scale = np.abs(expect) + 1.0
    assert np.max(err / scale) < 0.1, np.max(err / scale)


@given(
    st.sampled_from([64, 128, 256]),
    st.sampled_from([64, 128]),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=8, deadline=None)
def test_matmul_tiled_block_size_sweep(n, blk, seed):
    """The gridded kernel must be correct for every (matrix, block) combo
    the BlockSpec schedule can express."""
    if n % blk != 0:
        return
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    b = rng.standard_normal((n, n)).astype(np.float32)
    out = mxm.matmul_tiled(a, b, bm=blk, bn=blk, bk=blk)
    np.testing.assert_allclose(out, a @ b, rtol=1e-3, atol=5e-2)
