//! Integration tests: the full toolchain pipeline (app → basic trace →
//! dependence graph → elaboration → simulation → reports) across apps,
//! co-designs, policies and board variations.

use zynq_estimator::apps::{cholesky, matmul, stencil};
use zynq_estimator::config::{BoardConfig, CoDesign};
use zynq_estimator::coordinator::deps::DepGraph;
use zynq_estimator::coordinator::sched::Policy;
use zynq_estimator::experiments;
use zynq_estimator::hls::FpgaPart;
use zynq_estimator::metrics::SpeedupTable;
use zynq_estimator::sim::{self, emulate, estimate};
use zynq_estimator::trace;

fn board() -> BoardConfig {
    BoardConfig::zynq706()
}

// ---------------------------------------------------------------------------
// Paper headline reproductions (the EXPERIMENTS.md numbers come from here)
// ---------------------------------------------------------------------------

#[test]
fn fig5_full_reproduction() {
    let t = experiments::fig5(512, &board(), 5).unwrap();
    assert!(t.best_agrees());
    assert_eq!(t.rows[t.best_estimator()].name, "1acc 128");
    assert!(t.trend_agreement() >= 0.8, "tau {}", t.trend_agreement());
    // Estimator speedups exceed board speedups (no contention modelled) —
    // the systematic optimism §VI reports.
    let est_best = t.est_speedup.iter().cloned().fold(0.0, f64::max);
    let board_best = t.board_speedup.iter().cloned().fold(0.0, f64::max);
    assert!(
        est_best > board_best,
        "estimator should be optimistic: {est_best} vs {board_best}"
    );
}

#[test]
fn fig9_full_reproduction() {
    let t = experiments::fig9(512, &board(), 5).unwrap();
    assert!(t.best_agrees());
    assert!(t.trend_agreement() >= 0.8, "tau {}", t.trend_agreement());
    let best = &t.rows[t.best_estimator()].name;
    assert!(best.starts_with("dgemm+"), "winner {best} should be a dgemm pair");
}

#[test]
fn estimator_within_factor_two_of_board() {
    // Coarse-grain means order-of-magnitude correct: for every paper
    // configuration, the estimator lands within 2x of the "real" time.
    let b = board();
    for (cd, app) in matmul::fig5_cases(512) {
        let p = app.build_program(&b);
        let est = estimate(&p, &cd, &b).unwrap().makespan_ms();
        let real = emulate(&p, &cd, &b).unwrap().makespan_ms();
        let ratio = (est / real).max(real / est);
        assert!(ratio < 2.0, "{}: est {est:.1} vs real {real:.1}", cd.name);
    }
}

// ---------------------------------------------------------------------------
// Cross-app pipeline checks
// ---------------------------------------------------------------------------

#[test]
fn stencil_pipeline_end_to_end() {
    let b = board();
    let app = stencil::Stencil::new(512, 64, 4);
    let p = app.build_program(&b);
    for cd in stencil::example_codesigns() {
        let r = estimate(&p, &cd, &b).unwrap();
        assert!(r.validate().is_empty());
        assert_eq!(r.tasks_on_smp + r.tasks_on_accel, p.tasks.len());
    }
    // 2 accels beat 1 for this embarrassingly parallel sweep.
    let cds = stencil::example_codesigns();
    let r1 = estimate(&p, &cds[0], &b).unwrap();
    let r2 = estimate(&p, &cds[1], &b).unwrap();
    assert!(r2.makespan < r1.makespan);
}

#[test]
fn trace_file_roundtrip_preserves_simulation() {
    let b = board();
    let app = cholesky::Cholesky::new(512, 64);
    let p = app.build_program(&b);
    let text = trace::write_trace(&p);
    let p2 = trace::read_trace(&text).unwrap();
    let cd = &cholesky::fig9_codesigns()[5];
    let r1 = estimate(&p, cd, &b).unwrap();
    let r2 = estimate(&p2, cd, &b).unwrap();
    assert_eq!(r1.makespan, r2.makespan, "trace IO must not change timing");
}

#[test]
fn lookahead_policy_fixes_smp_pollution() {
    // The paper's future-work heuristic: with look-ahead scheduling the
    // "+ smp" configuration should no longer collapse.
    let b = board();
    let app = matmul::Matmul::new(512, 128);
    let p = app.build_program(&b);
    let cd = CoDesign::new("1acc128+smp")
        .with_accel("mxm128", matmul::UNROLL_128)
        .with_smp("mxm128");
    let run = |policy| {
        let mut m = sim::EstimatorModel::new(&b);
        sim::simulate(&p, &cd, &b, &FpgaPart::xc7z045(), policy, &mut m)
            .unwrap()
            .makespan_ms()
    };
    let greedy = run(Policy::Greedy);
    let lookahead = run(Policy::Lookahead);
    assert!(
        lookahead < greedy * 0.5,
        "lookahead {lookahead:.1} ms should beat greedy {greedy:.1} ms"
    );
}

#[test]
fn board_emulator_reps_are_stable() {
    let b = board();
    let app = matmul::Matmul::new(512, 128);
    let p = app.build_program(&b);
    let cd = CoDesign::new("1acc128").with_accel("mxm128", matmul::UNROLL_128);
    let m1 = sim::emulate_mean_ms(&p, &cd, &b, 5).unwrap();
    let m2 = sim::emulate_mean_ms(&p, &cd, &b, 5).unwrap();
    assert_eq!(m1, m2, "seeded emulation must be reproducible");
    // And the jitter across distinct seeds is small (CV ~4%).
    let single = emulate(&p, &cd, &b).unwrap().makespan_ms();
    assert!((single - m1).abs() / m1 < 0.2);
}

#[test]
fn faster_fabric_improves_fpga_configs() {
    let b = board();
    let mut fast = board();
    fast.fabric_freq_mhz = 250.0;
    let app = matmul::Matmul::new(512, 128);
    let p_slow = app.build_program(&b);
    let p_fast = app.build_program(&fast);
    let cd = CoDesign::new("1acc128").with_accel("mxm128", matmul::UNROLL_128);
    let slow_ms = estimate(&p_slow, &cd, &b).unwrap().makespan_ms();
    let fast_ms = estimate(&p_fast, &cd, &fast).unwrap().makespan_ms();
    assert!(fast_ms < slow_ms);
}

#[test]
fn dma_bandwidth_dominates_matmul() {
    // Matmul at the paper's sizes is DMA-bound on the Zynq: doubling DMA
    // bandwidth must help more than doubling fabric clock.
    let base = board();
    let mut bw2 = board();
    bw2.dma_bw_mbps *= 2.0;
    let mut clk2 = board();
    clk2.fabric_freq_mhz *= 2.0;
    let cd = CoDesign::new("1acc128").with_accel("mxm128", matmul::UNROLL_128);
    let run = |b: &BoardConfig| {
        let p = matmul::Matmul::new(512, 128).build_program(b);
        estimate(&p, &cd, b).unwrap().makespan_ms()
    };
    let t_base = run(&base);
    let t_bw = run(&bw2);
    let t_clk = run(&clk2);
    assert!(t_bw < t_base && t_clk < t_base);
    assert!(
        t_bw < t_clk,
        "bandwidth ({t_bw:.1}) should beat clock ({t_clk:.1})"
    );
}

#[test]
fn one_core_board_still_completes() {
    let mut b = board();
    b.smp_cores = 1;
    let app = cholesky::Cholesky::new(256, 64);
    let p = app.build_program(&b);
    for cd in cholesky::fig9_codesigns() {
        let r = estimate(&p, &cd, &b).unwrap();
        assert!(r.validate().is_empty());
    }
}

#[test]
fn speedup_table_render_is_stable() {
    let t = SpeedupTable::build(vec![
        zynq_estimator::metrics::ConfigRow {
            name: "x".into(),
            estimator_ms: 2.0,
            board_ms: 2.0,
        },
        zynq_estimator::metrics::ConfigRow {
            name: "y".into(),
            estimator_ms: 1.0,
            board_ms: 1.0,
        },
    ]);
    let r = t.render("t");
    assert!(r.contains("best config agrees: true"));
}

#[test]
fn graph_stats_match_apps() {
    let b = board();
    // Matmul NB=8: depth 8, width 64.
    let p = matmul::Matmul::new(512, 64).build_program(&b);
    let g = DepGraph::build(&p);
    assert_eq!(g.depth(), 8);
    assert_eq!(g.max_level_width(), 64);
    // Cholesky NB=8 has the long panel chain.
    let p = cholesky::Cholesky::new(512, 64).build_program(&b);
    let g = DepGraph::build(&p);
    assert!(g.depth() >= 3 * 7, "depth {}", g.depth());
}

#[test]
fn paraver_bundles_for_all_fig7_configs() {
    let b = board();
    let dir = std::env::temp_dir().join("zynq_fig7_test");
    let stems = experiments::fig7(512, &b, &dir).unwrap();
    assert_eq!(stems.len(), 4, "the paper plots four traces");
    for s in &stems {
        let prv = std::fs::read_to_string(s.with_extension("prv")).unwrap();
        assert!(prv.starts_with("#Paraver"));
        assert!(prv.lines().count() > 100);
    }
    std::fs::remove_dir_all(&dir).ok();
}
