//! Configuration system: board descriptions, co-design points, sweeps.
//!
//! A [`BoardConfig`] captures everything the cost models need to know about
//! the target platform (the paper's: Zynq APSoC on the ZC706 board — dual
//! Cortex-A9 @ 667 MHz + Kintex-7-class fabric). A [`CoDesign`] is one
//! hardware/software partitioning decision: which accelerators to
//! instantiate (kernel + unroll variant) and which kernels the runtime may
//! *also* schedule on the SMP (the paper's "+ smp" configurations).
//!
//! Configs load from TOML files (see `configs/zynq706.toml`) through the
//! `toml` submodule and every field has a calibrated default so programs
//! also run config-free.

pub mod toml;

use std::path::Path;

use crate::coordinator::task::KernelId;

/// Parameters of the detailed board emulator — the effects §VI says the
/// coarse-grain estimator deliberately ignores ("our estimator does not
/// consider memory hierarchy aspects like cache coherence and pinning of
/// memory pages, neither memory contention, etc.").
#[derive(Clone, Debug, PartialEq)]
pub struct EmuConfig {
    /// Memory/AXI-port contention: effective DMA bandwidth is divided by
    /// `1 + alpha * (streams - 1)` when `streams` transfers are in flight.
    pub contention_alpha: f64,
    /// Cache-coherence / flush cost (us) charged when a buffer last touched
    /// by a different device class is consumed (ACP/cache-flush traffic).
    pub coherence_us: f64,
    /// Page-pinning cost (us per KiB) charged on the first DMA touching a
    /// buffer (Linux get_user_pages on the ZC706 environment).
    pub pinning_us_per_kb: f64,
    /// SMP slowdown factor from sharing the L2/DDR with active DMA streams.
    pub smp_mem_factor: f64,
    /// Coefficient of variation of the lognormal-ish execution jitter.
    pub jitter_cv: f64,
    /// Seed for the emulator's jitter stream.
    pub seed: u64,
}

impl Default for EmuConfig {
    fn default() -> Self {
        Self {
            contention_alpha: 0.12,
            coherence_us: 18.0,
            pinning_us_per_kb: 0.22,
            smp_mem_factor: 0.12,
            jitter_cv: 0.04,
            seed: 0x5EED_2706,
        }
    }
}

/// Platform description consumed by both the estimator and the emulator.
#[derive(Clone, Debug, PartialEq)]
pub struct BoardConfig {
    /// Board name (reports, tables).
    pub name: String,
    /// Number of ARM cores available to the runtime (ZC706: dual A9).
    pub smp_cores: u32,
    /// ARM core clock, MHz.
    pub smp_freq_mhz: f64,
    /// Fabric clock Vivado HLS targets for the generated accelerators.
    pub fabric_freq_mhz: f64,

    // --- DMA subsystem (Fig. 3 behaviour) ---
    /// Input transfers use per-accelerator channels and scale with the
    /// number of accelerators (true on the ZC706 environment of the paper).
    pub dma_in_scales: bool,
    /// Output transfers share one channel and serialize (false = shared).
    pub dma_out_scales: bool,
    /// Sustained per-channel DMA bandwidth, MB/s.
    pub dma_bw_mbps: f64,
    /// Software cost (us) to program one DMA descriptor — the "submit"
    /// tasks of §IV, serialized on a shared resource.
    pub dma_submit_us: f64,

    // --- OmpSs runtime costs ---
    /// Task creation cost (us), run on the SMP regardless of where the task
    /// executes (§IV "creation cost task").
    pub task_creation_us: f64,

    // --- SMP cost model (stands in for the instrumented gettimeofday) ---
    /// Sustained FLOPs per cycle per A9 core for -O3 compiled kernels.
    pub smp_flops_per_cycle: f64,
    /// Multiplier on kernels with division/sqrt recurrences (dtrsm/dpotrf).
    pub smp_divsqrt_penalty: f64,
    /// Multiplier for double precision on the A9 VFP.
    pub smp_dp_penalty: f64,
    /// L1 data cache size per A9 core (KiB) — working sets beyond it pay
    /// the capacity-miss factor below (why SMP 128×128 tiles are
    /// disproportionately slower than 8× a 64×64 tile).
    pub smp_l1_kb: f64,
    /// Capacity-miss slowdown per doubling of working set beyond L1.
    pub smp_cache_alpha: f64,

    /// Board-emulator-only effect parameters.
    pub emu: EmuConfig,
}

impl BoardConfig {
    /// The paper's platform: Zynq All-Programmable SoC on the ZC706 board
    /// (XC7Z045: dual Cortex-A9 @ 667 MHz, Kintex-7 fabric, HLS ~125 MHz).
    /// Timing constants are calibrated against public OmpSs@Zynq numbers,
    /// see DESIGN.md §1 and the calibration tests in `board/`.
    pub fn zynq706() -> Self {
        Self {
            name: "zynq706".into(),
            smp_cores: 2,
            smp_freq_mhz: 667.0,
            fabric_freq_mhz: 125.0,
            dma_in_scales: true,
            dma_out_scales: false,
            dma_bw_mbps: 400.0,
            dma_submit_us: 4.0,
            task_creation_us: 18.0,
            smp_flops_per_cycle: 0.5,
            smp_divsqrt_penalty: 2.2,
            smp_dp_penalty: 1.6,
            smp_l1_kb: 32.0,
            smp_cache_alpha: 0.1,
            emu: EmuConfig::default(),
        }
    }

    /// Entry-level preset: Zynq APSoC on the ZC702 board (XC7Z020: the
    /// same dual Cortex-A9 PS as the ZC706, but an Artix-7-class fabric —
    /// roughly a quarter of the DSP/LUT budget — that typically closes
    /// timing at a lower HLS clock). DMA and runtime costs are PS-side and
    /// match the ZC706; only the fabric differs. Pair with
    /// `hls::FpgaPart::xc7z020()` in sweeps.
    pub fn zynq702() -> Self {
        Self {
            name: "zynq702".into(),
            fabric_freq_mhz: 100.0,
            ..Self::zynq706()
        }
    }

    /// Next-generation preset: Zynq UltraScale+ MPSoC (ZU9EG-class), the
    /// platform the paper's intro points to ("also includes GPUs in the
    /// next generation Zynq UltraScale+ MPSoC"). Quad Cortex-A53 @ 1.2 GHz
    /// (in-order but dual-issue: ~0.8 flops/cycle sustained), faster
    /// fabric and full-duplex high-bandwidth DMA. Pair with
    /// `hls::FpgaPart::xczu9eg()` in sweeps.
    pub fn zynq_ultrascale() -> Self {
        Self {
            name: "zynq-ultrascale".into(),
            smp_cores: 4,
            smp_freq_mhz: 1200.0,
            fabric_freq_mhz: 300.0,
            dma_in_scales: true,
            dma_out_scales: true, // US+ DMA: independent full-duplex channels
            dma_bw_mbps: 1600.0,
            dma_submit_us: 2.0,
            task_creation_us: 8.0,
            smp_flops_per_cycle: 0.8,
            smp_divsqrt_penalty: 1.8,
            smp_dp_penalty: 1.3,
            smp_l1_kb: 32.0,
            smp_cache_alpha: 0.08,
            emu: EmuConfig::default(),
        }
    }

    /// The ARM clock domain.
    pub fn smp_clock(&self) -> crate::sim::time::Clock {
        crate::sim::time::Clock::new(self.smp_freq_mhz)
    }

    /// The PL fabric clock domain.
    pub fn fabric_clock(&self) -> crate::sim::time::Clock {
        crate::sim::time::Clock::new(self.fabric_freq_mhz)
    }

    /// Load from a TOML file; unspecified keys keep the zynq706 defaults.
    pub fn from_toml_file(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text; unspecified keys keep the zynq706 defaults.
    /// Every numeric field is validated ([`BoardConfig::validate`]) so a
    /// bad board file is rejected here with the offending field named,
    /// not discovered as nonsense estimates deep inside a sweep.
    pub fn from_toml(text: &str) -> anyhow::Result<Self> {
        crate::util::faultpoint::hit("board.toml")?;
        let doc = toml::parse(text).map_err(|e| anyhow::anyhow!(e.to_string()))?;
        let d = Self::zynq706();
        // `smp.cores` is range-checked on the raw integer: a plain
        // `as u32` cast would wrap a negative count into a huge one.
        let smp_cores = doc.i64_or("smp.cores", d.smp_cores as i64);
        anyhow::ensure!(
            (1..=1024).contains(&smp_cores),
            "board config field 'smp.cores': must be in 1..=1024, got {smp_cores}"
        );
        let cfg = Self {
            name: doc.str_or("name", &d.name),
            smp_cores: smp_cores as u32,
            smp_freq_mhz: doc.f64_or("smp.freq_mhz", d.smp_freq_mhz),
            fabric_freq_mhz: doc.f64_or("fabric.freq_mhz", d.fabric_freq_mhz),
            dma_in_scales: doc.bool_or("dma.in_scales", d.dma_in_scales),
            dma_out_scales: doc.bool_or("dma.out_scales", d.dma_out_scales),
            dma_bw_mbps: doc.f64_or("dma.bw_mbps", d.dma_bw_mbps),
            dma_submit_us: doc.f64_or("dma.submit_us", d.dma_submit_us),
            task_creation_us: doc.f64_or("runtime.task_creation_us", d.task_creation_us),
            smp_flops_per_cycle: doc.f64_or("smp.flops_per_cycle", d.smp_flops_per_cycle),
            smp_divsqrt_penalty: doc.f64_or("smp.divsqrt_penalty", d.smp_divsqrt_penalty),
            smp_dp_penalty: doc.f64_or("smp.dp_penalty", d.smp_dp_penalty),
            smp_l1_kb: doc.f64_or("smp.l1_kb", d.smp_l1_kb),
            smp_cache_alpha: doc.f64_or("smp.cache_alpha", d.smp_cache_alpha),
            emu: EmuConfig {
                contention_alpha: doc.f64_or("emu.contention_alpha", d.emu.contention_alpha),
                coherence_us: doc.f64_or("emu.coherence_us", d.emu.coherence_us),
                pinning_us_per_kb: doc.f64_or("emu.pinning_us_per_kb", d.emu.pinning_us_per_kb),
                smp_mem_factor: doc.f64_or("emu.smp_mem_factor", d.emu.smp_mem_factor),
                jitter_cv: doc.f64_or("emu.jitter_cv", d.emu.jitter_cv),
                seed: doc.i64_or("emu.seed", d.emu.seed as i64) as u64,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Validate every numeric field. The cost models divide by
    /// frequencies, bandwidths and cache sizes, so a NaN, negative or
    /// zero value would surface as nonsense estimates (or a panic) far
    /// from its source; rejecting at ingestion names the offending field
    /// instead. The built-in presets all pass.
    pub fn validate(&self) -> anyhow::Result<()> {
        fn positive(field: &str, v: f64) -> anyhow::Result<()> {
            anyhow::ensure!(
                v.is_finite() && v > 0.0,
                "board config field '{field}': must be finite and > 0, got {v}"
            );
            Ok(())
        }
        fn non_negative(field: &str, v: f64) -> anyhow::Result<()> {
            anyhow::ensure!(
                v.is_finite() && v >= 0.0,
                "board config field '{field}': must be finite and >= 0, got {v}"
            );
            Ok(())
        }
        anyhow::ensure!(!self.name.is_empty(), "board config field 'name': must not be empty");
        anyhow::ensure!(
            (1..=1024).contains(&self.smp_cores),
            "board config field 'smp_cores': must be in 1..=1024, got {}",
            self.smp_cores
        );
        positive("smp_freq_mhz", self.smp_freq_mhz)?;
        positive("fabric_freq_mhz", self.fabric_freq_mhz)?;
        positive("dma_bw_mbps", self.dma_bw_mbps)?;
        positive("smp_flops_per_cycle", self.smp_flops_per_cycle)?;
        positive("smp_divsqrt_penalty", self.smp_divsqrt_penalty)?;
        positive("smp_dp_penalty", self.smp_dp_penalty)?;
        positive("smp_l1_kb", self.smp_l1_kb)?;
        non_negative("dma_submit_us", self.dma_submit_us)?;
        non_negative("task_creation_us", self.task_creation_us)?;
        non_negative("smp_cache_alpha", self.smp_cache_alpha)?;
        non_negative("emu.contention_alpha", self.emu.contention_alpha)?;
        non_negative("emu.coherence_us", self.emu.coherence_us)?;
        non_negative("emu.pinning_us_per_kb", self.emu.pinning_us_per_kb)?;
        non_negative("emu.smp_mem_factor", self.emu.smp_mem_factor)?;
        non_negative("emu.jitter_cv", self.emu.jitter_cv)?;
        Ok(())
    }

    /// Serialize to TOML (round-trips through `from_toml`).
    pub fn to_toml(&self) -> String {
        format!(
            "name = \"{}\"\n\n[smp]\ncores = {}\nfreq_mhz = {}\nflops_per_cycle = {}\ndivsqrt_penalty = {}\ndp_penalty = {}\nl1_kb = {}\ncache_alpha = {}\n\n[fabric]\nfreq_mhz = {}\n\n[dma]\nin_scales = {}\nout_scales = {}\nbw_mbps = {}\nsubmit_us = {}\n\n[runtime]\ntask_creation_us = {}\n\n[emu]\ncontention_alpha = {}\ncoherence_us = {}\npinning_us_per_kb = {}\nsmp_mem_factor = {}\njitter_cv = {}\nseed = {}\n",
            self.name,
            self.smp_cores,
            self.smp_freq_mhz,
            self.smp_flops_per_cycle,
            self.smp_divsqrt_penalty,
            self.smp_dp_penalty,
            self.smp_l1_kb,
            self.smp_cache_alpha,
            self.fabric_freq_mhz,
            self.dma_in_scales,
            self.dma_out_scales,
            self.dma_bw_mbps,
            self.dma_submit_us,
            self.task_creation_us,
            self.emu.contention_alpha,
            self.emu.coherence_us,
            self.emu.pinning_us_per_kb,
            self.emu.smp_mem_factor,
            self.emu.jitter_cv,
            self.emu.seed,
        )
    }
}

impl Default for BoardConfig {
    fn default() -> Self {
        Self::zynq706()
    }
}

/// One accelerator instance of a co-design: which kernel it implements and
/// the HLS unroll variant (how much fabric it is allowed to burn).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AccelSpec {
    /// Kernel name the accelerator implements.
    pub kernel: String,
    /// Unroll factor of the innermost pipelined loop — the HLS knob that
    /// trades DSP/LUT area for latency. `hls::CostModel` maps it to both.
    pub unroll: u32,
}

impl AccelSpec {
    /// An accelerator spec for `kernel` at `unroll`.
    pub fn new(kernel: &str, unroll: u32) -> Self {
        Self {
            kernel: kernel.to_string(),
            unroll,
        }
    }

    /// Compact text form used in config files and CLI: `"mxm64:U32"`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let (k, u) = s
            .split_once(":U")
            .ok_or_else(|| anyhow::anyhow!("accel spec '{s}' must look like 'kernel:U<unroll>'"))?;
        Ok(Self {
            kernel: k.to_string(),
            unroll: u
                .parse()
                .map_err(|_| anyhow::anyhow!("bad unroll in accel spec '{s}'"))?,
        })
    }

    /// The compact `kernel:U<unroll>` form.
    pub fn to_spec_string(&self) -> String {
        format!("{}:U{}", self.kernel, self.unroll)
    }
}

/// A hardware/software co-design point — the object the paper's programmer
/// iterates over ("which kernels have accelerators, how many, how big, and
/// is heterogeneous SMP execution allowed").
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CoDesign {
    /// Co-design name (tables, reports).
    pub name: String,
    /// Accelerator instances to synthesize.
    pub accels: Vec<AccelSpec>,
    /// Kernels the scheduler may run on the SMP even though they have an
    /// accelerator ("+ smp" configurations). Kernels *without* an
    /// accelerator always run on SMP if their annotation allows it.
    pub smp_kernels: Vec<String>,
}

impl CoDesign {
    /// An empty co-design with a name.
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            ..Default::default()
        }
    }

    /// Add one accelerator instance (builder).
    pub fn with_accel(mut self, kernel: &str, unroll: u32) -> Self {
        self.accels.push(AccelSpec::new(kernel, unroll));
        self
    }

    /// Allow SMP execution for an accelerated kernel (builder).
    pub fn with_smp(mut self, kernel: &str) -> Self {
        self.smp_kernels.push(kernel.to_string());
        self
    }

    /// Number of accelerator instances serving a kernel.
    pub fn accel_count_for(&self, kernel: &str) -> usize {
        self.accels.iter().filter(|a| a.kernel == kernel).count()
    }

    /// Whether `+ smp` execution is allowed for a kernel.
    pub fn allows_smp(&self, kernel: &str) -> bool {
        self.smp_kernels.iter().any(|k| k == kernel)
    }

    /// Whether any accelerator serves a kernel.
    pub fn has_accel(&self, kernel: &str) -> bool {
        self.accel_count_for(kernel) > 0
    }

    /// Parse a co-design from TOML text.
    pub fn from_toml(text: &str) -> anyhow::Result<Self> {
        let doc = toml::parse(text).map_err(|e| anyhow::anyhow!(e.to_string()))?;
        let mut cd = CoDesign::new(&doc.str_or("name", "unnamed"));
        if let Some(arr) = doc.get("accels").and_then(|i| i.as_str_arr()) {
            for s in arr {
                cd.accels.push(AccelSpec::parse(s)?);
            }
        }
        if let Some(arr) = doc.get("smp_kernels").and_then(|i| i.as_str_arr()) {
            cd.smp_kernels = arr.to_vec();
        }
        Ok(cd)
    }

    /// Serialize to TOML (round-trips through `from_toml`).
    pub fn to_toml(&self) -> String {
        let accels: Vec<String> = self
            .accels
            .iter()
            .map(|a| format!("\"{}\"", a.to_spec_string()))
            .collect();
        let smp: Vec<String> = self.smp_kernels.iter().map(|k| format!("\"{k}\"")).collect();
        format!(
            "name = \"{}\"\naccels = [{}]\nsmp_kernels = [{}]\n",
            self.name,
            accels.join(", "),
            smp.join(", ")
        )
    }
}

/// Mapping from co-design accel specs to the kernel-id space of a concrete
/// program (resolved at simulation setup).
#[derive(Clone, Debug)]
pub struct ResolvedAccel {
    /// Kernel id in the resolved program.
    pub kernel: KernelId,
    /// Unroll variant.
    pub unroll: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zynq702_shares_ps_side_with_706() {
        let b2 = BoardConfig::zynq702();
        let b6 = BoardConfig::zynq706();
        assert_eq!(b2.name, "zynq702");
        assert_eq!(b2.smp_cores, b6.smp_cores);
        assert_eq!(b2.smp_freq_mhz, b6.smp_freq_mhz);
        assert_eq!(b2.dma_bw_mbps, b6.dma_bw_mbps);
        assert!(b2.fabric_freq_mhz < b6.fabric_freq_mhz);
    }

    #[test]
    fn zynq706_defaults_sane() {
        let b = BoardConfig::zynq706();
        assert_eq!(b.smp_cores, 2);
        assert!(b.dma_in_scales && !b.dma_out_scales);
        assert!(b.smp_freq_mhz > b.fabric_freq_mhz);
    }

    #[test]
    fn board_toml_roundtrip() {
        let b = BoardConfig::zynq706();
        let b2 = BoardConfig::from_toml(&b.to_toml()).unwrap();
        assert_eq!(b, b2);
    }

    #[test]
    fn board_toml_partial_overrides() {
        let b = BoardConfig::from_toml("[dma]\nbw_mbps = 600.0\n").unwrap();
        assert_eq!(b.dma_bw_mbps, 600.0);
        assert_eq!(b.smp_cores, 2); // default retained
    }

    #[test]
    fn board_validation_names_the_offending_field() {
        for (toml, field) in [
            ("[fabric]\nfreq_mhz = -125.0\n", "fabric_freq_mhz"),
            ("[fabric]\nfreq_mhz = 0.0\n", "fabric_freq_mhz"),
            ("[dma]\nbw_mbps = 0.0\n", "dma_bw_mbps"),
            ("[smp]\ncores = -2\n", "smp.cores"),
            ("[smp]\ncores = 0\n", "smp.cores"),
            ("[smp]\nl1_kb = -32.0\n", "smp_l1_kb"),
            ("[runtime]\ntask_creation_us = -1.0\n", "task_creation_us"),
            ("[emu]\njitter_cv = -0.5\n", "emu.jitter_cv"),
            ("name = \"\"\n", "name"),
        ] {
            let err = BoardConfig::from_toml(toml).unwrap_err();
            assert!(err.to_string().contains(field), "{toml:?}: {err}");
        }
        // Non-finite values injected past the parser are still caught.
        let mut b = BoardConfig::zynq706();
        b.smp_freq_mhz = f64::NAN;
        assert!(b.validate().unwrap_err().to_string().contains("smp_freq_mhz"));
        let mut b = BoardConfig::zynq706();
        b.dma_submit_us = f64::INFINITY;
        assert!(b.validate().unwrap_err().to_string().contains("dma_submit_us"));
    }

    #[test]
    fn builtin_presets_validate() {
        BoardConfig::zynq706().validate().unwrap();
        BoardConfig::zynq702().validate().unwrap();
        BoardConfig::zynq_ultrascale().validate().unwrap();
    }

    #[test]
    fn accel_spec_parse() {
        let a = AccelSpec::parse("mxm128:U64").unwrap();
        assert_eq!(a.kernel, "mxm128");
        assert_eq!(a.unroll, 64);
        assert_eq!(a.to_spec_string(), "mxm128:U64");
        assert!(AccelSpec::parse("nounroll").is_err());
        assert!(AccelSpec::parse("k:Uxx").is_err());
    }

    #[test]
    fn codesign_builders_and_queries() {
        let cd = CoDesign::new("2acc64+smp")
            .with_accel("mxm64", 32)
            .with_accel("mxm64", 32)
            .with_smp("mxm64");
        assert_eq!(cd.accel_count_for("mxm64"), 2);
        assert!(cd.allows_smp("mxm64"));
        assert!(!cd.allows_smp("other"));
        assert!(cd.has_accel("mxm64"));
        assert!(!cd.has_accel("other"));
    }

    #[test]
    fn codesign_toml_roundtrip() {
        let cd = CoDesign::new("fr-dgemm")
            .with_accel("dgemm", 48)
            .with_smp("dgemm");
        let cd2 = CoDesign::from_toml(&cd.to_toml()).unwrap();
        assert_eq!(cd, cd2);
    }
}
