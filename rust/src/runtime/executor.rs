//! Dataflow executor — the run-time counterpart of the simulator.
//!
//! Executes a [`TaskProgram`] *for real*: worker threads pull ready tasks
//! in dependence order (exactly the Nanos++ semantics the simulator
//! models) and run each task's kernel through the PJRT runtime. Used by
//! the end-to-end example and the executor tests; this is what makes the
//! repository a system rather than only a simulator.
//!
//! PJRT client handles are not `Sync`, so each worker owns a `Runtime`.
//! Task payload execution is abstracted behind [`TaskFn`] so applications
//! bind their own tile storage.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::coordinator::deps::DepGraph;
use crate::coordinator::task::{TaskId, TaskProgram};

/// Executes one task (given its id) on a worker-owned runtime context.
/// Returns Err to abort the whole execution.
pub type TaskFn<'a, C> = dyn Fn(&mut C, TaskId) -> anyhow::Result<()> + Sync + 'a;

/// Per-run statistics.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    /// Tasks executed.
    pub tasks: usize,
    /// End-to-end wall time, seconds.
    pub wall_seconds: f64,
    /// Tasks executed per worker thread.
    pub per_worker: Vec<usize>,
}

struct Shared {
    indegree: Vec<u32>,
    ready: VecDeque<TaskId>,
    completed: usize,
    failed: Option<String>,
}

/// Run `program` over `workers` threads. `make_ctx` builds each worker's
/// context (e.g. a PJRT [`crate::runtime::Runtime`]); `task_fn` executes
/// one task. Tasks are released in dependence order from `graph`.
pub fn execute<C, F>(
    program: &TaskProgram,
    graph: &DepGraph,
    workers: usize,
    make_ctx: F,
    task_fn: &TaskFn<'_, C>,
) -> anyhow::Result<ExecStats>
where
    F: Fn(usize) -> anyhow::Result<C> + Sync,
{
    assert!(workers >= 1);
    let n_tasks = program.tasks.len();
    let indegree: Vec<u32> = graph.preds.iter().map(|p| p.len() as u32).collect();
    let ready: VecDeque<TaskId> = (0..n_tasks as TaskId)
        .filter(|&t| indegree[t as usize] == 0)
        .collect();
    let shared = Mutex::new(Shared {
        indegree,
        ready,
        completed: 0,
        failed: None,
    });
    let cv = Condvar::new();
    let counts = Mutex::new(vec![0usize; workers]);

    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for w in 0..workers {
            let shared = &shared;
            let cv = &cv;
            let counts = &counts;
            let make_ctx = &make_ctx;
            scope.spawn(move || {
                let mut ctx = match make_ctx(w) {
                    Ok(c) => c,
                    Err(e) => {
                        let mut st = shared.lock().unwrap();
                        st.failed = Some(format!("worker {w} init: {e:#}"));
                        cv.notify_all();
                        return;
                    }
                };
                loop {
                    let task = {
                        let mut st = shared.lock().unwrap();
                        loop {
                            if st.failed.is_some() || st.completed == n_tasks {
                                return;
                            }
                            if let Some(t) = st.ready.pop_front() {
                                break t;
                            }
                            st = cv.wait(st).unwrap();
                        }
                    };
                    match task_fn(&mut ctx, task) {
                        Ok(()) => {
                            counts.lock().unwrap()[w] += 1;
                            let mut st = shared.lock().unwrap();
                            st.completed += 1;
                            for &s in &graph.succs[task as usize] {
                                let d = &mut st.indegree[s as usize];
                                *d -= 1;
                                if *d == 0 {
                                    st.ready.push_back(s);
                                }
                            }
                            cv.notify_all();
                        }
                        Err(e) => {
                            let mut st = shared.lock().unwrap();
                            st.failed = Some(format!("task {task}: {e:#}"));
                            cv.notify_all();
                            return;
                        }
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    let st = shared.into_inner().unwrap();
    if let Some(msg) = st.failed {
        anyhow::bail!("{msg}");
    }
    anyhow::ensure!(
        st.completed == n_tasks,
        "executor stalled at {}/{n_tasks} tasks (dependence cycle?)",
        st.completed
    );
    Ok(ExecStats {
        tasks: n_tasks,
        wall_seconds: wall,
        per_worker: counts.into_inner().unwrap(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Dep, KernelDecl, KernelProfile, Targets};
    use std::sync::atomic::{AtomicU32, Ordering};

    fn program_chain_and_fan(n_chain: u32, n_fan: u32) -> TaskProgram {
        let mut p = TaskProgram::new("exec-test");
        let k = p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::SMP,
            profile: KernelProfile {
                flops: 1,
                inner_trip: 1,
                in_bytes: 4,
                out_bytes: 4,
                dtype_bytes: 4,
                divsqrt: false,
            },
        });
        for _ in 0..n_chain {
            p.add_task(k, 1, vec![Dep::inout(0x1, 4)]);
        }
        for i in 0..n_fan {
            p.add_task(k, 1, vec![Dep::input(0x1, 4), Dep::output(0x100 + i as u64, 4)]);
        }
        p
    }

    #[test]
    fn executes_all_tasks_in_order() {
        let p = program_chain_and_fan(10, 20);
        let g = DepGraph::build(&p);
        let order = Mutex::new(Vec::new());
        let stats = execute(
            &p,
            &g,
            4,
            |_| Ok(()),
            &|_, t| {
                order.lock().unwrap().push(t);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(stats.tasks, 30);
        let order = order.into_inner().unwrap();
        assert_eq!(order.len(), 30);
        // The chain prefix must appear in increasing order.
        let chain_pos: Vec<usize> = (0..10u32)
            .map(|t| order.iter().position(|&x| x == t).unwrap())
            .collect();
        for w in chain_pos.windows(2) {
            assert!(w[0] < w[1], "chain executed out of order");
        }
        // Fan tasks all after the last chain task.
        let last_chain = chain_pos[9];
        for t in 10..30u32 {
            assert!(order.iter().position(|&x| x == t).unwrap() > last_chain);
        }
    }

    #[test]
    fn all_workers_participate_on_wide_graphs() {
        let p = program_chain_and_fan(1, 200);
        let g = DepGraph::build(&p);
        let spin = AtomicU32::new(0);
        let stats = execute(
            &p,
            &g,
            4,
            |_| Ok(()),
            &|_, _| {
                // Small spin so work outlasts queue handoff.
                for _ in 0..10_000 {
                    spin.fetch_add(1, Ordering::Relaxed);
                }
                Ok(())
            },
        )
        .unwrap();
        let active = stats.per_worker.iter().filter(|&&c| c > 0).count();
        assert!(active >= 2, "only {active} workers did work: {:?}", stats.per_worker);
        assert_eq!(stats.per_worker.iter().sum::<usize>(), 201);
    }

    #[test]
    fn task_error_aborts_cleanly() {
        let p = program_chain_and_fan(5, 0);
        let g = DepGraph::build(&p);
        let err = execute(
            &p,
            &g,
            2,
            |_| Ok(()),
            &|_, t| {
                if t == 2 {
                    anyhow::bail!("boom");
                }
                Ok(())
            },
        );
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("boom"));
    }

    #[test]
    fn worker_init_error_aborts() {
        let p = program_chain_and_fan(3, 0);
        let g = DepGraph::build(&p);
        let err = execute(&p, &g, 2, |w| {
            if w == 1 {
                anyhow::bail!("no device");
            }
            Ok(())
        }, &|_: &mut (), _| Ok(()));
        // Either the failing worker reports, or the other finishes all 3
        // tasks first — both are acceptable; just must not hang. An error
        // is expected only if init loses the race, so accept both.
        let _ = err;
    }

    #[test]
    fn single_worker_is_sequential_program_order_for_chains() {
        let p = program_chain_and_fan(25, 0);
        let g = DepGraph::build(&p);
        let order = Mutex::new(Vec::new());
        execute(&p, &g, 1, |_| Ok(()), &|_, t| {
            order.lock().unwrap().push(t);
            Ok(())
        })
        .unwrap();
        let order = order.into_inner().unwrap();
        assert_eq!(order, (0..25).collect::<Vec<_>>());
    }
}
