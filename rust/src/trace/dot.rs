//! Graphviz DOT export of the task dependency graph — regenerates the
//! paper's Fig. 8 (cholesky task dependency graph for NB = 4).

use crate::coordinator::deps::DepGraph;
use crate::coordinator::task::TaskProgram;

/// Fixed palette (one colour per kernel, wraps around).
const PALETTE: [&str; 8] = [
    "#4c72b0", "#dd8452", "#55a868", "#c44e52", "#8172b3", "#937860", "#da8bc3", "#8c8c8c",
];

/// Render the dependence DAG as DOT. Node label = `name#id`; one colour
/// per kernel; edges follow dataflow order.
pub fn to_dot(program: &TaskProgram, graph: &DepGraph) -> String {
    let mut s = String::new();
    s.push_str("digraph tasks {\n");
    s.push_str("  rankdir=TB;\n  node [style=filled, fontname=\"monospace\"];\n");
    s.push_str(&format!(
        "  label=\"{} task dependency graph ({} tasks, {} edges)\";\n",
        program.app_name,
        program.tasks.len(),
        graph.edge_count()
    ));
    for t in &program.tasks {
        let k = &program.kernels[t.kernel as usize];
        let color = PALETTE[t.kernel as usize % PALETTE.len()];
        s.push_str(&format!(
            "  t{} [label=\"{}#{}\", fillcolor=\"{}\"];\n",
            t.id, k.name, t.id, color
        ));
    }
    for (t, preds) in graph.preds.iter().enumerate() {
        for &p in preds {
            s.push_str(&format!("  t{p} -> t{t};\n"));
        }
    }
    s.push_str("}\n");
    s
}

/// Legend mapping kernels to colours (printed next to the graph).
pub fn legend(program: &TaskProgram) -> String {
    program
        .kernels
        .iter()
        .enumerate()
        .map(|(i, k)| format!("{} = {}", k.name, PALETTE[i % PALETTE.len()]))
        .collect::<Vec<_>>()
        .join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::cholesky::Cholesky;
    use crate::config::BoardConfig;

    #[test]
    fn dot_is_syntactically_plausible() {
        let b = BoardConfig::zynq706();
        let p = Cholesky::new(256, 64).build_program(&b); // NB=4, Fig. 8
        let g = DepGraph::build(&p);
        let dot = to_dot(&p, &g);
        assert!(dot.starts_with("digraph tasks {"));
        assert!(dot.trim_end().ends_with('}'));
        // One node line per task.
        let nodes = dot.lines().filter(|l| l.contains("[label=")).count();
        assert_eq!(nodes, p.tasks.len());
        // One edge line per dependence edge.
        let edges = dot.lines().filter(|l| l.contains(" -> ")).count();
        assert_eq!(edges, g.edge_count());
        assert!(dot.contains("dpotrf#0"));
    }

    #[test]
    fn legend_lists_all_kernels() {
        let b = BoardConfig::zynq706();
        let p = Cholesky::new(256, 64).build_program(&b);
        let l = legend(&p);
        for k in ["dgemm", "dsyrk", "dtrsm", "dpotrf"] {
            assert!(l.contains(k));
        }
    }
}
