//! Estimation-error robustness — how wrong can the HLS estimates be before
//! the co-design decision flips?
//!
//! The whole methodology rests on Vivado HLS *estimates* ("considering
//! only synthesis estimation results", abstract). This experiment
//! quantifies the safety margin: perturb every kernel's accelerator
//! latency by a random factor in `[1-err, 1+err]` (independent per kernel
//! per trial), re-run the sweep, and measure how often the winning
//! co-design survives. A decision that is stable under ±30% cycle-estimate
//! error is exactly what "coarse-grain but order-of-magnitude right"
//! means; instability at small errors would invalidate the approach.

use std::collections::HashMap;

use crate::apps::matmul;
use crate::config::BoardConfig;
use crate::coordinator::sched::Policy;
use crate::coordinator::task::KernelId;
use crate::hls::FpgaPart;
use crate::sim::engine::{TaskCtx, TimingModel};
use crate::sim::time::Ps;
use crate::sim::{simulate, EstimatorModel};
use crate::util::Rng;

/// Wraps the estimator model, scaling accelerator occupancy per kernel.
struct PerturbedModel {
    inner: EstimatorModel,
    factors: HashMap<KernelId, f64>,
}

impl TimingModel for PerturbedModel {
    fn creation_ps(&mut self, board: &BoardConfig) -> Ps {
        self.inner.creation_ps(board)
    }
    fn smp_compute_ps(&mut self, ctx: &TaskCtx, board: &BoardConfig) -> Ps {
        self.inner.smp_compute_ps(ctx, board)
    }
    fn accel_occupancy_ps(
        &mut self,
        ctx: &TaskCtx,
        board: &BoardConfig,
        input_in_occupancy: bool,
    ) -> Ps {
        let base = self.inner.accel_occupancy_ps(ctx, board, input_in_occupancy);
        let f = self.factors.get(&ctx.kernel).copied().unwrap_or(1.0);
        (base as f64 * f) as Ps
    }
    fn submit_ps(&mut self, n: u32, board: &BoardConfig) -> Ps {
        self.inner.submit_ps(n, board)
    }
    fn dma_ps(&mut self, bytes: u64, ctx: &TaskCtx, board: &BoardConfig) -> Ps {
        self.inner.dma_ps(bytes, ctx, board)
    }
}

/// One row of the robustness study.
#[derive(Clone, Debug)]
pub struct RobustnessRow {
    /// Relative error bound on the HLS latency estimates.
    pub err: f64,
    /// Fraction of trials where the winner matched the unperturbed winner.
    pub decision_stability: f64,
    /// Mean relative makespan deviation of the winning configuration.
    pub mean_makespan_dev: f64,
}

/// Run the study over the matmul Fig. 5 co-design set.
pub fn matmul_decision_stability(
    n: u64,
    board: &BoardConfig,
    errs: &[f64],
    trials: u32,
    seed: u64,
) -> anyhow::Result<Vec<RobustnessRow>> {
    let cases = matmul::fig5_cases(n);
    let part = FpgaPart::xc7z045();

    // Unperturbed winner and makespans.
    let mut base_ms = Vec::new();
    for (cd, app) in &cases {
        let program = app.build_program(board);
        let mut model = EstimatorModel::new(board);
        let res = simulate(&program, cd, board, &part, Policy::Greedy, &mut model)?;
        base_ms.push(res.makespan_ms());
    }
    let base_winner = argmin(&base_ms);

    let mut rows = Vec::new();
    for &err in errs {
        let mut stable = 0u32;
        let mut devs = Vec::new();
        let mut rng = Rng::new(seed ^ (err * 1e6) as u64);
        for _ in 0..trials {
            let mut ms = Vec::new();
            for (cd, app) in &cases {
                let program = app.build_program(board);
                let factors: HashMap<KernelId, f64> = (0..program.kernels.len())
                    .map(|k| (k as KernelId, 1.0 + rng.gen_range_f64(-err, err)))
                    .collect();
                let mut model = PerturbedModel {
                    inner: EstimatorModel::new(board),
                    factors,
                };
                let res = simulate(&program, cd, board, &part, Policy::Greedy, &mut model)?;
                ms.push(res.makespan_ms());
            }
            if argmin(&ms) == base_winner {
                stable += 1;
            }
            devs.push((ms[base_winner] - base_ms[base_winner]).abs() / base_ms[base_winner]);
        }
        rows.push(RobustnessRow {
            err,
            decision_stability: stable as f64 / trials as f64,
            mean_makespan_dev: crate::util::mean(&devs),
        });
    }
    Ok(rows)
}

fn argmin(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Human-readable robustness table.
pub fn render(rows: &[RobustnessRow]) -> String {
    let mut out = String::from(
        "== Robustness: co-design decision stability vs HLS estimate error\n",
    );
    out.push_str(&format!(
        "{:>10} {:>18} {:>22}\n",
        "est. error", "decision stable", "winner makespan dev"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:>9.0}% {:>17.0}% {:>21.1}%\n",
            r.err * 100.0,
            r.decision_stability * 100.0,
            r.mean_makespan_dev * 100.0
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_is_stable_under_moderate_error() {
        let board = BoardConfig::zynq706();
        let rows =
            matmul_decision_stability(512, &board, &[0.1, 0.3], 10, 42).unwrap();
        assert_eq!(rows.len(), 2);
        // At ±10% HLS error the winner must essentially never flip.
        assert!(
            rows[0].decision_stability >= 0.9,
            "stability at 10%: {}",
            rows[0].decision_stability
        );
        // Deviation grows with error.
        assert!(rows[1].mean_makespan_dev >= rows[0].mean_makespan_dev);
    }

    #[test]
    fn render_has_all_rows() {
        let rows = vec![RobustnessRow {
            err: 0.2,
            decision_stability: 0.95,
            mean_makespan_dev: 0.07,
        }];
        let s = render(&rows);
        assert!(s.contains("20%"));
        assert!(s.contains("95%"));
    }
}
