//! Minimal TOML-subset parser for the configuration system.
//!
//! Supported grammar (everything the shipped configs use):
//! - `[section]` and `[section.sub]` headers
//! - `key = "string"`, `key = 123`, `key = 1.5`, `key = true/false`
//! - `key = ["a", "b"]` (homogeneous string / number arrays)
//! - `#` comments, blank lines
//!
//! Documents parse into a flat `BTreeMap<String, Item>` keyed by
//! `section.key` (dotted path), which is all the typed accessors in
//! `config::mod` need. Unsupported TOML constructs produce a parse error
//! rather than silent misconfiguration.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
/// One parsed TOML value.
pub enum Item {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Array of strings.
    StrArr(Vec<String>),
    /// Array of numbers.
    NumArr(Vec<f64>),
}

impl Item {
    /// String value, if the item is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Item::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Float value (integers convert).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Item::Int(i) => Some(*i as f64),
            Item::Float(f) => Some(*f),
            _ => None,
        }
    }
    /// Integer value, if the item is an integer.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Item::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Bool value, if the item is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Item::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// String-array value, if the item is one.
    pub fn as_str_arr(&self) -> Option<&[String]> {
        match self {
            Item::StrArr(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
/// Parse failure with its line number.
pub struct TomlError {
    /// 1-based line of the error.
    pub line: usize,
    /// Human-readable description.
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// A parsed document: dotted-path key → item.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    /// Flat `section.key` → value map.
    pub items: BTreeMap<String, Item>,
}

impl Doc {
    /// Item at a dotted path.
    pub fn get(&self, path: &str) -> Option<&Item> {
        self.items.get(path)
    }

    /// String at a path, or the default.
    pub fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path)
            .and_then(Item::as_str)
            .unwrap_or(default)
            .to_string()
    }

    /// Float at a path (integers convert), or the default.
    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Item::as_f64).unwrap_or(default)
    }

    /// Integer at a path, or the default.
    pub fn i64_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(Item::as_i64).unwrap_or(default)
    }

    /// Bool at a path, or the default.
    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Item::as_bool).unwrap_or(default)
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc, TomlError> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let errl = ln + 1;
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or(TomlError {
                line: errl,
                msg: "unterminated section header".into(),
            })?;
            if name.starts_with('[') {
                return Err(TomlError {
                    line: errl,
                    msg: "array-of-tables ([[..]]) is not supported; use string arrays".into(),
                });
            }
            section = name.trim().to_string();
            continue;
        }
        let eq = line.find('=').ok_or(TomlError {
            line: errl,
            msg: "expected 'key = value'".into(),
        })?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(TomlError {
                line: errl,
                msg: "empty key".into(),
            });
        }
        let val = line[eq + 1..].trim();
        let item = parse_value(val).map_err(|msg| TomlError { line: errl, msg })?;
        let path = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.items.insert(path, item);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<Item, String> {
    if v.is_empty() {
        return Err("empty value".into());
    }
    if let Some(s) = v.strip_prefix('"') {
        let s = s.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Item::Str(unescape(s)?));
    }
    if v == "true" {
        return Ok(Item::Bool(true));
    }
    if v == "false" {
        return Ok(Item::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Item::StrArr(Vec::new()));
        }
        let parts = split_array(inner)?;
        if parts.iter().all(|p| p.starts_with('"')) {
            let mut out = Vec::new();
            for p in parts {
                match parse_value(&p)? {
                    Item::Str(s) => out.push(s),
                    _ => return Err("mixed array".into()),
                }
            }
            return Ok(Item::StrArr(out));
        }
        let mut out = Vec::new();
        for p in parts {
            out.push(
                p.parse::<f64>()
                    .map_err(|_| format!("bad array element '{p}'"))?,
            );
        }
        return Ok(Item::NumArr(out));
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(Item::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(Item::Float(f));
    }
    Err(format!("unrecognized value '{v}'"))
}

fn split_array(inner: &str) -> Result<Vec<String>, String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in inner.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                let t = cur.trim().to_string();
                if !t.is_empty() {
                    parts.push(t);
                }
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    let t = cur.trim().to_string();
    if !t.is_empty() {
        parts.push(t);
    }
    Ok(parts)
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::new();
    let mut it = s.chars();
    while let Some(c) = it.next() {
        if c == '\\' {
            match it.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                other => return Err(format!("bad escape '\\{other:?}'")),
            }
        } else {
            out.push(c);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let doc = parse(
            r#"
# a board
name = "zynq706"
[smp]
cores = 2
freq_mhz = 667.0
[dma]
in_scales = true
kernels = ["a", "b"]
weights = [1, 2.5]
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "zynq706");
        assert_eq!(doc.i64_or("smp.cores", 0), 2);
        assert_eq!(doc.f64_or("smp.freq_mhz", 0.0), 667.0);
        assert!(doc.bool_or("dma.in_scales", false));
        assert_eq!(
            doc.get("dma.kernels").unwrap().as_str_arr().unwrap(),
            &["a".to_string(), "b".to_string()]
        );
        assert_eq!(
            doc.get("dma.weights"),
            Some(&Item::NumArr(vec![1.0, 2.5]))
        );
    }

    #[test]
    fn defaults_apply() {
        let doc = parse("").unwrap();
        assert_eq!(doc.f64_or("x.y", 9.5), 9.5);
        assert_eq!(doc.str_or("z", "d"), "d");
    }

    #[test]
    fn comments_and_strings() {
        let doc = parse("s = \"a # not comment\" # real comment").unwrap();
        assert_eq!(doc.str_or("s", ""), "a # not comment");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("[sec\nx = 1").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn rejects_array_of_tables() {
        assert!(parse("[[accel]]\nname = \"x\"").is_err());
    }

    #[test]
    fn escape_sequences() {
        let doc = parse(r#"s = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(doc.str_or("s", ""), "a\nb\t\"c\"");
    }

    #[test]
    fn hostile_inputs_error_instead_of_panicking() {
        // Fuzz-derived shapes: every one must parse or error, never panic.
        for s in [
            "=",
            "[",
            "]",
            "x =",
            "x = [1,",
            "x = \"",
            "x = \"\\q\"",
            "é = ☃",
            "x = [\"a\", 1]",
            "\u{0}\u{0}",
            "x = \"unterminated",
            "[s]\n= 1",
        ] {
            let _ = parse(s);
        }
    }

    #[test]
    fn ints_vs_floats() {
        let doc = parse("i = 42\nf = 42.0\nn = -3").unwrap();
        assert_eq!(doc.get("i"), Some(&Item::Int(42)));
        assert_eq!(doc.get("f"), Some(&Item::Float(42.0)));
        assert_eq!(doc.i64_or("n", 0), -3);
    }
}
