//! Zero-rebuild, parallel DSE sweep engine.
//!
//! The seed exploration loop paid O(points × tasks) redundant work: every
//! enumerated co-design rebuilt the dependence graph and elaborated
//! program from scratch (`sim::estimate` → `DepGraph::build` +
//! `ElabProgram::build`), re-ran the HLS cost model for every
//! (kernel, unroll) it touched, and evaluated points one after another.
//! CEDR (Mack et al., 2022) and the hardware-HEFT scheduler work (Fusco et
//! al., 2022) both separate one-time program analysis from
//! per-configuration scheduling; [`SweepContext`] is that separation here:
//!
//! * the [`DepGraph`] and [`ElabProgram`] are built **once** per program
//!   and shared (immutably) by every evaluation;
//! * HLS reports are memoized per `(kernel, unroll)` — [`SweepContext::prime`]
//!   fills the cache for a [`DseSpace`] up front so a sweep performs zero
//!   duplicate cost-model calls;
//! * point evaluation shards across `std::thread::scope` workers (keeping
//!   the repository's zero-external-dependency style). Each worker keeps
//!   one [`Simulator`] alive and [`Simulator::reset`]s it per point, so the
//!   event heap, ready queues and predecessor counters are allocated once
//!   per worker, not once per point, and segment recording is disabled
//!   because ranking needs only makespan + busy accounting.
//!
//! Determinism: candidates are evaluated under a work-stealing index
//! cursor, results are keyed by candidate index and merged in enumeration
//! order, and the final ranking uses the same stable sort as the serial
//! path — so `explore` returns a bit-identical `Vec<DsePoint>` for any
//! worker count (asserted by `rust/tests/sweep_determinism.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::deps::DepGraph;
use crate::coordinator::elaborate::ElabProgram;
use crate::coordinator::sched::Policy;
use crate::coordinator::task::{KernelId, TaskProgram};
use crate::hls::{CostModel, FpgaPart, HlsReport, Resources};
use crate::power::PowerModel;
use crate::sim::engine::{AccelInstance, DeltaPlan, SimCheckpoint, Simulator};
use crate::sim::{EstimatorModel, SimResult};
use crate::util::fxhash::FxHashMap;

use super::{describe, DsePoint, DseSpace, Objective};

/// Deterministic reuse counters for the incremental (delta) evaluation
/// path. `hits`/`fallbacks` partition the **non-head** positions of the
/// neighbor chains (see [`delta_chains`]); `suffix_events`/`total_events`
/// accumulate, per hit, the events the resume actually replayed vs the
/// events a scratch run of the same point processes — their ratio is the
/// evaluated-suffix fraction gated in `BENCH_engine.json`. All counters
/// depend only on the candidate list (chains are partitioned statically),
/// never on worker scheduling, so they are bit-identical for any worker
/// count.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeltaStats {
    /// Neighbor evaluations served by checkpoint resume.
    pub hits: u64,
    /// Neighbor evaluations that fell back to scratch (invalid or unsafe
    /// checkpoint, forced by the `delta.plan` faultpoint, or a poisoned
    /// chain head).
    pub fallbacks: u64,
    /// Events replayed by the delta hits (suffix only).
    pub suffix_events: u64,
    /// Events a scratch run of those same hit points processes.
    pub total_events: u64,
}

impl DeltaStats {
    /// Fold another counter set into this one.
    pub fn merge(&mut self, o: &DeltaStats) {
        self.hits += o.hits;
        self.fallbacks += o.fallbacks;
        self.suffix_events += o.suffix_events;
        self.total_events += o.total_events;
    }

    /// Fraction of neighbor-pair evaluations that took the delta path.
    pub fn reuse_rate(&self) -> f64 {
        let n = self.hits + self.fallbacks;
        if n == 0 {
            0.0
        } else {
            self.hits as f64 / n as f64
        }
    }

    /// Mean fraction of a hit point's events actually replayed — below
    /// 1.0 means the prefix reuse saved simulation work.
    pub fn suffix_fraction(&self) -> f64 {
        if self.total_events == 0 {
            1.0
        } else {
            self.suffix_events as f64 / self.total_events as f64
        }
    }
}

/// Which single kernel two co-designs differ in — `Some(k)` iff exactly
/// one kernel's option (its accelerator instance sequence or its SMP
/// flag) changed. Returns `None` for identical candidates, multi-kernel
/// diffs, or kernels the program does not know: no provably safe delta
/// either way. Instance *order* within a kernel is compared as-is
/// (heterogeneous multisets dispatch in instance order), which is
/// conservative but never unsafe.
pub(crate) fn single_kernel_diff(
    program: &TaskProgram,
    a: &CoDesign,
    b: &CoDesign,
) -> Option<KernelId> {
    let n_kernels = program.kernels.len();
    let mut ua: Vec<Vec<u32>> = vec![Vec::new(); n_kernels];
    let mut ub: Vec<Vec<u32>> = vec![Vec::new(); n_kernels];
    for s in &a.accels {
        ua[program.kernel_id(&s.kernel)? as usize].push(s.unroll);
    }
    for s in &b.accels {
        ub[program.kernel_id(&s.kernel)? as usize].push(s.unroll);
    }
    let mut diff: Option<KernelId> = None;
    for kid in 0..n_kernels {
        let name = &program.kernels[kid].name;
        if ua[kid] != ub[kid] || a.allows_smp(name) != b.allows_smp(name) {
            if diff.is_some() {
                return None; // more than one kernel changed
            }
            diff = Some(kid as KernelId);
        }
    }
    diff
}

/// Cap on neighbor-chain length. Chains are the parallel work unit (the
/// checkpoint lives on the worker that evaluated the chain head), so
/// short chains keep pool utilization high while still amortizing one
/// scratch run per `DELTA_CHAIN_CAP` points.
pub(crate) const DELTA_CHAIN_CAP: usize = 16;

/// One capped run of consecutive candidates where every adjacent pair
/// differs in exactly the same single kernel — the delta evaluation unit.
/// Every member then differs from the chain *head* only in that kernel
/// (single-kernel diffs against a fixed base compose), so one checkpoint
/// captured on the head's scratch run serves the whole chain.
#[derive(Clone, Copy, Debug)]
pub(crate) struct DeltaChain {
    /// Start index into the caller's candidate/work list.
    pub start: usize,
    /// Number of consecutive members (≥ 1).
    pub len: usize,
    /// The changed kernel (`None` for singleton chains — scratch only).
    pub kernel: Option<KernelId>,
}

/// Partition positions `0..n` into [`DeltaChain`]s. `diff(j)` reports the
/// single-kernel diff between positions `j - 1` and `j` (and `None` to
/// force a break — different suite job, no safe diff, …). Deterministic:
/// depends only on the list, so chain boundaries — and with them every
/// delta/scratch decision — are identical for any worker count.
pub(crate) fn delta_chains<D>(n: usize, diff: D) -> Vec<DeltaChain>
where
    D: Fn(usize) -> Option<KernelId>,
{
    let mut chains = Vec::new();
    let mut i = 0usize;
    while i < n {
        let mut len = 1usize;
        let mut kernel: Option<KernelId> = None;
        while i + len < n && len < DELTA_CHAIN_CAP {
            match (kernel, diff(i + len)) {
                (None, Some(k)) => kernel = Some(k),
                (Some(k0), Some(k)) if k == k0 => {}
                _ => break,
            }
            len += 1;
        }
        chains.push(DeltaChain { start: i, len, kernel });
        i += len;
    }
    chains
}

/// Outcome of one chain evaluated by [`evaluate_chain`].
pub(crate) struct ChainOutcome {
    /// `(position, point)` for every member that evaluated.
    pub results: Vec<(usize, DsePoint)>,
    /// Positions whose evaluation panicked (quarantined; ascending).
    pub poisoned: Vec<usize>,
    /// Delta counters attributed to this chain.
    pub stats: DeltaStats,
}

/// Evaluate one neighbor chain on one worker slot with per-point panic
/// isolation: the head runs from scratch (capturing the chain checkpoint
/// when the chain has a changed kernel), every later member goes through
/// [`SweepWorker::evaluate_delta`]. A panicking point poisons only
/// itself — the worker is dropped and lazily rebuilt, and because the
/// rebuilt worker holds no checkpoint the rest of the chain falls back to
/// scratch. Which points poison (and which fall back) depends only on the
/// points themselves, never on worker scheduling.
pub(crate) fn evaluate_chain<'c, 'p, 'x, F, C>(
    slot: &mut Option<SweepWorker<'c, 'p>>,
    make_worker: F,
    chain: DeltaChain,
    cand: C,
) -> ChainOutcome
where
    F: Fn() -> SweepWorker<'c, 'p>,
    C: Fn(usize) -> &'x CoDesign,
{
    let mut out = ChainOutcome {
        results: Vec::with_capacity(chain.len),
        poisoned: Vec::new(),
        stats: DeltaStats::default(),
    };
    for j in 0..chain.len {
        let i = chain.start + j;
        let w = slot.get_or_insert_with(&make_worker);
        let before = w.delta_stats();
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if j == 0 {
                w.evaluate_chain_head(cand(i), chain.kernel)
            } else {
                w.evaluate_delta(cand(i))
            }
        }));
        match run {
            Ok(maybe) => {
                let after = slot.as_ref().expect("worker alive after Ok").delta_stats();
                out.stats.hits += after.hits - before.hits;
                out.stats.fallbacks += after.fallbacks - before.fallbacks;
                out.stats.suffix_events += after.suffix_events - before.suffix_events;
                out.stats.total_events += after.total_events - before.total_events;
                if let Some(p) = maybe {
                    out.results.push((i, p));
                }
            }
            Err(_) => {
                // A panic can unwind mid-simulation: rebuild, don't trust.
                *slot = None;
                out.poisoned.push(i);
            }
        }
    }
    out
}

/// Number of evaluation workers to use by default: one per available core.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Work-stealing indexed parallel map — the one scoped-thread loop every
/// parallel stage of the DSE layer shares (point evaluation, suite
/// evaluation, bound computation, pruned rounds).
///
/// Item indices `0..n_items` are claimed through a shared atomic cursor;
/// `f` runs with the claiming worker's mutable slot (per-worker state such
/// as a reusable simulator); every `Some` result is collected **unordered**
/// — callers key results by index and sort, which is what keeps their
/// output independent of the worker count.
pub(crate) fn parallel_for_indexed<S, R, F>(slots: &mut [S], n_items: usize, f: F) -> Vec<R>
where
    S: Send,
    R: Send,
    F: Fn(&mut S, usize) -> Option<R> + Sync,
{
    debug_assert!(!slots.is_empty() || n_items == 0);
    let cursor = AtomicUsize::new(0);
    let mut out: Vec<R> = Vec::with_capacity(n_items);
    std::thread::scope(|s| {
        let handles: Vec<_> = slots
            .iter_mut()
            .map(|slot| {
                let f = &f;
                let cursor = &cursor;
                s.spawn(move || {
                    let mut acc: Vec<R> = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n_items {
                            break;
                        }
                        if let Some(r) = f(slot, i) {
                            acc.push(r);
                        }
                    }
                    acc
                })
            })
            .collect();
        for h in handles {
            out.extend(h.join().expect("parallel worker panicked"));
        }
    });
    out
}

/// Shared, immutable evaluation context for one (program, board, part)
/// triple: dependence graph, elaborated program and memoized HLS reports.
/// Build it once, then run any number of enumerations / explorations /
/// single-point estimates against it.
pub struct SweepContext<'p> {
    /// The program under exploration.
    pub program: &'p TaskProgram,
    /// Platform description shared by every evaluation.
    pub board: &'p BoardConfig,
    /// FPGA part the co-designs must fit.
    pub part: FpgaPart,
    /// One-time dependence graph (shared by bounds and simulation).
    pub graph: DepGraph,
    /// One-time elaborated program (creation chain + transfer footprints).
    pub elab: ElabProgram,
    cost: CostModel,
    power: PowerModel,
    /// Memoized `(kernel, unroll) → HlsReport`.
    reports: FxHashMap<(KernelId, u32), HlsReport>,
    /// Reports served from the level-1 kernel sub-memo by
    /// [`SweepContext::prime_with_memo`] instead of the cost model
    /// (surfaced as [`PruneStats::kernel_hits`](super::PruneStats) by the
    /// warm sweeps).
    kernel_memo_hits: usize,
}

impl<'p> SweepContext<'p> {
    /// Build the one-time program analysis (graph + elaboration). The HLS
    /// cache starts empty; call [`SweepContext::prime`] with the space you
    /// are about to sweep.
    pub fn new(program: &'p TaskProgram, board: &'p BoardConfig, part: FpgaPart) -> Self {
        let graph = DepGraph::build(program);
        let elab = ElabProgram::build(program, &graph);
        SweepContext {
            program,
            board,
            part,
            graph,
            elab,
            cost: CostModel::from_board(board),
            power: PowerModel::default(),
            reports: FxHashMap::default(),
            kernel_memo_hits: 0,
        }
    }

    /// Convenience constructor: build and prime for `space` in one step.
    pub fn for_space(
        program: &'p TaskProgram,
        board: &'p BoardConfig,
        part: &FpgaPart,
        space: &DseSpace,
    ) -> Self {
        let mut ctx = Self::new(program, board, part.clone());
        ctx.prime(space);
        ctx
    }

    /// [`SweepContext::for_space`] with the HLS cache primed from the
    /// level-1 kernel sub-memo first (see
    /// [`SweepContext::prime_with_memo`]).
    pub fn for_space_warm(
        program: &'p TaskProgram,
        board: &'p BoardConfig,
        part: &FpgaPart,
        space: &DseSpace,
        memo: &super::warm::EvalMemo,
    ) -> Self {
        let mut ctx = Self::new(program, board, part.clone());
        ctx.prime_with_memo(space, memo);
        ctx
    }

    /// Memoize the HLS report of every `(kernel, unroll)` pair the space
    /// can touch, so the sweep itself performs zero cost-model calls.
    pub fn prime(&mut self, space: &DseSpace) {
        for ks in &space.kernels {
            let Some(kid) = self.program.kernel_id(&ks.kernel) else {
                continue;
            };
            for &u in &ks.unrolls {
                if self.reports.contains_key(&(kid, u)) {
                    continue;
                }
                let r = self
                    .cost
                    .estimate(&ks.kernel, &self.program.kernel(kid).profile, u);
                self.reports.insert((kid, u), r);
            }
        }
    }

    /// Like [`SweepContext::prime`], but every `(kernel, unroll)` pair is
    /// first looked up in the level-1 kernel sub-memo of an
    /// [`EvalMemo`](super::EvalMemo): on a hit the stored report — exact
    /// by construction, since the level-1 key covers the kernel profile
    /// and both board-derived cost-model constants — fills the cache
    /// without a cost-model call, and only the misses run the model. This
    /// is the cross-size (and cross-run) warm start: two problem sizes of
    /// a blocked app share kernel profiles, so the second size primes
    /// entirely from the memo recorded at the first. Returns the number of
    /// memo-served reports (also surfaced as
    /// [`PruneStats::kernel_hits`](super::PruneStats) by the warm sweeps).
    pub fn prime_with_memo(&mut self, space: &DseSpace, memo: &super::warm::EvalMemo) -> usize {
        let mut hits = 0usize;
        for ks in &space.kernels {
            let Some(kid) = self.program.kernel_id(&ks.kernel) else {
                continue;
            };
            let kfp = crate::hls::kernel_fingerprint(&ks.kernel, &self.program.kernel(kid).profile);
            for &u in &ks.unrolls {
                if self.reports.contains_key(&(kid, u)) {
                    continue;
                }
                let r = match memo.lookup_report(
                    kfp,
                    u,
                    self.board.fabric_freq_mhz,
                    self.board.dma_bw_mbps,
                ) {
                    Some(report) => {
                        hits += 1;
                        report.clone()
                    }
                    None => self
                        .cost
                        .estimate(&ks.kernel, &self.program.kernel(kid).profile, u),
                };
                self.reports.insert((kid, u), r);
            }
        }
        self.kernel_memo_hits += hits;
        hits
    }

    /// Number of memoized HLS reports (bench/diagnostic).
    pub fn cached_reports(&self) -> usize {
        self.reports.len()
    }

    /// Reports served from the kernel sub-memo so far (see
    /// [`SweepContext::prime_with_memo`]).
    pub fn kernel_memo_hits(&self) -> usize {
        self.kernel_memo_hits
    }

    /// The power model shared by every point evaluation (the energy lower
    /// bound of `dse::prune` must use the exact same constants).
    pub(crate) fn power_model(&self) -> &PowerModel {
        &self.power
    }

    /// The HLS report for a variant: cache hit, or an on-the-fly estimate
    /// for variants outside the primed space (same numbers either way —
    /// the cost model is deterministic).
    pub fn report_for(&self, kid: KernelId, kernel: &str, unroll: u32) -> HlsReport {
        match self.reports.get(&(kid, unroll)) {
            Some(r) => r.clone(),
            None => self
                .cost
                .estimate(kernel, &self.program.kernel(kid).profile, unroll),
        }
    }

    /// Resource vector only (avoids cloning the report's strings on hit).
    pub fn resources_for(&self, kid: KernelId, kernel: &str, unroll: u32) -> Resources {
        match self.reports.get(&(kid, unroll)) {
            Some(r) => r.resources,
            None => {
                self.cost
                    .estimate(kernel, &self.program.kernel(kid).profile, unroll)
                    .resources
            }
        }
    }

    /// Resolve a co-design against the program using the memoized reports —
    /// the cached equivalent of [`crate::sim::resolve_codesign`], with the
    /// same feasibility checks and error conditions.
    pub fn resolve(&self, codesign: &CoDesign) -> anyhow::Result<(Vec<AccelInstance>, Vec<bool>)> {
        let mut accels = Vec::with_capacity(codesign.accels.len());
        for spec in &codesign.accels {
            let kid = self.program.kernel_id(&spec.kernel).ok_or_else(|| {
                anyhow::anyhow!("co-design accel '{}' not in program", spec.kernel)
            })?;
            if !self.program.kernel(kid).targets.fpga {
                anyhow::bail!(
                    "kernel '{}' is not annotated with target device(fpga)",
                    spec.kernel
                );
            }
            accels.push(AccelInstance {
                kernel: kid,
                report: self.report_for(kid, &spec.kernel, spec.unroll),
            });
        }
        let resources: Vec<Resources> = accels.iter().map(|a| a.report.resources).collect();
        if !self.part.fits(&resources) {
            anyhow::bail!(
                "co-design '{}' does not fit {} (utilization {:.0}%)",
                codesign.name,
                self.part.name,
                self.part.utilization(&resources) * 100.0
            );
        }
        let mut smp_eligible = Vec::with_capacity(self.program.kernels.len());
        for (kid, k) in self.program.kernels.iter().enumerate() {
            let has_accel = accels.iter().any(|a| a.kernel as usize == kid);
            let eligible = if has_accel {
                k.targets.smp && codesign.allows_smp(&k.name)
            } else {
                k.targets.smp
            };
            if !eligible && !has_accel {
                anyhow::bail!(
                    "kernel '{}' can run nowhere under co-design '{}'",
                    k.name,
                    codesign.name
                );
            }
            smp_eligible.push(eligible);
        }
        Ok((accels, smp_eligible))
    }

    /// One-shot coarse-grain estimate of a co-design against the shared
    /// context — equals `sim::estimate` on the same inputs, without
    /// rebuilding the graph/elaboration. For many points, prefer
    /// [`SweepContext::worker`] which also reuses the simulator buffers.
    pub fn estimate(&self, codesign: &CoDesign) -> anyhow::Result<SimResult> {
        let (accels, smp) = self.resolve(codesign)?;
        let mut sim = Simulator::new(
            self.program,
            &self.elab,
            self.board,
            &accels,
            &smp,
            Policy::Greedy,
        );
        let mut model = EstimatorModel::new(self.board);
        Ok(sim.run_mut(&mut model))
    }

    /// Enumerate feasible co-designs over the space (resource-pruned),
    /// identical to the seed `dse::enumerate` but with every resource
    /// vector served from the memoized reports. With `space.mixed`, a
    /// kernel's per-option accelerator multiset may mix unroll variants
    /// (see [`DseSpace::mixed`](super::DseSpace)); the homogeneous path is
    /// byte-identical to the historical enumeration.
    pub fn enumerate(&self, space: &DseSpace) -> Vec<CoDesign> {
        // Per-kernel options: (accel list, smp flag), parallel to the
        // surviving KernelSpace entries.
        let mut per_kernel: Vec<Vec<(Vec<(String, u32)>, bool)>> = Vec::new();
        let mut kspaces: Vec<&super::KernelSpace> = Vec::new();
        for ks in &space.kernels {
            let Some(kid) = self.program.kernel_id(&ks.kernel) else {
                continue;
            };
            // Variants that fit the part alone (a multiset containing an
            // infeasible-alone variant cannot fit either).
            let feasible: Vec<u32> = ks
                .unrolls
                .iter()
                .copied()
                .filter(|&u| self.part.fits(&[self.resources_for(kid, &ks.kernel, u)]))
                .collect();
            let mut opts: Vec<(Vec<(String, u32)>, bool)> = vec![(Vec::new(), false)];
            let multisets =
                super::variant_multisets(feasible.len(), ks.max_instances, space.mixed);
            for multiset in multisets {
                let accels: Vec<(String, u32)> = multiset
                    .iter()
                    .map(|&vi| (ks.kernel.clone(), feasible[vi]))
                    .collect();
                opts.push((accels.clone(), false));
                if ks.try_smp {
                    opts.push((accels, true));
                }
            }
            per_kernel.push(opts);
            kspaces.push(ks);
        }

        // Cartesian product with feasibility pruning.
        let mut out = Vec::new();
        let mut idx = vec![0usize; per_kernel.len()];
        let mut resources: Vec<Resources> = Vec::new();
        loop {
            // Assemble the candidate.
            let mut cd = CoDesign::new("dse");
            for (ki, &i) in idx.iter().enumerate() {
                let (accels, smp) = &per_kernel[ki][i];
                for (k, u) in accels {
                    cd = cd.with_accel(k, *u);
                }
                if *smp {
                    cd = cd.with_smp(&kspaces[ki].kernel);
                }
            }
            // Feasibility: total resources fit.
            resources.clear();
            for a in &cd.accels {
                let kid = self.program.kernel_id(&a.kernel).unwrap();
                resources.push(self.resources_for(kid, &a.kernel, a.unroll));
            }
            if self.part.fits(&resources) {
                cd.name = describe(&cd);
                out.push(cd);
            }
            // Advance the odometer.
            let mut carry = true;
            for (ki, i) in idx.iter_mut().enumerate() {
                if !carry {
                    break;
                }
                *i += 1;
                if *i < per_kernel[ki].len() {
                    carry = false;
                } else {
                    *i = 0;
                }
            }
            if carry {
                break;
            }
        }
        out
    }

    /// A reusable evaluation worker: one simulator + one timing model,
    /// reset per point. Create one per thread.
    pub fn worker(&self) -> SweepWorker<'_, 'p> {
        let mut sim = Simulator::new(
            self.program,
            &self.elab,
            self.board,
            &[],
            &[],
            Policy::Greedy,
        );
        // Ranking needs only makespan + busy accounting.
        sim.set_record_segments(false);
        SweepWorker {
            ctx: self,
            sim,
            model: EstimatorModel::new(self.board),
            plan: None,
            ckpt: SimCheckpoint::new(),
            delta: DeltaStats::default(),
        }
    }

    /// Turn a finished simulation into a ranked design point.
    fn point_from(&self, codesign: &CoDesign, res: &SimResult) -> DsePoint {
        let resources: Vec<Resources> = codesign
            .accels
            .iter()
            .map(|a| {
                let kid = self.program.kernel_id(&a.kernel).unwrap();
                self.resources_for(kid, &a.kernel, a.unroll)
            })
            .collect();
        let util = self.part.utilization(&resources);
        let energy = self
            .power
            .energy(res, &resources, util, self.board.fabric_freq_mhz);
        DsePoint {
            codesign: codesign.clone(),
            est_ms: res.makespan_ms(),
            energy_j: energy.total_j(),
            edp: energy.edp(),
            fabric_util: util,
        }
    }

    /// Evaluate a candidate list across `workers` threads with
    /// deterministic (enumeration-order) output. Points whose co-design
    /// cannot run (some kernel has nowhere to execute) are skipped, as in
    /// the serial path; a point whose evaluation *panics* is poisoned and
    /// skipped too (isolation — one bad point never tears down the pool),
    /// identically for any worker count.
    pub fn evaluate_all(&self, cands: &[CoDesign], workers: usize) -> Vec<DsePoint> {
        self.evaluate_all_with_stats(cands, workers).0
    }

    /// [`SweepContext::evaluate_all`] plus the delta-reuse counters. The
    /// candidate list is partitioned into static neighbor chains
    /// ([`delta_chains`]) and the chains — not the points — are the
    /// parallel work units, so both the points *and* the counters are
    /// bit-identical for any worker count.
    pub fn evaluate_all_with_stats(
        &self,
        cands: &[CoDesign],
        workers: usize,
    ) -> (Vec<DsePoint>, DeltaStats) {
        let chains = delta_chains(cands.len(), |j| {
            single_kernel_diff(self.program, &cands[j - 1], &cands[j])
        });
        let workers = workers.clamp(1, chains.len().max(1));
        // One lazily-built worker (simulator + model) per thread; a
        // poisoned worker is dropped and lazily rebuilt by the chain
        // executor.
        let mut slots: Vec<Option<SweepWorker<'_, 'p>>> = (0..workers).map(|_| None).collect();
        let outcomes = parallel_for_indexed(&mut slots, chains.len(), |slot, c| {
            Some(evaluate_chain(slot, || self.worker(), chains[c], |i| {
                &cands[i]
            }))
        });
        let mut indexed: Vec<(usize, DsePoint)> = Vec::with_capacity(cands.len());
        let mut stats = DeltaStats::default();
        for o in &outcomes {
            stats.merge(&o.stats);
        }
        for o in outcomes {
            indexed.extend(o.results);
        }
        // Restore enumeration order so ranking ties break exactly like the
        // serial path (the score sort below is stable).
        indexed.sort_unstable_by_key(|e| e.0);
        (indexed.into_iter().map(|(_, p)| p).collect(), stats)
    }

    /// Enumerate + evaluate + rank. Bit-identical output for any worker
    /// count, including `workers == 1`.
    ///
    /// # Example
    ///
    /// ```
    /// use zynq_estimator::apps::matmul::Matmul;
    /// use zynq_estimator::config::BoardConfig;
    /// use zynq_estimator::dse::{DseSpace, Objective, SweepContext};
    /// use zynq_estimator::hls::FpgaPart;
    ///
    /// let board = BoardConfig::zynq706();
    /// let program = Matmul::new(256, 64).build_program(&board);
    /// let space = DseSpace::from_program(&program);
    /// let ctx = SweepContext::for_space(&program, &board, &FpgaPart::xc7z045(), &space);
    /// let points = ctx.explore(&space, Objective::Time, 2);
    /// assert!(!points.is_empty());
    /// // The ranking is sorted by the objective...
    /// assert!(points.windows(2).all(|w| w[0].est_ms <= w[1].est_ms));
    /// // ...and is bit-identical for any worker count.
    /// let serial = ctx.explore(&space, Objective::Time, 1);
    /// assert_eq!(serial.len(), points.len());
    /// assert!(serial
    ///     .iter()
    ///     .zip(&points)
    ///     .all(|(a, b)| a.est_ms.to_bits() == b.est_ms.to_bits()));
    /// ```
    pub fn explore(
        &self,
        space: &DseSpace,
        objective: Objective,
        workers: usize,
    ) -> Vec<DsePoint> {
        self.explore_with_stats(space, objective, workers).0
    }

    /// [`SweepContext::explore`] plus the delta-reuse counters of the
    /// evaluation pass (`dse --profile` and the incremental bench read
    /// them; the ranking is byte-identical to `explore`'s).
    pub fn explore_with_stats(
        &self,
        space: &DseSpace,
        objective: Objective,
        workers: usize,
    ) -> (Vec<DsePoint>, DeltaStats) {
        let cands = {
            let _t = crate::util::profile::scope("enumerate");
            self.enumerate(space)
        };
        let (mut points, stats) = {
            let _t = crate::util::profile::scope("simulate");
            self.evaluate_all_with_stats(&cands, workers)
        };
        points.sort_by(|a, b| a.score(objective).partial_cmp(&b.score(objective)).unwrap());
        (points, stats)
    }

    /// Like [`SweepContext::explore`], but with the bound-guided pruned
    /// enumeration of [`dse::prune`](super::prune): infeasible odometer
    /// subtrees, dominated unroll variants and bound-dominated candidates
    /// are cut *before* simulation. The returned ranking contains only the
    /// evaluated points, is bit-identical for any worker count, and its
    /// best point and time-energy Pareto front equal the exhaustive
    /// sweep's (see the prune module docs for the guarantee).
    pub fn explore_pruned(
        &self,
        space: &DseSpace,
        objective: Objective,
        workers: usize,
    ) -> (Vec<DsePoint>, super::prune::PruneStats) {
        super::prune::explore_pruned_multi(&[(self, space)], objective, workers)
            .pop()
            .expect("one input yields one output")
    }

    /// [`SweepContext::explore_pruned`] with an explicit candidate
    /// [`OrderMode`](super::OrderMode) for the bound-guided rounds.
    /// Ordering only changes *when* candidates are considered (hence how
    /// early the incumbent tightens and how many points get simulated);
    /// every mode keeps the losslessness contract — identical best point
    /// and time-energy Pareto front — and is bit-identical for any worker
    /// count. `OrderMode::BoundAsc` reproduces `explore_pruned` exactly.
    pub fn explore_pruned_with(
        &self,
        space: &DseSpace,
        objective: Objective,
        workers: usize,
        order: super::prune::OrderMode,
    ) -> (Vec<DsePoint>, super::prune::PruneStats) {
        super::prune::explore_pruned_warm(self, space, None, order, objective, workers)
    }

    /// Warm-started pruned exploration against a persistent
    /// [`EvalMemo`](super::EvalMemo): candidates whose exact
    /// `(program, board, part, co-design)` evaluation is already memoized
    /// are returned without re-simulation (bit-identical by construction —
    /// the memo key fingerprints everything the evaluation depends on) and
    /// seed the bound frontier, so the remaining candidates start cutting
    /// against a warm incumbent. Newly evaluated points are recorded back
    /// into the memo. Same losslessness and any-worker-count determinism
    /// guarantees as [`SweepContext::explore_pruned`];
    /// [`PruneStats::memo_hits`](super::PruneStats) and
    /// [`PruneStats::seeded_cut`](super::PruneStats) account for the warm
    /// state.
    pub fn explore_warm(
        &self,
        space: &DseSpace,
        memo: &mut super::warm::EvalMemo,
        objective: Objective,
        workers: usize,
        order: super::prune::OrderMode,
    ) -> (Vec<DsePoint>, super::prune::PruneStats) {
        super::prune::explore_pruned_warm(self, space, Some(memo), order, objective, workers)
    }

    /// [`SweepContext::explore_warm`] with crash recovery through a
    /// [`RecoverySession`](super::RecoverySession): every committed round
    /// of fresh evaluations is journaled to the memo's `.wal` sidecar and
    /// the candidate order is checkpointed to `.ckpt`, so an interrupted
    /// sweep resumed from
    /// [`EvalMemo::load_with_recovery`](super::warm::EvalMemo::load_with_recovery)
    /// finishes with a ranking and saved memo bit-identical to an
    /// uninterrupted run (see `dse::ckpt`).
    pub fn explore_warm_recoverable(
        &self,
        space: &DseSpace,
        memo: &mut super::warm::EvalMemo,
        objective: Objective,
        workers: usize,
        order: super::prune::OrderMode,
        recovery: &mut super::ckpt::RecoverySession,
    ) -> anyhow::Result<(Vec<DsePoint>, super::prune::PruneStats)> {
        Ok(super::prune::explore_pruned_warm_recoverable(
            &[(self, space)],
            Some(memo),
            order,
            objective,
            workers,
            Some(recovery),
        )?
        .pop()
        .expect("one input yields one output"))
    }

    /// [`SweepContext::explore_warm`] with a cooperative cancellation
    /// hook, polled at chunk-synchronous round **barriers** only: the
    /// in-flight round always completes, so every round that did run is
    /// bit-identical to the uncancelled sweep's. A fired hook aborts with
    /// a [`SweepCancelled`](super::SweepCancelled)-carrying error
    /// *before* any memo recording — a cancelled sweep leaves `memo`
    /// unmodified. This is the engine behind the service daemon's
    /// per-request deadlines.
    pub fn explore_warm_cancellable(
        &self,
        space: &DseSpace,
        memo: &mut super::warm::EvalMemo,
        objective: Objective,
        workers: usize,
        order: super::prune::OrderMode,
        cancel: &(dyn Fn() -> bool + Sync),
    ) -> anyhow::Result<(Vec<DsePoint>, super::prune::PruneStats)> {
        super::prune::explore_pruned_warm_cancellable(
            self,
            space,
            Some(memo),
            order,
            objective,
            workers,
            Some(cancel),
        )
    }
}

/// Worker-local evaluation state: a [`Simulator`] whose buffers persist
/// across points (reset per co-design), an estimator timing model, and
/// the delta state for the neighbor chain currently running on this
/// worker — the chain's [`DeltaPlan`], the checkpoint captured on the
/// chain head's scratch run, and monotonic reuse counters.
pub struct SweepWorker<'c, 'p> {
    ctx: &'c SweepContext<'p>,
    sim: Simulator<'c>,
    model: EstimatorModel,
    plan: Option<DeltaPlan>,
    ckpt: SimCheckpoint,
    delta: DeltaStats,
}

impl<'c, 'p> SweepWorker<'c, 'p> {
    /// The `eval.point` faultpoint, tagged by the FNV hash of the
    /// co-design name: an armed spec always manifests as a **panic** here
    /// (evaluation has no error channel), exercising the poison-isolation
    /// path of [`evaluate_chain`] (one point never tears down a pool). The
    /// tag selects points by identity, never by schedule, so the poisoned
    /// set is identical for any worker count.
    fn fault_eval_point(codesign: &CoDesign) {
        if crate::util::faultpoint::armed() {
            if let Err(e) = crate::util::faultpoint::hit_tagged(
                "eval.point",
                crate::util::faultpoint::str_tag(&codesign.name),
            ) {
                panic!("{e}");
            }
        }
    }

    /// Evaluate one co-design from scratch; `None` if it cannot run
    /// (skipped point). This is the **oracle**: it never touches the
    /// delta machinery, and every delta-path result is regression-tested
    /// bitwise against it.
    pub fn evaluate(&mut self, codesign: &CoDesign) -> Option<DsePoint> {
        Self::fault_eval_point(codesign);
        let (accels, smp) = self.ctx.resolve(codesign).ok()?;
        // `resolve` already built owned instances: hand them to the
        // simulator instead of copying them a second time.
        self.sim.reset_owned(accels, smp);
        let res = self.sim.run_mut(&mut self.model);
        Some(self.ctx.point_from(codesign, &res))
    }

    /// Begin a neighbor chain: evaluate the head **from scratch** while
    /// capturing the chain checkpoint just before the first event whose
    /// timing depends on `kernel` (see
    /// [`Simulator::run_mut_with_checkpoint`]). `kernel == None` marks a
    /// singleton chain — plain scratch evaluation, and the stale
    /// checkpoint from any previous chain is invalidated so it can never
    /// leak across chains.
    pub fn evaluate_chain_head(
        &mut self,
        codesign: &CoDesign,
        kernel: Option<KernelId>,
    ) -> Option<DsePoint> {
        let Some(k) = kernel else {
            self.ckpt.invalidate();
            return self.evaluate(codesign);
        };
        Self::fault_eval_point(codesign);
        let plan_matches = matches!(&self.plan, Some(p) if p.kernel() == k);
        if !plan_matches {
            self.plan = Some(DeltaPlan::new(self.ctx.program, &self.ctx.elab, k));
        }
        let (accels, smp) = match self.ctx.resolve(codesign) {
            Ok(x) => x,
            Err(_) => {
                // Unrunnable head: no checkpoint, the rest of the chain
                // falls back to scratch.
                self.ckpt.invalidate();
                return None;
            }
        };
        self.sim.reset_owned(accels, smp);
        let plan = self.plan.as_ref().expect("plan installed above");
        let res = self
            .sim
            .run_mut_with_checkpoint(&mut self.model, plan, &mut self.ckpt);
        Some(self.ctx.point_from(codesign, &res))
    }

    /// Evaluate a non-head chain member against the chain checkpoint:
    /// resume the head's schedule prefix and replay only the suffix whose
    /// timing the changed kernel can influence. Falls back to a scratch
    /// run — bit-identical by the engine's determinism contract — whenever
    /// the resume is not provably safe (invalid checkpoint, unmappable
    /// accelerator layout, non-replay-safe timing model) or when the
    /// `delta.plan` faultpoint forces it.
    pub fn evaluate_delta(&mut self, codesign: &CoDesign) -> Option<DsePoint> {
        Self::fault_eval_point(codesign);
        // `delta.plan` is a *soft* faultpoint: an armed spec does not
        // panic, it forces this point down the scratch fallback — the
        // chaos suite uses it to prove fallback == delta == scratch.
        let forced = crate::util::faultpoint::armed()
            && crate::util::faultpoint::hit_tagged(
                "delta.plan",
                crate::util::faultpoint::str_tag(&codesign.name),
            )
            .is_err();
        let mut resolved = match self.ctx.resolve(codesign) {
            Ok(x) => Some(x),
            Err(_) => return None, // unrunnable either way
        };
        if !forced && self.ckpt.is_valid() {
            let (accels, smp) = resolved.take().expect("resolved above");
            if let Some(res) = self.sim.resume_mut(&mut self.model, &self.ckpt, accels, smp) {
                self.delta.hits += 1;
                self.delta.suffix_events +=
                    self.sim.events_processed() - self.ckpt.events();
                self.delta.total_events += self.sim.events_processed();
                return Some(self.ctx.point_from(codesign, &res));
            }
        }
        // Scratch fallback. `resume_mut` consumed the resolved instances
        // (and may have partially reset the simulator), so re-resolve and
        // rebuild run state from zero.
        self.delta.fallbacks += 1;
        let (accels, smp) = match resolved {
            Some(x) => x,
            None => self.ctx.resolve(codesign).ok()?,
        };
        self.sim.reset_owned(accels, smp);
        let res = self.sim.run_mut(&mut self.model);
        Some(self.ctx.point_from(codesign, &res))
    }

    /// Accumulated delta counters (monotonic over this worker's life).
    pub fn delta_stats(&self) -> DeltaStats {
        self.delta
    }
}

/// One application of a [`SweepSuite`]: its shared evaluation context and
/// the space to sweep.
pub struct SuiteApp<'p> {
    /// Display name (CLI tables, bench records).
    pub name: String,
    /// The primed per-application evaluation context.
    pub ctx: SweepContext<'p>,
    /// The space swept for this application.
    pub space: DseSpace,
}

/// Ranked sweep output for one application of a suite.
pub struct SuiteAppResult {
    /// The application's display name.
    pub name: String,
    /// Evaluated points, ranked by the sweep objective.
    pub points: Vec<DsePoint>,
    /// Cut statistics. Cut counters are zero for exhaustive sweeps;
    /// `unrunnable` (candidates where some kernel has no device) is
    /// filled either way, so `evaluated + unrunnable == feasible_points`
    /// always holds for exhaustive sweeps.
    pub stats: super::prune::PruneStats,
}

/// Batched multi-program sweep: several applications share **one** worker
/// pool, and each worker keeps one lazily-built [`SweepWorker`] (simulator
/// buffers included) per application, so a whole benchmark suite — e.g.
/// matmul/cholesky/lu/stencil — sweeps in a single pass instead of four
/// sequential sweeps with four pool spin-ups.
///
/// Determinism: work items are distributed by a work-stealing cursor but
/// results are merged by `(application, enumeration index)`, so every
/// application's ranking is bit-identical to running
/// [`SweepContext::explore`] (or [`SweepContext::explore_pruned`]) on it
/// alone, for any worker count.
#[derive(Default)]
pub struct SweepSuite<'p> {
    apps: Vec<SuiteApp<'p>>,
}

impl<'p> SweepSuite<'p> {
    /// An empty suite; add applications with [`SweepSuite::push`].
    pub fn new() -> Self {
        Self { apps: Vec::new() }
    }

    /// Add an application: builds and primes its [`SweepContext`].
    pub fn push(
        &mut self,
        name: &str,
        program: &'p TaskProgram,
        board: &'p BoardConfig,
        part: &FpgaPart,
        space: DseSpace,
    ) {
        let ctx = SweepContext::for_space(program, board, part, &space);
        self.apps.push(SuiteApp {
            name: name.to_string(),
            ctx,
            space,
        });
    }

    /// [`SweepSuite::push`] with the application's HLS cache primed from
    /// the level-1 kernel sub-memo ([`SweepContext::prime_with_memo`]), so
    /// a warm suite re-runs zero cost-model calls for kernels any earlier
    /// run — any app, any problem size — already characterized.
    pub fn push_warm(
        &mut self,
        name: &str,
        program: &'p TaskProgram,
        board: &'p BoardConfig,
        part: &FpgaPart,
        space: DseSpace,
        memo: &super::warm::EvalMemo,
    ) {
        let ctx = SweepContext::for_space_warm(program, board, part, &space, memo);
        self.apps.push(SuiteApp {
            name: name.to_string(),
            ctx,
            space,
        });
    }

    /// The registered applications.
    pub fn apps(&self) -> &[SuiteApp<'p>] {
        &self.apps
    }

    /// Evaluate a flattened `(application, candidate index)` work list
    /// through one shared worker pool: one lazily-built worker (simulator
    /// + model) per thread per application, reused for every point that
    /// thread evaluates for that application. The work list is partitioned
    /// into neighbor chains ([`delta_chains`]; chains never cross
    /// applications), so consecutive same-app candidates differing in one
    /// kernel ride the delta path. Results come back sorted by
    /// `(application, enumeration index)` — the merge order every suite
    /// sweep (cold, warm, exhaustive) shares, which is what makes them
    /// all bit-identical for any worker count. Points whose evaluation
    /// panicked come back separately as sorted `(application, candidate)`
    /// poison records; the pool survives them. The third element is the
    /// per-application delta counter set.
    fn evaluate_flat(
        &self,
        per_app: &[Vec<CoDesign>],
        flat: &[(usize, usize)],
        workers: usize,
    ) -> (
        Vec<(usize, usize, DsePoint)>,
        Vec<(usize, usize)>,
        Vec<DeltaStats>,
    ) {
        let chains = delta_chains(flat.len(), |j| {
            let (ai, ci) = flat[j];
            let (pai, pci) = flat[j - 1];
            if ai != pai {
                return None; // chains never cross applications
            }
            single_kernel_diff(self.apps[ai].ctx.program, &per_app[ai][pci], &per_app[ai][ci])
        });
        let workers = workers.clamp(1, chains.len().max(1));
        let mut slots: Vec<Vec<Option<SweepWorker<'_, 'p>>>> = (0..workers)
            .map(|_| (0..self.apps.len()).map(|_| None).collect())
            .collect();
        let outcomes = parallel_for_indexed(&mut slots, chains.len(), |pool, c| {
            let chain = chains[c];
            let ai = flat[chain.start].0;
            let out = evaluate_chain(
                &mut pool[ai],
                || self.apps[ai].ctx.worker(),
                chain,
                |i| &per_app[ai][flat[i].1],
            );
            Some((ai, out))
        });
        let mut indexed: Vec<(usize, usize, DsePoint)> = Vec::with_capacity(flat.len());
        let mut poisoned: Vec<(usize, usize)> = Vec::new();
        let mut delta = vec![DeltaStats::default(); self.apps.len()];
        for (ai, out) in outcomes {
            delta[ai].merge(&out.stats);
            for (i, p) in out.results {
                indexed.push((ai, flat[i].1, p));
            }
            for i in out.poisoned {
                poisoned.push(flat[i]);
            }
        }
        indexed.sort_unstable_by_key(|&(ai, ci, _)| (ai, ci));
        poisoned.sort_unstable();
        (indexed, poisoned, delta)
    }

    /// Exhaustively sweep every application in a single pass over one
    /// shared worker pool. Per-application output is bit-identical to
    /// [`SweepContext::explore`] on that application alone.
    pub fn explore(&self, objective: Objective, workers: usize) -> Vec<SuiteAppResult> {
        // Flatten (app, candidate) work items across the whole suite.
        let per_app: Vec<Vec<CoDesign>> = self
            .apps
            .iter()
            .map(|a| a.ctx.enumerate(&a.space))
            .collect();
        let flat: Vec<(usize, usize)> = per_app
            .iter()
            .enumerate()
            .flat_map(|(ai, cands)| (0..cands.len()).map(move |ci| (ai, ci)))
            .collect();
        let (indexed, poisoned, delta) = self.evaluate_flat(&per_app, &flat, workers);
        let mut results: Vec<SuiteAppResult> = self
            .apps
            .iter()
            .enumerate()
            .map(|(ai, a)| SuiteAppResult {
                name: a.name.clone(),
                points: Vec::new(),
                stats: super::prune::PruneStats {
                    feasible_points: per_app[ai].len() as u64,
                    delta_hits: delta[ai].hits,
                    delta_fallbacks: delta[ai].fallbacks,
                    delta_suffix_events: delta[ai].suffix_events,
                    delta_total_events: delta[ai].total_events,
                    ..Default::default()
                },
            })
            .collect();
        for (ai, _, p) in indexed {
            results[ai].points.push(p);
        }
        for &(ai, _) in &poisoned {
            results[ai].stats.poisoned += 1;
        }
        for r in &mut results {
            r.stats.evaluated = r.points.len() as u64;
            // Candidates the evaluation skipped (some kernel had nowhere
            // to run) — account for them so `evaluated < feasible_points`
            // can never read as pruning in an exhaustive sweep. Poisoned
            // points are quarantined in their own counter.
            r.stats.unrunnable =
                r.stats.feasible_points - r.stats.evaluated - r.stats.poisoned;
            r.points
                .sort_by(|a, b| a.score(objective).partial_cmp(&b.score(objective)).unwrap());
        }
        results
    }

    /// Bound-guided pruned sweep of the whole suite through one shared
    /// worker pool (see [`dse::prune`](super::prune)): per application,
    /// the best point and Pareto front equal [`SweepSuite::explore`]'s
    /// while strictly fewer points are simulated.
    pub fn explore_pruned(&self, objective: Objective, workers: usize) -> Vec<SuiteAppResult> {
        let inputs: Vec<(&SweepContext<'p>, &DseSpace)> =
            self.apps.iter().map(|a| (&a.ctx, &a.space)).collect();
        super::prune::explore_pruned_multi(&inputs, objective, workers)
            .into_iter()
            .zip(&self.apps)
            .map(|((points, stats), app)| SuiteAppResult {
                name: app.name.clone(),
                points,
                stats,
            })
            .collect()
    }

    /// Warm-started bound-guided pruned sweep of the whole suite — every
    /// job's memo hits, warm incumbents and level-1 ordering priors, all
    /// through **one** shared worker pool (the multi-job warm rounds of
    /// [`dse::prune`](super::prune)). Per application the output is
    /// bit-identical to [`SweepContext::explore_warm`] on that application
    /// alone against the same memo, for any worker count; a second warm
    /// run over an unchanged suite evaluates zero points. Fresh
    /// evaluations and kernel statistics are recorded back into `memo`.
    pub fn explore_pruned_warm(
        &self,
        memo: &mut super::warm::EvalMemo,
        objective: Objective,
        workers: usize,
        order: super::prune::OrderMode,
    ) -> Vec<SuiteAppResult> {
        let inputs: Vec<(&SweepContext<'p>, &DseSpace)> =
            self.apps.iter().map(|a| (&a.ctx, &a.space)).collect();
        super::prune::explore_pruned_warm_multi(&inputs, Some(memo), order, objective, workers)
            .into_iter()
            .zip(&self.apps)
            .map(|((points, stats), app)| SuiteAppResult {
                name: app.name.clone(),
                points,
                stats,
            })
            .collect()
    }

    /// Warm-started **exhaustive** sweep of the whole suite: every
    /// feasible candidate is returned, but candidates recorded in the memo
    /// are served bit-identically without simulation and only the misses
    /// run through the shared pool. Per-application output is
    /// bit-identical to [`SweepSuite::explore`] on that application alone,
    /// for any worker count. Fresh evaluations and kernel statistics are
    /// recorded back into `memo`.
    pub fn explore_warm(
        &self,
        memo: &mut super::warm::EvalMemo,
        objective: Objective,
        workers: usize,
    ) -> Vec<SuiteAppResult> {
        let per_app: Vec<Vec<CoDesign>> = self
            .apps
            .iter()
            .map(|a| a.ctx.enumerate(&a.space))
            .collect();
        let keys: Vec<Vec<String>> = per_app
            .iter()
            .map(|cands| cands.iter().map(super::warm::codesign_key).collect())
            .collect();
        let fps: Vec<u64> = self
            .apps
            .iter()
            .map(|a| super::warm::context_fingerprint(&a.ctx))
            .collect();
        // Level-2 hits per app, served without simulation.
        let mut hits: Vec<Vec<(usize, DsePoint)>> = Vec::new();
        let mut done: Vec<Vec<bool>> = Vec::new();
        for (ai, cands) in per_app.iter().enumerate() {
            memo.touch(fps[ai]);
            let mut app_hits = Vec::new();
            let mut app_done = vec![false; cands.len()];
            for (ci, key) in keys[ai].iter().enumerate() {
                if let Some(v) = memo.lookup(fps[ai], key) {
                    app_done[ci] = true;
                    app_hits.push((
                        ci,
                        DsePoint {
                            codesign: cands[ci].clone(),
                            est_ms: v.est_ms,
                            energy_j: v.energy_j,
                            edp: v.edp,
                            fabric_util: v.fabric_util,
                        },
                    ));
                }
            }
            hits.push(app_hits);
            done.push(app_done);
        }
        // Evaluate the misses through one shared pool, merged by
        // (application, enumeration index) as everywhere else.
        let mut flat: Vec<(usize, usize)> = Vec::new();
        for (ai, app_done) in done.iter().enumerate() {
            for (ci, &served) in app_done.iter().enumerate() {
                if !served {
                    flat.push((ai, ci));
                }
            }
        }
        let (indexed, poisoned, delta) = self.evaluate_flat(&per_app, &flat, workers);
        // Record both levels, then assemble per-app results.
        let mut fresh: Vec<Vec<(usize, DsePoint)>> =
            (0..self.apps.len()).map(|_| Vec::new()).collect();
        for (ai, ci, p) in indexed {
            fresh[ai].push((ci, p));
        }
        let mut poisoned_per_app = vec![0u64; self.apps.len()];
        for &(ai, _) in &poisoned {
            poisoned_per_app[ai] += 1;
        }
        let mut results: Vec<SuiteAppResult> = Vec::new();
        for (ai, app) in self.apps.iter().enumerate() {
            memo.record_kernels(&app.ctx, &app.space);
            for (ci, p) in &fresh[ai] {
                memo.record(&app.ctx, fps[ai], &keys[ai][*ci], p);
            }
            let fresh_points: Vec<DsePoint> =
                fresh[ai].iter().map(|(_, p)| p.clone()).collect();
            memo.record_occupancy(&app.ctx, &fresh_points);

            let mut all = hits[ai].clone();
            all.extend(fresh[ai].iter().cloned());
            all.sort_unstable_by_key(|e| e.0);
            let mut points: Vec<DsePoint> = all.into_iter().map(|(_, p)| p).collect();
            let stats = super::prune::PruneStats {
                feasible_points: per_app[ai].len() as u64,
                evaluated: fresh[ai].len() as u64,
                memo_hits: hits[ai].len() as u64,
                kernel_hits: app.ctx.kernel_memo_hits() as u64,
                poisoned: poisoned_per_app[ai],
                unrunnable: per_app[ai].len() as u64
                    - fresh[ai].len() as u64
                    - hits[ai].len() as u64
                    - poisoned_per_app[ai],
                delta_hits: delta[ai].hits,
                delta_fallbacks: delta[ai].fallbacks,
                delta_suffix_events: delta[ai].suffix_events,
                delta_total_events: delta[ai].total_events,
                ..Default::default()
            };
            points.sort_by(|a, b| a.score(objective).partial_cmp(&b.score(objective)).unwrap());
            results.push(SuiteAppResult {
                name: app.name.clone(),
                points,
                stats,
            });
        }
        results
    }
}

/// The seed *evaluation* path, kept for benchmarking and equivalence
/// testing: rebuilds the dependence graph and elaborated program for
/// **every** point (inside `sim::estimate`) and re-runs the HLS cost model
/// per point — exactly what `SweepContext` eliminates. (Candidate
/// enumeration goes through the shared wrapper, so both paths sweep the
/// identical candidate list; the timed difference is per-point
/// evaluation, which dominates.)
pub fn explore_rebuild_baseline(
    program: &TaskProgram,
    board: &BoardConfig,
    part: &FpgaPart,
    space: &DseSpace,
    objective: Objective,
) -> anyhow::Result<Vec<DsePoint>> {
    let cm = CostModel::from_board(board);
    let pm = PowerModel::default();
    let mut points = Vec::new();
    for cd in super::enumerate(program, board, part, space) {
        // Skip configurations where some kernel has nowhere to run.
        let Ok(res) = crate::sim::estimate(program, &cd, board) else {
            continue;
        };
        let resources: Vec<Resources> = cd
            .accels
            .iter()
            .map(|a| {
                let kid = program.kernel_id(&a.kernel).unwrap();
                cm.estimate(&a.kernel, &program.kernel(kid).profile, a.unroll)
                    .resources
            })
            .collect();
        let util = part.utilization(&resources);
        let energy = pm.energy(&res, &resources, util, board.fabric_freq_mhz);
        points.push(DsePoint {
            codesign: cd,
            est_ms: res.makespan_ms(),
            energy_j: energy.total_j(),
            edp: energy.edp(),
            fabric_util: util,
        });
    }
    points.sort_by(|a, b| a.score(objective).partial_cmp(&b.score(objective)).unwrap());
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::Matmul;
    use crate::dse::KernelSpace;

    fn space() -> DseSpace {
        DseSpace {
            kernels: vec![KernelSpace {
                kernel: "mxm64".into(),
                unrolls: vec![8, 16, 32],
                max_instances: 2,
                try_smp: true,
            }],
            mixed: false,
        }
    }

    #[test]
    fn mixed_enumeration_is_a_superset_with_heterogeneous_pairs() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let part = FpgaPart::xc7z045();
        let sp = space();
        let mixed = sp.clone().with_mixed();
        let ctx = SweepContext::for_space(&p, &board, &part, &mixed);
        let homogeneous = ctx.enumerate(&sp);
        let cds = ctx.enumerate(&mixed);
        // Every homogeneous candidate appears in the mixed space.
        for h in &homogeneous {
            assert!(cds.contains(h), "missing homogeneous candidate {}", h.name);
        }
        assert!(cds.len() > homogeneous.len());
        // And a genuinely heterogeneous pair exists (two different unrolls
        // of the same kernel).
        assert!(cds.iter().any(|c| c.accels.len() == 2
            && c.accels[0].unroll != c.accels[1].unroll));
    }

    #[test]
    fn context_enumeration_matches_free_function() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let part = FpgaPart::xc7z045();
        let sp = space();
        let ctx = SweepContext::for_space(&p, &board, &part, &sp);
        let a = ctx.enumerate(&sp);
        let b = super::super::enumerate(&p, &board, &part, &sp);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn prime_fills_the_cache() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let sp = space();
        let mut ctx = SweepContext::new(&p, &board, FpgaPart::xc7z045());
        assert_eq!(ctx.cached_reports(), 0);
        ctx.prime(&sp);
        assert_eq!(ctx.cached_reports(), 3);
        // Idempotent.
        ctx.prime(&sp);
        assert_eq!(ctx.cached_reports(), 3);
        // Cache hits equal fresh estimates.
        let kid = p.kernel_id("mxm64").unwrap();
        let cached = ctx.report_for(kid, "mxm64", 16);
        let fresh = CostModel::from_board(&board).estimate("mxm64", &p.kernel(kid).profile, 16);
        assert_eq!(cached, fresh);
        // Uncached unrolls fall through to the cost model.
        let off_space = ctx.report_for(kid, "mxm64", 64);
        let fresh64 = CostModel::from_board(&board).estimate("mxm64", &p.kernel(kid).profile, 64);
        assert_eq!(off_space, fresh64);
    }

    #[test]
    fn cached_estimate_matches_sim_estimate() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let ctx = SweepContext::new(&p, &board, FpgaPart::xc7z045());
        let cd = CoDesign::new("2acc").with_accel("mxm64", 32).with_accel("mxm64", 32);
        let a = ctx.estimate(&cd).unwrap();
        let b = crate::sim::estimate(&p, &cd, &board).unwrap();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.device_busy, b.device_busy);
        // Infeasible co-designs error through both paths.
        let huge = CoDesign::new("huge")
            .with_accel("mxm64", 512)
            .with_accel("mxm64", 512);
        assert!(ctx.estimate(&huge).is_err());
        assert!(crate::sim::estimate(&p, &huge, &board).is_err());
    }

    #[test]
    fn explore_matches_rebuild_baseline() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let part = FpgaPart::xc7z045();
        let sp = space();
        let ctx = SweepContext::for_space(&p, &board, &part, &sp);
        let baseline =
            explore_rebuild_baseline(&p, &board, &part, &sp, Objective::Time).unwrap();
        for workers in [1, 2, 4] {
            let pts = ctx.explore(&sp, Objective::Time, workers);
            assert_eq!(pts.len(), baseline.len(), "workers={workers}");
            for (a, b) in pts.iter().zip(&baseline) {
                assert_eq!(a.codesign.name, b.codesign.name, "workers={workers}");
                assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "workers={workers}");
                assert_eq!(
                    a.energy_j.to_bits(),
                    b.energy_j.to_bits(),
                    "workers={workers}"
                );
            }
        }
    }
}
