//! Machine-readable experiment export: CSV and JSON writers for the figure
//! data, so the reproduction plots can be regenerated outside this binary
//! (gnuplot / matplotlib) and diffed in CI.

use crate::dse::{BudgetAxis, BudgetRow, CrossBoardResult};
use crate::metrics::SpeedupTable;
use crate::util::json::{arr, obj, Value};

/// CSV for a Fig.5/Fig.9-style table.
pub fn speedup_table_csv(table: &SpeedupTable) -> String {
    let mut out = String::from("config,estimator_ms,board_ms,estimator_speedup,board_speedup\n");
    for (i, r) in table.rows.iter().enumerate() {
        out.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{:.6}\n",
            csv_escape(&r.name),
            r.estimator_ms,
            r.board_ms,
            table.est_speedup[i],
            table.board_speedup[i]
        ));
    }
    out
}

/// JSON document for a speedup table, with the trend metadata.
pub fn speedup_table_json(table: &SpeedupTable, title: &str) -> String {
    let rows: Vec<Value> = table
        .rows
        .iter()
        .enumerate()
        .map(|(i, r)| {
            obj(vec![
                ("config", r.name.as_str().into()),
                ("estimator_ms", r.estimator_ms.into()),
                ("board_ms", r.board_ms.into()),
                ("estimator_speedup", table.est_speedup[i].into()),
                ("board_speedup", table.board_speedup[i].into()),
            ])
        })
        .collect();
    obj(vec![
        ("title", title.into()),
        ("rows", arr(rows)),
        ("kendall_tau", table.trend_agreement().into()),
        ("best_agrees", table.best_agrees().into()),
        (
            "best_config",
            table.rows[table.best_estimator()].name.as_str().into(),
        ),
    ])
    .to_json()
}

/// CSV for the cross-board winner tables (one row per budget point,
/// time-budget axis).
pub fn cross_board_winners_csv(tables: &[(String, Vec<BudgetRow>)]) -> String {
    let mut out = String::from("app,time_budget_ms,board,codesign,energy_j,fabric_util\n");
    for (app, rows) in tables {
        for r in rows {
            out.push_str(&format!(
                "{},{:.6},{},{},{:.6},{:.6}\n",
                csv_escape(app),
                r.time_budget_ms,
                csv_escape(&r.board),
                csv_escape(&r.codesign),
                r.energy_j,
                r.fabric_util
            ));
        }
    }
    out
}

/// CSV for winner tables on any [`BudgetAxis`]: one row per budget point
/// with the axis and the budget coordinate made explicit, plus the
/// winning point's full coordinates.
pub fn budget_tables_csv(axis: BudgetAxis, tables: &[(String, Vec<BudgetRow>)]) -> String {
    let mut out =
        String::from("app,budget_axis,budget,board,codesign,time_ms,energy_j,fabric_util\n");
    for (app, rows) in tables {
        for r in rows {
            let budget = match axis {
                BudgetAxis::Time => r.time_budget_ms,
                BudgetAxis::Energy => r.energy_j,
                BudgetAxis::Area => r.fabric_util,
            };
            out.push_str(&format!(
                "{},{},{:.6},{},{},{:.6},{:.6},{:.6}\n",
                csv_escape(app),
                axis.as_str(),
                budget,
                csv_escape(&r.board),
                csv_escape(&r.codesign),
                r.time_budget_ms,
                r.energy_j,
                r.fabric_util
            ));
        }
    }
    out
}

/// JSON for winner tables on any [`BudgetAxis`] — the machine-readable
/// form of `dse --boards --budget <axis>`.
pub fn budget_tables_json(axis: BudgetAxis, tables: &[(String, Vec<BudgetRow>)]) -> String {
    let tables_json: Vec<Value> = tables
        .iter()
        .map(|(app, rows)| {
            let rows: Vec<Value> = rows
                .iter()
                .map(|r| {
                    obj(vec![
                        ("time_ms", r.time_budget_ms.into()),
                        ("board", r.board.as_str().into()),
                        ("codesign", r.codesign.as_str().into()),
                        ("energy_j", r.energy_j.into()),
                        ("fabric_util", r.fabric_util.into()),
                    ])
                })
                .collect();
            obj(vec![("app", app.as_str().into()), ("rows", arr(rows))])
        })
        .collect();
    obj(vec![
        ("budget_axis", axis.as_str().into()),
        ("tables", arr(tables_json)),
    ])
    .to_json()
}

/// JSON document for a cross-board sweep: one record per (board, app)
/// entry (best point + prune accounting) plus the per-application winner
/// tables — the machine-readable form of the `dse --boards` output,
/// emitted by `benches/cross_board.rs`.
pub fn cross_board_json(
    results: &[CrossBoardResult],
    tables: &[(String, Vec<BudgetRow>)],
) -> String {
    let entries: Vec<Value> = results
        .iter()
        .map(|r| {
            let best = r.points.first();
            obj(vec![
                ("board", r.board.as_str().into()),
                ("app", r.app.as_str().into()),
                ("feasible_points", r.stats.feasible_points.into()),
                ("evaluated_points", r.stats.evaluated.into()),
                ("bound_cut", r.stats.bound_cut.into()),
                ("global_cut", r.stats.global_cut.into()),
                (
                    "best",
                    best.map(|p| p.codesign.name.as_str().into())
                        .unwrap_or(Value::Null),
                ),
                (
                    "best_ms",
                    best.map(|p| p.est_ms.into()).unwrap_or(Value::Null),
                ),
                (
                    "best_energy_j",
                    best.map(|p| p.energy_j.into()).unwrap_or(Value::Null),
                ),
            ])
        })
        .collect();
    let winners: Vec<Value> = tables
        .iter()
        .map(|(app, rows)| {
            let rows: Vec<Value> = rows
                .iter()
                .map(|r| {
                    obj(vec![
                        ("time_budget_ms", r.time_budget_ms.into()),
                        ("board", r.board.as_str().into()),
                        ("codesign", r.codesign.as_str().into()),
                        ("energy_j", r.energy_j.into()),
                    ])
                })
                .collect();
            obj(vec![("app", app.as_str().into()), ("rows", arr(rows))])
        })
        .collect();
    obj(vec![("entries", arr(entries)), ("winners", arr(winners))]).to_json()
}

/// Machine-readable fields of the daemon's `{"req":"memo","action":"stats"}`
/// response: the memo layout plus the cumulative service counters. Kept in
/// the export module so the stats schema lives next to the other
/// machine-readable schemas (`total_evaluated` is the lifetime counter —
/// named apart from the per-response `evaluated` field).
#[allow(clippy::too_many_arguments)]
pub fn service_stats_fields(
    stats: &crate::dse::MemoStats,
    requests: u64,
    coalesced: u64,
    batched: u64,
    total_evaluated: u64,
    errors: u64,
    saves: u64,
    lanes: u64,
    degraded: bool,
) -> Vec<(String, Value)> {
    vec![
        ("contexts".into(), (stats.contexts as u64).into()),
        ("points".into(), (stats.points as u64).into()),
        ("kernel_entries".into(), (stats.kernel_entries as u64).into()),
        ("bytes".into(), (stats.bytes as u64).into()),
        ("requests".into(), requests.into()),
        ("coalesced".into(), coalesced.into()),
        ("batched".into(), batched.into()),
        ("total_evaluated".into(), total_evaluated.into()),
        ("errors".into(), errors.into()),
        ("saves".into(), saves.into()),
        ("lanes".into(), lanes.into()),
        ("degraded".into(), degraded.into()),
    ]
}

fn csv_escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::ConfigRow;

    fn table() -> SpeedupTable {
        SpeedupTable::build(vec![
            ConfigRow {
                name: "a, plain".into(),
                estimator_ms: 10.0,
                board_ms: 12.0,
            },
            ConfigRow {
                name: "b".into(),
                estimator_ms: 5.0,
                board_ms: 6.0,
            },
        ])
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = speedup_table_csv(&table());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("config,"));
        assert!(lines[1].starts_with("\"a, plain\"")); // escaped comma
    }

    #[test]
    fn json_parses_back() {
        let j = speedup_table_json(&table(), "fig-test");
        let v = crate::util::json::parse(&j).unwrap();
        assert_eq!(v.get("title").unwrap().as_str().unwrap(), "fig-test");
        assert_eq!(v.get("rows").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("best_config").unwrap().as_str().unwrap(), "b");
        assert_eq!(v.get("best_agrees").unwrap().as_bool().unwrap(), true);
    }

    #[test]
    fn cross_board_export_roundtrips() {
        use crate::config::CoDesign;
        use crate::dse::DsePoint;
        let point = DsePoint {
            codesign: CoDesign::new("1acc"),
            est_ms: 12.5,
            energy_j: 0.75,
            edp: 0.009375,
            fabric_util: 0.4,
        };
        let results = vec![CrossBoardResult {
            board: "zynq706".into(),
            app: "matmul".into(),
            points: vec![point],
            stats: Default::default(),
        }];
        let tables = vec![(
            "matmul".to_string(),
            vec![BudgetRow {
                time_budget_ms: 12.5,
                board: "zynq706".into(),
                codesign: "1acc".into(),
                energy_j: 0.75,
                fabric_util: 0.4,
            }],
        )];
        let csv = cross_board_winners_csv(&tables);
        assert!(csv.lines().count() == 2 && csv.contains("zynq706"));
        // Budget-axis exports carry the axis and the budget coordinate.
        let ecsv = budget_tables_csv(BudgetAxis::Energy, &tables);
        assert!(ecsv.lines().count() == 2 && ecsv.contains(",energy,0.75"));
        let acsv = budget_tables_csv(BudgetAxis::Area, &tables);
        assert!(acsv.contains(",area,0.4"));
        let ej =
            crate::util::json::parse(&budget_tables_json(BudgetAxis::Energy, &tables)).unwrap();
        assert_eq!(ej.get("budget_axis").unwrap().as_str().unwrap(), "energy");
        assert_eq!(ej.get("tables").unwrap().as_arr().unwrap().len(), 1);
        let j = cross_board_json(&results, &tables);
        let v = crate::util::json::parse(&j).unwrap();
        let entries = v.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries[0].get("board").unwrap().as_str().unwrap(), "zynq706");
        assert_eq!(entries[0].get("best").unwrap().as_str().unwrap(), "1acc");
        let winners = v.get("winners").unwrap().as_arr().unwrap();
        assert_eq!(winners[0].get("app").unwrap().as_str().unwrap(), "matmul");
    }

    #[test]
    fn csv_quote_escaping() {
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("with \"q\""), "\"with \"\"q\"\"\"");
    }
}
