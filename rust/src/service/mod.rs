//! Service layer: the estimator as a resident queryable daemon.
//!
//! Everything below the CLI already separated one-time analysis from
//! per-query work (sweep contexts, the two-level [`EvalMemo`]); this
//! module adds the missing top: a long-running process that keeps that
//! state warm across queries instead of rebuilding it per invocation —
//! the CEDR-style resident runtime applied to estimation. Three small
//! modules, strictly layered:
//!
//! * [`proto`] — the NDJSON wire protocol: request parsing into a typed
//!   [`RequestKind`] (including the `batch` envelope), response
//!   serialization, the canonical coalescing key, and the error taxonomy
//!   (mirroring the CLI exit codes).
//! * [`query`] — the memo-backed query core shared verbatim by the
//!   one-shot CLI and the daemon, which is what makes daemon responses
//!   byte-identical to CLI stdout by construction. Its batch half
//!   ([`pre_evaluate`] + [`point_query_prepared`]) evaluates many cold
//!   points in one worker-pool round without changing a response byte.
//! * [`daemon`] — the [`Service`] runtime: shared memo behind a
//!   read/write lock, kernel-group memo lanes with per-shard WAL
//!   journals (`--lanes`), cross-request batch evaluation (explicit
//!   envelopes and the `--batch-window-ms` accumulation window),
//!   in-flight coalescing, periodic persistence, stdio and TCP
//!   transports — plus the overload controls: per-request deadlines
//!   with round-barrier sweep cancellation, admission control and
//!   backpressure (`--max-queue`/`--max-inflight`/`--max-conns`/
//!   `--max-line-bytes`), the save circuit breaker's read-only degraded
//!   mode, the `{"req":"health"}` probe, and SIGTERM draining.
//!
//! [`EvalMemo`]: crate::dse::EvalMemo

pub mod daemon;
pub mod proto;
pub mod query;

pub use daemon::{serve, ServeConfig, Service};
pub use proto::{
    parse_request, BatchItem, DseQuery, Envelope, GcSpec, PointQuery, QueryReply, RequestKind,
    ServiceError, MAX_BATCH_ITEMS,
};
pub use query::{
    dse_query, point_query, point_query_prepared, pre_evaluate, space_for_codesign,
    space_for_codesigns, PointOutcome, PreEvaluated,
};
