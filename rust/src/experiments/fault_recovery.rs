//! Crash/recovery study — the fault-injection acceptance test of the
//! crash-safe sweep layer, runnable from the CLI (`fault-recovery`).
//!
//! For each interruption round `k`, the study arms the deterministic
//! `sweep.round@k!error` faultpoint, runs a journaled warm sweep of a
//! mixed-variant matmul space until the injected fault aborts it, then
//! reloads the memo (replaying the committed journal rounds), resumes the
//! sweep from the `.ckpt` candidate order, and compares the resumed run
//! against an uninterrupted reference: the final ranking and the saved
//! memo file must both be **bit-identical**. That is the recovery
//! contract of `dse::ckpt` — a crash loses at most the in-flight round,
//! and resuming is indistinguishable from never having crashed.

use std::path::PathBuf;

use crate::config::BoardConfig;
use crate::dse::{
    DsePoint, DseSpace, EvalMemo, Objective, OrderMode, RecoverySession, SweepContext,
};
use crate::hls::FpgaPart;
use crate::util::faultpoint;

/// One interruption round of the crash/recovery study.
#[derive(Clone, Debug)]
pub struct FaultRecoveryRow {
    /// The armed fault spec (e.g. `sweep.round@2!error`).
    pub fault: String,
    /// Whether the fault actually fired — a small space can finish before
    /// round `k` and outrun the fault, leaving nothing to recover.
    pub fired: bool,
    /// Committed journal rounds replayed when the resume reloaded the memo.
    pub committed_rounds: u64,
    /// Points restored from the journal on reload.
    pub recovered_points: u64,
    /// Points the resumed sweep still had to simulate.
    pub resume_evaluated: u64,
    /// The resumed ranking and the saved memo file are bit-identical to
    /// the uninterrupted reference run.
    pub identical: bool,
}

/// Run the study: an uninterrupted reference sweep, then one
/// crash-at-round-`k` / resume cycle for `k` in 1..=3, all over the same
/// shared [`SweepContext`]. Arms **real** fault sites, so never call this
/// from in-process unit tests that share the global faultpoint registry —
/// the CLI and the `crash_recovery` integration suite (its own process)
/// are the supported drivers.
pub fn study(
    n: u64,
    bs: u64,
    board: &BoardConfig,
    workers: usize,
) -> anyhow::Result<Vec<FaultRecoveryRow>> {
    let program = crate::apps::build_app_program("matmul", n, bs, board)?;
    let space = DseSpace::from_program(&program).with_mixed();
    let part = FpgaPart::xc7z045();
    let ctx = SweepContext::for_space(&program, board, &part, &space);

    // The uninterrupted reference: the same recoverable path, never
    // faulted, so journaling overhead itself cannot hide in the diff.
    let ref_dir = studydir("reference")?;
    let ref_path = ref_dir.join("memo.json");
    let (mut memo, recovered) = EvalMemo::load_with_recovery(&ref_path)?;
    let mut session = RecoverySession::open(&ref_path, recovered, false)?;
    let (reference, _) = ctx.explore_warm_recoverable(
        &space,
        &mut memo,
        Objective::Time,
        workers,
        OrderMode::Ranked,
        &mut session,
    )?;
    memo.save(&ref_path)?;
    let ref_bytes = std::fs::read(&ref_path)?;
    std::fs::remove_dir_all(&ref_dir).ok();

    let mut rows = Vec::new();
    for k in 1..=3u64 {
        let spec = format!("sweep.round@{k}!error");
        let dir = studydir(&format!("round{k}"))?;
        let path = dir.join("memo.json");

        // Leg 1 — sweep with the fault armed; the injected error aborts
        // the run after round `k` commits to the journal.
        let mut completed: Option<(Vec<DsePoint>, u64)> = None;
        {
            let guard = faultpoint::arm(&spec)?;
            let (mut memo, recovered) = EvalMemo::load_with_recovery(&path)?;
            let mut session = RecoverySession::open(&path, recovered, false)?;
            let res = ctx.explore_warm_recoverable(
                &space,
                &mut memo,
                Objective::Time,
                workers,
                OrderMode::Ranked,
                &mut session,
            );
            drop(guard);
            match res {
                Err(e) if format!("{e:#}").contains("sweep.round") => {}
                Err(e) => return Err(e),
                Ok((points, stats)) => {
                    memo.save(&path)?;
                    completed = Some((points, stats.evaluated));
                }
            }
        }

        let row = if let Some((points, evaluated)) = completed {
            // The sweep outran the fault — nothing was interrupted, but the
            // journaled run must still match the reference exactly.
            let bytes = std::fs::read(&path)?;
            FaultRecoveryRow {
                fault: spec,
                fired: false,
                committed_rounds: 0,
                recovered_points: 0,
                resume_evaluated: evaluated,
                identical: same_ranking(&reference, &points) && bytes == ref_bytes,
            }
        } else {
            // Leg 2 — reload (journal replay) and resume to completion.
            let (mut memo, recovered) = EvalMemo::load_with_recovery(&path)?;
            let (committed_rounds, recovered_points) = recovered
                .as_ref()
                .map(|r| (r.rounds, r.n_points() as u64))
                .unwrap_or((0, 0));
            let mut session = RecoverySession::open(&path, recovered, true)?;
            let (resumed, stats) = ctx.explore_warm_recoverable(
                &space,
                &mut memo,
                Objective::Time,
                workers,
                OrderMode::Ranked,
                &mut session,
            )?;
            memo.save(&path)?;
            let bytes = std::fs::read(&path)?;
            FaultRecoveryRow {
                fault: spec,
                fired: true,
                committed_rounds,
                recovered_points,
                resume_evaluated: stats.evaluated,
                identical: same_ranking(&reference, &resumed) && bytes == ref_bytes,
            }
        };
        std::fs::remove_dir_all(&dir).ok();
        rows.push(row);
    }
    Ok(rows)
}

/// Render the study rows as the CLI table (trailing newline included).
pub fn render(rows: &[FaultRecoveryRow]) -> String {
    let mut s = String::new();
    s.push_str("crash/recovery study (matmul mixed space, interrupted warm sweeps):\n");
    s.push_str(&format!(
        "  {:<22} {:>6} {:>8} {:>10} {:>12} {:>10}\n",
        "fault", "fired", "rounds", "recovered", "resume-eval", "identical"
    ));
    for r in rows {
        s.push_str(&format!(
            "  {:<22} {:>6} {:>8} {:>10} {:>12} {:>10}\n",
            r.fault,
            if r.fired { "yes" } else { "no" },
            r.committed_rounds,
            r.recovered_points,
            r.resume_evaluated,
            if r.identical { "yes" } else { "NO" },
        ));
    }
    s
}

/// Bitwise ranking equality: same length, same co-design sequence, same
/// metric bit patterns.
fn same_ranking(a: &[DsePoint], b: &[DsePoint]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.codesign.name == y.codesign.name
                && x.est_ms.to_bits() == y.est_ms.to_bits()
                && x.energy_j.to_bits() == y.energy_j.to_bits()
                && x.edp.to_bits() == y.edp.to_bits()
                && x.fabric_util.to_bits() == y.fabric_util.to_bits()
        })
}

/// A fresh per-process scratch directory for one leg of the study.
fn studydir(tag: &str) -> anyhow::Result<PathBuf> {
    let d = std::env::temp_dir().join(format!("zynq_fault_recovery_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).map_err(|e| anyhow::anyhow!("{}: {e}", d.display()))?;
    Ok(d)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The study itself arms *real* fault sites and therefore only runs in
    // the `crash_recovery` integration suite (its own process) and from
    // the CLI; in-process tests cover the pure pieces.

    #[test]
    fn render_flags_divergence() {
        let rows = vec![
            FaultRecoveryRow {
                fault: "sweep.round@1!error".into(),
                fired: true,
                committed_rounds: 1,
                recovered_points: 8,
                resume_evaluated: 24,
                identical: true,
            },
            FaultRecoveryRow {
                fault: "sweep.round@2!error".into(),
                fired: true,
                committed_rounds: 2,
                recovered_points: 16,
                resume_evaluated: 16,
                identical: false,
            },
        ];
        let out = render(&rows);
        assert!(out.contains("sweep.round@1!error"));
        assert!(out.contains("yes"));
        assert!(out.contains("NO"), "{out}");
        assert_eq!(out.lines().count(), 4);
    }

    #[test]
    fn ranking_comparison_is_bitwise() {
        let p = DsePoint {
            codesign: crate::config::CoDesign::new("a"),
            est_ms: 1.0,
            energy_j: 2.0,
            edp: 3.0,
            fabric_util: 0.5,
        };
        let mut q = p.clone();
        assert!(same_ranking(&[p.clone()], &[q.clone()]));
        q.est_ms = f64::from_bits(p.est_ms.to_bits() + 1);
        assert!(!same_ranking(&[p.clone()], &[q]));
        assert!(!same_ranking(&[p], &[]));
    }
}
