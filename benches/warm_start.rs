//! Warm-start / ordered DSE benchmark — cold-vs-warm and ordered-vs-FIFO
//! on **mixed-variant** spaces.
//!
//! Runs `experiments::warm_start_latency` (matmul + cholesky mixed-variant
//! spaces through cold FIFO / bound-ascending / ranked pruned sweeps and a
//! memo-warm second run) plus the perturbed-space robustness study, and
//! emits `BENCH_warm.json`. The harness itself asserts the exactness
//! contracts (identical best point and Pareto front across every mode;
//! zero re-evaluations on the warm second run); the JSON records the point
//! accounting so `bench-check` gates the headline claims against
//! `bench_baselines/BENCH_warm.json`:
//!
//! * `warm_total_evaluated == 0` — a warm repeat simulates nothing;
//! * `warm_lt_fifo` — the warm sweep simulates strictly fewer points than
//!   the cold FIFO baseline;
//! * `ranked_le_fifo` — best-first ranked ordering never simulates more
//!   than FIFO on these spaces (the incumbent tightens earlier).

use zynq_estimator::config::BoardConfig;
use zynq_estimator::dse::default_workers;
use zynq_estimator::experiments;
use zynq_estimator::util::json::{arr, obj, Value};

fn main() {
    let board = BoardConfig::zynq706();
    let workers = default_workers();
    let n = 512;
    let r = experiments::warm_start_latency(n, &board, workers)
        .expect("warm-start sweeps must be exact");
    let perturbed = experiments::warm_perturbed_study(n, &board, workers)
        .expect("perturbed warm sweeps must be exact");

    println!("== Warm-start DSE on mixed-variant spaces (n = {n}, {workers} workers)");
    println!(
        "{:>10} {:>9} {:>10} {:>8} {:>8} {:>8} {:>8} {:>9}  {}",
        "app", "feasible", "enumerated", "fifo", "bound", "ranked", "warm", "memo hit", "best"
    );
    let mut fifo_total = 0u64;
    let mut bound_total = 0u64;
    let mut ranked_total = 0u64;
    let mut warm_total = 0u64;
    let mut records: Vec<Value> = Vec::new();
    for a in &r.apps {
        println!(
            "{:>10} {:>9} {:>10} {:>8} {:>8} {:>8} {:>8} {:>9}  {}",
            a.name,
            a.feasible,
            a.enumerated,
            a.fifo_evaluated,
            a.bound_evaluated,
            a.ranked_evaluated,
            a.warm_evaluated,
            a.memo_hits,
            a.best
        );
        fifo_total += a.fifo_evaluated;
        bound_total += a.bound_evaluated;
        ranked_total += a.ranked_evaluated;
        warm_total += a.warm_evaluated;
        records.push(obj(vec![
            ("app", a.name.as_str().into()),
            ("feasible_points", a.feasible.into()),
            ("enumerated_points", a.enumerated.into()),
            ("fifo_evaluated", a.fifo_evaluated.into()),
            ("bound_evaluated", a.bound_evaluated.into()),
            ("ranked_evaluated", a.ranked_evaluated.into()),
            ("warm_evaluated", a.warm_evaluated.into()),
            ("memo_hits", a.memo_hits.into()),
            ("seeded_cut", a.seeded_cut.into()),
            ("best", a.best.as_str().into()),
        ]));
    }
    println!(
        "totals: fifo {fifo_total}, bound {bound_total}, ranked {ranked_total}, warm {warm_total}; \
         cold-fifo {:.3} s, cold-ranked {:.3} s, warm {:.3} s ({:.1}x vs fifo)",
        r.fifo_s,
        r.ranked_s,
        r.warm_s,
        r.fifo_s / r.warm_s.max(1e-12),
    );

    println!("-- perturbed-space robustness (matmul mixed base memo)");
    let mut perturbed_records: Vec<Value> = Vec::new();
    for p in &perturbed {
        println!(
            "{:>16}: cold {:>4}, warm {:>4}, memo hits {:>4}",
            p.label, p.cold_evaluated, p.warm_evaluated, p.memo_hits
        );
        perturbed_records.push(obj(vec![
            ("label", p.label.as_str().into()),
            ("cold_evaluated", p.cold_evaluated.into()),
            ("warm_evaluated", p.warm_evaluated.into()),
            ("memo_hits", p.memo_hits.into()),
        ]));
    }

    // Cross-size kernel-sub-memo warm start: the harness asserts exactness
    // (warm best + front bit-identical to cold) and the two-level hit
    // contract (level-2 misses, level-1 hits); the JSON pins the hit
    // counts so bench-check gates the cross-size reuse claim.
    let cross = experiments::warm_cross_size_study(&board, workers)
        .expect("cross-size warm sweeps must be exact");
    println!(
        "-- cross-size kernel-memo warm start (matmul {} -> {})",
        cross.small_n, cross.large_n
    );
    println!(
        "   kernel hits {} (L1), memo hits {} (L2), prior-ordered {}, warm evaluated {}, \
         cold evaluated {}, best {}",
        cross.kernel_hits,
        cross.memo_hits,
        cross.prior_ordered,
        cross.warm_evaluated,
        cross.cold_evaluated,
        cross.best
    );

    let out = obj(vec![
        ("n", n.into()),
        ("workers", r.workers.into()),
        ("fifo_s", r.fifo_s.into()),
        ("ranked_s", r.ranked_s.into()),
        ("warm_s", r.warm_s.into()),
        ("fifo_total_evaluated", fifo_total.into()),
        ("bound_total_evaluated", bound_total.into()),
        ("ranked_total_evaluated", ranked_total.into()),
        ("warm_total_evaluated", warm_total.into()),
        ("warm_lt_fifo", (warm_total < fifo_total).into()),
        ("ranked_le_fifo", (ranked_total <= fifo_total).into()),
        ("apps", arr(records)),
        ("perturbed", arr(perturbed_records)),
        (
            "cross_size",
            obj(vec![
                ("small_n", cross.small_n.into()),
                ("large_n", cross.large_n.into()),
                ("kernel_hits", cross.kernel_hits.into()),
                ("memo_hits", cross.memo_hits.into()),
                ("prior_ordered", cross.prior_ordered.into()),
                ("warm_evaluated", cross.warm_evaluated.into()),
                ("cold_evaluated", cross.cold_evaluated.into()),
                ("best", cross.best.as_str().into()),
            ]),
        ),
    ])
    .to_json();
    match std::fs::write("BENCH_warm.json", &out) {
        Ok(()) => println!("wrote BENCH_warm.json ({} bytes)", out.len()),
        Err(e) => eprintln!("could not write BENCH_warm.json: {e}"),
    }
}
