//! HLS report structures — the information the paper extracts from Vivado
//! HLS for each annotated kernel (§IV): estimated compute cycles and
//! estimated input/output transfer cycles, plus the resource usage the
//! feasibility analysis needs.

use crate::sim::time::{Clock, Ps};
use crate::util::json::{obj, Value};

/// Resource vector of one synthesized accelerator (7-series primitives).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Resources {
    /// Look-up tables.
    pub luts: u64,
    /// Flip-flops.
    pub ffs: u64,
    /// DSP48 slices.
    pub dsps: u64,
    /// BRAM counted in 18 Kb halves (a BRAM36 = 2 × BRAM18).
    pub bram18: u64,
}

impl Resources {
    /// The empty resource vector.
    pub const ZERO: Resources = Resources {
        luts: 0,
        ffs: 0,
        dsps: 0,
        bram18: 0,
    };

    /// Component-wise sum.
    pub fn add(&self, o: &Resources) -> Resources {
        Resources {
            luts: self.luts + o.luts,
            ffs: self.ffs + o.ffs,
            dsps: self.dsps + o.dsps,
            bram18: self.bram18 + o.bram18,
        }
    }

    /// Component-wise `<=` against a budget.
    pub fn fits_in(&self, budget: &Resources) -> bool {
        self.luts <= budget.luts
            && self.ffs <= budget.ffs
            && self.dsps <= budget.dsps
            && self.bram18 <= budget.bram18
    }

    /// Highest fractional utilization across resource classes w.r.t. a
    /// budget (the quantity place-and-route difficulty tracks).
    pub fn max_utilization(&self, budget: &Resources) -> f64 {
        [
            self.luts as f64 / budget.luts as f64,
            self.ffs as f64 / budget.ffs as f64,
            self.dsps as f64 / budget.dsps as f64,
            self.bram18 as f64 / budget.bram18 as f64,
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }
}

/// One kernel variant's synthesis estimate — the stand-in for the Vivado
/// HLS report the paper's toolchain parses.
#[derive(Clone, Debug, PartialEq)]
pub struct HlsReport {
    /// Kernel the variant implements.
    pub kernel: String,
    /// Unroll factor of the variant.
    pub unroll: u32,
    /// Achieved initiation interval of the pipelined innermost loop.
    pub ii: u32,
    /// Pipeline depth (fill/flush latency), cycles.
    pub depth: u32,
    /// Estimated compute cycles per task invocation (fabric clock).
    pub compute_cycles: u64,
    /// Achieved fabric clock after HLS scheduling, MHz.
    pub fmax_mhz: f64,
    /// Estimated cycles to DMA the inputs in (fabric clock domain).
    pub in_cycles: u64,
    /// Estimated cycles to DMA the outputs back (fabric clock domain).
    pub out_cycles: u64,
    /// Resource usage of the synthesized accelerator.
    pub resources: Resources,
}

impl HlsReport {
    /// The fabric clock domain the variant achieved.
    pub fn clock(&self) -> Clock {
        Clock::new(self.fmax_mhz)
    }

    /// Compute-only latency in picoseconds.
    pub fn compute_ps(&self) -> Ps {
        self.clock().cycles_to_ps(self.compute_cycles)
    }

    /// Input-transfer latency in picoseconds.
    pub fn in_ps(&self) -> Ps {
        self.clock().cycles_to_ps(self.in_cycles)
    }

    /// Output-transfer latency in picoseconds.
    pub fn out_ps(&self) -> Ps {
        self.clock().cycles_to_ps(self.out_cycles)
    }

    /// Serialize the report for the evaluation-memo file. Every cycle and
    /// resource count is an integer and `fmax_mhz` is stored as its exact
    /// bit pattern, so [`HlsReport::from_json_value`] reconstructs the
    /// report bit for bit — the level-1 memo serves it in place of a
    /// cost-model call.
    pub fn to_json_value(&self) -> Value {
        obj(vec![
            ("kernel", self.kernel.as_str().into()),
            ("unroll", self.unroll.into()),
            ("ii", self.ii.into()),
            ("depth", self.depth.into()),
            ("compute_cycles", self.compute_cycles.into()),
            ("fmax_mhz", self.fmax_mhz.to_bits().into()),
            ("in_cycles", self.in_cycles.into()),
            ("out_cycles", self.out_cycles.into()),
            ("luts", self.resources.luts.into()),
            ("ffs", self.resources.ffs.into()),
            ("dsps", self.resources.dsps.into()),
            ("bram18", self.resources.bram18.into()),
        ])
    }

    /// Parse a report serialized by [`HlsReport::to_json_value`]
    /// (round-trip exact; any missing or mistyped field is an error).
    pub fn from_json_value(v: &Value) -> anyhow::Result<HlsReport> {
        let u = |field: &str| -> anyhow::Result<u64> {
            v.get(field)
                .and_then(Value::as_u64)
                .ok_or_else(|| anyhow::anyhow!("hls report misses {field}"))
        };
        let kernel = v
            .get("kernel")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("hls report misses kernel"))?
            .to_string();
        Ok(HlsReport {
            kernel,
            unroll: u("unroll")? as u32,
            ii: u("ii")? as u32,
            depth: u("depth")? as u32,
            compute_cycles: u("compute_cycles")?,
            fmax_mhz: f64::from_bits(u("fmax_mhz")?),
            in_cycles: u("in_cycles")?,
            out_cycles: u("out_cycles")?,
            resources: Resources {
                luts: u("luts")?,
                ffs: u("ffs")?,
                dsps: u("dsps")?,
                bram18: u("bram18")?,
            },
        })
    }

    /// Render in the style of a Vivado HLS synthesis summary (human
    /// consumption; the `hls` CLI subcommand prints this).
    pub fn render(&self) -> String {
        format!(
            "== Vivado HLS-style report: {} (U{})\n\
             * Timing: target clock {:.1} MHz\n\
             * Latency: compute {} cycles (II={}, depth={})\n\
             *          xfer-in {} cycles, xfer-out {} cycles\n\
             * Utilization: {} DSP48E, {} BRAM18K, {} LUT, {} FF\n",
            self.kernel,
            self.unroll,
            self.fmax_mhz,
            self.compute_cycles,
            self.ii,
            self.depth,
            self.in_cycles,
            self.out_cycles,
            self.resources.dsps,
            self.resources.bram18,
            self.resources.luts,
            self.resources.ffs,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resources_algebra() {
        let a = Resources {
            luts: 100,
            ffs: 200,
            dsps: 10,
            bram18: 4,
        };
        let b = a.add(&a);
        assert_eq!(b.dsps, 20);
        let budget = Resources {
            luts: 1000,
            ffs: 1000,
            dsps: 25,
            bram18: 100,
        };
        assert!(a.fits_in(&budget));
        assert!(b.fits_in(&budget));
        assert!(!b.add(&a).fits_in(&budget)); // 30 dsps > 25
        assert!((b.max_utilization(&budget) - 0.8).abs() < 1e-12); // 20/25
    }

    #[test]
    fn report_latency_conversion() {
        let r = HlsReport {
            kernel: "k".into(),
            unroll: 1,
            ii: 1,
            depth: 10,
            compute_cycles: 125_000, // 1 ms at 125 MHz
            fmax_mhz: 125.0,
            in_cycles: 12_500, // 100 us
            out_cycles: 1_250, // 10 us
            resources: Resources::ZERO,
        };
        assert_eq!(r.compute_ps(), 1_000_000_000);
        assert_eq!(r.in_ps(), 100_000_000);
        assert_eq!(r.out_ps(), 10_000_000);
        assert!(r.render().contains("DSP48E"));
    }

    #[test]
    fn report_json_roundtrip_is_bit_exact() {
        let r = HlsReport {
            kernel: "mxm64".into(),
            unroll: 32,
            ii: 1,
            depth: 23,
            compute_cycles: 8_215,
            fmax_mhz: 125.0,
            in_cycles: 15_360,
            out_cycles: 5_120,
            resources: Resources {
                luts: 18_640,
                ffs: 37_280,
                dsps: 172,
                bram18: 36,
            },
        };
        let back = HlsReport::from_json_value(&r.to_json_value()).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.fmax_mhz.to_bits(), r.fmax_mhz.to_bits());
        // Missing fields are rejected, never defaulted.
        let v = crate::util::json::parse("{\"kernel\":\"k\",\"unroll\":1}").unwrap();
        assert!(HlsReport::from_json_value(&v).is_err());
    }
}
