//! Batched suite-sweep benchmark — pruned vs exhaustive DSE over the
//! matmul/cholesky/lu/stencil suite, both through one shared
//! `dse::SweepSuite` worker pool.
//!
//! Reports, per application, how many points the exhaustive sweep
//! evaluates vs how many survive the `dse::prune` cuts (resource subtree,
//! unroll-variant dominance, lower bound), plus the end-to-end wall time
//! of both passes. Emits `BENCH_dse_suite.json` so CI tracks the pruning
//! ratio and the suite latency across PRs, next to `BENCH_engine.json`.

use zynq_estimator::config::BoardConfig;
use zynq_estimator::dse::default_workers;
use zynq_estimator::experiments;
use zynq_estimator::util::json::{arr, obj, Value};

fn main() {
    let board = BoardConfig::zynq706();
    let workers = default_workers();
    let n = 512;
    let r = experiments::dse_suite_latency(n, &board, workers)
        .expect("suite sweep must be lossless");

    let mut records: Vec<Value> = Vec::new();
    let mut evaluated = 0u64;
    let mut feasible = 0u64;
    println!("== DSE suite sweep (n = {n}, {workers} workers, one shared pool)");
    println!(
        "{:>10} {:>9} {:>9} {:>10} {:>10}  {}",
        "app", "feasible", "pruned", "bound cut", "dom. cut", "best co-design"
    );
    for a in &r.apps {
        println!(
            "{:>10} {:>9} {:>9} {:>10} {:>10}  {}",
            a.name, a.feasible, a.evaluated, a.bound_cut, a.dominance_cut, a.best
        );
        evaluated += a.evaluated;
        feasible += a.feasible;
        records.push(obj(vec![
            ("app", a.name.clone().into()),
            ("feasible_points", a.feasible.into()),
            ("evaluated_points", a.evaluated.into()),
            ("bound_cut", a.bound_cut.into()),
            ("dominance_cut", a.dominance_cut.into()),
            ("best", a.best.clone().into()),
        ]));
    }
    println!(
        "total: {evaluated}/{feasible} points evaluated ({:.0}% pruned); exhaustive {:.3} s, pruned {:.3} s ({:.2}x)",
        100.0 * (1.0 - evaluated as f64 / feasible.max(1) as f64),
        r.exhaustive_s,
        r.pruned_s,
        r.exhaustive_s / r.pruned_s.max(1e-12),
    );

    let out = obj(vec![
        ("n", n.into()),
        ("workers", r.workers.into()),
        ("exhaustive_s", r.exhaustive_s.into()),
        ("pruned_s", r.pruned_s.into()),
        ("speedup", (r.exhaustive_s / r.pruned_s.max(1e-12)).into()),
        ("feasible_points", feasible.into()),
        ("evaluated_points", evaluated.into()),
        ("apps", arr(records)),
    ])
    .to_json();
    match std::fs::write("BENCH_dse_suite.json", &out) {
        Ok(()) => println!("wrote BENCH_dse_suite.json ({} bytes)", out.len()),
        Err(e) => eprintln!("could not write BENCH_dse_suite.json: {e}"),
    }
}
