//! Machine-readable experiment export: CSV and JSON writers for the figure
//! data, so the reproduction plots can be regenerated outside this binary
//! (gnuplot / matplotlib) and diffed in CI.

use crate::metrics::SpeedupTable;
use crate::util::json::{arr, obj, Value};

/// CSV for a Fig.5/Fig.9-style table.
pub fn speedup_table_csv(table: &SpeedupTable) -> String {
    let mut out = String::from("config,estimator_ms,board_ms,estimator_speedup,board_speedup\n");
    for (i, r) in table.rows.iter().enumerate() {
        out.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{:.6}\n",
            csv_escape(&r.name),
            r.estimator_ms,
            r.board_ms,
            table.est_speedup[i],
            table.board_speedup[i]
        ));
    }
    out
}

/// JSON document for a speedup table, with the trend metadata.
pub fn speedup_table_json(table: &SpeedupTable, title: &str) -> String {
    let rows: Vec<Value> = table
        .rows
        .iter()
        .enumerate()
        .map(|(i, r)| {
            obj(vec![
                ("config", r.name.as_str().into()),
                ("estimator_ms", r.estimator_ms.into()),
                ("board_ms", r.board_ms.into()),
                ("estimator_speedup", table.est_speedup[i].into()),
                ("board_speedup", table.board_speedup[i].into()),
            ])
        })
        .collect();
    obj(vec![
        ("title", title.into()),
        ("rows", arr(rows)),
        ("kendall_tau", table.trend_agreement().into()),
        ("best_agrees", table.best_agrees().into()),
        (
            "best_config",
            table.rows[table.best_estimator()].name.as_str().into(),
        ),
    ])
    .to_json()
}

fn csv_escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::ConfigRow;

    fn table() -> SpeedupTable {
        SpeedupTable::build(vec![
            ConfigRow {
                name: "a, plain".into(),
                estimator_ms: 10.0,
                board_ms: 12.0,
            },
            ConfigRow {
                name: "b".into(),
                estimator_ms: 5.0,
                board_ms: 6.0,
            },
        ])
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = speedup_table_csv(&table());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("config,"));
        assert!(lines[1].starts_with("\"a, plain\"")); // escaped comma
    }

    #[test]
    fn json_parses_back() {
        let j = speedup_table_json(&table(), "fig-test");
        let v = crate::util::json::parse(&j).unwrap();
        assert_eq!(v.get("title").unwrap().as_str().unwrap(), "fig-test");
        assert_eq!(v.get("rows").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("best_config").unwrap().as_str().unwrap(), "b");
        assert_eq!(v.get("best_agrees").unwrap().as_bool().unwrap(), true);
    }

    #[test]
    fn csv_quote_escaping() {
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("with \"q\""), "\"with \"\"q\"\"\"");
    }
}
