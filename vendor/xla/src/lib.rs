//! Placeholder for the vendored `xla` bindings crate.
//!
//! This crate exists so that `cargo build --features pjrt` resolves and
//! compiles from a clean checkout: Cargo requires optional *path*
//! dependencies to be present at resolution time, so the root manifest
//! points `xla = { path = "vendor/xla", optional = true }` at this stub.
//! It is API-surface-compatible with the subset of the real
//! `xla`/`xla_extension` bindings that `zynq_estimator::runtime` uses —
//! every constructor fails at run time with a message explaining how to
//! vendor the real crate (drop it over this directory; the signatures
//! below document exactly what the runtime links against).
//!
//! With this placeholder in place the `--features pjrt` build behaves
//! like the stub-runtime build: `Runtime::new` reports the missing
//! backend cleanly, the `runtime_pjrt` integration tests skip (they also
//! require `make artifacts`), and nothing panics.

use std::fmt;

/// Error type of the placeholder: every operation fails with this.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn placeholder<T>() -> Result<T, Error> {
    Err(Error(
        "vendor/xla is the placeholder crate — vendor the real xla_extension bindings over \
         vendor/xla/ to enable the PJRT backend (see README.md: the pjrt feature and the \
         vendoring story)"
            .to_string(),
    ))
}

/// PJRT client handle (placeholder: cannot be constructed).
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU PJRT client — always fails on the placeholder.
    pub fn cpu() -> Result<Self, Error> {
        placeholder()
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "xla-placeholder".to_string()
    }

    /// Compile a computation — always fails on the placeholder.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        placeholder()
    }
}

/// Parsed HLO module proto (placeholder).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file — always fails on the placeholder.
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        placeholder()
    }
}

/// An XLA computation wrapping an HLO module (placeholder).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a module proto.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled, loaded executable (placeholder: cannot be constructed).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals — always fails on the
    /// placeholder.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        placeholder()
    }
}

/// A device buffer returned by execution (placeholder).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer to a host literal — always fails on the
    /// placeholder.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        placeholder()
    }
}

/// A host literal (placeholder).
pub struct Literal;

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape — always fails on the placeholder.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        placeholder()
    }

    /// Extract the single element of a 1-tuple — always fails on the
    /// placeholder.
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        placeholder()
    }

    /// Copy out as a typed vector — always fails on the placeholder.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        placeholder()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placeholder_reports_the_vendoring_story() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("vendor/xla"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
    }
}
