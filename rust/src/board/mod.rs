//! Detailed Zynq board emulator — the repository's substitute for "real
//! execution" on the ZC706 (DESIGN.md §1, substitution 1).
//!
//! The paper validates the estimator against gettimeofday measurements on
//! the physical board; we do not have the board, so this module implements
//! precisely the effects the paper lists as *ignored by the estimator* and
//! therefore responsible for the estimator-vs-real gap:
//!
//! * **memory/port contention** — concurrent DMA streams degrade each
//!   other's bandwidth (`EmuConfig::contention_alpha`);
//! * **cache coherence** — consuming data last produced by the other
//!   device class pays a flush/invalidate cost (`coherence_us`);
//! * **page pinning** — the first DMA touching a buffer pays
//!   `pinning_us_per_kb` (get_user_pages / sg-list build under Linux);
//! * **SMP memory interference** — ARM kernels slow down while DMA streams
//!   hammer the DDR controller (`smp_mem_factor`);
//! * **run-to-run jitter** — multiplicative noise with CV `jitter_cv`
//!   (the paper averages 10 board runs for the same reason).
//!
//! Everything is seeded and deterministic given `EmuConfig::seed`, so
//! "board measurements" are reproducible.

pub mod space;

pub use space::{BoardSpace, BoardTarget};

use crate::util::fxhash::FxHashSet;

use crate::config::BoardConfig;
use crate::sim::dma::contended_bw_mbps;
use crate::sim::engine::{TaskCtx, TimingModel};
use crate::sim::time::{transfer_ps, us_to_ps, Clock, Ps};
use crate::util::Rng;

/// The detailed timing model. Implements [`TimingModel`] over the same
/// engine as the estimator; the estimator-vs-board delta is exactly the
/// effect set above.
#[derive(Clone, Debug)]
pub struct BoardModel {
    smp_clock: Clock,
    rng: Rng,
    /// Buffers that have already been pinned for DMA (addresses).
    pinned: FxHashSet<u64>,
}

impl BoardModel {
    /// Bind the emulator to a board description (seeds the jitter stream).
    pub fn new(board: &BoardConfig) -> Self {
        Self {
            smp_clock: board.smp_clock(),
            rng: Rng::new(board.emu.seed),
            pinned: FxHashSet::default(),
        }
    }

    /// Multiplicative jitter factor, mean ~1, CV = `jitter_cv`.
    fn jitter(&mut self, board: &BoardConfig) -> f64 {
        let g = self.rng.next_gaussian();
        (1.0 + board.emu.jitter_cv * g).max(0.5)
    }

    /// Pinning cost for the not-yet-pinned buffers among the given deps.
    fn pinning_ps(&mut self, ctx: &TaskCtx, board: &BoardConfig, writes: bool) -> Ps {
        let mut cost = 0u64;
        for d in &ctx.program.tasks[ctx.task as usize].deps {
            let relevant = if writes { d.dir.writes() } else { d.dir.reads() };
            if relevant && self.pinned.insert(d.addr) {
                let kib = (d.len as f64 / 1024.0).max(1.0);
                cost += us_to_ps(board.emu.pinning_us_per_kb * kib);
            }
        }
        cost
    }
}

impl TimingModel for BoardModel {
    fn creation_ps(&mut self, board: &BoardConfig) -> Ps {
        let j = self.jitter(board);
        (us_to_ps(board.task_creation_us) as f64 * j) as Ps
    }

    fn smp_compute_ps(&mut self, ctx: &TaskCtx, board: &BoardConfig) -> Ps {
        let base = self
            .smp_clock
            .cycles_to_ps(ctx.program.tasks[ctx.task as usize].smp_cycles)
            as f64;
        // DDR interference from in-flight DMA streams.
        let mem = 1.0 + board.emu.smp_mem_factor * ctx.active_dma_streams.min(4) as f64;
        // Cache invalidations for FPGA-produced inputs.
        let coherence = us_to_ps(board.emu.coherence_us) * ctx.cross_device_inputs as u64;
        let j = self.jitter(board);
        (base * mem * j) as Ps + coherence
    }

    fn accel_occupancy_ps(
        &mut self,
        ctx: &TaskCtx,
        board: &BoardConfig,
        input_in_occupancy: bool,
    ) -> Ps {
        let report = ctx.report.expect("accel occupancy requires an HLS report");
        let mut total = report.compute_ps() as f64 * self.jitter(board);
        if input_in_occupancy {
            let streams = ctx.active_dma_streams.max(1);
            let bw = contended_bw_mbps(board.dma_bw_mbps, board.emu.contention_alpha, streams);
            total += transfer_ps(ctx.xfers.bytes_in, bw) as f64;
            total += self.pinning_ps(ctx, board, false) as f64;
        }
        // Cache flush of SMP-produced inputs before the accelerator may
        // stream them in.
        total += (us_to_ps(board.emu.coherence_us) * ctx.cross_device_inputs as u64) as f64;
        total as Ps
    }

    fn submit_ps(&mut self, n_transfers: u32, board: &BoardConfig) -> Ps {
        // Descriptor programming + driver syscall overhead per descriptor.
        let per = us_to_ps(board.dma_submit_us) + us_to_ps(1.5);
        let j = self.jitter(board);
        ((per * n_transfers as u64) as f64 * j) as Ps
    }

    fn dma_ps(&mut self, bytes: u64, ctx: &TaskCtx, board: &BoardConfig) -> Ps {
        let streams = ctx.active_dma_streams.max(1);
        let bw = contended_bw_mbps(board.dma_bw_mbps, board.emu.contention_alpha, streams);
        let pin = self.pinning_ps(ctx, board, true);
        transfer_ps(bytes, bw) + pin
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::elaborate::Xfers;
    use crate::coordinator::task::{Dep, KernelDecl, KernelProfile, Targets, TaskProgram};
    use crate::sim::estimator::EstimatorModel;

    fn fixture() -> (TaskProgram, BoardConfig) {
        let mut p = TaskProgram::new("t");
        let k = p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::BOTH,
            profile: KernelProfile {
                flops: 1000,
                inner_trip: 1000,
                in_bytes: 16_384,
                out_bytes: 16_384,
                dtype_bytes: 4,
                divsqrt: false,
            },
        });
        p.add_task(k, 667_000, vec![Dep::inout(0x10, 16_384)]);
        (p, BoardConfig::zynq706())
    }

    fn ctx(p: &TaskProgram, streams: u32, cross: u32) -> TaskCtx<'_> {
        TaskCtx {
            task: 0,
            kernel: 0,
            program: p,
            xfers: Xfers {
                n_in: 1,
                n_out: 1,
                bytes_in: 16_384,
                bytes_out: 16_384,
            },
            report: None,
            accels_for_kernel: 1,
            active_dma_streams: streams,
            cross_device_inputs: cross,
            now: 0,
        }
    }

    #[test]
    fn board_is_slower_than_estimator_on_smp() {
        let (p, b) = fixture();
        let mut est = EstimatorModel::new(&b);
        let mut brd = BoardModel::new(&b);
        let c = ctx(&p, 2, 1);
        // Average over jitter.
        let runs: Vec<f64> = (0..200)
            .map(|_| brd.smp_compute_ps(&c, &b) as f64)
            .collect();
        let board_mean = crate::util::mean(&runs);
        let est_t = est.smp_compute_ps(&c, &b) as f64;
        assert!(
            board_mean > est_t * 1.05,
            "board {board_mean} should exceed estimator {est_t}"
        );
    }

    #[test]
    fn contention_slows_dma() {
        let (p, b) = fixture();
        let mut brd = BoardModel::new(&b);
        let c0 = ctx(&p, 1, 0);
        let c4 = ctx(&p, 4, 0);
        // Use large transfer so pinning noise is negligible; pin first.
        let _ = brd.dma_ps(1, &c0, &b);
        let t1 = brd.dma_ps(100 << 20, &c0, &b);
        let t4 = brd.dma_ps(100 << 20, &c4, &b);
        assert!(t4 > t1);
    }

    #[test]
    fn pinning_charged_once() {
        let (p, b) = fixture();
        let mut brd = BoardModel::new(&b);
        let c = ctx(&p, 1, 0);
        let first = brd.dma_ps(1024, &c, &b);
        let second = brd.dma_ps(1024, &c, &b);
        assert!(first > second, "first touch must include pinning");
    }

    #[test]
    fn coherence_charged_for_cross_device_inputs() {
        let (p, b) = fixture();
        let mut brd = BoardModel::new(&b);
        let runs0: Vec<f64> = (0..100)
            .map(|_| brd.smp_compute_ps(&ctx(&p, 0, 0), &b) as f64)
            .collect();
        let runs2: Vec<f64> = (0..100)
            .map(|_| brd.smp_compute_ps(&ctx(&p, 0, 2), &b) as f64)
            .collect();
        let delta = crate::util::mean(&runs2) - crate::util::mean(&runs0);
        let expected = 2.0 * us_to_ps(b.emu.coherence_us) as f64;
        assert!((delta - expected).abs() < expected * 0.25);
    }

    #[test]
    fn deterministic_given_seed() {
        let (p, b) = fixture();
        let mut a = BoardModel::new(&b);
        let mut c = BoardModel::new(&b);
        for _ in 0..50 {
            assert_eq!(
                a.smp_compute_ps(&ctx(&p, 1, 0), &b),
                c.smp_compute_ps(&ctx(&p, 1, 0), &b)
            );
        }
    }

    #[test]
    fn jitter_bounded_below() {
        let (_, b) = fixture();
        let mut brd = BoardModel::new(&b);
        for _ in 0..10_000 {
            assert!(brd.jitter(&b) >= 0.5);
        }
    }
}
