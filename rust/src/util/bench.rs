//! Minimal benchmark harness — the criterion-equivalent substrate for the
//! vendored-offline build. `cargo bench` runs each `benches/*.rs` binary
//! (`harness = false`); they use [`bench`] for timing and print the same
//! rows/series the paper's figures report.

use std::time::Instant;

/// Result of one benchmark: wall-clock stats over the measured iterations.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Benchmark label.
    pub name: String,
    /// Measured iterations (after warm-up).
    pub iters: u32,
    /// Mean wall-clock per iteration, ms.
    pub mean_ms: f64,
    /// Sample standard deviation, ms.
    pub stdev_ms: f64,
    /// Fastest iteration, ms (the figure benches compare minima).
    pub min_ms: f64,
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations and print
/// a criterion-style line. Returns the stats for programmatic use.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let mean = super::mean(&samples);
    let stdev = super::stdev(&samples);
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "bench {name:40} {mean:>10.3} ms/iter (+/- {stdev:>7.3}, min {min:>8.3}, n={iters})"
    );
    BenchStats {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        stdev_ms: stdev,
        min_ms: min,
    }
}

/// Black-box: defeat the optimizer without the unstable intrinsic.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench("noop-ish", 1, 5, || {
            let v: u64 = (0..1000).sum();
            black_box(v);
        });
        assert!(s.mean_ms >= 0.0);
        assert!(s.min_ms <= s.mean_ms + 1e-9);
        assert_eq!(s.iters, 5);
    }
}
