//! Service-throughput benchmark: sharded lanes and cross-request batch
//! evaluation against the sequential single-lane daemon.
//!
//! Four clients (one per suite app — apps are kernel-disjoint, so each
//! client's memo state lives in one lane) fire the same mixed hot/cold
//! request sequences three ways:
//!
//! 1. **sequential** — single-lane service, clients one after another
//!    (the pre-sharding daemon's cost model);
//! 2. **sharded** — `lanes = 4`, four concurrent clients;
//! 3. **batch** — `lanes = 4`, each client's whole sequence as one
//!    `batch` envelope (one worker-pool round per context).
//!
//! The harness itself asserts the exactness contracts — every sharded
//! and batch response byte-identical to the sequential one, and every
//! run evaluating exactly the distinct cold points — and emits
//! `BENCH_service.json` so `bench-check` gates them against
//! `bench_baselines/BENCH_service.json` in CI (timings recorded,
//! machine-dependent, skipped by the gate).

use std::sync::{Arc, Barrier};
use std::time::Instant;

use zynq_estimator::config::BoardConfig;
use zynq_estimator::service::{ServeConfig, Service};
use zynq_estimator::util::json::{obj, parse, Value};

/// One FPGA kernel per suite app (bs 64 everywhere).
const APPS: [(&str, &str); 4] = [
    ("matmul", "mxm64"),
    ("cholesky", "dgemm"),
    ("lu", "trsm_row"),
    ("stencil", "jacobi64"),
];

fn service(lanes: usize) -> Service {
    Service::new(
        BoardConfig::zynq706(),
        ServeConfig {
            lanes,
            workers: 2,
            ..ServeConfig::default()
        },
    )
    .unwrap()
}

/// One client's request sequence: 8 distinct cold points (2 sizes × 4
/// unrolls), then two hot repeats of each — 24 requests, 1/3 cold.
fn client_sequence(client: usize, app: &str, kernel: &str) -> Vec<String> {
    let mut cold = Vec::new();
    for n in [128u64, 256] {
        for unroll in [4u64, 8, 16, 32] {
            let id = client * 100 + cold.len();
            cold.push(format!(
                r#"{{"id":{id},"req":"estimate","app":"{app}","n":{n},"accel":["{kernel}:U{unroll}"]}}"#
            ));
        }
    }
    let mut reqs = cold.clone();
    for _ in 0..2 {
        reqs.extend(cold.iter().cloned());
    }
    reqs
}

fn run_sequential(svc: &Service, schedule: &[Vec<String>]) -> Vec<Vec<String>> {
    schedule
        .iter()
        .map(|reqs| {
            reqs.iter()
                .map(|r| svc.handle_line(r).0.expect("request must answer"))
                .collect()
        })
        .collect()
}

fn run_concurrent(svc: &Arc<Service>, schedule: &[Vec<String>]) -> Vec<Vec<String>> {
    let barrier = Arc::new(Barrier::new(schedule.len()));
    let handles: Vec<_> = schedule
        .iter()
        .cloned()
        .map(|reqs| {
            let svc = Arc::clone(svc);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                reqs.iter()
                    .map(|r| svc.handle_line(r).0.expect("request must answer"))
                    .collect::<Vec<String>>()
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn main() {
    let schedule: Vec<Vec<String>> = APPS
        .iter()
        .enumerate()
        .map(|(c, (app, kernel))| client_sequence(c, app, kernel))
        .collect();
    let total_requests: usize = schedule.iter().map(|s| s.len()).sum();

    // 1. Sequential single lane — the reference for bytes and counts.
    let sequential = service(1);
    let t = Instant::now();
    let expect = run_sequential(&sequential, &schedule);
    let sequential_s = t.elapsed().as_secs_f64();
    let evaluated = sequential.evaluated();

    // 2. Sharded lanes, concurrent clients.
    let sharded = Arc::new(service(4));
    let t = Instant::now();
    let got = run_concurrent(&sharded, &schedule);
    let sharded_s = t.elapsed().as_secs_f64();
    let responses_identical = got == expect;
    assert!(
        responses_identical,
        "sharded responses diverged from the sequential reference"
    );
    assert_eq!(sharded.evaluated(), evaluated, "sharded run re-evaluated");

    // 3. Batch envelopes on sharded lanes: each client sends its whole
    // sequence as one envelope; every item must equal its standalone
    // response line.
    let batcher = Arc::new(service(4));
    let envelopes: Vec<Vec<String>> = schedule
        .iter()
        .enumerate()
        .map(|(c, reqs)| {
            vec![format!(
                r#"{{"id":{c},"req":"batch","items":[{}]}}"#,
                reqs.join(",")
            )]
        })
        .collect();
    let t = Instant::now();
    let batch_lines = run_concurrent(&batcher, &envelopes);
    let batch_s = t.elapsed().as_secs_f64();
    let mut batch_identical = true;
    for (client, lines) in batch_lines.iter().enumerate() {
        let v = parse(&lines[0]).expect("batch response parses");
        let Some(Value::Arr(items)) = v.get("items") else {
            panic!("batch response without items: {}", lines[0]);
        };
        assert_eq!(items.len(), expect[client].len());
        for (item, exp) in items.iter().zip(&expect[client]) {
            if item.to_json() != parse(exp).unwrap().to_json() {
                batch_identical = false;
            }
        }
    }
    assert!(
        batch_identical,
        "batch items diverged from the standalone response lines"
    );
    assert_eq!(batcher.evaluated(), evaluated, "batch run re-evaluated");
    let no_duplicate_evaluation =
        sharded.evaluated() == evaluated && batcher.evaluated() == evaluated;

    println!("== service throughput ({} clients, {total_requests} requests, {evaluated} cold points)", APPS.len());
    println!("   sequential 1 lane : {sequential_s:.3} s");
    println!(
        "   sharded 4 lanes   : {sharded_s:.3} s ({:.2}x)",
        sequential_s / sharded_s.max(1e-12)
    );
    println!(
        "   batch envelopes   : {batch_s:.3} s ({:.2}x)",
        sequential_s / batch_s.max(1e-12)
    );

    let out = obj(vec![
        ("clients", APPS.len().into()),
        ("requests", total_requests.into()),
        ("evaluated", evaluated.into()),
        ("sequential_s", sequential_s.into()),
        ("sharded_s", sharded_s.into()),
        ("batch_s", batch_s.into()),
        (
            "sharded_speedup",
            (sequential_s / sharded_s.max(1e-12)).into(),
        ),
        ("batch_speedup", (sequential_s / batch_s.max(1e-12)).into()),
        ("responses_identical", responses_identical.into()),
        ("batch_identical", batch_identical.into()),
        ("no_duplicate_evaluation", no_duplicate_evaluation.into()),
    ])
    .to_json();
    match std::fs::write("BENCH_service.json", &out) {
        Ok(()) => println!("wrote BENCH_service.json ({} bytes)", out.len()),
        Err(e) => eprintln!("could not write BENCH_service.json: {e}"),
    }
}
